//! Property tests for the shared stage-DAG planner
//! (`scheduler::plan`) that both the in-process scheduler and the
//! multi-process dispatcher execute: random matrices must always
//! yield (a) a graph whose dependencies point strictly backwards
//! (topological by construction), (b) exactly-once stage execution
//! under any ready-order, and (c) dedup counts that match the
//! independently-computed unique key sets — i.e. what a cold serial
//! run executes. The dispatcher publishes exactly this graph, so
//! these invariants are what make its sharding sound.

use std::collections::{HashMap, HashSet};

use mlonmcu::features::Features;
use mlonmcu::session::cache::{build_key, load_key, tune_key, TuneParams};
use mlonmcu::session::run::RunSpec;
use mlonmcu::session::scheduler::{plan, StageKind, TaskGraph};
use mlonmcu::util::XorShift64;

const TP: TuneParams = TuneParams { trials: 600, seed: 7 };

fn fingerprints() -> HashMap<String, u64> {
    (0..4).map(|i| (format!("m{i}"), 0x1000 + i as u64)).collect()
}

/// One random spec from small fixed pools (components need not be
/// executable — the planner never validates, it only keys).
fn random_spec(rng: &mut XorShift64) -> RunSpec {
    let pick = |rng: &mut XorShift64, n: usize| (rng.next_u64() % n as u64) as usize;
    let models = ["m0", "m1", "m2", "m3"];
    let backends = ["tflmi", "tflmc", "tvmaot", "tvmaot+", "tvmrt"];
    let targets = ["etiss", "esp32c3", "stm32f4", "stm32f7", "esp32"];
    let schedules = [None, Some("default-nchw"), Some("arm-nhwc"), Some("default-nhwc")];
    let features = if pick(rng, 4) == 0 {
        Features::parse(&["autotvm".to_string()]).unwrap()
    } else {
        Features::default()
    };
    RunSpec {
        model: models[pick(rng, models.len())].to_string(),
        backend: backends[pick(rng, backends.len())].to_string(),
        target: targets[pick(rng, targets.len())].to_string(),
        schedule: schedules[pick(rng, schedules.len())].map(str::to_string),
        tuned: pick(rng, 3) == 0,
        features,
    }
}

fn random_specs(rng: &mut XorShift64, max: usize) -> Vec<RunSpec> {
    let n = 1 + (rng.next_u64() % max as u64) as usize;
    (0..n).map(|_| random_spec(rng)).collect()
}

/// Execute the DAG in a random ready-order, asserting exactly-once
/// execution and deps-before-dependents. Returns per-kind counts.
fn simulate(graph: &TaskGraph, rng: &mut XorShift64) -> HashMap<&'static str, usize> {
    let n = graph.tasks.len();
    let mut pending: Vec<usize> = graph.tasks.iter().map(|t| t.deps.len()).collect();
    let mut ready: Vec<usize> = (0..n).filter(|&i| pending[i] == 0).collect();
    let mut executed = vec![false; n];
    let mut counts: HashMap<&'static str, usize> = HashMap::new();
    while let Some(slot) = (!ready.is_empty())
        .then(|| (rng.next_u64() % ready.len() as u64) as usize)
    {
        let id = ready.swap_remove(slot);
        assert!(!executed[id], "task {id} executed twice");
        for &d in &graph.tasks[id].deps {
            assert!(executed[d], "task {id} ran before its dep {d}");
        }
        executed[id] = true;
        *counts.entry(graph.tasks[id].kind.stage_name()).or_default() += 1;
        for &dep in &graph.tasks[id].dependents {
            pending[dep] -= 1;
            if pending[dep] == 0 {
                ready.push(dep);
            }
        }
    }
    assert!(
        executed.iter().all(|&e| e),
        "DAG did not drain: a cycle or a lost dependent"
    );
    counts
}

#[test]
fn random_matrices_graph_invariants_and_exact_once_execution() {
    let fp = fingerprints();
    let mut rng = XorShift64::new(0x9E3779B97F4A7C15);
    for _ in 0..200 {
        let specs = random_specs(&mut rng, 24);
        let graph = plan(&specs, TP, &fp, true);

        // (a) deps point strictly backwards and are deduplicated
        for (id, t) in graph.tasks.iter().enumerate() {
            let mut seen = HashSet::new();
            for &d in &t.deps {
                assert!(d < id, "dep {d} of task {id} not earlier");
                assert!(seen.insert(d), "duplicate dep {d} of task {id}");
            }
            assert_eq!(
                t.consumers.iter().copied().min(),
                Some(t.spec_idx),
                "a task's representative spec is its lowest consumer"
            );
            let sorted = t.consumers.windows(2).all(|w| w[0] <= w[1]);
            assert!(sorted, "consumers of task {id} not in run order");
        }

        // (b) one tail per run, wired to that run's load + build
        let tails: Vec<_> = graph
            .tasks
            .iter()
            .filter(|t| t.kind == StageKind::Tail)
            .collect();
        assert_eq!(tails.len(), specs.len());
        for (i, tail) in tails.iter().enumerate() {
            assert_eq!(tail.spec_idx, i);
            let kinds: Vec<StageKind> =
                tail.deps.iter().map(|&d| graph.tasks[d].kind).collect();
            assert!(kinds.contains(&StageKind::Load));
            assert!(kinds.contains(&StageKind::Build));
            for &d in &tail.deps {
                assert!(
                    graph.tasks[d].consumers.contains(&i),
                    "tail {i}'s dep does not list it as consumer"
                );
            }
        }

        // (c) dedup counts match the independently-computed unique key
        // sets — what a cold serial scheduler executes
        let mut loads = HashSet::new();
        let mut tunes = HashSet::new();
        let mut builds = HashSet::new();
        for s in &specs {
            let f = fp[&s.model];
            loads.insert(load_key(f).0);
            if s.needs_tune() {
                tunes.insert(tune_key(f, s, TP).0);
            }
            builds.insert(build_key(f, s, TP).0);
        }
        let unique = graph.unique_stage_counts();
        assert_eq!(unique.loads, loads.len());
        assert_eq!(unique.tunes, tunes.len());
        assert_eq!(unique.builds, builds.len());
        assert_eq!(
            graph.stage_task_count(),
            loads.len() + tunes.len() + builds.len()
        );

        // (d) exactly-once execution under a random ready-order, with
        // per-kind execution counts equal to the unique key sets
        let counts = simulate(&graph, &mut rng);
        assert_eq!(counts.get("load").copied().unwrap_or(0), loads.len());
        assert_eq!(counts.get("tune").copied().unwrap_or(0), tunes.len());
        assert_eq!(counts.get("build").copied().unwrap_or(0), builds.len());
        assert_eq!(counts.get("tail").copied().unwrap_or(0), specs.len());
    }
}

#[test]
fn planner_is_deterministic() {
    let fp = fingerprints();
    let mut rng = XorShift64::new(42);
    for _ in 0..50 {
        let specs = random_specs(&mut rng, 16);
        let a = plan(&specs, TP, &fp, true);
        let b = plan(&specs, TP, &fp, true);
        assert_eq!(
            format!("{:?}", a.tasks),
            format!("{:?}", b.tasks),
            "planning the same specs twice must yield the identical graph \
             (the dispatcher and the tail pass both re-plan it)"
        );
    }
}

#[test]
fn no_cache_plan_shares_nothing() {
    let fp = fingerprints();
    let mut rng = XorShift64::new(7);
    for _ in 0..50 {
        let specs = random_specs(&mut rng, 16);
        let graph = plan(&specs, TP, &fp, false);
        let expected: usize = specs
            .iter()
            .map(|s| 2 + usize::from(s.needs_tune()) + 1)
            .sum();
        assert_eq!(graph.tasks.len(), expected, "no dedup under --no-cache");
        for t in &graph.tasks {
            assert!(t.key.is_none(), "no keys under --no-cache");
            assert_eq!(t.consumers.len(), 1, "no sharing under --no-cache");
        }
        // still drains exactly once
        simulate(&graph, &mut rng);
    }
}
