//! Fault injection for the remote store tier: an unreachable or
//! mid-session-killed serve daemon must degrade the session to
//! local-only execution (counted, never fatal), corrupt wire entries
//! — truncated frames, wrong `FORMAT_VERSION` — must decode as misses
//! and recompute, and the client's retry/backoff loop must be bounded.
//! A seeded frame fuzzer closes the loop from both sides: mutated
//! request frames never crash the server, mutated response frames
//! never panic the client.

use std::path::PathBuf;
use std::sync::Arc;

use mlonmcu::config::Environment;
use mlonmcu::frontends::tmodel;
use mlonmcu::graph::{Graph, OpNode, TensorInfo};
use mlonmcu::graph::{OpCode, ACT_RELU, PAD_SAME};
use mlonmcu::session::transport::{Client, RemoteConfig, Server};
use mlonmcu::session::{EnvStore, RunMatrix, RunOptions, Session};
use mlonmcu::tensor::DType;

/// Same tiny conv graph as tests/dispatch_equivalence.rs.
fn tiny_conv_graph() -> Graph {
    let mut attrs = std::collections::BTreeMap::new();
    attrs.insert("stride_h".to_string(), 1);
    attrs.insert("stride_w".to_string(), 1);
    attrs.insert("padding".to_string(), PAD_SAME);
    attrs.insert("fused_act".to_string(), ACT_RELU);
    Graph {
        name: "tinyconv".into(),
        tensors: vec![
            TensorInfo {
                name: "input".into(),
                shape: vec![1, 4, 4, 2],
                dtype: DType::I8,
                scale: 0.5,
                zero_point: 0,
                data: None,
            },
            TensorInfo {
                name: "w".into(),
                shape: vec![3, 3, 3, 2],
                dtype: DType::I8,
                scale: 0.01,
                zero_point: 0,
                data: Some((0..54).map(|x| (x % 7) as u8).collect()),
            },
            TensorInfo {
                name: "b".into(),
                shape: vec![3],
                dtype: DType::I32,
                scale: 0.005,
                zero_point: 0,
                data: Some(vec![0; 12]),
            },
            TensorInfo {
                name: "out".into(),
                shape: vec![1, 4, 4, 3],
                dtype: DType::I8,
                scale: 0.25,
                zero_point: -128,
                data: None,
            },
        ],
        ops: vec![OpNode {
            opcode: OpCode::Conv2D,
            name: "conv0".into(),
            inputs: vec![0, 1, 2],
            outputs: vec![3],
            attrs,
        }],
        inputs: vec![0],
        outputs: vec![3],
    }
}

fn fresh_env(tag: &str, extra: &[String]) -> (Environment, PathBuf) {
    let dir = std::env::temp_dir().join(format!("mlonmcu_transportfault_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    let env = Environment::init(&dir).unwrap();
    tmodel::write_file(
        &tiny_conv_graph(),
        &dir.join("artifacts/models/tinyconv.tmodel"),
    )
    .unwrap();
    let mut overrides = vec![
        "tune.trials=8".to_string(),
        // fail fast: a dead server costs one quick round, not seconds
        "remote.timeout_ms=200".to_string(),
        "remote.retries=1".to_string(),
        "remote.backoff_ms=10".to_string(),
    ];
    overrides.extend_from_slice(extra);
    (env.with_overrides(&overrides).unwrap(), dir)
}

fn spawn_server(tag: &str) -> (mlonmcu::session::transport::ServerHandle, PathBuf) {
    let dir = std::env::temp_dir().join(format!("mlonmcu_transportfault_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let store = Arc::new(EnvStore::open(&dir, 512 << 20).unwrap());
    let handle = Server::spawn(store, "127.0.0.1:0").unwrap();
    (handle, dir)
}

fn dedup_matrix() -> RunMatrix {
    RunMatrix::new()
        .models(["tinyconv"])
        .backends(["tflmi", "tvmaot"])
        .targets(["etiss", "esp32c3", "stm32f4", "stm32f7", "esp32"])
}

fn opts(workers: usize) -> RunOptions {
    RunOptions { parallel: 2, use_cache: true, workers }
}

/// Nothing listens on 127.0.0.1:1 — every connect is refused.
const DEAD_ADDR: &str = "127.0.0.1:1";

#[test]
fn unreachable_server_degrades_to_local_execution() {
    let (env, dir) =
        fresh_env("dead", &[format!("remote.connect={DEAD_ADDR}")]);
    let session = Session::new(&env).unwrap();
    let report = session.run_matrix_opts(&dedup_matrix(), opts(0)).unwrap();
    for row in &report.rows {
        assert_eq!(row["status"].render(), "ok");
    }
    let t = *session.last_timing.lock().unwrap();
    assert_eq!(t.stage_execs.loads, 1, "everything executed locally");
    assert_eq!(t.stage_execs.builds, 2);
    assert_eq!(
        t.remote_errors, 1,
        "one counted transport error, then the tier is off"
    );
    assert_eq!((t.remote_hits, t.remote_misses), (0, 0));
    assert!(
        report
            .notes
            .iter()
            .any(|n| n.contains("remote store: 0 hit(s), 0 miss(es), 1 error(s)")),
        "degradation must be reported: {:?}",
        report.notes
    );
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn dispatch_falls_back_in_process_when_server_unreachable() {
    // --workers N --connect <dead addr>: the remote dispatcher cannot
    // even ping, so the matrix must fall back to in-process execution
    // rather than fail or hang
    let (env, dir) =
        fresh_env("deadfleet", &[format!("remote.connect={DEAD_ADDR}")]);
    let session = Session::new(&env).unwrap();
    let report = session.run_matrix_opts(&dedup_matrix(), opts(2)).unwrap();
    assert_eq!(report.len(), 10);
    for row in &report.rows {
        assert_eq!(row["status"].render(), "ok");
    }
    let t = *session.last_timing.lock().unwrap();
    assert_eq!(t.stage_execs.builds, 2);
    assert_eq!(t.worker_procs, 0, "no fleet, no local shards");
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn server_killed_mid_session_degrades_to_local() {
    let (server, server_dir) = spawn_server("kill_srv");
    let addr = server.addr.to_string();

    // seed the server through one remote-attached home
    let (env_a, dir_a) = fresh_env("kill_a", &[format!("remote.connect={addr}")]);
    let a = Session::new(&env_a).unwrap();
    a.run_matrix_opts(&dedup_matrix(), opts(0)).unwrap();

    // a fresh home warms itself entirely over the wire...
    let (env_b, dir_b) = fresh_env("kill_b", &[format!("remote.connect={addr}")]);
    let b = Session::new(&env_b).unwrap();
    b.run_matrix_opts(&dedup_matrix(), opts(0)).unwrap();
    assert_eq!(b.last_timing.lock().unwrap().remote_hits, 3);

    // ...then the server dies mid-session; the next run needs stages
    // the memory tier has never seen and must execute them locally
    server.shutdown();
    let wider = RunMatrix::new()
        .models(["tinyconv"])
        .backends(["tflmc", "tvmrt"])
        .targets(["etiss"]);
    let report = b.run_matrix_opts(&wider, opts(0)).unwrap();
    for row in &report.rows {
        assert_eq!(row["status"].render(), "ok");
    }
    let t = *b.last_timing.lock().unwrap();
    assert_eq!(t.stage_execs.builds, 2, "recomputed locally");
    assert_eq!(t.remote_errors, 1, "dead server counted once, then off");
    for d in [dir_a, dir_b, server_dir] {
        let _ = std::fs::remove_dir_all(d);
    }
}

#[test]
fn corrupt_served_entries_decode_as_misses_and_recompute() {
    let (server, server_dir) = spawn_server("corrupt_srv");
    let addr = server.addr.to_string();

    // populate the served store: load + tflmi build + tvmaot build
    let (env_a, dir_a) =
        fresh_env("corrupt_a", &[format!("remote.connect={addr}")]);
    let a = Session::new(&env_a).unwrap();
    a.run_matrix_opts(&dedup_matrix(), opts(0)).unwrap();

    // sabotage the server's files in place (the server is a dumb byte
    // pipe — OP_GET replays file bytes verbatim, the *client* verifies):
    // the load entry gets a wrong FORMAT_VERSION, one build entry is
    // truncated mid-frame, the other stays intact
    let load_files = bin_files(&server_dir.join("load"));
    assert_eq!(load_files.len(), 1);
    let mut bytes = std::fs::read(&load_files[0]).unwrap();
    bytes[4] = bytes[4].wrapping_add(1); // version u32 LE at [4..8]
    std::fs::write(&load_files[0], &bytes).unwrap();

    let build_files = bin_files(&server_dir.join("build"));
    assert_eq!(build_files.len(), 2);
    let bytes = std::fs::read(&build_files[0]).unwrap();
    std::fs::write(&build_files[0], &bytes[..10.min(bytes.len())]).unwrap();

    // a fresh home: the poisoned entries must read as remote misses
    // (never a crash, never a bad artifact) and recompute locally; the
    // intact build is still served
    let (env_b, dir_b) =
        fresh_env("corrupt_b", &[format!("remote.connect={addr}")]);
    let b = Session::new(&env_b).unwrap();
    let report = b.run_matrix_opts(&dedup_matrix(), opts(0)).unwrap();
    for row in &report.rows {
        assert_eq!(row["status"].render(), "ok");
    }
    let t = *b.last_timing.lock().unwrap();
    assert_eq!(t.stage_execs.loads, 1, "version-skewed load recomputed");
    assert_eq!(t.stage_execs.builds, 1, "truncated build recomputed");
    assert_eq!(t.remote_misses, 2);
    assert_eq!(t.remote_hits, 1, "the intact entry still serves");
    assert_eq!(t.remote_errors, 0, "corruption is a miss, not a fault");

    server.shutdown();
    for d in [dir_a, dir_b, server_dir] {
        let _ = std::fs::remove_dir_all(d);
    }
}

#[test]
fn retry_backoff_is_bounded_and_fails_fast() {
    let client = Client::new(RemoteConfig {
        addr: DEAD_ADDR.to_string(),
        timeout_ms: 200,
        retries: 3,
        backoff_ms: 10,
        grace_ms: 100,
    });
    let start = std::time::Instant::now();
    assert!(client.ping().is_err(), "nothing listens on port 1");
    assert!(
        start.elapsed() < std::time::Duration::from_secs(10),
        "4 attempts with 10ms base backoff must not spin for {:?}",
        start.elapsed()
    );
}

// ------------------------------------------------- seeded frame fuzzer --

/// Hand-built wire frame: `magic | version u32 LE | tag u8 | len u32 LE
/// | payload` — the layout `transport::write_frame` produces, built
/// here by hand because the fuzzer needs to forge *invalid* frames too.
fn frame(magic: &[u8; 4], version: u32, tag: u8, payload: &[u8]) -> Vec<u8> {
    let mut v = Vec::with_capacity(13 + payload.len());
    v.extend_from_slice(magic);
    v.extend_from_slice(&version.to_le_bytes());
    v.push(tag);
    v.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    v.extend_from_slice(payload);
    v
}

/// Apply one seeded mutation in place: a bit flip anywhere (magic,
/// version, tag, length or payload), a truncation at a random point, a
/// length field that promises far more bytes than follow, or trailing
/// garbage.
fn mutate(rng: &mut mlonmcu::util::XorShift64, bytes: &mut Vec<u8>) {
    match rng.below(4) {
        0 => {
            let i = rng.below(bytes.len() as u64) as usize;
            bytes[i] ^= 1u8 << (rng.below(8) as u8);
        }
        1 => {
            let keep = rng.below(bytes.len() as u64 + 1) as usize;
            bytes.truncate(keep);
        }
        2 => {
            let lie = (rng.next_u64() % u32::MAX as u64) as u32;
            bytes[9..13].copy_from_slice(&lie.to_le_bytes());
        }
        _ => {
            for _ in 0..rng.below(32) + 1 {
                let b = rng.next_u64() as u8;
                bytes.push(b);
            }
        }
    }
}

#[test]
fn fuzzed_request_frames_never_crash_the_server() {
    use mlonmcu::session::persist::FORMAT_VERSION;
    use mlonmcu::session::transport;
    use mlonmcu::util::XorShift64;
    use std::io::{Read, Write};
    use std::net::TcpStream;
    use std::time::Duration;

    let (server, dir) = spawn_server("fuzz_srv");
    let addr = server.addr.to_string();

    // seeded mutations of otherwise-plausible request frames — random
    // ops (including undefined ones), random payloads, then one of the
    // `mutate` corruptions. The server may answer ERR/MISS or drop the
    // connection; it must never die.
    for seed in [101u64, 202, 303] {
        let mut rng = XorShift64::stream(seed, "req-fuzz");
        for _ in 0..48 {
            let op = rng.below(18) as u8; // ops 16/17 are undefined
            let payload: Vec<u8> =
                (0..rng.below(64)).map(|_| rng.next_u64() as u8).collect();
            let mut bytes =
                frame(transport::REQ_MAGIC, FORMAT_VERSION, op, &payload);
            mutate(&mut rng, &mut bytes);
            let mut s = TcpStream::connect(&addr).unwrap();
            s.set_read_timeout(Some(Duration::from_millis(250))).unwrap();
            let _ = s.write_all(&bytes);
            let _ = s.flush();
            let mut sink = [0u8; 256];
            let _ = s.read(&mut sink); // answer, error or close: all fine
        }
    }

    // a length prefix near u32::MAX must be rejected by the MAX_FRAME
    // bound up front — connection dropped promptly, no 4 GiB buffer
    let mut lying =
        frame(transport::REQ_MAGIC, FORMAT_VERSION, transport::OP_GET, &[]);
    lying[9..13].copy_from_slice(&u32::MAX.to_le_bytes());
    let start = std::time::Instant::now();
    let mut s = TcpStream::connect(&addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
    s.write_all(&lying).unwrap();
    let mut sink = [0u8; 64];
    let _ = s.read(&mut sink);
    assert!(
        start.elapsed() < Duration::from_secs(5),
        "huge length prefix stalled the connection for {:?}",
        start.elapsed()
    );

    // after the whole barrage the server still answers a clean ping
    let client = Client::new(RemoteConfig {
        addr,
        timeout_ms: 1000,
        retries: 1,
        backoff_ms: 10,
        grace_ms: 100,
    });
    assert_eq!(
        client.ping().unwrap(),
        FORMAT_VERSION,
        "server died or desynced under the fuzzed frames"
    );

    server.shutdown();
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn fuzzed_responses_never_panic_the_client() {
    use mlonmcu::session::persist::FORMAT_VERSION;
    use mlonmcu::session::transport;
    use mlonmcu::util::XorShift64;
    use std::io::{Read, Write};
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::time::Duration;

    // a hostile "server": drains each request, answers with a seeded
    // mutation of a response frame — skewed versions, bogus statuses,
    // torn bytes, trailing junk
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let stop_srv = stop.clone();
    let srv = std::thread::spawn(move || {
        let mut rng = XorShift64::stream(404, "rsp-fuzz");
        for conn in listener.incoming() {
            if stop_srv.load(Ordering::Relaxed) {
                break;
            }
            let Ok(mut s) = conn else { continue };
            let _ = s.set_read_timeout(Some(Duration::from_millis(250)));
            let mut head = [0u8; 13];
            if s.read_exact(&mut head).is_err() {
                continue;
            }
            let len =
                u32::from_le_bytes(head[9..13].try_into().unwrap()) as usize;
            if len <= 4096 {
                let mut p = vec![0u8; len];
                let _ = s.read_exact(&mut p);
            }
            let version = if rng.below(3) == 0 {
                FORMAT_VERSION + 1 + rng.below(9) as u32
            } else {
                FORMAT_VERSION
            };
            let status = rng.below(6) as u8; // statuses 4/5 are undefined
            let body: Vec<u8> =
                (0..rng.below(48)).map(|_| rng.next_u64() as u8).collect();
            let mut bytes =
                frame(transport::RSP_MAGIC, version, status, &body);
            if rng.below(4) != 0 {
                mutate(&mut rng, &mut bytes);
            }
            let _ = s.write_all(&bytes);
        }
    });

    let client = Client::new(RemoteConfig {
        addr: addr.clone(),
        timeout_ms: 300,
        retries: 0,
        backoff_ms: 5,
        grace_ms: 50,
    });
    let mut oks = 0usize;
    let mut errs = 0usize;
    for i in 0..96u64 {
        // a GET-shaped request; every outcome must be a clean Ok/Err —
        // a skewed version maps to a miss, torn frames to errors, and
        // nothing may panic or over-allocate
        let mut payload = vec![2u8]; // build stage tag
        payload.extend_from_slice(&i.to_le_bytes());
        match client.request(transport::OP_GET, &payload) {
            Ok(_) => oks += 1,
            Err(_) => errs += 1,
        }
    }
    // the typed wrappers survive the same hostility
    for fp in 0..8u64 {
        let _ = client.blob_get(fp);
        let _ = client.ping();
    }
    assert_eq!(oks + errs, 96);
    assert!(
        oks > 0 && errs > 0,
        "fuzz plan should produce both clean and torn rounds \
         (got {oks} ok / {errs} err)"
    );

    stop.store(true, Ordering::Relaxed);
    let _ = std::net::TcpStream::connect(&addr); // unblock incoming()
    srv.join().unwrap();
}

fn bin_files(dir: &std::path::Path) -> Vec<PathBuf> {
    let mut out: Vec<PathBuf> = std::fs::read_dir(dir)
        .map(|rd| {
            rd.flatten()
                .map(|e| e.path())
                .filter(|p| p.extension().is_some_and(|x| x == "bin"))
                .collect()
        })
        .unwrap_or_default();
    out.sort();
    out
}
