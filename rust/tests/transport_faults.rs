//! Fault injection for the remote store tier: an unreachable or
//! mid-session-killed serve daemon must degrade the session to
//! local-only execution (counted, never fatal), corrupt wire entries
//! — truncated frames, wrong `FORMAT_VERSION` — must decode as misses
//! and recompute, and the client's retry/backoff loop must be bounded.

use std::path::PathBuf;
use std::sync::Arc;

use mlonmcu::config::Environment;
use mlonmcu::frontends::tmodel;
use mlonmcu::graph::{Graph, OpNode, TensorInfo};
use mlonmcu::graph::{OpCode, ACT_RELU, PAD_SAME};
use mlonmcu::session::transport::{Client, RemoteConfig, Server};
use mlonmcu::session::{EnvStore, RunMatrix, RunOptions, Session};
use mlonmcu::tensor::DType;

/// Same tiny conv graph as tests/dispatch_equivalence.rs.
fn tiny_conv_graph() -> Graph {
    let mut attrs = std::collections::BTreeMap::new();
    attrs.insert("stride_h".to_string(), 1);
    attrs.insert("stride_w".to_string(), 1);
    attrs.insert("padding".to_string(), PAD_SAME);
    attrs.insert("fused_act".to_string(), ACT_RELU);
    Graph {
        name: "tinyconv".into(),
        tensors: vec![
            TensorInfo {
                name: "input".into(),
                shape: vec![1, 4, 4, 2],
                dtype: DType::I8,
                scale: 0.5,
                zero_point: 0,
                data: None,
            },
            TensorInfo {
                name: "w".into(),
                shape: vec![3, 3, 3, 2],
                dtype: DType::I8,
                scale: 0.01,
                zero_point: 0,
                data: Some((0..54).map(|x| (x % 7) as u8).collect()),
            },
            TensorInfo {
                name: "b".into(),
                shape: vec![3],
                dtype: DType::I32,
                scale: 0.005,
                zero_point: 0,
                data: Some(vec![0; 12]),
            },
            TensorInfo {
                name: "out".into(),
                shape: vec![1, 4, 4, 3],
                dtype: DType::I8,
                scale: 0.25,
                zero_point: -128,
                data: None,
            },
        ],
        ops: vec![OpNode {
            opcode: OpCode::Conv2D,
            name: "conv0".into(),
            inputs: vec![0, 1, 2],
            outputs: vec![3],
            attrs,
        }],
        inputs: vec![0],
        outputs: vec![3],
    }
}

fn fresh_env(tag: &str, extra: &[String]) -> (Environment, PathBuf) {
    let dir = std::env::temp_dir().join(format!("mlonmcu_transportfault_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    let env = Environment::init(&dir).unwrap();
    tmodel::write_file(
        &tiny_conv_graph(),
        &dir.join("artifacts/models/tinyconv.tmodel"),
    )
    .unwrap();
    let mut overrides = vec![
        "tune.trials=8".to_string(),
        // fail fast: a dead server costs one quick round, not seconds
        "remote.timeout_ms=200".to_string(),
        "remote.retries=1".to_string(),
        "remote.backoff_ms=10".to_string(),
    ];
    overrides.extend_from_slice(extra);
    (env.with_overrides(&overrides).unwrap(), dir)
}

fn spawn_server(tag: &str) -> (mlonmcu::session::transport::ServerHandle, PathBuf) {
    let dir = std::env::temp_dir().join(format!("mlonmcu_transportfault_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let store = Arc::new(EnvStore::open(&dir, 512 << 20).unwrap());
    let handle = Server::spawn(store, "127.0.0.1:0").unwrap();
    (handle, dir)
}

fn dedup_matrix() -> RunMatrix {
    RunMatrix::new()
        .models(["tinyconv"])
        .backends(["tflmi", "tvmaot"])
        .targets(["etiss", "esp32c3", "stm32f4", "stm32f7", "esp32"])
}

fn opts(workers: usize) -> RunOptions {
    RunOptions { parallel: 2, use_cache: true, workers }
}

/// Nothing listens on 127.0.0.1:1 — every connect is refused.
const DEAD_ADDR: &str = "127.0.0.1:1";

#[test]
fn unreachable_server_degrades_to_local_execution() {
    let (env, dir) =
        fresh_env("dead", &[format!("remote.connect={DEAD_ADDR}")]);
    let session = Session::new(&env).unwrap();
    let report = session.run_matrix_opts(&dedup_matrix(), opts(0)).unwrap();
    for row in &report.rows {
        assert_eq!(row["status"].render(), "ok");
    }
    let t = *session.last_timing.lock().unwrap();
    assert_eq!(t.stage_execs.loads, 1, "everything executed locally");
    assert_eq!(t.stage_execs.builds, 2);
    assert_eq!(
        t.remote_errors, 1,
        "one counted transport error, then the tier is off"
    );
    assert_eq!((t.remote_hits, t.remote_misses), (0, 0));
    assert!(
        report
            .notes
            .iter()
            .any(|n| n.contains("remote store: 0 hit(s), 0 miss(es), 1 error(s)")),
        "degradation must be reported: {:?}",
        report.notes
    );
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn dispatch_falls_back_in_process_when_server_unreachable() {
    // --workers N --connect <dead addr>: the remote dispatcher cannot
    // even ping, so the matrix must fall back to in-process execution
    // rather than fail or hang
    let (env, dir) =
        fresh_env("deadfleet", &[format!("remote.connect={DEAD_ADDR}")]);
    let session = Session::new(&env).unwrap();
    let report = session.run_matrix_opts(&dedup_matrix(), opts(2)).unwrap();
    assert_eq!(report.len(), 10);
    for row in &report.rows {
        assert_eq!(row["status"].render(), "ok");
    }
    let t = *session.last_timing.lock().unwrap();
    assert_eq!(t.stage_execs.builds, 2);
    assert_eq!(t.worker_procs, 0, "no fleet, no local shards");
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn server_killed_mid_session_degrades_to_local() {
    let (server, server_dir) = spawn_server("kill_srv");
    let addr = server.addr.to_string();

    // seed the server through one remote-attached home
    let (env_a, dir_a) = fresh_env("kill_a", &[format!("remote.connect={addr}")]);
    let a = Session::new(&env_a).unwrap();
    a.run_matrix_opts(&dedup_matrix(), opts(0)).unwrap();

    // a fresh home warms itself entirely over the wire...
    let (env_b, dir_b) = fresh_env("kill_b", &[format!("remote.connect={addr}")]);
    let b = Session::new(&env_b).unwrap();
    b.run_matrix_opts(&dedup_matrix(), opts(0)).unwrap();
    assert_eq!(b.last_timing.lock().unwrap().remote_hits, 3);

    // ...then the server dies mid-session; the next run needs stages
    // the memory tier has never seen and must execute them locally
    server.shutdown();
    let wider = RunMatrix::new()
        .models(["tinyconv"])
        .backends(["tflmc", "tvmrt"])
        .targets(["etiss"]);
    let report = b.run_matrix_opts(&wider, opts(0)).unwrap();
    for row in &report.rows {
        assert_eq!(row["status"].render(), "ok");
    }
    let t = *b.last_timing.lock().unwrap();
    assert_eq!(t.stage_execs.builds, 2, "recomputed locally");
    assert_eq!(t.remote_errors, 1, "dead server counted once, then off");
    for d in [dir_a, dir_b, server_dir] {
        let _ = std::fs::remove_dir_all(d);
    }
}

#[test]
fn corrupt_served_entries_decode_as_misses_and_recompute() {
    let (server, server_dir) = spawn_server("corrupt_srv");
    let addr = server.addr.to_string();

    // populate the served store: load + tflmi build + tvmaot build
    let (env_a, dir_a) =
        fresh_env("corrupt_a", &[format!("remote.connect={addr}")]);
    let a = Session::new(&env_a).unwrap();
    a.run_matrix_opts(&dedup_matrix(), opts(0)).unwrap();

    // sabotage the server's files in place (the server is a dumb byte
    // pipe — OP_GET replays file bytes verbatim, the *client* verifies):
    // the load entry gets a wrong FORMAT_VERSION, one build entry is
    // truncated mid-frame, the other stays intact
    let load_files = bin_files(&server_dir.join("load"));
    assert_eq!(load_files.len(), 1);
    let mut bytes = std::fs::read(&load_files[0]).unwrap();
    bytes[4] = bytes[4].wrapping_add(1); // version u32 LE at [4..8]
    std::fs::write(&load_files[0], &bytes).unwrap();

    let build_files = bin_files(&server_dir.join("build"));
    assert_eq!(build_files.len(), 2);
    let bytes = std::fs::read(&build_files[0]).unwrap();
    std::fs::write(&build_files[0], &bytes[..10.min(bytes.len())]).unwrap();

    // a fresh home: the poisoned entries must read as remote misses
    // (never a crash, never a bad artifact) and recompute locally; the
    // intact build is still served
    let (env_b, dir_b) =
        fresh_env("corrupt_b", &[format!("remote.connect={addr}")]);
    let b = Session::new(&env_b).unwrap();
    let report = b.run_matrix_opts(&dedup_matrix(), opts(0)).unwrap();
    for row in &report.rows {
        assert_eq!(row["status"].render(), "ok");
    }
    let t = *b.last_timing.lock().unwrap();
    assert_eq!(t.stage_execs.loads, 1, "version-skewed load recomputed");
    assert_eq!(t.stage_execs.builds, 1, "truncated build recomputed");
    assert_eq!(t.remote_misses, 2);
    assert_eq!(t.remote_hits, 1, "the intact entry still serves");
    assert_eq!(t.remote_errors, 0, "corruption is a miss, not a fault");

    server.shutdown();
    for d in [dir_a, dir_b, server_dir] {
        let _ = std::fs::remove_dir_all(d);
    }
}

#[test]
fn retry_backoff_is_bounded_and_fails_fast() {
    let client = Client::new(RemoteConfig {
        addr: DEAD_ADDR.to_string(),
        timeout_ms: 200,
        retries: 3,
        backoff_ms: 10,
        grace_ms: 100,
    });
    let start = std::time::Instant::now();
    assert!(client.ping().is_err(), "nothing listens on port 1");
    assert!(
        start.elapsed() < std::time::Duration::from_secs(10),
        "4 attempts with 10ms base backoff must not spin for {:?}",
        start.elapsed()
    );
}

fn bin_files(dir: &std::path::Path) -> Vec<PathBuf> {
    let mut out: Vec<PathBuf> = std::fs::read_dir(dir)
        .map(|rd| {
            rd.flatten()
                .map(|e| e.path())
                .filter(|p| p.extension().is_some_and(|x| x == "bin"))
                .collect()
        })
        .unwrap_or_default();
    out.sort();
    out
}
