//! Table IV shape assertions over the real zoo models: every
//! qualitative claim of paper §III-B must hold on our reproduction.
//! Requires `make artifacts`.

use std::collections::BTreeMap;
use std::path::PathBuf;

use mlonmcu::backends::{all_backend_names, by_name, BackendConfig, BuildResult};
use mlonmcu::frontends::load_model;
use mlonmcu::graph::Graph;
use mlonmcu::targets;

fn models() -> Option<Vec<(String, Graph)>> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/models");
    if !dir.join("aww.tmodel").is_file() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(
        ["aww", "vww", "resnet", "toycar"]
            .iter()
            .map(|m| (m.to_string(), load_model(m, &[dir.clone()]).unwrap()))
            .collect(),
    )
}

struct Row {
    setup: u64,
    invoke: u64,
    rom: u64,
    ram: u64,
}

fn table4() -> Option<BTreeMap<(String, String), Row>> {
    let etiss = targets::by_name("etiss").unwrap();
    let mut out = BTreeMap::new();
    for (name, graph) in models()? {
        for bname in all_backend_names() {
            let backend = by_name(bname).unwrap();
            let build: BuildResult =
                backend.build(&graph, &BackendConfig::default()).unwrap();
            let dep = etiss.deploy(&build, backend.framework()).unwrap();
            let input = vec![0i8; graph.tensor(graph.inputs[0]).numel()];
            let o = etiss.run(&build, &dep, &input, false).unwrap();
            out.insert(
                (name.clone(), bname.to_string()),
                Row {
                    setup: o.setup_instructions,
                    invoke: o.invoke_instructions,
                    rom: build.metrics.rom_total(),
                    ram: build.metrics.ram_total(),
                },
            );
        }
    }
    Some(out)
}

#[test]
fn paper_section_3b_claims_hold() {
    let Some(t) = table4() else { return };
    let g = |m: &str, b: &str| &t[&(m.to_string(), b.to_string())];
    for m in ["aww", "vww", "resnet", "toycar"] {
        // "both backends loop over the same set of kernels, their
        // inference performance is equivalent"
        let (i, c) = (g(m, "tflmi"), g(m, "tflmc"));
        assert_eq!(i.invoke, c.invoke, "{m}: tflmi vs tflmc invoke");
        // "a reduction of ROM usage between 15 and 30 kB" for the
        // interpreter code itself; the full container delta in the
        // paper's Table IV reaches 74 kB for vww (416 vs 342) — we
        // accept 10-80 kB — and "RAM usage of at least 12%"
        let rom_delta = i.rom as i64 - c.rom as i64;
        assert!(
            (10_000..80_000).contains(&rom_delta),
            "{m}: tflmc ROM delta {rom_delta}"
        );
        assert!(
            (c.ram as f64) < 0.88 * i.ram as f64,
            "{m}: tflmc RAM -12%: {} vs {}",
            c.ram,
            i.ram
        );
        // "setup time ... reduced by utilizing the tflmc backend"
        assert!(c.setup < i.setup / 3, "{m}: tflmc setup");
        // "AoT-compiled models basically have no initialization"
        assert!(g(m, "tvmaot").setup < 2_000, "{m}: tvmaot setup ~0");
        assert!(g(m, "tvmaot+").setup < 2_000);
        // "tvmrt requires at least one million instructions to prepare"
        assert!(g(m, "tvmrt").setup > 1_000_000, "{m}: tvmrt setup");
        // tvmrt RAM blow-up (+605%..+14374% vs tvmaot)
        assert!(
            g(m, "tvmrt").ram > 4 * g(m, "tvmaot").ram,
            "{m}: tvmrt RAM explosion"
        );
        // "tvmaot outperform[s] tvmrt in every considered metric"
        assert!(g(m, "tvmaot").invoke <= g(m, "tvmrt").invoke * 11 / 10);
        assert!(g(m, "tvmaot").rom < g(m, "tvmrt").rom);
        // usmp: RAM reduction, never a regression
        assert!(g(m, "tvmaot+").ram <= g(m, "tvmaot").ram, "{m}: usmp");
    }
    // "toycar tvmrt setup exceeds even the inference time"
    assert!(
        g("toycar", "tvmrt").setup > g("toycar", "tvmrt").invoke,
        "toycar: tvmrt setup > invoke"
    );
    // "TFLite Micro can not keep up with TVM's kernels" (CNNs 2-8x)
    for m in ["aww", "vww", "resnet"] {
        let ratio =
            g(m, "tflmi").invoke as f64 / g(m, "tvmaot").invoke as f64;
        assert!(
            (2.0..10.0).contains(&ratio),
            "{m}: TFLM/TVM invoke ratio {ratio}"
        );
        // "TFLM outperforms TVM [RAM] for more complex models, often
        // by a factor of two" — the int16 legalization story. Our
        // storage-token planner reuses buffers better than 2021-era
        // TVM did, so the factor is 1.8-2.5x for vww/resnet and only
        // ~1.3x for aww (EXPERIMENTS.md documents the delta).
        let factor = if m == "aww" { 1.1 } else { 1.5 };
        assert!(
            g(m, "tvmaot").ram as f64 > factor * g(m, "tflmi").ram as f64,
            "{m}: TVM RAM > {factor}x TFLM"
        );
    }
    // toycar: dense model — TVM memory is NOT worse there (paper: TVM
    // wins RAM on toycar)
    assert!(g("toycar", "tvmaot").ram < g("toycar", "tflmi").ram);
    // invoke ratios across models track MACs (resnet > vww > aww > toycar)
    let inv = |m: &str| g(m, "tvmaot").invoke;
    assert!(inv("resnet") > inv("vww"));
    assert!(inv("vww") > inv("aww"));
    assert!(inv("aww") > inv("toycar"));
}

#[test]
fn table4_invoke_magnitudes_near_paper() {
    let Some(t) = table4() else { return };
    // our MAC-calibrated cost model should land within ~45% of the
    // paper's absolute invoke counts (documented in EXPERIMENTS.md)
    let paper: &[(&str, &str, f64)] = &[
        ("aww", "tflmi", 153.1e6),
        ("aww", "tvmaot", 29.8e6),
        ("resnet", "tflmi", 687.5e6),
        ("resnet", "tvmaot", 114.8e6),
        ("toycar", "tvmaot", 2.44e6),
    ];
    for (m, b, want) in paper {
        let got = t[&(m.to_string(), b.to_string())].invoke as f64;
        let ratio = got / want;
        assert!(
            (0.5..2.0).contains(&ratio),
            "{m}/{b}: invoke {got:.2e} vs paper {want:.2e} (x{ratio:.2})"
        );
    }
}
