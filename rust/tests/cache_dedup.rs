//! Artifact-cache + stage-scheduler integration: a matrix whose runs
//! share (model, backend, schedule) prefixes must execute each
//! distinct Load/Build stage exactly once, reusing the artifacts for
//! every other run — the paper's "benchmark a large number of
//! configurations in a low amount of time" mechanism. Uses a
//! rust-generated .tmodel, so no `make artifacts` is needed.

use std::path::PathBuf;

use mlonmcu::config::Environment;
use mlonmcu::frontends::tmodel;
use mlonmcu::graph::{Graph, OpNode, TensorInfo};
use mlonmcu::graph::{OpCode, ACT_RELU, PAD_SAME};
use mlonmcu::session::{RunMatrix, RunOptions, Session};
use mlonmcu::tensor::DType;

/// input[1,4,4,2] -> conv 3ch 3x3 SAME relu -> out[1,4,4,3]; small
/// enough to pass every hardware target's memory gates.
fn tiny_conv_graph() -> Graph {
    let mut attrs = std::collections::BTreeMap::new();
    attrs.insert("stride_h".to_string(), 1);
    attrs.insert("stride_w".to_string(), 1);
    attrs.insert("padding".to_string(), PAD_SAME);
    attrs.insert("fused_act".to_string(), ACT_RELU);
    Graph {
        name: "tinyconv".into(),
        tensors: vec![
            TensorInfo {
                name: "input".into(),
                shape: vec![1, 4, 4, 2],
                dtype: DType::I8,
                scale: 0.5,
                zero_point: 0,
                data: None,
            },
            TensorInfo {
                name: "w".into(),
                shape: vec![3, 3, 3, 2],
                dtype: DType::I8,
                scale: 0.01,
                zero_point: 0,
                data: Some((0..54).map(|x| (x % 7) as u8).collect()),
            },
            TensorInfo {
                name: "b".into(),
                shape: vec![3],
                dtype: DType::I32,
                scale: 0.005,
                zero_point: 0,
                data: Some(vec![0; 12]),
            },
            TensorInfo {
                name: "out".into(),
                shape: vec![1, 4, 4, 3],
                dtype: DType::I8,
                scale: 0.25,
                zero_point: -128,
                data: None,
            },
        ],
        ops: vec![OpNode {
            opcode: OpCode::Conv2D,
            name: "conv0".into(),
            inputs: vec![0, 1, 2],
            outputs: vec![3],
            attrs,
        }],
        inputs: vec![0],
        outputs: vec![3],
    }
}

/// Fresh environment in a temp dir with the generated model in place.
fn cache_env(tag: &str) -> (Environment, PathBuf) {
    let dir = std::env::temp_dir().join(format!("mlonmcu_cachededup_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    let env = Environment::init(&dir).unwrap();
    let model_path = dir.join("artifacts/models/tinyconv.tmodel");
    tmodel::write_file(&tiny_conv_graph(), &model_path).unwrap();
    (env, dir)
}

fn matrix() -> RunMatrix {
    // 1 model × 2 backends × 5 targets = 10 runs sharing 2 distinct
    // (model, backend, schedule) build prefixes
    RunMatrix::new()
        .models(["tinyconv"])
        .backends(["tflmi", "tvmaot"])
        .targets(["etiss", "esp32c3", "stm32f4", "stm32f7", "esp32"])
}

#[test]
fn shared_prefixes_build_exactly_once() {
    let (env, dir) = cache_env("dedup");
    let session = Session::new(&env).unwrap();
    let report = session.run_matrix(&matrix(), 4).unwrap();
    assert_eq!(report.len(), 10);
    for row in &report.rows {
        assert_eq!(
            row["status"].render(),
            "ok",
            "{}/{} failed",
            row["backend"].render(),
            row["target"].render()
        );
    }

    let t = *session.last_timing.lock().unwrap();
    assert_eq!(t.stage_execs.builds, 2, "one build per distinct prefix");
    assert_eq!(t.stage_execs.loads, 1, "one load per distinct model");
    assert_eq!(t.stage_execs.tunes, 0);
    // 3 unique stage tasks miss; the 7 sharing runs count 9 + 8 hits
    // (9 shared loads, 4 shared builds per backend)
    assert_eq!(t.cache_misses, 3);
    assert_eq!(t.cache_hits, 17);
    assert_eq!(t.cache_evictions, 0);

    // the report says which runs reused which stages: run 0 executed
    // load+build, run 5 (first tvmaot run) only built, the rest reused
    // everything
    assert_eq!(report.rows[0]["cached_stages"].render(), "-");
    assert_eq!(report.rows[1]["cached_stages"].render(), "load+build");
    assert_eq!(report.rows[5]["cached_stages"].render(), "load");

    // disk tier: index + per-entry artifacts under the session dir
    assert!(session.dir.join("cache/index.json").is_file());
    assert!(session.dir.join("cache/build").is_dir());

    std::fs::remove_dir_all(dir).unwrap();
}

#[test]
fn second_run_matrix_is_all_hits() {
    let (env, dir) = cache_env("rerun");
    let session = Session::new(&env).unwrap();
    session.run_matrix(&matrix(), 2).unwrap();
    let first = *session.last_timing.lock().unwrap();
    assert_eq!(first.stage_execs.builds, 2);

    let report = session.run_matrix(&matrix(), 2).unwrap();
    assert_eq!(report.len(), 10);
    let second = *session.last_timing.lock().unwrap();
    assert_eq!(second.stage_execs.builds, 0, "all builds served from cache");
    assert_eq!(second.stage_execs.loads, 0);
    assert_eq!(second.cache_misses, 0);
    assert_eq!(second.cache_hits, 20);
    // every run reused its whole prefix this time
    for row in &report.rows {
        assert_eq!(row["cached_stages"].render(), "load+build");
    }
    std::fs::remove_dir_all(dir).unwrap();
}

#[test]
fn no_cache_executes_every_stage_per_run() {
    let (env, dir) = cache_env("nocache");
    let session = Session::new(&env).unwrap();
    let opts = RunOptions { parallel: 4, use_cache: false, workers: 0 };
    let report = session.run_matrix_opts(&matrix(), opts).unwrap();
    assert_eq!(report.len(), 10);
    for row in &report.rows {
        assert_eq!(row["status"].render(), "ok");
        assert_eq!(row["cached_stages"].render(), "-");
    }
    let t = *session.last_timing.lock().unwrap();
    assert_eq!(t.stage_execs.builds, 10, "no dedup under --no-cache");
    assert_eq!(t.stage_execs.loads, 10);
    assert_eq!((t.cache_hits, t.cache_misses), (0, 0));
    // the session cache itself stays untouched
    assert_eq!(session.cache_stats(), mlonmcu::session::CacheStats::default());
    std::fs::remove_dir_all(dir).unwrap();
}

#[test]
fn cached_and_uncached_reports_agree() {
    let (env, dir) = cache_env("agree");
    let cached = Session::new(&env).unwrap();
    let r1 = cached.run_matrix(&matrix(), 4).unwrap();
    let uncached = Session::new(&env).unwrap();
    let r2 = uncached
        .run_matrix_opts(&matrix(), RunOptions { parallel: 1, use_cache: false, workers: 0 })
        .unwrap();
    assert_eq!(r1.len(), r2.len());
    for (a, b) in r1.rows.iter().zip(&r2.rows) {
        for col in [
            "model", "backend", "target", "status", "invoke_instr", "time_s",
            "rom_b", "ram_b",
        ] {
            assert_eq!(a.get(col), b.get(col), "col {col} differs");
        }
    }
    std::fs::remove_dir_all(dir).unwrap();
}

// ----------------------------------------------------------------------
// environment-level store: persistence across sessions (and thereby
// across CLI invocations — each invocation is one fresh Session)

/// Every serialized build entry under the environment cache dir.
fn build_entries(dir: &std::path::Path) -> Vec<PathBuf> {
    std::fs::read_dir(dir.join("cache/build"))
        .map(|rd| {
            rd.flatten()
                .map(|f| f.path())
                .filter(|p| p.extension().is_some_and(|e| e == "bin"))
                .collect()
        })
        .unwrap_or_default()
}

#[test]
fn second_session_in_same_env_gets_disk_hits() {
    let (env, dir) = cache_env("xsession");
    let first_report;
    {
        let s1 = Session::new(&env).unwrap();
        first_report = s1.run_matrix(&matrix(), 2).unwrap();
        let t = *s1.last_timing.lock().unwrap();
        assert_eq!(t.stage_execs.builds, 2);
        assert_eq!(t.disk_hits, 0, "nothing persisted yet");
        assert_eq!(t.disk_misses, 3, "1 load + 2 builds consulted the store");
    }
    // the store now holds 1 graph + 2 build artifacts
    assert!(dir.join("cache/index.json").is_file());
    assert_eq!(build_entries(&dir).len(), 2);

    // a brand-new session (fresh memory tier) is served entirely from
    // the environment store: zero stage executions
    let s2 = Session::new(&env).unwrap();
    let report = s2.run_matrix(&matrix(), 2).unwrap();
    let t = *s2.last_timing.lock().unwrap();
    assert_eq!(t.stage_execs.builds, 0, "builds come from the env store");
    assert_eq!(t.stage_execs.loads, 0);
    assert_eq!(t.disk_hits, 3, "1 load + 2 builds deserialized");
    assert_eq!(t.cache_misses, 0);
    assert_eq!(t.verify_fails, 0);
    for row in &report.rows {
        assert_eq!(row["cached_stages"].render(), "load+build");
    }
    // deserialized artifacts must produce byte-identical results
    for (a, b) in first_report.rows.iter().zip(&report.rows) {
        for col in [
            "model", "backend", "target", "status", "invoke_instr", "time_s",
            "rom_b", "ram_b",
        ] {
            assert_eq!(a.get(col), b.get(col), "col {col} differs");
        }
    }
    std::fs::remove_dir_all(dir).unwrap();
}

#[test]
fn flipped_byte_in_store_is_detected_and_recomputed() {
    let (env, dir) = cache_env("corrupt");
    {
        let s1 = Session::new(&env).unwrap();
        s1.run_matrix(&matrix(), 2).unwrap();
    }
    // flip one payload byte in one stored build artifact
    let victim = &build_entries(&dir)[0];
    let mut bytes = std::fs::read(victim).unwrap();
    let last = bytes.len() - 1;
    bytes[last] ^= 0x01;
    std::fs::write(victim, &bytes).unwrap();

    let s2 = Session::new(&env).unwrap();
    let report = s2.run_matrix(&matrix(), 2).unwrap();
    for row in &report.rows {
        assert_eq!(row["status"].render(), "ok", "corruption must not fail runs");
    }
    let t = *s2.last_timing.lock().unwrap();
    assert_eq!(t.verify_fails, 1, "the flipped entry fails verification");
    assert_eq!(t.stage_execs.builds, 1, "only the corrupt build re-executes");
    assert_eq!(t.stage_execs.loads, 0);
    assert_eq!(t.disk_hits, 2, "the intact load + build still serve");
    // the recomputed artifact was re-persisted: a third session hits
    let s3 = Session::new(&env).unwrap();
    s3.run_matrix(&matrix(), 2).unwrap();
    let t = *s3.last_timing.lock().unwrap();
    assert_eq!(t.stage_execs.builds, 0);
    assert_eq!(t.verify_fails, 0);
    std::fs::remove_dir_all(dir).unwrap();
}

#[test]
fn no_cache_ignores_populated_env_store() {
    let (env, dir) = cache_env("nocachestore");
    {
        let s1 = Session::new(&env).unwrap();
        s1.run_matrix(&matrix(), 2).unwrap();
    }
    let s2 = Session::new(&env).unwrap();
    let opts = RunOptions { parallel: 2, use_cache: false, workers: 0 };
    s2.run_matrix_opts(&matrix(), opts).unwrap();
    let t = *s2.last_timing.lock().unwrap();
    assert_eq!(t.stage_execs.builds, 10, "--no-cache bypasses the store too");
    assert_eq!((t.disk_hits, t.cache_hits), (0, 0));
    std::fs::remove_dir_all(dir).unwrap();
}

#[test]
fn store_gc_under_tiny_budget_evicts_lru_order() {
    use mlonmcu::session::cache::{load_key, Artifact, CachedStage};
    use mlonmcu::session::{persist, EnvStore};
    use std::sync::Arc;

    let dir = std::env::temp_dir().join("mlonmcu_cachededup_gcbudget");
    let _ = std::fs::remove_dir_all(&dir);
    let artifact = Artifact::Graph(Arc::new(tiny_conv_graph()));
    let one = persist::encode(load_key(0), &artifact).len() as u64;
    // budget fits exactly two entries
    let store = EnvStore::open(&dir, 2 * one + one / 2).unwrap();
    store.save(load_key(0), &artifact).unwrap();
    store.save(load_key(1), &artifact).unwrap();
    assert_eq!(store.stats().entries, 2);
    // touch 0 so 1 is least-recently-used, then overflow the budget
    assert!(matches!(
        store.load(load_key(0), CachedStage::Load),
        mlonmcu::session::store::StoreLookup::Hit(_)
    ));
    store.save(load_key(2), &artifact).unwrap();
    let s = store.stats();
    assert_eq!(s.entries, 2);
    assert_eq!(s.evictions, 1, "eviction counter updated");
    assert!(
        matches!(
            store.load(load_key(1), CachedStage::Load),
            mlonmcu::session::store::StoreLookup::Miss
        ),
        "LRU entry evicted first"
    );
    // shrinking the budget and running gc trims to the single MRU entry
    drop(store);
    let store = EnvStore::open(&dir, one + one / 2).unwrap();
    let (evicted, freed) = store.gc().unwrap();
    assert_eq!(evicted, 1);
    assert_eq!(freed, one);
    assert_eq!(store.stats().entries, 1);
    assert!(matches!(
        store.load(load_key(2), CachedStage::Load),
        mlonmcu::session::store::StoreLookup::Hit(_)
    ));
    std::fs::remove_dir_all(dir).unwrap();
}

#[test]
fn model_content_change_invalidates_cache_keys() {
    let (env, dir) = cache_env("invalidate");
    let session = Session::new(&env).unwrap();
    session.run_matrix(&matrix(), 2).unwrap();
    assert_eq!(session.last_timing.lock().unwrap().stage_execs.builds, 2);

    // regenerate the model with different weights: same name, new
    // content => new keys => stages re-execute
    let mut g = tiny_conv_graph();
    g.tensors[1].data = Some((0..54).map(|x| (x % 5) as u8).collect());
    tmodel::write_file(&g, &dir.join("artifacts/models/tinyconv.tmodel")).unwrap();

    session.run_matrix(&matrix(), 2).unwrap();
    let t = *session.last_timing.lock().unwrap();
    assert_eq!(t.stage_execs.builds, 2, "content change must rebuild");
    assert_eq!(t.stage_execs.loads, 1);
    std::fs::remove_dir_all(dir).unwrap();
}
