//! Multi-process stress test for the environment store: N concurrent
//! writer processes plus GC loops hammering one `index.json` must
//! never corrupt an entry or lose a verified artifact. Children are
//! real processes (this test binary re-executing itself with
//! `MLONMCU_STRESS_*` set), not threads — the lock file, tmp-rename
//! writes and index merge are exactly the cross-process surfaces the
//! sharded dispatcher (`session/dispatch.rs`) leans on.

use std::path::PathBuf;
use std::process::Command;

use mlonmcu::graph::model::testutil::tiny_conv;
use mlonmcu::session::cache::{load_key, Artifact, CachedStage};
use mlonmcu::session::store::{EnvStore, StoreLookup};
use std::sync::Arc;

const WRITERS: usize = 4;
const KEYS_PER_WRITER: u64 = 60;

fn artifact() -> Artifact {
    Artifact::Graph(Arc::new(tiny_conv()))
}

fn child_key(child: u64, i: u64) -> u64 {
    child * 1_000_000 + i
}

/// Re-execute this test binary as a stress child.
fn spawn_child(dir: &std::path::Path, id: usize, budget: &str) -> std::process::Child {
    Command::new(std::env::current_exe().unwrap())
        .args(["stress_child_worker", "--exact", "--include-ignored", "--nocapture"])
        .env("MLONMCU_STRESS_CHILD", id.to_string())
        .env("MLONMCU_STRESS_DIR", dir)
        .env("MLONMCU_STRESS_BUDGET", budget)
        .spawn()
        .expect("spawning stress child")
}

/// The child body: save/load/gc loops against the shared store. Run
/// only when re-executed by the parent tests (ignored otherwise).
#[test]
#[ignore = "helper: re-executed as a child process by the stress tests"]
fn stress_child_worker() {
    let Ok(id) = std::env::var("MLONMCU_STRESS_CHILD") else { return };
    let id: u64 = id.parse().unwrap();
    let dir = PathBuf::from(std::env::var("MLONMCU_STRESS_DIR").unwrap());
    let budget: u64 = std::env::var("MLONMCU_STRESS_BUDGET").unwrap().parse().unwrap();
    let store = EnvStore::open(&dir, budget).expect("child open");
    let a = artifact();
    for i in 0..KEYS_PER_WRITER {
        store.save(load_key(child_key(id, i)), &a).expect("child save");
        // read back own + sibling keys: any Hit decoded through the
        // key/hash verifier; Corrupt would mean torn bytes
        for probe in [child_key(id, i), child_key((id + 1) % WRITERS as u64, i)] {
            match store.load(load_key(probe), CachedStage::Load) {
                StoreLookup::Hit(_) | StoreLookup::Miss => {}
                StoreLookup::Corrupt => {
                    panic!("child {id}: store served a corrupt entry for {probe:x}")
                }
            }
        }
        if i % 8 == 0 {
            // GC loop hammering the same index under the same lock
            store.gc().expect("child gc");
        }
    }
}

fn run_stress(tag: &str, budget: u64) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mlonmcu_store_stress_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let children: Vec<_> = (0..WRITERS)
        .map(|i| spawn_child(&dir, i, &budget.to_string()))
        .collect();
    for mut c in children {
        let status = c.wait().expect("child waited");
        assert!(status.success(), "stress child failed: {status:?}");
    }
    dir
}

#[test]
fn concurrent_writers_and_gc_lose_nothing_under_unlimited_budget() {
    // guard: when libtest runs this inside a child re-execution the
    // filter already excludes it, but belt-and-braces
    if std::env::var("MLONMCU_STRESS_CHILD").is_ok() {
        return;
    }
    let dir = run_stress("unlimited", u64::MAX);
    // with no budget pressure GC evicts nothing: every verified
    // artifact every child saved must still load — and decode clean
    let store = EnvStore::open(&dir, u64::MAX).unwrap();
    assert_eq!(
        store.stats().entries as u64,
        WRITERS as u64 * KEYS_PER_WRITER,
        "index lost entries under concurrent writers"
    );
    for child in 0..WRITERS as u64 {
        for i in 0..KEYS_PER_WRITER {
            match store.load(load_key(child_key(child, i)), CachedStage::Load) {
                StoreLookup::Hit(_) => {}
                StoreLookup::Miss => {
                    panic!("lost verified artifact {child}/{i}")
                }
                StoreLookup::Corrupt => {
                    panic!("corrupt artifact {child}/{i}")
                }
            }
        }
    }
    std::fs::remove_dir_all(dir).unwrap();
}

#[test]
fn concurrent_writers_under_tiny_budget_never_corrupt() {
    if std::env::var("MLONMCU_STRESS_CHILD").is_ok() {
        return;
    }
    // budget fits only a handful of entries: eviction races everywhere
    let one = mlonmcu::session::persist::encode(load_key(0), &artifact()).len() as u64;
    let dir = run_stress("tiny", 8 * one);
    // losing entries to eviction is legal; serving corrupt ones never:
    // every surviving index row must decode through verification
    let store = EnvStore::open(&dir, u64::MAX).unwrap();
    let mut survivors = 0usize;
    for child in 0..WRITERS as u64 {
        for i in 0..KEYS_PER_WRITER {
            match store.load(load_key(child_key(child, i)), CachedStage::Load) {
                StoreLookup::Hit(_) => survivors += 1,
                StoreLookup::Miss => {}
                StoreLookup::Corrupt => {
                    panic!("corrupt artifact {child}/{i} after eviction races")
                }
            }
        }
    }
    assert!(survivors > 0, "at least the newest entries survive");
    // the validated open dropped any index row without a matching
    // file, so every remaining entry was probed above and served clean
    let s = store.stats();
    assert_eq!(s.entries, survivors, "index rows == loadable artifacts");
    assert_eq!(s.total_bytes, survivors as u64 * one);
    std::fs::remove_dir_all(dir).unwrap();
}
