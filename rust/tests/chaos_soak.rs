//! Seeded chaos-soak harness for the fault-injection subsystem
//! (`util/faults.rs`) and the stage retry/quarantine machinery: full
//! matrices executed serially, with a 4-process local fleet and with a
//! remote fleet, all under deterministic fault plans. Every session
//! must terminate, the environment store must verify clean (or
//! self-heal on the next session), and every report row must either be
//! byte-identical to the fault-free baseline or be a deterministic
//! `failed:` row — injected chaos may fail work, it may never corrupt
//! or wedge it.
//!
//! The fault registry is process-global, so every test here holds a
//! shared gate for its whole baseline + chaos window. Each test prints
//! a `faults_injected=N` line; CI greps the soak log for a nonzero
//! count to prove the chaos actually happened.

use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::{Arc, Mutex, MutexGuard};

use mlonmcu::config::Environment;
use mlonmcu::frontends::tmodel;
use mlonmcu::graph::{Graph, OpNode, TensorInfo};
use mlonmcu::graph::{OpCode, ACT_RELU, PAD_SAME};
use mlonmcu::session::transport::Server;
use mlonmcu::session::{EnvStore, RunMatrix, RunOptions, Session};
use mlonmcu::tensor::DType;

/// Serializes chaos tests: fault plans live in a process-global
/// registry, and cargo runs the tests in this binary on parallel
/// threads.
static GATE: Mutex<()> = Mutex::new(());

fn gate() -> MutexGuard<'static, ()> {
    let g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    // a previous test that panicked mid-chaos may have left its plan
    // armed; every test starts from a disarmed registry
    mlonmcu::util::faults::clear();
    g
}

/// Same tiny conv graph as tests/dispatch_equivalence.rs — small
/// enough for every hardware target's memory gates.
fn tiny_conv_graph() -> Graph {
    let mut attrs = std::collections::BTreeMap::new();
    attrs.insert("stride_h".to_string(), 1);
    attrs.insert("stride_w".to_string(), 1);
    attrs.insert("padding".to_string(), PAD_SAME);
    attrs.insert("fused_act".to_string(), ACT_RELU);
    Graph {
        name: "tinyconv".into(),
        tensors: vec![
            TensorInfo {
                name: "input".into(),
                shape: vec![1, 4, 4, 2],
                dtype: DType::I8,
                scale: 0.5,
                zero_point: 0,
                data: None,
            },
            TensorInfo {
                name: "w".into(),
                shape: vec![3, 3, 3, 2],
                dtype: DType::I8,
                scale: 0.01,
                zero_point: 0,
                data: Some((0..54).map(|x| (x % 7) as u8).collect()),
            },
            TensorInfo {
                name: "b".into(),
                shape: vec![3],
                dtype: DType::I32,
                scale: 0.005,
                zero_point: 0,
                data: Some(vec![0; 12]),
            },
            TensorInfo {
                name: "out".into(),
                shape: vec![1, 4, 4, 3],
                dtype: DType::I8,
                scale: 0.25,
                zero_point: -128,
                data: None,
            },
        ],
        ops: vec![OpNode {
            opcode: OpCode::Conv2D,
            name: "conv0".into(),
            inputs: vec![0, 1, 2],
            outputs: vec![3],
            attrs,
        }],
        inputs: vec![0],
        outputs: vec![3],
    }
}

/// Fresh environment with the model in place, dispatch pointed at the
/// real CLI binary and fast lease/tune knobs. `extra` appends
/// overrides (fault plans, retry policy, remote.connect).
fn fresh_env(tag: &str, extra: &[String]) -> (Environment, PathBuf) {
    let dir = std::env::temp_dir().join(format!("mlonmcu_chaossoak_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    let env = Environment::init(&dir).unwrap();
    tmodel::write_file(
        &tiny_conv_graph(),
        &dir.join("artifacts/models/tinyconv.tmodel"),
    )
    .unwrap();
    let mut overrides = vec![
        format!("dispatch.worker_bin={}", env!("CARGO_BIN_EXE_mlonmcu")),
        "tune.trials=8".to_string(),
        "dispatch.lease_ms=400".to_string(),
    ];
    overrides.extend_from_slice(extra);
    (env.with_overrides(&overrides).unwrap(), dir)
}

fn full_matrix() -> RunMatrix {
    RunMatrix::new()
        .models(["tinyconv"])
        .backends(["tflmi", "tflmc", "tvmaot", "tvmaot+", "tvmrt"])
        .targets(["etiss", "esp32"])
        .schedules(["default-nchw", "arm-nhwc"])
        .with_tuning_sweep()
}

fn dedup_matrix() -> RunMatrix {
    RunMatrix::new()
        .models(["tinyconv"])
        .backends(["tflmi", "tvmaot"])
        .targets(["etiss", "esp32c3", "stm32f4", "stm32f7", "esp32"])
}

/// parallel=1 keeps serial chaos runs fully deterministic: a single
/// scheduler thread means one global order of fault-site checks.
fn serial_opts() -> RunOptions {
    RunOptions { parallel: 1, use_cache: true, workers: 0 }
}

fn opts(workers: usize) -> RunOptions {
    RunOptions { parallel: 2, use_cache: true, workers }
}

/// A fault-free serial baseline in its own home.
fn baseline(tag: &str) -> (mlonmcu::report::Report, PathBuf) {
    let (env, dir) = fresh_env(tag, &[]);
    let report = Session::new(&env)
        .unwrap()
        .run_matrix_opts(&full_matrix(), serial_opts())
        .unwrap();
    (report, dir)
}

/// Chaos may fail rows, never mutate them: every CSV line must be
/// byte-identical to the baseline's, or be a `failed:` row.
fn assert_rows_degrade_cleanly(base_csv: &str, chaos_csv: &str, label: &str) {
    let base: Vec<&str> = base_csv.lines().collect();
    let chaos: Vec<&str> = chaos_csv.lines().collect();
    assert_eq!(
        base.len(),
        chaos.len(),
        "{label}: chaos run changed the row count"
    );
    for (i, (b, c)) in base.iter().zip(&chaos).enumerate() {
        assert!(
            b == c || c.contains("failed:"),
            "{label}: row {i} was mutated (not failed) by chaos:\n  \
             baseline: {b}\n  chaos:    {c}"
        );
    }
}

/// Every entry the faulted session left in the home's store must still
/// decode + hash-verify; injected corruption is only ever allowed to
/// surface as a miss/recompute, never as a bad persisted artifact.
fn assert_store_verifies_clean(env: &Environment, label: &str) {
    let store = EnvStore::open(&env.cache_dir(), u64::MAX).unwrap();
    let rep = store.verify();
    assert!(
        rep.clean(),
        "{label}: store corrupt after chaos: {:?}",
        rep.corrupt
    );
}

#[test]
fn serial_chaos_is_deterministic_and_rows_degrade_cleanly() {
    let _g = gate();
    let (base, dir_b) = baseline("serial_base");

    for seed in [11u64, 12, 13] {
        let plan = format!(
            "seed={seed},store.save:error:0.15,store.load:error:0.2,\
             store.load:bitflip:0.1,stage.load:error:0.1,\
             stage.tune:error:0.3,stage.build:error:0.35"
        );
        let extra = [
            format!("faults.plan={plan}"),
            "retry.attempts=2".to_string(),
            "retry.backoff_ms=0".to_string(),
        ];
        let run = |tag: &str| {
            let (env, dir) = fresh_env(tag, &extra);
            let session = Session::new(&env).unwrap();
            let report =
                session.run_matrix_opts(&full_matrix(), serial_opts()).unwrap();
            let t = *session.last_timing.lock().unwrap();
            assert_store_verifies_clean(&env, tag);
            let _ = std::fs::remove_dir_all(dir);
            (report, t)
        };
        let (r1, t1) = run(&format!("serial_s{seed}_a"));
        let (r2, _) = run(&format!("serial_s{seed}_b"));

        // the same plan replays the same fault sequence: two fresh
        // homes produce byte-identical reports, quarantine markers and
        // all
        assert_eq!(
            r1.to_csv(),
            r2.to_csv(),
            "seed {seed}: chaos run is not deterministic"
        );
        assert_eq!(r1.to_markdown(), r2.to_markdown(), "seed {seed}");
        assert_rows_degrade_cleanly(
            &base.to_csv(),
            &r1.to_csv(),
            &format!("seed {seed}"),
        );
        println!(
            "chaos-soak[serial seed={seed}]: faults_injected={}",
            t1.faults_injected
        );
    }
    let _ = std::fs::remove_dir_all(dir_b);
}

#[test]
fn save_errors_are_warnings_report_identical_and_counted() {
    let _g = gate();
    let (base, dir_b) = baseline("save_base");

    // every store.save fails: persistence is best-effort, the memory
    // tier stays authoritative, so the report must not change by a
    // single byte — while every injected failure is counted
    let (env, dir) = fresh_env(
        "save_err",
        &["faults.plan=seed=11,store.save:error:1".to_string()],
    );
    let session = Session::new(&env).unwrap();
    let report =
        session.run_matrix_opts(&full_matrix(), serial_opts()).unwrap();
    assert_eq!(
        base.to_csv(),
        report.to_csv(),
        "failed saves leaked into the report"
    );
    let t = *session.last_timing.lock().unwrap();
    assert!(
        t.faults_injected >= 3,
        "a full matrix saves load+tune+build artifacts at least \
         3 times (injected {})",
        t.faults_injected
    );
    assert_store_verifies_clean(&env, "save_err");
    println!(
        "chaos-soak[save-errors]: faults_injected={}",
        t.faults_injected
    );
    let _ = std::fs::remove_dir_all(dir);
    let _ = std::fs::remove_dir_all(dir_b);
}

#[test]
fn exhausted_retries_quarantine_rows_with_attempt_markers() {
    let _g = gate();
    let (base, dir_b) = baseline("quar_base");

    // every tune execution fails: with retry.attempts=3 each tuned row
    // burns all attempts and is quarantined with the [attempts=3]
    // marker; untuned rows stay byte-identical
    let (env, dir) = fresh_env(
        "quarantine",
        &[
            "faults.plan=seed=11,stage.tune:error:1".to_string(),
            "retry.attempts=3".to_string(),
            "retry.backoff_ms=0".to_string(),
        ],
    );
    let report = Session::new(&env)
        .unwrap()
        .run_matrix_opts(&full_matrix(), serial_opts())
        .unwrap();
    let quarantined = report
        .rows
        .iter()
        .filter(|r| {
            let s = r["status"].render();
            s.starts_with("failed:tune") && s.contains("[attempts=3]")
        })
        .count();
    assert!(
        quarantined > 0,
        "no row carries the quarantine marker:\n{}",
        report.to_csv()
    );
    assert_rows_degrade_cleanly(&base.to_csv(), &report.to_csv(), "quarantine");
    let _ = std::fs::remove_dir_all(dir);

    // with the default single attempt the marker must not appear —
    // today's failure rendering is preserved bit-for-bit
    let (env1, dir1) = fresh_env(
        "quarantine1",
        &["faults.plan=seed=11,stage.tune:error:1".to_string()],
    );
    let report1 = Session::new(&env1)
        .unwrap()
        .run_matrix_opts(&full_matrix(), serial_opts())
        .unwrap();
    assert!(
        !report1.to_csv().contains("[attempts="),
        "attempts=1 must not annotate failures"
    );
    let _ = std::fs::remove_dir_all(dir1);
    let _ = std::fs::remove_dir_all(dir_b);
}

#[test]
fn four_worker_chaos_with_dying_workers_terminates_clean() {
    let _g = gate();
    let (base, dir_b) = baseline("fleet_base");

    // workers randomly exit(9) mid-stage with their leases held, on
    // top of store read errors and stage errors with retries; the
    // parent (exit rules are inert there) must reclaim, retry and
    // finish the matrix with every row clean-or-failed
    let plan = "seed=12,stage.build:exit:0.4:1,stage.tune:exit:0.2:2,\
                store.load:error:0.2,stage.build:error:0.25";
    let (env, dir) = fresh_env(
        "fleet",
        &[
            format!("faults.plan={plan}"),
            "retry.attempts=2".to_string(),
            "retry.backoff_ms=0".to_string(),
        ],
    );
    let session = Session::new(&env).unwrap();
    let report = session.run_matrix_opts(&full_matrix(), opts(4)).unwrap();
    let t = *session.last_timing.lock().unwrap();
    assert_eq!(t.worker_procs, 4, "the doomed fleet must actually spawn");
    assert_rows_degrade_cleanly(&base.to_csv(), &report.to_csv(), "fleet");
    assert_store_verifies_clean(&env, "fleet");
    println!(
        "chaos-soak[4-worker]: faults_injected={}",
        t.faults_injected
    );
    let _ = std::fs::remove_dir_all(dir);
    let _ = std::fs::remove_dir_all(dir_b);
}

#[test]
fn torn_writes_self_heal_across_sessions() {
    let _g = gate();
    let (base, dir_b) = baseline("heal_base");

    // session 1: half the artifact saves are torn mid-write. The
    // session itself is untouched (the memory tier is authoritative) —
    // the report stays byte-identical — but the store may now hold
    // entries that fail hash verification
    let (env, dir) = fresh_env(
        "heal",
        &["faults.plan=seed=13,store.save:short:0.5".to_string()],
    );
    {
        let session = Session::new(&env).unwrap();
        let report =
            session.run_matrix_opts(&full_matrix(), serial_opts()).unwrap();
        assert_eq!(
            base.to_csv(),
            report.to_csv(),
            "torn writes leaked into the live session's report"
        );
        let t = *session.last_timing.lock().unwrap();
        println!(
            "chaos-soak[torn-writes]: faults_injected={}",
            t.faults_injected
        );
    }
    let torn = EnvStore::open(&env.cache_dir(), u64::MAX).unwrap();
    let rep1 = torn.verify();
    drop(torn);

    // session 2, same home, no faults: every torn entry must read as
    // Corrupt → deleted → recomputed → re-saved, with the report again
    // byte-identical; afterwards the store verifies clean
    let (env2, _) = fresh_env_reuse(&dir);
    let session2 = Session::new(&env2).unwrap();
    let report2 =
        session2.run_matrix_opts(&full_matrix(), serial_opts()).unwrap();
    assert_eq!(
        base.to_csv(),
        report2.to_csv(),
        "self-healing rerun diverged from the baseline"
    );
    let healed = EnvStore::open(&env2.cache_dir(), u64::MAX).unwrap();
    let rep2 = healed.verify();
    assert!(
        rep2.clean(),
        "store still corrupt after the healing session: {:?} \
         (was: {:?})",
        rep2.corrupt,
        rep1.corrupt
    );
    let _ = std::fs::remove_dir_all(dir);
    let _ = std::fs::remove_dir_all(dir_b);
}

/// Re-open an existing chaos home *without* wiping it and without any
/// fault overrides — the fault-free healing session of the torn-write
/// test.
fn fresh_env_reuse(dir: &std::path::Path) -> (Environment, PathBuf) {
    let env = Environment::init(dir).unwrap();
    let overrides = vec![
        format!("dispatch.worker_bin={}", env!("CARGO_BIN_EXE_mlonmcu")),
        "tune.trials=8".to_string(),
        "dispatch.lease_ms=400".to_string(),
    ];
    (env.with_overrides(&overrides).unwrap(), dir.to_path_buf())
}

#[test]
fn hung_workers_are_revoked_and_report_stays_byte_identical() {
    let _g = gate();
    // fault-free serial baseline of the all-ok dedup matrix
    let (env_s, dir_s) = fresh_env("hang_base", &[]);
    let base = Session::new(&env_s)
        .unwrap()
        .run_matrix_opts(&dedup_matrix(), serial_opts())
        .unwrap();

    // every Build wedges for 900ms with its heartbeat alive — lease
    // staleness never fires, only the 300ms deadline watchdog revokes
    // the lease for retry elsewhere. First-writer-wins done markers
    // absorb the duplicate executions: the report must not move by a
    // byte
    let (env, dir) = fresh_env(
        "hang",
        &[
            "faults.plan=seed=11,hang_ms=900,stage.build:hang:1".to_string(),
            "retry.deadline_ms=300".to_string(),
        ],
    );
    let session = Session::new(&env).unwrap();
    let report = session.run_matrix_opts(&dedup_matrix(), opts(2)).unwrap();
    let t = *session.last_timing.lock().unwrap();
    assert_eq!(t.worker_procs, 2);
    assert_eq!(
        base.to_csv(),
        report.to_csv(),
        "hang + revocation chaos changed the report"
    );
    assert_eq!(base.to_markdown(), report.to_markdown());
    assert!(
        t.faults_injected >= 2,
        "both builds must have hung at least once (injected {})",
        t.faults_injected
    );
    assert_store_verifies_clean(&env, "hang");
    println!(
        "chaos-soak[hang-watchdog]: faults_injected={}",
        t.faults_injected
    );
    let _ = std::fs::remove_dir_all(dir);
    let _ = std::fs::remove_dir_all(dir_s);
}

// ----------------------------------------------------- remote fleet --

/// A model-less home for one remote worker (artifacts travel through
/// the server's blob pool). No fault config on disk: the worker can
/// only arm its plan from the served queue's claim payload.
fn worker_home(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mlonmcu_chaossoak_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    Environment::init(&dir).unwrap();
    dir
}

fn spawn_remote_worker(addr: &str, home: &std::path::Path) -> Child {
    Command::new(env!("CARGO_BIN_EXE_mlonmcu"))
        .arg("worker")
        .arg("--connect")
        .arg(addr)
        .arg("--home")
        .arg(home)
        .stdin(Stdio::null())
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawning remote worker")
}

/// Kills + reaps the fleet even when an assertion panics.
struct Fleet(Vec<Child>);

impl Drop for Fleet {
    fn drop(&mut self) {
        for c in &mut self.0 {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

#[test]
fn remote_fleet_chaos_terminates_and_rows_degrade_cleanly() {
    let _g = gate();
    let (base, dir_b) = baseline("remote_base");

    let server_dir =
        std::env::temp_dir().join("mlonmcu_chaossoak_remote_srv");
    let _ = std::fs::remove_dir_all(&server_dir);
    std::fs::create_dir_all(&server_dir).unwrap();
    let store = Arc::new(EnvStore::open(&server_dir, 512 << 20).unwrap());
    let server = Server::spawn(store, "127.0.0.1:0").unwrap();
    let addr = server.addr.to_string();

    // two remote workers in bare homes: their only source for the
    // fault plan is the claim payload; stalled heartbeats age their
    // served leases out, and the claim's deadline_ms reopens claims
    // that outstay the stage deadline even while the heartbeat lives.
    // Rare transport drops ride the client's retry loop — and if they
    // exhaust it the parent degrades to in-process execution, which
    // still must finish the matrix
    let homes: Vec<PathBuf> =
        (0..2).map(|i| worker_home(&format!("remote_wh{i}"))).collect();
    let fleet =
        Fleet(homes.iter().map(|h| spawn_remote_worker(&addr, h)).collect());

    let plan = "seed=12,hang_ms=600,stage.build:error:0.3,\
                store.load:error:0.2,queue.lease.heartbeat:stall:0.15,\
                transport.send:drop:0.03:10";
    let (env, dir) = fresh_env(
        "remote_parent",
        &[
            format!("remote.connect={addr}"),
            format!("faults.plan={plan}"),
            "retry.attempts=2".to_string(),
            "retry.backoff_ms=0".to_string(),
            "retry.deadline_ms=2000".to_string(),
            "remote.retries=2".to_string(),
            "remote.backoff_ms=10".to_string(),
        ],
    );
    let session = Session::new(&env).unwrap();
    let report = session.run_matrix_opts(&full_matrix(), opts(2)).unwrap();
    let t = *session.last_timing.lock().unwrap();
    assert_rows_degrade_cleanly(&base.to_csv(), &report.to_csv(), "remote");
    assert_store_verifies_clean(&env, "remote");
    println!(
        "chaos-soak[remote-fleet]: faults_injected={}",
        t.faults_injected
    );

    drop(fleet);
    server.shutdown();
    for d in homes {
        let _ = std::fs::remove_dir_all(d);
    }
    let _ = std::fs::remove_dir_all(dir);
    let _ = std::fs::remove_dir_all(dir_b);
    let _ = std::fs::remove_dir_all(server_dir);
}
