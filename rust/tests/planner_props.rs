//! Property tests (in-repo prop framework) on coordinator invariants:
//! memory planners never produce colliding plans, USMP dominates
//! storage-tokens, greedy never exceeds no-reuse, and lifetimes are
//! respected for arbitrary DAG-shaped programs.

use mlonmcu::backends::planner::{plan, PlannerKind};
use mlonmcu::kernels::copy_cost;
use mlonmcu::prop::{check, no_shrink, Config};
use mlonmcu::tensor::DType;
use mlonmcu::tinyir::*;
use mlonmcu::util::XorShift64;

/// Generate a random (but valid) program: a DAG where each call reads
/// 1-2 earlier buffers and writes a fresh one.
fn random_program(rng: &mut XorShift64) -> Program {
    let n_calls = rng.range(1, 24);
    let mut buffers = vec![BufferDecl {
        name: "input".into(),
        size: rng.range(1, 4096),
        dtype: DType::I8,
        offset: None,
        first_use: 0,
        last_use: 0,
    }];
    let mut calls = Vec::new();
    for i in 0..n_calls {
        let src = rng.range(0, buffers.len() - 1);
        let elems = rng.range(1, 4096);
        buffers.push(BufferDecl {
            name: format!("b{i}"),
            size: elems,
            dtype: DType::I8,
            offset: None,
            first_use: 0,
            last_use: 0,
        });
        let dst = buffers.len() - 1;
        let mut inputs = vec![Operand::Buf(src)];
        if rng.f64() < 0.3 && buffers.len() > 2 {
            inputs.push(Operand::Buf(rng.range(0, buffers.len() - 2)));
        }
        calls.push(KernelCall {
            kind: KernelKind::Copy { elems },
            inputs,
            consts: vec![],
            output: dst,
            cost: copy_cost(elems as u64),
            origin: format!("c{i}"),
        });
    }
    let out = buffers.len() - 1;
    let mut p = Program {
        name: "prop".into(),
        buffers,
        consts: vec![],
        calls,
        input: 0,
        output: out,
        arena_size: 0,
        workspace_size: 0,
    };
    p.recompute_lifetimes();
    p
}

#[test]
fn all_planners_always_produce_valid_plans() {
    for kind in [
        PlannerKind::GreedyArena,
        PlannerKind::StorageTokens,
        PlannerKind::UsmpInterval,
        PlannerKind::NoReuse,
    ] {
        check(
            Config { cases: 150, seed: 0xC0FFEE },
            random_program,
            no_shrink,
            |p| {
                let mut p = p.clone();
                plan(&mut p, kind);
                p.check_plan().is_ok()
            },
        );
    }
}

#[test]
fn usmp_never_worse_than_tokens_or_noreuse() {
    check(
        Config { cases: 150, seed: 0xBEEF },
        random_program,
        no_shrink,
        |p| {
            let mut a = p.clone();
            let mut b = p.clone();
            let mut c = p.clone();
            let usmp = plan(&mut a, PlannerKind::UsmpInterval);
            let tok = plan(&mut b, PlannerKind::StorageTokens);
            let none = plan(&mut c, PlannerKind::NoReuse);
            usmp <= tok && tok <= none
        },
    );
}

#[test]
fn arena_always_fits_largest_live_set_lower_bound() {
    // the arena can never be smaller than the largest single buffer
    check(
        Config { cases: 100, seed: 0xA11CE },
        random_program,
        no_shrink,
        |p| {
            let mut q = p.clone();
            let arena = plan(&mut q, PlannerKind::UsmpInterval);
            let max_buf = q.buffers.iter().map(|b| b.size).max().unwrap_or(0);
            arena >= max_buf
        },
    );
}

#[test]
fn lifetimes_cover_all_uses() {
    check(
        Config { cases: 100, seed: 0xD00D },
        random_program,
        no_shrink,
        |p| {
            p.calls.iter().enumerate().all(|(i, c)| {
                let out = &p.buffers[c.output];
                let out_ok = out.first_use <= i && i <= out.last_use;
                let ins_ok = c.inputs.iter().all(|op| match op {
                    Operand::Buf(id) => {
                        let b = &p.buffers[*id];
                        b.first_use <= i && i <= b.last_use
                    }
                    _ => true,
                });
                out_ok && ins_ok
            })
        },
    );
}
