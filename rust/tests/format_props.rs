//! Property tests on the data-format substrates: TModel parsing never
//! panics on corrupted bytes, MLIF round-trips arbitrary reports, JSON
//! round-trips arbitrary golden vectors, CSV round-trips arbitrary
//! cells.

use mlonmcu::data::csv::{parse_csv, to_csv};
use mlonmcu::data::Json;
use mlonmcu::frontends::tmodel;
use mlonmcu::platform::mlif::{self, MlifReport};
use mlonmcu::prop::{check, no_shrink, Config};
use mlonmcu::util::XorShift64;

#[test]
fn tmodel_parser_never_panics_on_fuzz() {
    check(
        Config { cases: 300, seed: 0xF122 },
        |rng: &mut XorShift64| {
            let n = rng.range(0, 300);
            let mut v: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
            // half the cases: corrupt a valid-ish header instead
            if rng.f64() < 0.5 {
                let mut h = b"TMDL".to_vec();
                h.extend(1u32.to_le_bytes());
                h.extend(v.clone());
                v = h;
            }
            v
        },
        no_shrink,
        |bytes| {
            // must return Err or Ok, never panic
            let _ = tmodel::parse(bytes);
            true
        },
    );
}

#[test]
fn mlif_roundtrip_arbitrary_reports() {
    check(
        Config { cases: 200, seed: 0x3117 },
        |rng: &mut XorShift64| MlifReport {
            model: format!("m{}", rng.range(0, 999)),
            setup_instructions: rng.next_u64() >> 20,
            invoke_instructions: rng.next_u64() >> 20,
            invoke_cycles: rng.next_u64() >> 20,
            invoke_us: rng.next_u64() >> 30,
            output: (0..rng.range(0, 64))
                .map(|_| rng.next_u64() as i8)
                .collect(),
        },
        no_shrink,
        |r| mlif::parse(&mlif::render(r)).map(|p| p == *r).unwrap_or(false),
    );
}

#[test]
fn json_roundtrip_i64_vectors() {
    check(
        Config { cases: 200, seed: 0x7E57 },
        |rng: &mut XorShift64| {
            (0..rng.range(0, 80))
                .map(|_| (rng.next_u64() as i8) as i64)
                .collect::<Vec<i64>>()
        },
        mlonmcu::prop::shrink_vec,
        |v| {
            let j = Json::obj(vec![("output", Json::from_i64s(v))]);
            Json::parse(&j.to_string())
                .ok()
                .and_then(|p| p.get("output").and_then(|o| o.as_i64_vec()))
                .map(|got| got == *v)
                .unwrap_or(false)
        },
    );
}

#[test]
fn csv_roundtrip_arbitrary_cells() {
    let charset: Vec<char> =
        "abc,\"\n x-7".chars().collect();
    check(
        Config { cases: 200, seed: 0xC54 },
        |rng: &mut XorShift64| {
            let cols = rng.range(1, 5);
            let rows = rng.range(0, 6);
            let cell = |rng: &mut XorShift64| -> String {
                (0..rng.range(0, 8))
                    .map(|_| charset[rng.range(0, charset.len() - 1)])
                    .collect()
            };
            let headers: Vec<String> =
                (0..cols).map(|i| format!("h{i}{}", cell(rng))).collect();
            let data: Vec<Vec<String>> = (0..rows)
                .map(|_| (0..cols).map(|_| cell(rng)).collect())
                .collect();
            (headers, data)
        },
        no_shrink,
        |(headers, data)| {
            let text = to_csv(headers, data);
            let parsed = parse_csv(&text);
            if parsed.is_empty() {
                return data.is_empty() && headers.iter().all(String::is_empty);
            }
            let hdr_ok = parsed[0] == *headers;
            let rows_ok = parsed[1..].len() == data.len()
                && parsed[1..].iter().zip(data).all(|(a, b)| a == b);
            hdr_ok && rows_ok
        },
    );
}
