//! Serial-vs-parallel conformance harness for the sharded
//! multi-process matrix executor (`session/dispatch.rs`): the same
//! matrix executed serially, with 1 worker process and with 4 worker
//! processes must produce **byte-identical** reports — including the
//! counter note — across the full backend × schedule matrix with a
//! tuning sweep (which exercises shared Load/Tune/Build dedup *and*
//! failure propagation: esp32 rejects AutoTVM, so tuned esp32 rows
//! fail identically everywhere). A worker killed mid-Build must be
//! reclaimed with the run still completing, still byte-identical.
//!
//! Workers are real `mlonmcu` child processes
//! (`CARGO_BIN_EXE_mlonmcu` via the `dispatch.worker_bin` override —
//! the test harness binary has no `worker` subcommand), exchanging
//! artifacts exclusively through the environment store.

use std::path::PathBuf;

use mlonmcu::config::Environment;
use mlonmcu::frontends::tmodel;
use mlonmcu::graph::{Graph, OpNode, TensorInfo};
use mlonmcu::graph::{OpCode, ACT_RELU, PAD_SAME};
use mlonmcu::session::{RunMatrix, RunOptions, Session};
use mlonmcu::tensor::DType;

/// input[1,4,4,2] -> conv 3ch 3x3 SAME relu -> out[1,4,4,3]; small
/// enough to pass every hardware target's memory gates (same graph as
/// tests/cache_dedup.rs).
fn tiny_conv_graph() -> Graph {
    let mut attrs = std::collections::BTreeMap::new();
    attrs.insert("stride_h".to_string(), 1);
    attrs.insert("stride_w".to_string(), 1);
    attrs.insert("padding".to_string(), PAD_SAME);
    attrs.insert("fused_act".to_string(), ACT_RELU);
    Graph {
        name: "tinyconv".into(),
        tensors: vec![
            TensorInfo {
                name: "input".into(),
                shape: vec![1, 4, 4, 2],
                dtype: DType::I8,
                scale: 0.5,
                zero_point: 0,
                data: None,
            },
            TensorInfo {
                name: "w".into(),
                shape: vec![3, 3, 3, 2],
                dtype: DType::I8,
                scale: 0.01,
                zero_point: 0,
                data: Some((0..54).map(|x| (x % 7) as u8).collect()),
            },
            TensorInfo {
                name: "b".into(),
                shape: vec![3],
                dtype: DType::I32,
                scale: 0.005,
                zero_point: 0,
                data: Some(vec![0; 12]),
            },
            TensorInfo {
                name: "out".into(),
                shape: vec![1, 4, 4, 3],
                dtype: DType::I8,
                scale: 0.25,
                zero_point: -128,
                data: None,
            },
        ],
        ops: vec![OpNode {
            opcode: OpCode::Conv2D,
            name: "conv0".into(),
            inputs: vec![0, 1, 2],
            outputs: vec![3],
            attrs,
        }],
        inputs: vec![0],
        outputs: vec![3],
    }
}

/// Fresh environment in a temp dir with the generated model in place
/// and the dispatch knobs pointed at the real CLI binary. `extra`
/// appends overrides (fault markers, lease tuning).
fn fresh_env(tag: &str, extra: &[String]) -> (Environment, PathBuf) {
    let dir = std::env::temp_dir().join(format!("mlonmcu_dispatcheq_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    let env = Environment::init(&dir).unwrap();
    tmodel::write_file(
        &tiny_conv_graph(),
        &dir.join("artifacts/models/tinyconv.tmodel"),
    )
    .unwrap();
    let mut overrides = vec![
        format!("dispatch.worker_bin={}", env!("CARGO_BIN_EXE_mlonmcu")),
        // small budget keeps tune fast; identical across envs so keys
        // and outcomes agree
        "tune.trials=8".to_string(),
        "dispatch.lease_ms=400".to_string(),
    ];
    overrides.extend_from_slice(extra);
    (env.with_overrides(&overrides).unwrap(), dir)
}

/// The full backend × schedule matrix, with a tuning sweep: every
/// backend family, schedule-capable and not, plus a target (esp32)
/// whose tuned runs fail — failure rows must propagate identically
/// under sharded execution.
fn full_matrix() -> RunMatrix {
    RunMatrix::new()
        .models(["tinyconv"])
        .backends(["tflmi", "tflmc", "tvmaot", "tvmaot+", "tvmrt"])
        .targets(["etiss", "esp32"])
        .schedules(["default-nchw", "arm-nhwc"])
        .with_tuning_sweep()
}

/// 1 model × 2 backends × 5 targets: the cache-dedup matrix (all-ok
/// rows, heavy artifact sharing).
fn dedup_matrix() -> RunMatrix {
    RunMatrix::new()
        .models(["tinyconv"])
        .backends(["tflmi", "tvmaot"])
        .targets(["etiss", "esp32c3", "stm32f4", "stm32f7", "esp32"])
}

fn opts(workers: usize) -> RunOptions {
    RunOptions { parallel: 2, use_cache: true, workers }
}

#[test]
fn serial_one_and_four_workers_byte_identical() {
    let (env_s, dir_s) = fresh_env("serial", &[]);
    let serial_session = Session::new(&env_s).unwrap();
    let baseline = serial_session.run_matrix_opts(&full_matrix(), opts(0)).unwrap();
    let baseline_t = *serial_session.last_timing.lock().unwrap();
    // the matrix exercises both failure rows and ok rows
    assert!(baseline.rows.iter().any(|r| r["status"].render() == "ok"));
    assert!(baseline
        .rows
        .iter()
        .any(|r| r["status"].render().starts_with("failed:tune")));

    for workers in [1usize, 4] {
        let (env_w, dir_w) = fresh_env(&format!("w{workers}"), &[]);
        let session = Session::new(&env_w).unwrap();
        let report = session.run_matrix_opts(&full_matrix(), opts(workers)).unwrap();
        assert_eq!(
            baseline.to_csv(),
            report.to_csv(),
            "{workers}-worker CSV differs from serial"
        );
        assert_eq!(
            baseline.to_markdown(),
            report.to_markdown(),
            "{workers}-worker markdown (rows + counter note) differs from serial"
        );
        // the dispatch accounting reconstructs the serial counters
        let t = *session.last_timing.lock().unwrap();
        assert_eq!(t.stage_execs, baseline_t.stage_execs, "{workers} workers");
        assert_eq!(t.cache_hits, baseline_t.cache_hits, "{workers} workers");
        assert_eq!(t.cache_misses, baseline_t.cache_misses, "{workers} workers");
        assert_eq!(t.disk_misses, baseline_t.disk_misses, "{workers} workers");
        assert_eq!((t.disk_hits, t.verify_fails), (0, 0), "{workers} workers");
        std::fs::remove_dir_all(dir_w).unwrap();
    }
    std::fs::remove_dir_all(dir_s).unwrap();
}

#[test]
fn killed_worker_mid_build_is_reclaimed_and_report_still_identical() {
    let (env_s, dir_s) = fresh_env("killserial", &[]);
    let baseline = Session::new(&env_s)
        .unwrap()
        .run_matrix_opts(&full_matrix(), opts(0))
        .unwrap();

    // fault injection: `stage.build:exit:1` makes every worker process
    // die (exit 9) with its lease held the moment it enters a Build —
    // exactly like a SIGKILL mid-Build. Exit rules are inert outside
    // worker processes, so this test process and the parent's own
    // drain never die; the Build tasks can only ever complete in the
    // parent's drain AFTER the whole fleet died and was reclaimed.
    let (env_k, dir_k) = fresh_env(
        "killed",
        &["faults.plan=stage.build:exit:1".to_string()],
    );
    let session = Session::new(&env_k).unwrap();
    let report = session.run_matrix_opts(&full_matrix(), opts(4)).unwrap();

    let t = *session.last_timing.lock().unwrap();
    assert_eq!(t.worker_procs, 4, "the doomed fleet must actually spawn");
    assert_eq!(
        baseline.to_csv(),
        report.to_csv(),
        "run with killed workers diverged from serial"
    );
    assert_eq!(baseline.to_markdown(), report.to_markdown());

    std::fs::remove_dir_all(dir_k).unwrap();
    std::fs::remove_dir_all(dir_s).unwrap();
}

#[test]
fn sharded_rerun_is_all_disk_hits_and_matches_warm_serial() {
    let (env, dir) = fresh_env("rerun", &[]);
    // session 0 populates the store
    {
        let s = Session::new(&env).unwrap();
        s.run_matrix_opts(&dedup_matrix(), opts(0)).unwrap();
        let t = *s.last_timing.lock().unwrap();
        assert_eq!(t.stage_execs.builds, 2);
        assert_eq!(t.stage_execs.loads, 1);
    }
    // session 1: warm serial baseline
    let warm = Session::new(&env).unwrap();
    let warm_report = warm.run_matrix_opts(&dedup_matrix(), opts(0)).unwrap();
    let warm_t = *warm.last_timing.lock().unwrap();
    assert_eq!(warm_t.stage_execs, Default::default());
    assert_eq!(warm_t.disk_hits, 3);

    // session 2: 4 worker processes, everything served from the store
    let sharded = Session::new(&env).unwrap();
    let sharded_report = sharded.run_matrix_opts(&dedup_matrix(), opts(4)).unwrap();
    let t = *sharded.last_timing.lock().unwrap();
    assert_eq!(t.stage_execs, Default::default(), "0 executed stages");
    assert_eq!(t.disk_hits, 3);
    assert_eq!(t.cache_misses, 0);
    assert_eq!(t.cache_hits, warm_t.cache_hits);
    for row in &sharded_report.rows {
        assert_eq!(row["cached_stages"].render(), "load+build");
    }
    assert_eq!(warm_report.to_csv(), sharded_report.to_csv());
    assert_eq!(warm_report.to_markdown(), sharded_report.to_markdown());
    std::fs::remove_dir_all(dir).unwrap();
}

#[test]
fn same_session_sharded_rerun_counts_memory_hits_like_serial() {
    let (env, dir) = fresh_env("samesession", &[]);
    let session = Session::new(&env).unwrap();
    session.run_matrix_opts(&dedup_matrix(), opts(0)).unwrap();

    // serial semantics for a warm same-session rerun: everything is a
    // memory-tier hit, zero disk hits — the sharded accounting must
    // reconstruct exactly that even though the workers consult the
    // store (the parent's memory tier would have served a serial pass)
    let report = session.run_matrix_opts(&dedup_matrix(), opts(4)).unwrap();
    let t = *session.last_timing.lock().unwrap();
    assert_eq!(t.stage_execs, Default::default());
    assert_eq!((t.cache_hits, t.cache_misses), (20, 0));
    assert_eq!(t.disk_hits, 0, "memory tier hits must not read as disk hits");
    for row in &report.rows {
        assert_eq!(row["cached_stages"].render(), "load+build");
    }
    std::fs::remove_dir_all(dir).unwrap();
}

#[test]
fn tracing_on_keeps_reports_byte_identical_and_spans_cover_the_fleet() {
    // untraced serial baseline
    let (env_s, dir_s) = fresh_env("traceserial", &[]);
    let baseline = Session::new(&env_s)
        .unwrap()
        .run_matrix_opts(&full_matrix(), opts(0))
        .unwrap();

    // traced 4-worker run of the same matrix
    let trace_file = std::env::temp_dir().join("mlonmcu_dispatcheq_trace.json");
    let _ = std::fs::remove_file(&trace_file);
    let (env_t, dir_t) = fresh_env(
        "traced",
        &[format!("trace.file={}", trace_file.display())],
    );
    let session = Session::new(&env_t).unwrap();
    let report = session.run_matrix_opts(&full_matrix(), opts(4)).unwrap();

    // tracing must not add a single byte to the report
    assert_eq!(baseline.to_csv(), report.to_csv(), "tracing leaked into CSV");
    assert_eq!(
        baseline.to_markdown(),
        report.to_markdown(),
        "tracing leaked into the markdown report"
    );

    let t = *session.last_timing.lock().unwrap();
    assert_eq!(t.worker_procs, 4);
    assert!(t.trace_spans > 0, "no spans exported");
    let spans = mlonmcu::util::trace::read_spans(&trace_file).unwrap();
    assert_eq!(spans.len(), t.trace_spans);

    // the merged timeline covers the parent and every worker process
    let parent = std::process::id();
    let mut pids: std::collections::BTreeSet<u32> =
        spans.iter().map(|s| s.pid).collect();
    assert!(pids.remove(&parent), "parent spans missing from the trace");
    assert!(
        pids.len() >= 4,
        "expected spans from 4 worker pids, got {pids:?}"
    );

    // ≥1 span per executed pipeline stage, plus lease + cache activity
    let names: std::collections::BTreeSet<&str> =
        spans.iter().map(|s| s.name.as_str()).collect();
    for name in ["load", "tune", "build", "compile", "run", "claim", "lookup"] {
        assert!(names.contains(name), "no '{name}' span in {names:?}");
    }
    // every span is a complete interval on the shared epoch clock
    assert!(spans.iter().all(|s| s.ts_us > 0));
    // and the summary aggregation has per-stage/per-pid rows to print
    let aggs = mlonmcu::util::trace::aggregate(&spans);
    assert!(aggs.iter().any(|a| a.name == "build" && a.count > 0));

    std::fs::remove_dir_all(dir_t).unwrap();
    std::fs::remove_dir_all(dir_s).unwrap();
    let _ = std::fs::remove_file(&trace_file);
}

#[test]
fn metrics_on_keeps_reports_byte_identical_and_cover_the_fleet() {
    // unmetered serial baseline: [metrics] off must export nothing
    let (env_s, dir_s) = fresh_env(
        "metricserial",
        &["metrics.enabled=false".to_string()],
    );
    let session_s = Session::new(&env_s).unwrap();
    let baseline = session_s.run_matrix_opts(&full_matrix(), opts(0)).unwrap();
    assert!(
        !session_s.dir.join("metrics.json").exists(),
        "metrics.json written with [metrics] disabled"
    );

    // metered 4-worker run of the same matrix ([metrics] default: on)
    let (env_m, dir_m) = fresh_env("metered", &[]);
    let session = Session::new(&env_m).unwrap();
    let report = session.run_matrix_opts(&full_matrix(), opts(4)).unwrap();

    // metering must not add a single byte to the report
    assert_eq!(baseline.to_csv(), report.to_csv(), "metrics leaked into CSV");
    assert_eq!(
        baseline.to_markdown(),
        report.to_markdown(),
        "metrics leaked into the markdown report"
    );

    // the exported snapshot merges every worker's queue-dir registry
    // file: fleet-wide stage latencies and lease timings are present
    let snap = mlonmcu::util::metrics::read_snapshot(
        &session.dir.join("metrics.json"),
    )
    .expect("metered session must export metrics.json");
    for name in ["stage.tune.us", "stage.build.us"] {
        let h = snap
            .hists
            .get(name)
            .unwrap_or_else(|| panic!("no '{name}' series in metrics.json"));
        assert!(h.count > 0, "'{name}' recorded no observations");
        assert!(h.max >= h.min, "'{name}' has inconsistent bounds");
    }
    assert!(
        snap.hists.keys().any(|k| k.starts_with("lease.")),
        "no lease series in {:?}",
        snap.hists.keys().collect::<Vec<_>>()
    );
    // consumed after collection: a second run must not re-merge them
    let queues = session.dir.join("queue");
    if let Ok(subs) = std::fs::read_dir(&queues) {
        for sub in subs.flatten() {
            if let Ok(files) = std::fs::read_dir(sub.path()) {
                for f in files.flatten() {
                    let n = f.file_name();
                    let n = n.to_string_lossy();
                    assert!(
                        !(n.starts_with("metrics-") && n.ends_with(".json")),
                        "leftover worker snapshot {n}"
                    );
                }
            }
        }
    }

    std::fs::remove_dir_all(dir_m).unwrap();
    std::fs::remove_dir_all(dir_s).unwrap();
}

#[test]
fn workers_without_store_fall_back_to_in_process() {
    let (env, dir) = fresh_env("nostore", &["cache.persist=false".to_string()]);
    let session = Session::new(&env).unwrap();
    assert!(session.env_store().is_none());
    // requesting workers must not fail — it degrades to the serial path
    let report = session.run_matrix_opts(&dedup_matrix(), opts(4)).unwrap();
    assert_eq!(report.len(), 10);
    let t = *session.last_timing.lock().unwrap();
    assert_eq!(t.stage_execs.builds, 2, "in-process scheduler executed");
    std::fs::remove_dir_all(dir).unwrap();
}
