//! Session-level integration: full Load→Build→Compile→Run flows over
//! the real zoo models (requires `make artifacts`), exercising the
//! run matrix, parallel executor, failure capture and report pipeline.

use std::path::PathBuf;

use mlonmcu::config::Environment;
use mlonmcu::report::Cell;
use mlonmcu::session::{RunMatrix, Session};

/// Environment rooted at the repo checkout (artifacts/ present) but
/// with sessions redirected to a temp dir.
fn repo_env(tag: &str) -> Option<Environment> {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    if !root.join("artifacts/models/aww.tmodel").is_file() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    let sdir = std::env::temp_dir().join(format!("mlonmcu_e2e_{tag}"));
    let _ = std::fs::remove_dir_all(&sdir);
    let env = Environment::load(&root)
        .or_else(|_| {
            // no environment.toml at repo root: use implicit default
            std::env::set_var("MLONMCU_UNUSED", "1");
            Ok::<_, anyhow::Error>(Environment {
                root: root.clone(),
                doc: mlonmcu::data::toml::TomlDoc::parse(
                    mlonmcu::config::DEFAULT_TEMPLATE,
                )
                .unwrap(),
                overrides: Default::default(),
            })
        })
        .ok()?;
    // sessions AND the env cache go to the temp dir: a persistent
    // store under the checkout would leak state between test runs
    env.with_overrides(&[
        format!("paths.sessions={}", sdir.display()),
        format!("paths.cache={}", sdir.join("cache").display()),
    ])
    .ok()
}

#[test]
fn single_run_aww_tvmaot_etiss() {
    let Some(env) = repo_env("single") else { return };
    let s = Session::new(&env).unwrap();
    let m = RunMatrix::new()
        .models(["aww"])
        .backends(["tvmaot"])
        .targets(["etiss"]);
    let report = s.run_matrix(&m, 1).unwrap();
    assert_eq!(report.len(), 1);
    let row = &report.rows[0];
    assert_eq!(row["status"].render(), "ok");
    // Table IV ballpark: aww tvmaot invoke ~30M ref instructions ±40%
    let invoke = row["invoke_instr"].as_f64().unwrap();
    assert!(
        (18e6..45e6).contains(&invoke),
        "aww/tvmaot invoke {invoke} out of Table IV ballpark"
    );
    // run artifacts exist (reproducibility)
    assert!(s.dir.join("run_0/program.tir").is_file());
    assert!(s.dir.join("run_0/metrics.json").is_file());
    assert!(s.dir.join("report.csv").is_file());
}

#[test]
fn parallel_matches_serial_results() {
    let Some(env) = repo_env("par") else { return };
    let m = RunMatrix::new()
        .models(["aww", "toycar"])
        .backends(["tflmi", "tvmaot"])
        .targets(["etiss", "stm32f7"]);
    let s1 = Session::new(&env).unwrap();
    let r1 = s1.run_matrix(&m, 1).unwrap();
    let s2 = Session::new(&env).unwrap();
    let r2 = s2.run_matrix(&m, 4).unwrap();
    assert_eq!(r1.len(), r2.len());
    for (a, b) in r1.rows.iter().zip(&r2.rows) {
        for col in ["model", "backend", "target", "status", "invoke_instr", "time_s"] {
            assert_eq!(a.get(col), b.get(col), "col {col} differs");
        }
    }
}

#[test]
fn memory_gate_failures_become_missing_rows() {
    let Some(env) = repo_env("gates") else { return };
    let s = Session::new(&env).unwrap();
    // vww on esp32: must fail the flash gate (Table V "—")
    let m = RunMatrix::new()
        .models(["vww"])
        .backends(["tvmaot"])
        .targets(["esp32"]);
    let report = s.run_matrix(&m, 1).unwrap();
    let row = &report.rows[0];
    assert!(row["status"].render().starts_with("failed:"));
    assert_eq!(row["time_s"], Cell::Missing);
}

#[test]
fn esp32_tuned_runs_fail_as_in_table5() {
    let Some(env) = repo_env("tunegate") else { return };
    let env = env.with_overrides(&["tune.trials=5".into()]).unwrap();
    let s = Session::new(&env).unwrap();
    let m = RunMatrix::new()
        .models(["toycar"])
        .backends(["tvmaot"])
        .targets(["esp32"])
        .schedules(["arm-nhwc"])
        .with_tuning_sweep();
    let report = s.run_matrix(&m, 1).unwrap();
    assert_eq!(report.len(), 2);
    let untuned = &report.rows[0];
    let tuned = &report.rows[1];
    assert_eq!(untuned["status"].render(), "ok");
    assert_eq!(tuned["status"].render(), "failed:tune");
}

#[test]
fn table4_campaign_all_green_on_etiss() {
    let Some(env) = repo_env("t4") else { return };
    let s = Session::new(&env).unwrap();
    let m = RunMatrix::new()
        .models(["aww", "vww", "resnet", "toycar"])
        .backends(["tflmi", "tflmc", "tvmaot", "tvmaot+", "tvmrt"])
        .targets(["etiss"]);
    let report = s.run_matrix(&m, 2).unwrap();
    assert_eq!(report.len(), 20);
    for row in &report.rows {
        assert_eq!(
            row["status"].render(),
            "ok",
            "{}/{} failed",
            row["model"].render(),
            row["backend"].render()
        );
    }
}
