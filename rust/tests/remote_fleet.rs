//! Distributed-fleet conformance harness for the remote transport
//! (`session/transport.rs` + the `--connect` dispatch path): a matrix
//! executed by real `mlonmcu worker --connect` child processes — each
//! with its **own** fresh MLONMCU_HOME, exchanging artifacts and tasks
//! only through a serve daemon — must produce a report byte-identical
//! to a plain serial run, failures included. Worker homes never see
//! the model file (it travels through the server's blob pool), and a
//! parent with zero connected workers must still complete the matrix
//! by draining the served queue itself.

use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::Arc;

use mlonmcu::config::Environment;
use mlonmcu::frontends::tmodel;
use mlonmcu::graph::{Graph, OpNode, TensorInfo};
use mlonmcu::graph::{OpCode, ACT_RELU, PAD_SAME};
use mlonmcu::session::transport::Server;
use mlonmcu::session::{EnvStore, RunMatrix, RunOptions, Session};
use mlonmcu::tensor::DType;

/// Same tiny conv graph as tests/dispatch_equivalence.rs — small
/// enough for every hardware target's memory gates.
fn tiny_conv_graph() -> Graph {
    let mut attrs = std::collections::BTreeMap::new();
    attrs.insert("stride_h".to_string(), 1);
    attrs.insert("stride_w".to_string(), 1);
    attrs.insert("padding".to_string(), PAD_SAME);
    attrs.insert("fused_act".to_string(), ACT_RELU);
    Graph {
        name: "tinyconv".into(),
        tensors: vec![
            TensorInfo {
                name: "input".into(),
                shape: vec![1, 4, 4, 2],
                dtype: DType::I8,
                scale: 0.5,
                zero_point: 0,
                data: None,
            },
            TensorInfo {
                name: "w".into(),
                shape: vec![3, 3, 3, 2],
                dtype: DType::I8,
                scale: 0.01,
                zero_point: 0,
                data: Some((0..54).map(|x| (x % 7) as u8).collect()),
            },
            TensorInfo {
                name: "b".into(),
                shape: vec![3],
                dtype: DType::I32,
                scale: 0.005,
                zero_point: 0,
                data: Some(vec![0; 12]),
            },
            TensorInfo {
                name: "out".into(),
                shape: vec![1, 4, 4, 3],
                dtype: DType::I8,
                scale: 0.25,
                zero_point: -128,
                data: None,
            },
        ],
        ops: vec![OpNode {
            opcode: OpCode::Conv2D,
            name: "conv0".into(),
            inputs: vec![0, 1, 2],
            outputs: vec![3],
            attrs,
        }],
        inputs: vec![0],
        outputs: vec![3],
    }
}

/// Fresh environment with the generated model in place. `extra`
/// appends overrides (remote.connect, tuning knobs).
fn fresh_env(tag: &str, extra: &[String]) -> (Environment, PathBuf) {
    let dir = std::env::temp_dir().join(format!("mlonmcu_remotefleet_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    let env = Environment::init(&dir).unwrap();
    tmodel::write_file(
        &tiny_conv_graph(),
        &dir.join("artifacts/models/tinyconv.tmodel"),
    )
    .unwrap();
    let mut overrides = vec![
        // identical budget across envs so keys and outcomes agree
        "tune.trials=8".to_string(),
        "dispatch.lease_ms=400".to_string(),
    ];
    overrides.extend_from_slice(extra);
    (env.with_overrides(&overrides).unwrap(), dir)
}

/// A fresh, *model-less* home for one remote worker: workers must get
/// model bytes from the server's blob pool, never from their own disk.
fn worker_home(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mlonmcu_remotefleet_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    Environment::init(&dir).unwrap();
    dir
}

/// Serve-side store in its own directory (the machine that would run
/// `mlonmcu serve`).
fn spawn_server(tag: &str) -> (mlonmcu::session::transport::ServerHandle, PathBuf) {
    let dir = std::env::temp_dir().join(format!("mlonmcu_remotefleet_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let store = Arc::new(EnvStore::open(&dir, 512 << 20).unwrap());
    let handle = Server::spawn(store, "127.0.0.1:0").unwrap();
    (handle, dir)
}

fn spawn_remote_worker(addr: &str, home: &std::path::Path) -> Child {
    Command::new(env!("CARGO_BIN_EXE_mlonmcu"))
        .arg("worker")
        .arg("--connect")
        .arg(addr)
        .arg("--home")
        .arg(home)
        .stdin(Stdio::null())
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawning remote worker")
}

/// Kills + reaps the fleet even when an assertion panics.
struct Fleet(Vec<Child>);

impl Drop for Fleet {
    fn drop(&mut self) {
        for c in &mut self.0 {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

fn full_matrix() -> RunMatrix {
    RunMatrix::new()
        .models(["tinyconv"])
        .backends(["tflmi", "tflmc", "tvmaot", "tvmaot+", "tvmrt"])
        .targets(["etiss", "esp32"])
        .schedules(["default-nchw", "arm-nhwc"])
        .with_tuning_sweep()
}

fn dedup_matrix() -> RunMatrix {
    RunMatrix::new()
        .models(["tinyconv"])
        .backends(["tflmi", "tvmaot"])
        .targets(["etiss", "esp32c3", "stm32f4", "stm32f7", "esp32"])
}

fn opts(workers: usize) -> RunOptions {
    RunOptions { parallel: 2, use_cache: true, workers }
}

#[test]
fn remote_fleet_report_is_byte_identical_to_serial() {
    // serial baseline: no remote anywhere
    let (env_s, dir_s) = fresh_env("serial", &[]);
    let serial = Session::new(&env_s).unwrap();
    let baseline = serial.run_matrix_opts(&full_matrix(), opts(0)).unwrap();
    let baseline_t = *serial.last_timing.lock().unwrap();
    assert!(baseline
        .rows
        .iter()
        .any(|r| r["status"].render().starts_with("failed:tune")));

    // the fleet: a serve daemon plus 4 workers, each in its own home
    let (server, server_dir) = spawn_server("srv");
    let addr = server.addr.to_string();
    let homes: Vec<PathBuf> =
        (0..4).map(|i| worker_home(&format!("wh{i}"))).collect();
    let fleet =
        Fleet(homes.iter().map(|h| spawn_remote_worker(&addr, h)).collect());

    // dispatching parent in its own fresh home — traced: worker spans
    // must flow back over the wire without costing report equivalence
    let trace_file =
        std::env::temp_dir().join("mlonmcu_remotefleet_trace.json");
    let _ = std::fs::remove_file(&trace_file);
    let (env_p, dir_p) = fresh_env(
        "parent",
        &[
            format!("remote.connect={addr}"),
            format!("trace.file={}", trace_file.display()),
        ],
    );
    let parent = Session::new(&env_p).unwrap();
    let report = parent.run_matrix_opts(&full_matrix(), opts(4)).unwrap();

    assert_eq!(
        baseline.to_csv(),
        report.to_csv(),
        "remote-fleet CSV differs from serial"
    );
    assert_eq!(
        baseline.to_markdown(),
        report.to_markdown(),
        "remote-fleet markdown (rows + counter note) differs from serial"
    );
    let t = *parent.last_timing.lock().unwrap();
    assert_eq!(t.stage_execs, baseline_t.stage_execs);
    assert_eq!(t.cache_hits, baseline_t.cache_hits);
    assert_eq!(t.cache_misses, baseline_t.cache_misses);
    assert_eq!(t.disk_misses, baseline_t.disk_misses);

    // the remote workers shipped their spans back through the serve
    // daemon: the exported timeline must carry stage spans from pids
    // other than the parent's (workers are separate processes)
    assert!(t.trace_spans > 0, "no spans exported");
    let spans = mlonmcu::util::trace::read_spans(&trace_file).unwrap();
    assert_eq!(spans.len(), t.trace_spans);
    let parent_pid = std::process::id();
    let worker_pids: std::collections::BTreeSet<u32> = spans
        .iter()
        .filter(|s| s.cat == "stage" && s.pid != parent_pid)
        .map(|s| s.pid)
        .collect();
    assert!(
        !worker_pids.is_empty(),
        "no remote-worker stage spans made it back over the wire"
    );
    let _ = std::fs::remove_file(&trace_file);

    // cold dedup run through the fleet seeds the server with the
    // dedup matrix's load + both builds...
    let (env_c, dir_c) = fresh_env("cold2", &[format!("remote.connect={addr}")]);
    let cold2 = Session::new(&env_c).unwrap();
    cold2.run_matrix_opts(&dedup_matrix(), opts(4)).unwrap();

    // ...so a warm rerun from ANOTHER fresh parent home is served
    // entirely by the fleet/server — nothing executes anywhere
    let (env_w, dir_w) = fresh_env("warm", &[format!("remote.connect={addr}")]);
    let warm = Session::new(&env_w).unwrap();
    let warm_report = warm.run_matrix_opts(&dedup_matrix(), opts(4)).unwrap();
    let wt = *warm.last_timing.lock().unwrap();
    assert_eq!(wt.stage_execs, Default::default(), "0 executed stages");
    assert_eq!(wt.cache_misses, 0);
    assert!(
        wt.remote_hits >= 3,
        "the parent's tail pass must fetch load+2 builds through the \
         remote tier (got {})",
        wt.remote_hits
    );
    for row in &warm_report.rows {
        assert_eq!(row["cached_stages"].render(), "load+build");
    }

    drop(fleet);
    server.shutdown();
    for d in homes {
        let _ = std::fs::remove_dir_all(d);
    }
    for d in [dir_s, dir_p, dir_c, dir_w, server_dir] {
        let _ = std::fs::remove_dir_all(d);
    }
}

#[test]
fn parent_alone_drains_served_queue_without_any_workers() {
    let (server, server_dir) = spawn_server("alone_srv");
    let addr = server.addr.to_string();
    let (env, dir) = fresh_env("alone", &[format!("remote.connect={addr}")]);
    let session = Session::new(&env).unwrap();
    // workers requested, none ever connect: the parent must claim and
    // execute every served task itself
    let report = session.run_matrix_opts(&dedup_matrix(), opts(4)).unwrap();
    assert_eq!(report.len(), 10);
    let t = *session.last_timing.lock().unwrap();
    assert_eq!(t.stage_execs.builds, 2);
    assert_eq!(t.stage_execs.loads, 1);
    assert_eq!(t.worker_procs, 0, "no remote worker ever connected");
    for row in &report.rows {
        assert_eq!(row["status"].render(), "ok");
    }
    server.shutdown();
    let _ = std::fs::remove_dir_all(dir);
    let _ = std::fs::remove_dir_all(server_dir);
}

#[test]
fn serial_runs_share_artifacts_through_the_remote_tier() {
    let (server, server_dir) = spawn_server("tier_srv");
    let addr = server.addr.to_string();

    // first home computes everything and replicates it to the server
    let (env_a, dir_a) = fresh_env("tier_a", &[format!("remote.connect={addr}")]);
    let a = Session::new(&env_a).unwrap();
    a.run_matrix_opts(&dedup_matrix(), opts(0)).unwrap();
    let at = *a.last_timing.lock().unwrap();
    assert_eq!(at.stage_execs.builds, 2);
    assert_eq!(at.remote_misses, 3, "cold lookups fall through to remote");

    // a second, fresh home executes nothing: local store misses, the
    // remote tier serves load + both builds
    let (env_b, dir_b) = fresh_env("tier_b", &[format!("remote.connect={addr}")]);
    let b = Session::new(&env_b).unwrap();
    let report = b.run_matrix_opts(&dedup_matrix(), opts(0)).unwrap();
    let bt = *b.last_timing.lock().unwrap();
    assert_eq!(bt.stage_execs, Default::default());
    assert_eq!(bt.remote_hits, 3);
    assert_eq!(bt.cache_misses, 0);
    assert!(
        report
            .notes
            .iter()
            .any(|n| n.contains("remote store: 3 hit(s)")),
        "in-process runs must note the remote tier: {:?}",
        report.notes
    );
    server.shutdown();
    for d in [dir_a, dir_b, server_dir] {
        let _ = std::fs::remove_dir_all(d);
    }
}
