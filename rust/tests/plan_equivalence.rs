//! Plan-vs-interpreter equivalence: `ExecPlan` (compile-once hot
//! path) must produce bit-identical outputs and identical `ExecStats`
//! versus the reference interpreter (`mcu::execute`) for every
//! schedule family/layout, for i16-legalized programs, across
//! repeated `run` calls on one plan (stale-scratch regression), and
//! for arbitrary random data-movement programs (prop framework).

use mlonmcu::backends::builder::{lower, LowerOpts};
use mlonmcu::backends::planner::{plan, PlannerKind};
use mlonmcu::graph::model::testutil::{tiny_conv, tiny_mlp};
use mlonmcu::isa;
use mlonmcu::kernels::{self, KernelLib};
use mlonmcu::mcu::{execute, ExecOpts, ExecPlan, McuSpec, MemSystem};
use mlonmcu::prop::{check, no_shrink, Config};
use mlonmcu::schedules::{Family, Layout, Schedule};
use mlonmcu::tensor::DType;
use mlonmcu::tinyir::*;
use mlonmcu::util::XorShift64;

fn etiss_spec() -> McuSpec {
    McuSpec {
        name: "etiss",
        isa: &isa::RV32GC,
        clock_mhz: 100.0,
        flash_total: u64::MAX / 2,
        flash_reserved: 0,
        ram_total: u64::MAX / 2,
        ram_reserved: 0,
        memsys: MemSystem::ideal(),
    }
}

/// All five lowerings of a graph: TFLM reference + the four Table V
/// schedule families/layouts (x86 ones i16-legalized).
fn lowerings(g: &mlonmcu::graph::Graph) -> Vec<(String, Program)> {
    let mut out = Vec::new();
    let mut lowered = |label: &str, lib, legalize, planner| {
        let mut p = lower(
            g,
            label,
            LowerOpts { lib, legalize_i16: legalize, transform_input: legalize },
        )
        .unwrap();
        plan(&mut p, planner);
        out.push((label.to_string(), p));
    };
    lowered("tflm", KernelLib::TflmRef, false, PlannerKind::GreedyArena);
    for (fam, lay, planner) in [
        (Family::DefaultX86, Layout::Nhwc, PlannerKind::StorageTokens),
        (Family::DefaultX86, Layout::Nchw, PlannerKind::UsmpInterval),
        (Family::Arm, Layout::Nhwc, PlannerKind::GreedyArena),
        (Family::Arm, Layout::Nchw, PlannerKind::StorageTokens),
    ] {
        let s = Schedule::new(fam, lay);
        lowered(
            &format!("{fam:?}-{lay:?}"),
            KernelLib::Tvm(s),
            s.legalizes_to_i16(),
            planner,
        );
    }
    out
}

fn assert_equivalent(label: &str, p: &Program, input: &[i8]) {
    let spec = etiss_spec();
    let (ref_out, ref_stats) =
        execute(p, &spec, input, ExecOpts::default()).unwrap();
    let exec_plan = ExecPlan::compile(p, &spec).unwrap();
    let (out, stats) = exec_plan.run(p, input).unwrap();
    assert_eq!(out, ref_out, "{label}: outputs diverged");
    assert_eq!(stats, ref_stats, "{label}: stats diverged");
    // cost-only accounting is the same pre-summed struct
    let (empty, dry) =
        execute(p, &spec, input, ExecOpts { compute: false }).unwrap();
    assert!(empty.is_empty());
    assert_eq!(exec_plan.stats(), dry, "{label}: cost-only stats diverged");
}

#[test]
fn plan_matches_interpreter_across_schedules() {
    let g = tiny_conv();
    let input: Vec<i8> = (0..32).map(|x| (x as i8).wrapping_mul(23)).collect();
    for (label, p) in lowerings(&g) {
        assert_equivalent(&label, &p, &input);
    }
}

#[test]
fn plan_matches_interpreter_on_multi_op_model() {
    let g = tiny_mlp();
    let n = 8 * 8 * 2;
    let input: Vec<i8> =
        (0..n).map(|x| ((x * 37 + 5) % 256) as i8).collect();
    for (label, p) in lowerings(&g) {
        assert_equivalent(&label, &p, &input);
    }
}

#[test]
fn repeated_runs_have_no_stale_scratch() {
    let g = tiny_mlp();
    let spec = etiss_spec();
    let (label, p) = lowerings(&g).remove(2); // x86-nchw, legalized
    let exec_plan = ExecPlan::compile(&p, &spec).unwrap();
    let n = 8 * 8 * 2;
    for round in 0u8..4 {
        let input: Vec<i8> = (0..n)
            .map(|x| ((x * 13 + round as usize * 91) % 256) as i8)
            .collect();
        let (ref_out, _) =
            execute(&p, &spec, &input, ExecOpts::default()).unwrap();
        let (out, _) = exec_plan.run(&p, &input).unwrap();
        assert_eq!(out, ref_out, "{label}: round {round} diverged");
    }
}

// --------------------------------------------- hand-built programs --

fn buf(name: &str, elems: usize, dtype: DType) -> BufferDecl {
    BufferDecl {
        name: name.into(),
        size: elems * dtype.size(),
        dtype,
        offset: None,
        first_use: 0,
        last_use: 0,
    }
}

fn finish(mut p: Program) -> Program {
    p.recompute_lifetimes();
    plan(&mut p, PlannerKind::GreedyArena);
    p
}

/// AvgPool feeding a (self-)Add with a fused ReLU and an i16 output.
fn avgpool_add_program() -> Program {
    finish(Program {
        name: "pool_add".into(),
        buffers: vec![
            buf("in", 32, DType::I8),
            buf("pool", 8, DType::I8),
            buf("add", 8, DType::I16),
        ],
        consts: vec![],
        calls: vec![
            KernelCall {
                kind: KernelKind::AvgPool2D {
                    ih: 4,
                    iw: 4,
                    c: 2,
                    oh: 2,
                    ow: 2,
                    fh: 2,
                    fw: 2,
                    stride: (2, 2),
                },
                inputs: vec![Operand::Buf(0)],
                consts: vec![],
                output: 1,
                cost: kernels::pool_cost(32, 8),
                origin: "pool".into(),
            },
            KernelCall {
                kind: KernelKind::Add {
                    elems: 8,
                    s_a: 0.3,
                    zp_a: -2,
                    s_b: 0.3,
                    zp_b: -2,
                    s_o: 0.5,
                    zp_o: 3,
                    act: 1,
                },
                inputs: vec![Operand::Buf(1), Operand::Buf(1)],
                consts: vec![],
                output: 2,
                cost: kernels::add_cost(8),
                origin: "add".into(),
            },
        ],
        input: 0,
        output: 2,
        arena_size: 0,
        workspace_size: 0,
    })
}

/// A lone depthwise conv with SAME padding and nonzero zero-points.
fn dwconv_program() -> Program {
    finish(Program {
        name: "dw".into(),
        buffers: vec![buf("in", 32, DType::I8), buf("out", 32, DType::I8)],
        consts: vec![
            ConstDecl {
                name: "w".into(),
                data: (0..18u32).map(|x| ((x * 29 + 7) % 255) as u8).collect(),
                dtype: DType::I8,
            },
            ConstDecl {
                name: "b".into(),
                data: [900i32, -450]
                    .iter()
                    .flat_map(|v| v.to_le_bytes())
                    .collect(),
                dtype: DType::I32,
            },
        ],
        calls: vec![KernelCall {
            kind: KernelKind::DwConv2D {
                ih: 4,
                iw: 4,
                c: 2,
                oh: 4,
                ow: 4,
                kh: 3,
                kw: 3,
                stride: (1, 1),
                padding: 0,
                requant: Requant {
                    multiplier: 0.07,
                    zp_in: 1,
                    zp_out: -5,
                    act: 0,
                },
            },
            inputs: vec![Operand::Buf(0)],
            consts: vec![0, 1],
            output: 1,
            cost: kernels::dwconv2d_cost(KernelLib::TflmRef, 4, 4, 2, 3, 3),
            origin: "dw".into(),
        }],
        input: 0,
        output: 1,
        arena_size: 0,
        workspace_size: 0,
    })
}

#[test]
fn plan_matches_interpreter_on_handbuilt_kernels() {
    let input: Vec<i8> = (0..32).map(|x| (x as i8).wrapping_mul(19)).collect();
    assert_equivalent("pool_add", &avgpool_add_program(), &input);
    assert_equivalent("dwconv", &dwconv_program(), &input);
}

// ------------------------------------------------- random programs --

/// Random chains of Copy/Transform/Add/Softmax over mixed-dtype
/// buffers of a common element count, plus a random input vector.
fn random_case(rng: &mut XorShift64) -> (Program, Vec<i8>) {
    let n = rng.range(4, 40);
    let n_calls = rng.range(1, 10);
    let dts = [DType::I8, DType::I16, DType::I32];
    let mut buffers = vec![buf("in", n, DType::I8)];
    let mut calls = Vec::new();
    for i in 0..n_calls {
        let src = rng.range(0, buffers.len() - 1);
        let sdt = buffers[src].dtype;
        let dt = *rng.choose(&dts);
        buffers.push(buf(&format!("b{i}"), n, dt));
        let dst = buffers.len() - 1;
        let (kind, inputs, cost) = match rng.range(0, 2) {
            0 if sdt == dt => (
                KernelKind::Copy { elems: n },
                vec![Operand::Buf(src)],
                kernels::copy_cost(n as u64),
            ),
            0 => (
                KernelKind::Transform { elems: n, widen: dt.size() > sdt.size() },
                vec![Operand::Buf(src)],
                kernels::transform_cost(n as u64),
            ),
            1 => {
                let src2 = rng.range(0, buffers.len() - 2);
                (
                    KernelKind::Add {
                        elems: n,
                        s_a: 0.25 + rng.f64(),
                        zp_a: rng.range(0, 8) as i32 - 4,
                        s_b: 0.25 + rng.f64(),
                        zp_b: rng.range(0, 8) as i32 - 4,
                        s_o: 0.25 + rng.f64(),
                        zp_o: rng.range(0, 8) as i32 - 4,
                        act: rng.range(0, 1) as i64,
                    },
                    vec![Operand::Buf(src), Operand::Buf(src2)],
                    kernels::add_cost(n as u64),
                )
            }
            _ => (
                KernelKind::Softmax {
                    elems: n,
                    s_in: 0.05 + rng.f64() * 0.2,
                    zp_in: rng.range(0, 8) as i32 - 4,
                },
                vec![Operand::Buf(src)],
                kernels::softmax_cost(n as u64),
            ),
        };
        calls.push(KernelCall {
            kind,
            inputs,
            consts: vec![],
            output: dst,
            cost,
            origin: format!("c{i}"),
        });
    }
    let out = buffers.len() - 1;
    let p = finish(Program {
        name: "prop".into(),
        buffers,
        consts: vec![],
        calls,
        input: 0,
        output: out,
        arena_size: 0,
        workspace_size: 0,
    });
    let input: Vec<i8> = (0..n).map(|_| (rng.next_u64() & 0xff) as i8).collect();
    (p, input)
}

#[test]
fn random_programs_agree_with_interpreter() {
    check(
        Config { cases: 80, seed: 0x91A4 },
        random_case,
        no_shrink,
        |(p, input)| {
            let spec = etiss_spec();
            let (ref_out, ref_stats) =
                execute(p, &spec, input, ExecOpts::default()).unwrap();
            let exec_plan = ExecPlan::compile(p, &spec).unwrap();
            // two runs on one plan: both must match (scratch reuse)
            let (a, sa) = exec_plan.run(p, input).unwrap();
            let (b, sb) = exec_plan.run(p, input).unwrap();
            a == ref_out && b == ref_out && sa == ref_stats && sb == ref_stats
        },
    );
}
