//! Cross-language golden validation (DESIGN.md experiment V1): the
//! virtual MCU's int8 outputs must match the JAX/Pallas golden path —
//! both via the pre-dumped golden JSON vectors and via live PJRT
//! execution of the AOT-lowered HLO. Requires `make artifacts`.

use std::path::PathBuf;

use mlonmcu::backends::{by_name, BackendConfig};
use mlonmcu::features::{compare_outputs, Validation};
use mlonmcu::frontends::load_model;
use mlonmcu::runtime::GoldenRuntime;
use mlonmcu::targets;

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("models/aww.tmodel").is_file() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

fn device_output(model: &str, input: &[i8], backend: &str) -> Vec<i8> {
    let dir = artifacts().unwrap();
    let g = load_model(model, &[dir.join("models")]).unwrap();
    let b = by_name(backend).unwrap();
    let build = b.build(&g, &BackendConfig::default()).unwrap();
    let t = targets::by_name("etiss").unwrap();
    let dep = t.deploy(&build, b.framework()).unwrap();
    t.run(&build, &dep, input, true).unwrap().output
}

#[test]
fn mcu_outputs_match_dumped_goldens_all_models_all_backends() {
    let Some(dir) = artifacts() else { return };
    for model in ["aww", "resnet", "toycar"] {
        let path = dir.join("golden").join(format!("{model}.json"));
        let j = mlonmcu::data::Json::parse_file(&path).unwrap();
        let input: Vec<i8> = j
            .get("input")
            .unwrap()
            .as_i64_vec()
            .unwrap()
            .into_iter()
            .map(|x| x as i8)
            .collect();
        let golden: Vec<i8> = j
            .get("output")
            .unwrap()
            .as_i64_vec()
            .unwrap()
            .into_iter()
            .map(|x| x as i8)
            .collect();
        for backend in ["tflmi", "tflmc", "tvmaot", "tvmaot+", "tvmrt"] {
            let out = device_output(model, &input, backend);
            match compare_outputs(&out, &golden, 1) {
                Validation::Pass { max_diff } => {
                    assert!(max_diff <= 1, "{model}/{backend}: diff {max_diff}");
                }
                v => panic!("{model}/{backend}: validation failed: {v:?}"),
            }
        }
    }
}

#[test]
fn pjrt_golden_matches_dumped_golden() {
    let Some(dir) = artifacts() else { return };
    let rt = match GoldenRuntime::new(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping: PJRT unavailable: {e}");
            return;
        }
    };
    for model in ["toycar", "aww"] {
        let (input, golden, shape) = rt.load_golden_json(model).unwrap();
        let out = rt.run_golden(model, &input, &shape).unwrap();
        assert_eq!(
            out, golden,
            "{model}: PJRT execution disagrees with aot.py dump"
        );
    }
}

#[test]
fn live_pjrt_vs_virtual_mcu_fresh_input() {
    let Some(dir) = artifacts() else { return };
    let rt = match GoldenRuntime::new(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping: PJRT unavailable: {e}");
            return;
        }
    };
    // an input the python side never saw: full cross-language check
    let g = load_model("toycar", &[dir.join("models")]).unwrap();
    let shape = g.tensor(g.inputs[0]).shape.clone();
    let n: usize = shape.iter().product();
    let input: Vec<i8> = (0..n).map(|i| ((i * 37 + 11) % 251) as u8 as i8).collect();
    let golden = rt.run_golden("toycar", &input, &shape).unwrap();
    let device = device_output("toycar", &input, "tvmaot");
    match compare_outputs(&device, &golden, 1) {
        Validation::Pass { .. } => {}
        v => panic!("fresh-input validation failed: {v:?}"),
    }
}
