//! Cross-language golden validation (DESIGN.md experiment V1): the
//! virtual MCU's int8 outputs must match the JAX/Pallas golden path —
//! both via the pre-dumped golden JSON vectors and via live PJRT
//! execution of the AOT-lowered HLO. Requires `make artifacts`.

use std::path::PathBuf;

use mlonmcu::backends::{by_name, BackendConfig};
use mlonmcu::config::Environment;
use mlonmcu::features::{compare_outputs, Validation};
use mlonmcu::frontends::load_model;
use mlonmcu::runtime::GoldenRuntime;
use mlonmcu::session::{RunMatrix, RunOptions, Session};
use mlonmcu::targets;

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("models/aww.tmodel").is_file() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

fn device_output(model: &str, input: &[i8], backend: &str) -> Vec<i8> {
    let dir = artifacts().unwrap();
    let g = load_model(model, &[dir.join("models")]).unwrap();
    let b = by_name(backend).unwrap();
    let build = b.build(&g, &BackendConfig::default()).unwrap();
    let t = targets::by_name("etiss").unwrap();
    let dep = t.deploy(&build, b.framework()).unwrap();
    t.run(&build, &dep, input, true).unwrap().output
}

#[test]
fn mcu_outputs_match_dumped_goldens_all_models_all_backends() {
    let Some(dir) = artifacts() else { return };
    for model in ["aww", "resnet", "toycar"] {
        let path = dir.join("golden").join(format!("{model}.json"));
        let j = mlonmcu::data::Json::parse_file(&path).unwrap();
        let input: Vec<i8> = j
            .get("input")
            .unwrap()
            .as_i64_vec()
            .unwrap()
            .into_iter()
            .map(|x| x as i8)
            .collect();
        let golden: Vec<i8> = j
            .get("output")
            .unwrap()
            .as_i64_vec()
            .unwrap()
            .into_iter()
            .map(|x| x as i8)
            .collect();
        for backend in ["tflmi", "tflmc", "tvmaot", "tvmaot+", "tvmrt"] {
            let out = device_output(model, &input, backend);
            match compare_outputs(&out, &golden, 1) {
                Validation::Pass { max_diff } => {
                    assert!(max_diff <= 1, "{model}/{backend}: diff {max_diff}");
                }
                v => panic!("{model}/{backend}: validation failed: {v:?}"),
            }
        }
    }
}

#[test]
fn pjrt_golden_matches_dumped_golden() {
    let Some(dir) = artifacts() else { return };
    let rt = match GoldenRuntime::new(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping: PJRT unavailable: {e}");
            return;
        }
    };
    for model in ["toycar", "aww"] {
        let (input, golden, shape) = rt.load_golden_json(model).unwrap();
        let out = rt.run_golden(model, &input, &shape).unwrap();
        assert_eq!(
            out, golden,
            "{model}: PJRT execution disagrees with aot.py dump"
        );
    }
}

/// The full-matrix run of the real zoo models under the sharded
/// multi-process executor must render the exact same report bytes as
/// the serial baseline — the golden-artifact variant of
/// tests/dispatch_equivalence.rs.
#[test]
fn sharded_executor_report_matches_serial_on_real_models() {
    let Some(artifacts) = artifacts() else { return };
    let models_dir = artifacts.join("models");
    let make_env = |tag: &str| {
        let root = std::env::temp_dir().join(format!("mlonmcu_golden_shard_{tag}"));
        let _ = std::fs::remove_dir_all(&root);
        let env = Environment::init(&root).unwrap();
        let env = env
            .with_overrides(&[
                format!("paths.models={}", models_dir.display()),
                format!("dispatch.worker_bin={}", env!("CARGO_BIN_EXE_mlonmcu")),
            ])
            .unwrap();
        (env, root)
    };
    let matrix = RunMatrix::new()
        .models(["aww", "toycar"])
        .backends(["tflmi", "tflmc", "tvmaot", "tvmaot+", "tvmrt"])
        .targets(["etiss"]);

    let (env_s, dir_s) = make_env("serial");
    let baseline = Session::new(&env_s)
        .unwrap()
        .run_matrix_opts(&matrix, RunOptions { parallel: 2, use_cache: true, workers: 0 })
        .unwrap();
    for row in &baseline.rows {
        assert_eq!(row["status"].render(), "ok", "baseline run failed");
    }

    let (env_w, dir_w) = make_env("workers");
    let sharded = Session::new(&env_w)
        .unwrap()
        .run_matrix_opts(&matrix, RunOptions { parallel: 2, use_cache: true, workers: 4 })
        .unwrap();
    assert_eq!(baseline.to_csv(), sharded.to_csv());
    assert_eq!(baseline.to_markdown(), sharded.to_markdown());

    std::fs::remove_dir_all(dir_s).unwrap();
    std::fs::remove_dir_all(dir_w).unwrap();
}

#[test]
fn live_pjrt_vs_virtual_mcu_fresh_input() {
    let Some(dir) = artifacts() else { return };
    let rt = match GoldenRuntime::new(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping: PJRT unavailable: {e}");
            return;
        }
    };
    // an input the python side never saw: full cross-language check
    let g = load_model("toycar", &[dir.join("models")]).unwrap();
    let shape = g.tensor(g.inputs[0]).shape.clone();
    let n: usize = shape.iter().product();
    let input: Vec<i8> = (0..n).map(|i| ((i * 37 + 11) % 251) as u8 as i8).collect();
    let golden = rt.run_golden("toycar", &input, &shape).unwrap();
    let device = device_output("toycar", &input, "tvmaot");
    match compare_outputs(&device, &golden, 1) {
        Validation::Pass { .. } => {}
        v => panic!("fresh-input validation failed: {v:?}"),
    }
}
