//! # mlonmcu — TinyML benchmarking with fast retargeting
//!
//! A from-scratch reproduction of *"MLonMCU: TinyML Benchmarking with
//! Fast Retargeting"* (van Kempen et al., 2023) as a three-layer
//! rust + JAX + Pallas stack. This crate is Layer 3: the benchmarking
//! coordinator — session/run flow, backends, targets, platforms,
//! features, postprocesses and reports — plus every substrate the
//! paper's evaluation depends on (virtual MCUs, an instruction-set
//! simulator, TFLM/TVM-like code generators, an AutoTVM-like tuner).
//!
//! See DESIGN.md for the system inventory and the experiment index
//! mapping each paper table/figure to a module and bench target.
//!
//! ## Quick tour
//!
//! ```no_run
//! use mlonmcu::prelude::*;
//!
//! let env = Environment::discover().unwrap();
//! let mut session = Session::new(&env).unwrap();
//! let matrix = RunMatrix::new()
//!     .models(["aww"])
//!     .backends(["tvmaot"])
//!     .targets(["etiss"]);
//! let report = session.run_matrix(&matrix, 1).unwrap();
//! println!("{}", report.to_markdown());
//! ```

pub mod util;
pub mod data;
pub mod tensor;
pub mod graph;
pub mod frontends;
pub mod tinyir;
pub mod kernels;
pub mod schedules;
pub mod backends;
pub mod calib;
pub mod isa;
pub mod mcu;
pub mod platform;
pub mod targets;
pub mod tuner;
pub mod runtime;
pub mod features;
pub mod session;
pub mod postprocess;
pub mod report;
pub mod config;
pub mod cli;
pub mod prop;

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::backends::{Backend, BuildResult};
    pub use crate::config::Environment;
    pub use crate::frontends::load_model;
    pub use crate::graph::Graph;
    pub use crate::report::Report;
    pub use crate::session::{RunMatrix, Session};
    pub use crate::targets::Target;
}
