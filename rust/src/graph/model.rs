//! The model graph: tensors + ops, with validation and the size/MAC
//! accounting that drives Table I and the cost models.

use anyhow::{bail, ensure, Result};

use crate::tensor::{numel, DType};

use super::op::{OpCode, OpNode};

/// One tensor: quantization params and (for weights) constant data.
#[derive(Debug, Clone)]
pub struct TensorInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
    pub scale: f32,
    pub zero_point: i32,
    /// Raw little-endian constant data; `None` for activations.
    pub data: Option<Vec<u8>>,
}

impl TensorInfo {
    pub fn numel(&self) -> usize {
        numel(&self.shape)
    }

    pub fn nbytes(&self) -> usize {
        self.numel() * self.dtype.size()
    }

    pub fn is_const(&self) -> bool {
        self.data.is_some()
    }

    /// Constant data as i8 (weights).
    pub fn data_i8(&self) -> Result<&[i8]> {
        ensure!(self.dtype == DType::I8, "{}: not i8", self.name);
        let d = self.data.as_ref().ok_or_else(|| {
            anyhow::anyhow!("{}: no constant data", self.name)
        })?;
        // i8 and u8 have identical layout
        Ok(unsafe { std::slice::from_raw_parts(d.as_ptr() as *const i8, d.len()) })
    }

    /// Constant data as i32 (biases).
    pub fn data_i32(&self) -> Result<Vec<i32>> {
        ensure!(self.dtype == DType::I32, "{}: not i32", self.name);
        let d = self.data.as_ref().ok_or_else(|| {
            anyhow::anyhow!("{}: no constant data", self.name)
        })?;
        ensure!(d.len() % 4 == 0);
        Ok(d.chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// A loaded model: the rust-side equivalent of a TFLite flatbuffer.
#[derive(Debug, Clone)]
pub struct Graph {
    pub name: String,
    pub tensors: Vec<TensorInfo>,
    pub ops: Vec<OpNode>,
    pub inputs: Vec<usize>,
    pub outputs: Vec<usize>,
}

impl Graph {
    pub fn tensor(&self, id: usize) -> &TensorInfo {
        &self.tensors[id]
    }

    /// Structural validation: ids in range, topological order, conv-like
    /// ops carry weights+bias, and activations are i8.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.inputs.len() == 1, "exactly one input supported");
        ensure!(self.outputs.len() == 1, "exactly one output supported");
        let n = self.tensors.len();
        let mut produced: Vec<bool> = vec![false; n];
        for &i in &self.inputs {
            ensure!(i < n, "input id {i} out of range");
            produced[i] = true;
        }
        for op in &self.ops {
            for &t in op.inputs.iter().chain(&op.outputs) {
                ensure!(t < n, "op {}: tensor id {t} out of range", op.name);
            }
            for &t in &op.inputs {
                ensure!(
                    self.tensors[t].is_const() || produced[t],
                    "op {}: input {} used before production (not topological)",
                    op.name,
                    self.tensors[t].name
                );
            }
            if op.opcode.is_conv_like() {
                ensure!(
                    op.inputs.len() == 3,
                    "op {}: conv-like needs [input, weights, bias]",
                    op.name
                );
                ensure!(
                    self.tensors[op.inputs[1]].is_const()
                        && self.tensors[op.inputs[2]].is_const(),
                    "op {}: weights/bias must be constant",
                    op.name
                );
                ensure!(
                    self.tensors[op.inputs[2]].dtype == DType::I32,
                    "op {}: bias must be i32",
                    op.name
                );
            }
            for &t in &op.outputs {
                ensure!(
                    !self.tensors[t].is_const(),
                    "op {}: writes to constant {}",
                    op.name,
                    self.tensors[t].name
                );
                produced[t] = true;
            }
        }
        for &o in &self.outputs {
            ensure!(produced[o], "output never produced");
        }
        self.check_shapes()?;
        Ok(())
    }

    /// Shape inference checks: declared output shapes must match what
    /// the op semantics produce (guards against malformed models).
    fn check_shapes(&self) -> Result<()> {
        use crate::tensor::conv_out;
        for op in &self.ops {
            let outs = &self.tensors[op.outputs[0]].shape;
            match op.opcode {
                OpCode::Conv2D => {
                    let x = &self.tensors[op.inputs[0]].shape;
                    let w = &self.tensors[op.inputs[1]].shape;
                    ensure!(x.len() == 4 && w.len() == 4, "{}: rank", op.name);
                    ensure!(w[3] == x[3], "{}: ic mismatch", op.name);
                    let oh = conv_out(x[1], w[1], op.attr("stride_h")? as usize,
                                      op.attr("padding")? as u8);
                    let ow = conv_out(x[2], w[2], op.attr("stride_w")? as usize,
                                      op.attr("padding")? as u8);
                    ensure!(
                        outs == &vec![1, oh, ow, w[0]],
                        "{}: output shape {:?} != expected {:?}",
                        op.name, outs, [1, oh, ow, w[0]]
                    );
                }
                OpCode::DepthwiseConv2D => {
                    let x = &self.tensors[op.inputs[0]].shape;
                    let w = &self.tensors[op.inputs[1]].shape;
                    ensure!(w[0] == 1 && w[3] == x[3], "{}: dw shape", op.name);
                    let oh = conv_out(x[1], w[1], op.attr("stride_h")? as usize,
                                      op.attr("padding")? as u8);
                    let ow = conv_out(x[2], w[2], op.attr("stride_w")? as usize,
                                      op.attr("padding")? as u8);
                    ensure!(outs == &vec![1, oh, ow, x[3]], "{}: out", op.name);
                }
                OpCode::FullyConnected => {
                    let x = &self.tensors[op.inputs[0]].shape;
                    let w = &self.tensors[op.inputs[1]].shape;
                    ensure!(w.len() == 2, "{}: fc weights rank", op.name);
                    ensure!(
                        x.last() == Some(&w[1]),
                        "{}: fc in dim {:?} vs {:?}", op.name, x, w
                    );
                    ensure!(outs.last() == Some(&w[0]), "{}: fc out", op.name);
                }
                OpCode::Add => {
                    let a = &self.tensors[op.inputs[0]].shape;
                    let b = &self.tensors[op.inputs[1]].shape;
                    ensure!(a == b && a == outs, "{}: add shapes", op.name);
                }
                OpCode::Reshape => {
                    let a = numel(&self.tensors[op.inputs[0]].shape);
                    ensure!(a == numel(outs), "{}: reshape numel", op.name);
                }
                OpCode::AvgPool2D | OpCode::MaxPool2D | OpCode::Softmax => {}
            }
        }
        Ok(())
    }

    // -- Table I accounting ------------------------------------------------
    /// Total bytes of constant data — the "quantized size".
    pub fn weight_bytes(&self) -> usize {
        self.tensors
            .iter()
            .filter(|t| t.is_const())
            .map(|t| t.nbytes())
            .sum()
    }

    pub fn param_count(&self) -> usize {
        self.tensors
            .iter()
            .filter(|t| t.is_const())
            .map(|t| t.numel())
            .sum()
    }

    /// Multiply-accumulates per inference — the invoke-cost driver.
    pub fn macs(&self) -> u64 {
        let mut total = 0u64;
        for op in &self.ops {
            total += self.op_macs(op);
        }
        total
    }

    pub fn op_macs(&self, op: &OpNode) -> u64 {
        match op.opcode {
            OpCode::Conv2D => {
                let w = &self.tensors[op.inputs[1]].shape;
                let o = &self.tensors[op.outputs[0]].shape;
                (o[1] * o[2] * w[0] * w[1] * w[2] * w[3]) as u64
            }
            OpCode::DepthwiseConv2D => {
                let w = &self.tensors[op.inputs[1]].shape;
                let o = &self.tensors[op.outputs[0]].shape;
                (o[1] * o[2] * o[3] * w[1] * w[2]) as u64
            }
            OpCode::FullyConnected => {
                numel(&self.tensors[op.inputs[1]].shape) as u64
            }
            _ => 0,
        }
    }

    /// Largest activation tensor in bytes (RAM lower bound).
    pub fn max_activation_bytes(&self) -> usize {
        self.tensors
            .iter()
            .filter(|t| !t.is_const())
            .map(|t| t.nbytes())
            .max()
            .unwrap_or(0)
    }

    /// Ids of all non-constant tensors.
    pub fn activation_ids(&self) -> Vec<usize> {
        (0..self.tensors.len())
            .filter(|&i| !self.tensors[i].is_const())
            .collect()
    }

    /// Stable content hash over everything a backend can observe:
    /// tensors (shape, dtype, quant params, constant data) and ops
    /// (opcode, wiring, attributes). Two graphs with the same hash
    /// build identical programs. Note: the session's cache keys hash
    /// the *model file bytes* (scheduler::model_fingerprint), not this
    /// — this in-memory fingerprint is recorded in the cache's
    /// graph.json metadata and checks serializer round-trips.
    pub fn content_hash(&self) -> u64 {
        let mut h = crate::util::StableHasher::new();
        h.write_str(&self.name);
        for t in &self.tensors {
            h.write_str(&t.name);
            h.write_u64(t.shape.len() as u64);
            for &d in &t.shape {
                h.write_u64(d as u64);
            }
            h.write_u8(t.dtype as u8);
            h.write_f32(t.scale);
            h.write_i64(t.zero_point as i64);
            match &t.data {
                Some(d) => h.write_bool(true).write_bytes(d),
                None => h.write_bool(false),
            };
        }
        for op in &self.ops {
            h.write_str(op.opcode.name());
            h.write_str(&op.name);
            h.write_u64(op.inputs.len() as u64);
            for &i in &op.inputs {
                h.write_u64(i as u64);
            }
            h.write_u64(op.outputs.len() as u64);
            for &o in &op.outputs {
                h.write_u64(o as u64);
            }
            h.write_u64(op.attrs.len() as u64);
            for (k, &v) in &op.attrs {
                h.write_str(k);
                h.write_i64(v);
            }
        }
        h.write_u64(self.inputs.len() as u64);
        for &i in &self.inputs {
            h.write_u64(i as u64);
        }
        h.write_u64(self.outputs.len() as u64);
        for &o in &self.outputs {
            h.write_u64(o as u64);
        }
        h.finish()
    }
}

pub mod testutil {
    //! Tiny hand-built graphs shared by unit tests across the crate,
    //! the integration tests, the `gen_model` example and the CI
    //! jobs that seed environments. Not `#[cfg(test)]`-gated: the
    //! example and `tests/` build the library without that cfg.
    use super::*;
    use crate::graph::op::*;

    /// input[1,4,4,2] -> conv 3ch 3x3 SAME relu -> out[1,4,4,3]
    pub fn tiny_conv() -> Graph {
        let mut attrs = Attrs::new();
        attrs.insert("stride_h".into(), 1);
        attrs.insert("stride_w".into(), 1);
        attrs.insert("padding".into(), PAD_SAME);
        attrs.insert("fused_act".into(), ACT_RELU);
        Graph {
            name: "tiny_conv".into(),
            tensors: vec![
                TensorInfo {
                    name: "input".into(),
                    shape: vec![1, 4, 4, 2],
                    dtype: DType::I8,
                    scale: 0.5,
                    zero_point: 0,
                    data: None,
                },
                TensorInfo {
                    name: "w".into(),
                    shape: vec![3, 3, 3, 2],
                    dtype: DType::I8,
                    scale: 0.01,
                    zero_point: 0,
                    data: Some((0..54).map(|x| (x % 7) as u8).collect()),
                },
                TensorInfo {
                    name: "b".into(),
                    shape: vec![3],
                    dtype: DType::I32,
                    scale: 0.005,
                    zero_point: 0,
                    data: Some(vec![0; 12]),
                },
                TensorInfo {
                    name: "out".into(),
                    shape: vec![1, 4, 4, 3],
                    dtype: DType::I8,
                    scale: 0.25,
                    zero_point: -128,
                    data: None,
                },
            ],
            ops: vec![OpNode {
                opcode: OpCode::Conv2D,
                name: "conv0".into(),
                inputs: vec![0, 1, 2],
                outputs: vec![3],
                attrs,
            }],
            inputs: vec![0],
            outputs: vec![3],
        }
    }

    /// input[1,8,8,2] → conv 4ch 3×3 SAME relu → maxpool 2×2/2 →
    /// reshape → dense 10 → softmax. A deeper pipeline exercising
    /// every non-residual kernel kind end-to-end; also the second
    /// model the CI hotpath bench seeds (`gen_model tinymlp`).
    pub fn tiny_mlp() -> Graph {
        let act = |name: &str, shape: Vec<usize>, scale: f32, zp: i32| TensorInfo {
            name: name.into(),
            shape,
            dtype: DType::I8,
            scale,
            zero_point: zp,
            data: None,
        };
        let conv_w: Vec<u8> = (0..72u32).map(|x| ((x * 5 + 3) % 251) as u8).collect();
        let conv_b: Vec<u8> = [1200i32, -800, 300, 0]
            .iter()
            .flat_map(|v| v.to_le_bytes())
            .collect();
        let fc_w: Vec<u8> = (0..640u32).map(|x| ((x * 7 + 11) % 253) as u8).collect();
        let fc_b: Vec<u8> = [250i32, -125, 60, -30, 15, -8, 4, -2, 1, 0]
            .iter()
            .flat_map(|v| v.to_le_bytes())
            .collect();
        let mut conv_attrs = Attrs::new();
        conv_attrs.insert("stride_h".into(), 1);
        conv_attrs.insert("stride_w".into(), 1);
        conv_attrs.insert("padding".into(), PAD_SAME);
        conv_attrs.insert("fused_act".into(), ACT_RELU);
        let mut pool_attrs = Attrs::new();
        pool_attrs.insert("filter_h".into(), 2);
        pool_attrs.insert("filter_w".into(), 2);
        pool_attrs.insert("stride_h".into(), 2);
        pool_attrs.insert("stride_w".into(), 2);
        Graph {
            name: "tinymlp".into(),
            tensors: vec![
                act("input", vec![1, 8, 8, 2], 0.5, 2),
                TensorInfo {
                    name: "conv.w".into(),
                    shape: vec![4, 3, 3, 2],
                    dtype: DType::I8,
                    scale: 0.02,
                    zero_point: 0,
                    data: Some(conv_w),
                },
                TensorInfo {
                    name: "conv.b".into(),
                    shape: vec![4],
                    dtype: DType::I32,
                    scale: 0.01,
                    zero_point: 0,
                    data: Some(conv_b),
                },
                act("conv.out", vec![1, 8, 8, 4], 0.3, -10),
                act("pool.out", vec![1, 4, 4, 4], 0.3, -10),
                TensorInfo {
                    name: "fc.w".into(),
                    shape: vec![10, 64],
                    dtype: DType::I8,
                    scale: 0.015,
                    zero_point: 0,
                    data: Some(fc_w),
                },
                TensorInfo {
                    name: "fc.b".into(),
                    shape: vec![10],
                    dtype: DType::I32,
                    scale: 0.005,
                    zero_point: 0,
                    data: Some(fc_b),
                },
                act("flat.out", vec![1, 64], 0.3, -10),
                act("fc.out", vec![1, 10], 0.2, 3),
                act("softmax.out", vec![1, 10], 1.0 / 256.0, -128),
            ],
            ops: vec![
                OpNode {
                    opcode: OpCode::Conv2D,
                    name: "conv0".into(),
                    inputs: vec![0, 1, 2],
                    outputs: vec![3],
                    attrs: conv_attrs,
                },
                OpNode {
                    opcode: OpCode::MaxPool2D,
                    name: "pool0".into(),
                    inputs: vec![3],
                    outputs: vec![4],
                    attrs: pool_attrs,
                },
                OpNode {
                    opcode: OpCode::Reshape,
                    name: "flat0".into(),
                    inputs: vec![4],
                    outputs: vec![7],
                    attrs: Attrs::new(),
                },
                OpNode {
                    opcode: OpCode::FullyConnected,
                    name: "fc0".into(),
                    inputs: vec![7, 5, 6],
                    outputs: vec![8],
                    attrs: Attrs::new(),
                },
                OpNode {
                    opcode: OpCode::Softmax,
                    name: "softmax0".into(),
                    inputs: vec![8],
                    outputs: vec![9],
                    attrs: Attrs::new(),
                },
            ],
            inputs: vec![0],
            outputs: vec![9],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::tiny_conv;
    use super::*;

    #[test]
    fn tiny_conv_validates() {
        tiny_conv().validate().unwrap();
    }

    #[test]
    fn tiny_mlp_validates() {
        let g = testutil::tiny_mlp();
        g.validate().unwrap();
        assert_eq!(g.ops.len(), 5);
        assert!(g.macs() > 0);
    }

    #[test]
    fn accounting() {
        let g = tiny_conv();
        assert_eq!(g.param_count(), 54 + 3);
        assert_eq!(g.weight_bytes(), 54 + 12);
        assert_eq!(g.macs(), 4 * 4 * 3 * 3 * 3 * 2);
        assert_eq!(g.max_activation_bytes(), 4 * 4 * 3);
    }

    #[test]
    fn validation_catches_bad_topology() {
        let mut g = tiny_conv();
        g.ops[0].inputs[0] = 3; // op consumes its own output
        assert!(g.validate().is_err());
    }

    #[test]
    fn validation_catches_shape_mismatch() {
        let mut g = tiny_conv();
        g.tensors[3].shape = vec![1, 5, 4, 3];
        assert!(g.validate().is_err());
    }

    #[test]
    fn validation_catches_write_to_const() {
        let mut g = tiny_conv();
        g.ops[0].outputs[0] = 1; // writes to weights
        assert!(g.validate().is_err());
    }
}
