//! Graph operations. The opcode registry mirrors
//! python/compile/tmodel.py — keep the two in sync.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

pub const PAD_SAME: i64 = 0;
pub const PAD_VALID: i64 = 1;
pub const ACT_NONE: i64 = 0;
pub const ACT_RELU: i64 = 1;

/// Supported TinyML graph operations (the MLPerf-Tiny op set).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpCode {
    Conv2D,
    DepthwiseConv2D,
    FullyConnected,
    AvgPool2D,
    MaxPool2D,
    Add,
    Reshape,
    Softmax,
}

impl OpCode {
    pub fn from_u8(x: u8) -> Result<OpCode> {
        Ok(match x {
            0 => OpCode::Conv2D,
            1 => OpCode::DepthwiseConv2D,
            2 => OpCode::FullyConnected,
            3 => OpCode::AvgPool2D,
            4 => OpCode::MaxPool2D,
            5 => OpCode::Add,
            6 => OpCode::Reshape,
            7 => OpCode::Softmax,
            _ => bail!("unknown opcode {x}"),
        })
    }

    /// Inverse of `from_u8` (the on-disk .tmodel tag).
    pub fn to_u8(self) -> u8 {
        match self {
            OpCode::Conv2D => 0,
            OpCode::DepthwiseConv2D => 1,
            OpCode::FullyConnected => 2,
            OpCode::AvgPool2D => 3,
            OpCode::MaxPool2D => 4,
            OpCode::Add => 5,
            OpCode::Reshape => 6,
            OpCode::Softmax => 7,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            OpCode::Conv2D => "CONV_2D",
            OpCode::DepthwiseConv2D => "DEPTHWISE_CONV_2D",
            OpCode::FullyConnected => "FULLY_CONNECTED",
            OpCode::AvgPool2D => "AVG_POOL_2D",
            OpCode::MaxPool2D => "MAX_POOL_2D",
            OpCode::Add => "ADD",
            OpCode::Reshape => "RESHAPE",
            OpCode::Softmax => "SOFTMAX",
        }
    }

    /// Ops that carry weights and dominate compute (Table IV's
    /// invoke-instruction drivers).
    pub fn is_conv_like(self) -> bool {
        matches!(
            self,
            OpCode::Conv2D | OpCode::DepthwiseConv2D | OpCode::FullyConnected
        )
    }
}

/// Integer attribute map (stride_h, padding, fused_act, ...).
pub type Attrs = BTreeMap<String, i64>;

/// One operation node: opcode + tensor ids + attributes.
#[derive(Debug, Clone)]
pub struct OpNode {
    pub opcode: OpCode,
    pub name: String,
    pub inputs: Vec<usize>,
    pub outputs: Vec<usize>,
    pub attrs: Attrs,
}

impl OpNode {
    pub fn attr(&self, key: &str) -> Result<i64> {
        self.attrs
            .get(key)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("op {}: missing attr {key}", self.name))
    }

    pub fn attr_or(&self, key: &str, default: i64) -> i64 {
        self.attrs.get(key).copied().unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opcode_roundtrip() {
        for x in 0..8u8 {
            let op = OpCode::from_u8(x).unwrap();
            assert!(!op.name().is_empty());
        }
        assert!(OpCode::from_u8(42).is_err());
    }

    #[test]
    fn conv_like_classification() {
        assert!(OpCode::Conv2D.is_conv_like());
        assert!(OpCode::FullyConnected.is_conv_like());
        assert!(!OpCode::Softmax.is_conv_like());
        assert!(!OpCode::Add.is_conv_like());
    }
}
