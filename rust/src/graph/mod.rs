//! Model IR: the in-memory graph loaded from `.tmodel` files —
//! quantized tensors plus a topologically-ordered op list. This is the
//! substrate standing in for the TFLite flatbuffer schema.

pub mod op;
pub mod model;

pub use model::{Graph, TensorInfo};
pub use op::{Attrs, OpCode, OpNode, ACT_NONE, ACT_RELU, PAD_SAME, PAD_VALID};
