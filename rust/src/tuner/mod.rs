//! AutoTVM-style tuner (paper §III-C, the Tune stage).
//!
//! For each tunable op class in the model, the tuner enumerates the
//! schedule's knob space and *measures each candidate on the target*
//! — rebuild, deploy, run — exactly MicroTVM's measure loop (the paper
//! notes this needs a flash+run per iteration, which is why tuning is
//! slow and wears out flash on real boards; our virtual targets make
//! it cheap, but the code path is the same). The measured objective is
//! invoke latency; candidates that fail to deploy (workspace blows the
//! RAM budget) are rejected, mirroring AutoTVM's error states.

use anyhow::Result;

use crate::backends::{Backend, BackendConfig, BuildResult};
use crate::graph::Graph;
use crate::schedules::{Knobs, Schedule};
use crate::targets::Target;
use crate::util::XorShift64;

/// Whether a measured trial produced a number or was rejected —
/// AutoTVM's error states, preserved so ablation plots can show them
/// instead of silently conflating rejections with kept trials.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrialStatus {
    /// Measured successfully (kept or not, depending on best-so-far).
    Ok,
    /// Deploy/measure failed (e.g. workspace blows the RAM budget).
    Rejected,
}

/// One entry of the tuning history.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Trial {
    pub index: usize,
    /// Best-so-far seconds *after* this trial (ablation plot y-value).
    pub best_seconds: f64,
    pub status: TrialStatus,
}

/// Outcome of a tuning session for one (model, schedule, target).
#[derive(Debug, Clone)]
pub struct TuneResult {
    pub best: Schedule,
    pub best_seconds: f64,
    pub baseline_seconds: f64,
    pub trials: usize,
    /// Per-trial history (best-so-far + ok/rejected status).
    pub history: Vec<Trial>,
}

impl TuneResult {
    pub fn improvement(&self) -> f64 {
        if self.baseline_seconds > 0.0 {
            1.0 - self.best_seconds / self.baseline_seconds
        } else {
            0.0
        }
    }
}

/// Tuning options.
#[derive(Debug, Clone, Copy)]
pub struct TuneOpts {
    /// Measurement budget (paper: "at least 600 iterations").
    pub trials: usize,
    pub seed: u64,
}

impl Default for TuneOpts {
    fn default() -> Self {
        TuneOpts { trials: crate::calib::PAPER_TUNING_ITERATIONS, seed: 0xA57 }
    }
}

/// Measure one already-built candidate on the target (deploy → run in
/// cost-only mode — the same flash+run path MicroTVM takes). Deploy
/// failures (workspace OOM) surface as Err: AutoTVM's rejected trials.
fn measure_build(
    backend: &dyn Backend,
    target: &dyn Target,
    build: &BuildResult,
    input: &[i8],
) -> Result<f64> {
    let dep = target.deploy(build, backend.framework())?;
    let out = target.run(build, &dep, input, false)?;
    Ok(out.invoke_seconds)
}

/// Measure one schedule candidate, reusing `cand` (a clone of the
/// baseline build) when the backend supports the cheap re-cost path:
/// knob candidates share the baseline's lowering, so the 600-trial
/// loop is 1 lower + 600 re-costs instead of 600 full builds.
fn measure(
    backend: &dyn Backend,
    graph: &Graph,
    target: &dyn Target,
    cand: &mut BuildResult,
    schedule: Schedule,
    input: &[i8],
) -> Result<f64> {
    if backend.recost(cand, schedule) {
        return measure_build(backend, target, cand, input);
    }
    // fallback: full lowering (non-TVM backends, template changes)
    let mut cfg = BackendConfig::default();
    cfg.schedule = Some(schedule);
    let build = backend.build(graph, &cfg)?;
    measure_build(backend, target, &build, input)
}

/// Tune the schedule's knobs for `graph` on `target`.
///
/// Search: random sampling over the joint (conv, dense) knob space
/// with greedy keep-best — AutoTVM's default random tuner. The knob
/// space is per-schedule: untunable templates have singleton spaces,
/// reproducing Table V's "no improvement" cells.
pub fn tune(
    backend: &dyn Backend,
    graph: &Graph,
    target: &dyn Target,
    base: Schedule,
    opts: TuneOpts,
) -> Result<TuneResult> {
    anyhow::ensure!(
        target.supports_tuning(),
        "target {} does not support AutoTVM measurement",
        target.name()
    );
    let input = vec![0i8; graph.tensor(graph.inputs[0]).numel()];
    // lower the graph once at the base schedule (the reused Load/Build
    // artifact); every knob trial re-costs a clone of it in place
    let mut cfg = BackendConfig::default();
    cfg.schedule = Some(base);
    let base_build = backend.build(graph, &cfg)?;
    let baseline = measure_build(backend, target, &base_build, &input)?;
    let mut cand_build = base_build;
    // joint space: conv knobs × dense unroll — sampled, not exhaustive
    let max_oc = graph
        .ops
        .iter()
        .filter(|o| o.opcode == crate::graph::OpCode::Conv2D)
        .map(|o| graph.tensor(o.inputs[1]).shape[0])
        .max()
        .unwrap_or(8);
    // only op classes actually present in the model contribute
    // templates (AutoTVM extracts tasks from the graph)
    let has_conv = graph.ops.iter().any(|o| {
        matches!(
            o.opcode,
            crate::graph::OpCode::Conv2D | crate::graph::OpCode::DepthwiseConv2D
        )
    });
    let has_dense = graph
        .ops
        .iter()
        .any(|o| o.opcode == crate::graph::OpCode::FullyConnected);
    let conv_space = if has_conv {
        base.conv_knob_space(max_oc)
    } else {
        vec![base.knobs]
    };
    let dense_space = if has_dense {
        base.dense_knob_space()
    } else {
        vec![base.knobs]
    };
    let mut rng = XorShift64::new(opts.seed);
    let mut best = base;
    let mut best_s = baseline;
    let mut history = Vec::new();
    let singleton = conv_space.len() == 1 && dense_space.len() == 1;
    let trials = if singleton { 1 } else { opts.trials };
    for t in 0..trials {
        let knobs: Knobs = if singleton {
            base.knobs
        } else {
            // dense unroll shares the knob struct's unroll field; a
            // candidate is one joint assignment
            let c = *rng.choose(&conv_space);
            let d = *rng.choose(&dense_space);
            Knobs { unroll: if dense_space.len() > 1 { d.unroll } else { c.unroll }, ..c }
        };
        let cand = base.with_knobs(knobs);
        match measure(backend, graph, target, &mut cand_build, cand, &input) {
            Ok(s) => {
                if s < best_s {
                    best_s = s;
                    best = cand;
                }
                history.push(Trial {
                    index: t,
                    best_seconds: best_s,
                    status: TrialStatus::Ok,
                });
            }
            Err(_) => {
                // deploy failure (e.g. workspace OOM) — rejected trial
                history.push(Trial {
                    index: t,
                    best_seconds: best_s,
                    status: TrialStatus::Rejected,
                });
            }
        }
    }
    Ok(TuneResult {
        best,
        best_seconds: best_s,
        baseline_seconds: baseline,
        trials,
        history,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backends;
    use crate::graph::model::testutil::tiny_conv;
    use crate::schedules::{Family, Layout};
    use crate::targets;

    fn quick(trials: usize) -> TuneOpts {
        TuneOpts { trials, seed: 7 }
    }

    #[test]
    fn tuning_never_worse_than_baseline() {
        let g = tiny_conv();
        let b = backends::by_name("tvmaot").unwrap();
        let t = targets::by_name("stm32f7").unwrap();
        let base = Schedule::new(Family::DefaultX86, Layout::Nchw);
        let r = tune(&*b, &g, &*t, base, quick(40)).unwrap();
        assert!(r.best_seconds <= r.baseline_seconds);
        assert!(r.improvement() >= 0.0);
    }

    #[test]
    fn nchw_tuning_improves() {
        let g = tiny_conv();
        let b = backends::by_name("tvmaot").unwrap();
        let t = targets::by_name("esp32c3").unwrap();
        let base = Schedule::new(Family::DefaultX86, Layout::Nchw);
        let r = tune(&*b, &g, &*t, base, quick(60)).unwrap();
        // tunable conv template: some gain expected (paper: 10-35 %)
        assert!(r.improvement() > 0.0, "improvement {}", r.improvement());
    }

    #[test]
    fn x86_nhwc_conv_only_model_sees_no_gain() {
        let g = tiny_conv(); // conv-only graph, no dense
        let b = backends::by_name("tvmaot").unwrap();
        let t = targets::by_name("stm32f4").unwrap();
        let base = Schedule::new(Family::DefaultX86, Layout::Nhwc);
        let r = tune(&*b, &g, &*t, base, quick(30)).unwrap();
        // conv untunable + no dense layer => singleton space
        assert_eq!(r.trials, 1);
        assert!(r.improvement().abs() < 1e-12);
    }

    #[test]
    fn esp32_refuses_tuning() {
        let g = tiny_conv();
        let b = backends::by_name("tvmaot").unwrap();
        let t = targets::by_name("esp32").unwrap();
        let base = Schedule::new(Family::DefaultX86, Layout::Nchw);
        assert!(tune(&*b, &g, &*t, base, quick(5)).is_err());
    }

    #[test]
    fn history_records_per_trial_status() {
        let g = tiny_conv();
        let b = backends::by_name("tvmaot").unwrap();
        let t = targets::by_name("stm32f7").unwrap();
        let base = Schedule::new(Family::DefaultX86, Layout::Nchw);
        let r = tune(&*b, &g, &*t, base, quick(20)).unwrap();
        assert_eq!(r.history.len(), r.trials);
        for (i, tr) in r.history.iter().enumerate() {
            assert_eq!(tr.index, i);
            assert_eq!(tr.status, TrialStatus::Ok);
        }
        // best-so-far is monotone non-increasing
        for w in r.history.windows(2) {
            assert!(w[1].best_seconds <= w[0].best_seconds);
        }
    }

    #[test]
    fn deterministic_for_same_seed() {
        let g = tiny_conv();
        let b = backends::by_name("tvmaot").unwrap();
        let t = targets::by_name("stm32f7").unwrap();
        let base = Schedule::new(Family::DefaultX86, Layout::Nchw);
        let a = tune(&*b, &g, &*t, base, quick(25)).unwrap();
        let c = tune(&*b, &g, &*t, base, quick(25)).unwrap();
        assert_eq!(a.best_seconds, c.best_seconds);
        assert_eq!(a.best.knobs, c.best.knobs);
    }
}
