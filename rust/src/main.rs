//! mlonmcu binary — leader entrypoint. See `cli` for the command
//! surface and README.md for usage.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match mlonmcu::cli::main_with_args(&argv) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
