//! Kernel-library code generation: computes the `LoopCost` descriptor
//! and packs constants for each graph op, for the two kernel libraries
//! the paper compares:
//!
//!   * `TflmRef` — TFLite-Micro reference kernels (portable nested
//!     loops, per-element offset math; both tflmi and tflmc loop over
//!     the same kernels, which is why their invoke counts are equal in
//!     Table IV).
//!   * `Tvm(schedule)` — TVM-generated kernels under a `Schedule`
//!     (family × layout × knobs), GEMM-ified convs.
//!
//! The *numerics* of every kernel are identical (and identical to the
//! Pallas/JAX golden path); libraries differ only in cost, memory and
//! code-size characteristics — exactly the paper's framing.

use crate::calib;
use crate::graph::{Graph, OpNode};
use crate::schedules::Schedule;
use crate::tinyir::{InstrMix, LoopCost, WeightStream};

/// Which kernel implementations a backend links.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum KernelLib {
    TflmRef,
    Tvm(Schedule),
}

impl KernelLib {
    pub fn is_tvm(&self) -> bool {
        matches!(self, KernelLib::Tvm(_))
    }

    pub fn schedule(&self) -> Option<Schedule> {
        match self {
            KernelLib::Tvm(s) => Some(*s),
            KernelLib::TflmRef => None,
        }
    }
}

/// Conv2D cost under a kernel library.
///
/// Dimensions: output `oh×ow×oc`, kernel `kh×kw×ic`.
pub fn conv2d_cost(
    lib: KernelLib,
    ih: usize, iw: usize,
    oh: usize, ow: usize, oc: usize,
    kh: usize, kw: usize, ic: usize,
) -> LoopCost {
    let macs = (oh * ow * oc * kh * kw * ic) as u64;
    let out_elems = (oh * ow * oc) as u64;
    let weight_bytes = (kh * kw * ic * oc) as u64;
    match lib {
        KernelLib::TflmRef => LoopCost {
            macs,
            out_elems,
            per_mac: calib::TFLM_CONV_PER_MAC,
            per_out: calib::REQUANT_PER_OUT,
            fixed: calib::CALL_FIXED,
            // reference kernels walk OHWI weights row-contiguously per
            // output pixel: full-layer window, but instruction counting
            // (ETISS) is what Table IV uses for TFLM anyway.
            weights: WeightStream {
                bytes_streamed: macs, // one weight byte per MAC
                reuse_window: weight_bytes,
                contiguous: true,
            },
            code_bytes: 0, // charged per kernel *type* by the backend
            workspace: 0,
        },
        KernelLib::Tvm(s) => {
            let tile_oh = if s.knobs.tile_oh == 0 { oh } else { s.knobs.tile_oh.min(oh) };
            // bytes streamed from flash per inference:
            //  - packed NCHWc blocks are re-fetched once per spatial
            //    tile pass (bounded: blocks stay line-resident)
            //  - strided NHWC walks touch one weight byte per MAC —
            //    the flash-thrash driver on SPI-cached targets when
            //    the reuse window outgrows the (conflict-degraded)
            //    cache (mcu/memsys.rs)
            let passes = (oh as u64).div_ceil(tile_oh as u64);
            let bytes_streamed = if s.weights_contiguous() {
                weight_bytes * passes.min(4)
            } else {
                macs
            };
            let elem = if s.legalizes_to_i16() { 2 } else { 1 };
            // workspace: the x86 NHWC conv schedule materializes a
            // PaddedInput copy of the whole feature map (TVM's
            // conv2d_nhwc pad stage); NCHW keeps a small line block
            let workspace = match s.layout {
                crate::schedules::Layout::Nhwc => {
                    (ih + kh - 1) * (iw + kw - 1) * ic * elem
                }
                crate::schedules::Layout::Nchw => {
                    tile_oh.min(8) * ow * ic.min(32) * elem
                }
            };
            LoopCost {
                macs,
                out_elems,
                per_mac: conv_mix(s),
                per_out: calib::REQUANT_PER_OUT,
                fixed: calib::CALL_FIXED,
                weights: WeightStream {
                    bytes_streamed,
                    reuse_window: s.conv_reuse_window(kh, kw, ic, oc),
                    contiguous: s.weights_contiguous(),
                },
                code_bytes: tvm_conv_code_bytes(s),
                workspace,
            }
        }
    }
}

fn conv_mix(s: Schedule) -> InstrMix {
    s.conv_per_mac()
}

/// x86-NHWC conv bodies are aggressively unrolled for SIMD → large
/// per-instance code; NCHW tiled bodies are compact.
fn tvm_conv_code_bytes(s: Schedule) -> u64 {
    use crate::schedules::{Family, Layout};
    match (s.family, s.layout) {
        (Family::DefaultX86, Layout::Nhwc) => 9_000,
        (Family::Arm, Layout::Nhwc) => 6_000,
        _ => calib::TVM_KERNEL_CODE_PER_INSTANCE,
    }
}

/// Depthwise conv cost.
pub fn dwconv2d_cost(
    lib: KernelLib,
    oh: usize, ow: usize, c: usize,
    kh: usize, kw: usize,
) -> LoopCost {
    let macs = (oh * ow * c * kh * kw) as u64;
    let out_elems = (oh * ow * c) as u64;
    let weight_bytes = (kh * kw * c) as u64;
    let (per_mac, code, workspace) = match lib {
        KernelLib::TflmRef => (calib::TFLM_DWCONV_PER_MAC, 0, 0),
        KernelLib::Tvm(s) => {
            let elem = if s.legalizes_to_i16() { 2 } else { 1 };
            (
                s.dwconv_per_mac(),
                tvm_conv_code_bytes(s) / 2,
                kh * kw * c.min(64) * elem,
            )
        }
    };
    LoopCost {
        macs,
        out_elems,
        per_mac,
        per_out: calib::REQUANT_PER_OUT,
        fixed: calib::CALL_FIXED,
        // dw weights are tiny (kh*kw*c) — always cache-resident
        weights: WeightStream {
            bytes_streamed: weight_bytes,
            reuse_window: weight_bytes,
            contiguous: true,
        },
        code_bytes: code,
        workspace,
    }
}

/// Fully-connected cost.
pub fn dense_cost(lib: KernelLib, batch: usize, in_n: usize, out_n: usize) -> LoopCost {
    let macs = (batch * in_n * out_n) as u64;
    let out_elems = (batch * out_n) as u64;
    let (per_mac, code) = match lib {
        KernelLib::TflmRef => (calib::TFLM_DENSE_PER_MAC, 0),
        KernelLib::Tvm(s) => (s.dense_per_mac(), calib::TVM_KERNEL_CODE_PER_INSTANCE),
    };
    LoopCost {
        macs,
        out_elems,
        per_mac,
        per_out: calib::REQUANT_PER_OUT,
        fixed: calib::CALL_FIXED,
        // dense weights are streamed exactly once (no reuse across
        // outputs of a single inference)
        weights: WeightStream {
            bytes_streamed: (in_n * out_n) as u64,
            reuse_window: 0,
            contiguous: true,
        },
        code_bytes: code,
        workspace: 0,
    }
}

/// Pooling cost (window elements dominate).
pub fn pool_cost(ih_elems: u64, out_elems: u64) -> LoopCost {
    LoopCost {
        macs: 0,
        out_elems,
        per_mac: InstrMix::default(),
        per_out: calib::REQUANT_PER_OUT.add(&calib::POOL_PER_ELEM.scale(
            (ih_elems as f64 / out_elems.max(1) as f64).max(1.0),
        )),
        fixed: calib::CALL_FIXED,
        weights: WeightStream::none(),
        code_bytes: 600,
        workspace: 0,
    }
}

/// Elementwise add cost.
pub fn add_cost(elems: u64) -> LoopCost {
    LoopCost {
        macs: 0,
        out_elems: elems,
        per_mac: InstrMix::default(),
        per_out: calib::ADD_PER_ELEM,
        fixed: calib::CALL_FIXED,
        weights: WeightStream::none(),
        code_bytes: 450,
        workspace: 0,
    }
}

/// Softmax cost.
pub fn softmax_cost(elems: u64) -> LoopCost {
    LoopCost {
        macs: 0,
        out_elems: elems,
        per_mac: InstrMix::default(),
        per_out: calib::SOFTMAX_PER_ELEM,
        fixed: calib::CALL_FIXED,
        weights: WeightStream::none(),
        code_bytes: 900,
        workspace: 0,
    }
}

/// Copy / reshape cost.
pub fn copy_cost(elems: u64) -> LoopCost {
    LoopCost {
        macs: 0,
        out_elems: elems,
        per_mac: InstrMix::default(),
        per_out: calib::COPY_PER_ELEM,
        fixed: calib::CALL_FIXED / 3.0,
        weights: WeightStream::none(),
        code_bytes: 120,
        workspace: 0,
    }
}

/// Layout/dtype transform cost (TVM legalization copies).
pub fn transform_cost(elems: u64) -> LoopCost {
    LoopCost {
        macs: 0,
        out_elems: elems,
        per_mac: InstrMix::default(),
        per_out: calib::TRANSFORM_PER_ELEM,
        fixed: calib::CALL_FIXED / 2.0,
        weights: WeightStream::none(),
        code_bytes: 350,
        workspace: 0,
    }
}

/// Distinct conv-like kernel *types* in a graph (TFLM links one
/// reference kernel per type — ROM model).
pub fn distinct_kernel_types(g: &Graph) -> usize {
    let mut set = std::collections::BTreeSet::new();
    for op in &g.ops {
        set.insert(op.opcode.name());
    }
    set.len()
}

/// Workspace-free MAC count of one op (used by tuner heuristics).
pub fn op_macs(g: &Graph, op: &OpNode) -> u64 {
    g.op_macs(op)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedules::{Family, Layout};

    #[test]
    fn table4_invoke_shape_tflm_vs_tvm() {
        // aww-scale conv: tflm must be ~5-7x tvm-nchw per Table IV
        let tflm = conv2d_cost(KernelLib::TflmRef, 25, 5, 25, 5, 64, 1, 1, 64);
        let tvm = conv2d_cost(
            KernelLib::Tvm(Schedule::new(Family::DefaultX86, Layout::Nchw)),
            25, 5, 25, 5, 64, 1, 1, 64,
        );
        assert_eq!(tflm.macs, tvm.macs);
        let r = tflm.ref_instructions() as f64 / tvm.ref_instructions() as f64;
        assert!((4.0..8.0).contains(&r), "ratio {r}");
    }

    #[test]
    fn identical_invoke_for_tflm_backends_is_by_construction() {
        // tflmi and tflmc share kernels — cost comes from the same fn
        let a = conv2d_cost(KernelLib::TflmRef, 4, 4, 4, 4, 8, 3, 3, 8);
        let b = conv2d_cost(KernelLib::TflmRef, 4, 4, 4, 4, 8, 3, 3, 8);
        assert_eq!(a.ref_instructions(), b.ref_instructions());
    }

    #[test]
    fn nhwc_workspace_exceeds_nchw() {
        let nhwc = conv2d_cost(
            KernelLib::Tvm(Schedule::new(Family::DefaultX86, Layout::Nhwc)),
            48, 48, 48, 48, 16, 3, 3, 8,
        );
        let nchw = conv2d_cost(
            KernelLib::Tvm(Schedule::new(Family::DefaultX86, Layout::Nchw)),
            48, 48, 48, 48, 16, 3, 3, 8,
        );
        assert!(nhwc.workspace > 4 * nchw.workspace);
    }

    #[test]
    fn nhwc_reuse_window_is_whole_layer() {
        let s = Schedule::new(Family::DefaultX86, Layout::Nhwc);
        let c = conv2d_cost(KernelLib::Tvm(s), 32, 32, 32, 32, 64, 3, 3, 64);
        assert_eq!(c.weights.reuse_window, 3 * 3 * 64 * 64);
        assert!(!c.weights.contiguous);
        let nchw = Schedule::new(Family::DefaultX86, Layout::Nchw);
        let c2 = conv2d_cost(KernelLib::Tvm(nchw), 32, 32, 32, 32, 64, 3, 3, 64);
        assert!(c2.weights.reuse_window <= 3 * 3 * 64 * 8);
        assert!(c2.weights.contiguous);
    }

    #[test]
    fn dense_stream_once() {
        let c = dense_cost(KernelLib::TflmRef, 1, 640, 128);
        assert_eq!(c.weights.bytes_streamed, 640 * 128);
        assert_eq!(c.macs, 640 * 128);
    }

    #[test]
    fn dwconv_weights_always_resident() {
        let s = Schedule::new(Family::DefaultX86, Layout::Nhwc);
        let c = dwconv2d_cost(KernelLib::Tvm(s), 24, 24, 40, 3, 3);
        assert!(c.weights.reuse_window < 32 * 1024);
    }
}
