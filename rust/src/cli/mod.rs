//! Command line interface (paper §II-A: "a straightforward to use but
//! very powerful command line interface"). Hand-rolled parser (clap is
//! not reachable offline).
//!
//! ```text
//! mlonmcu init [DIR]
//! mlonmcu models ls
//! mlonmcu flow run -m M.. -b B.. -t T.. [--schedule S..] [--tune]
//!         [-f FEAT..] [--parallel N] [-c k=v..] [--postprocess P..]
//! mlonmcu cache stats | gc | clear
//! mlonmcu report [--session N]
//! mlonmcu trace summary FILE
//! mlonmcu top --connect HOST:PORT [--once]
//! mlonmcu metrics export [--format prometheus|json]
//! mlonmcu targets ls | backends ls
//! ```

pub mod args;

use anyhow::{bail, Context, Result};

use crate::config::Environment;
use crate::data::Json;
use crate::postprocess;
use crate::report::{row, Cell, Report};
use crate::session::persist;
use crate::session::transport::{Client, RemoteConfig, ServeConfig, Server};
use crate::session::{EnvStore, RunMatrix, RunOptions, Session};
use crate::util::fmt::human_bytes;
use crate::util::metrics::Snapshot;

use args::Parsed;

pub const USAGE: &str = "\
mlonmcu — TinyML benchmarking with fast retargeting (paper reproduction)

USAGE:
  mlonmcu init [DIR]                      initialize an environment
  mlonmcu models ls                       list available models
  mlonmcu backends ls                     list backends (Table IV)
  mlonmcu targets ls                      list targets (Table II)
  mlonmcu flow run -m M [-m M2..] -b B.. -t T..
          [--schedule default-nchw ..] [--tune]
          [-f validate ..] [--parallel N] [--workers N] [-c key=val ..]
          [--postprocess filter_cols:a,b ..] [--no-cache]
          [--cache-dir DIR] [--cache-budget MB] [--connect HOST:PORT]
  mlonmcu serve [--listen HOST:PORT]      export the env store + a task
          [--cache-dir DIR] [--cache-budget MB] [-c key=val ..]
                                          queue to remote workers
  mlonmcu cache stats|gc|clear|verify     manage the environment cache
          [--cache-dir DIR] [--cache-budget MB] [-c key=val ..]
          [--connect HOST:PORT]
  mlonmcu report [--session N]            reprint a session report
  mlonmcu trace summary FILE              aggregate an exported trace
  mlonmcu top --connect HOST:PORT         live fleet dashboard from a
          [--once] [--interval MS]        serve daemon (ops/s, cache hit
                                          ratio, stage p50/p95/p99,
                                          tasks, per-worker liveness)
  mlonmcu metrics export                  dump recorded metrics
          [--format prometheus|json] [--session N] [--connect HOST:PORT]
  mlonmcu worker (--queue DIR | --connect HOST:PORT) --home DIR
          [-c key=val ..]                 (internal) dispatch worker

FLAGS:
  --no-cache       disable all artifact-cache tiers: every run executes
                   every stage itself (no Load/Tune/Build deduplication)
  --cache-dir      environment artifact-store directory
                   (default: $ENV/cache, config key paths.cache)
  --cache-budget   store size budget in MB before LRU GC
                   (default: 512, config key cache.budget_mb)
  --workers        shard Load/Tune/Build across N `mlonmcu worker`
                   child processes exchanging artifacts through the
                   env store (default: 0 = in-process; config key
                   dispatch.workers). Reports are byte-identical to a
                   serial run.
  --connect        address of a `mlonmcu serve` daemon (config key
                   remote.connect). Adds a remote tier behind the env
                   store; with --workers the Load/Tune/Build stages are
                   dispatched through the daemon's task queue to
                   `worker --connect` fleets on any machine. An
                   unreachable server degrades to local execution.
  --listen         serve bind address (default 127.0.0.1:4917)
  --trace          write a Chrome trace_event JSON timeline of every
                   pipeline stage, cache/store lookup, lease and
                   transport request — merged across the whole fleet
                   (local worker processes and remote workers alike);
                   config key trace.file. Tracing never changes the
                   report: traced and untraced runs stay byte-identical.
  --faults         deterministic fault-injection plan (chaos testing):
                   comma-separated site:kind:prob[:after_n] rules plus
                   seed=N / hang_ms=N / delay_ms=N, e.g.
                   'seed=7,store.save:error:0.2,stage.build:exit:1:2'.
                   Config key faults.plan, env MLONMCU_FAULTS. The plan
                   propagates to local and remote worker fleets; every
                   injection is counted and traced. See
                   docs/OPERATIONS.md for the site table.
";

/// Entry point for the binary.
pub fn main_with_args(argv: &[String]) -> Result<i32> {
    let mut it = argv.iter();
    let Some(cmd) = it.next() else {
        println!("{USAGE}");
        return Ok(2);
    };
    let rest: Vec<String> = it.cloned().collect();
    match cmd.as_str() {
        "init" => cmd_init(&rest),
        "models" => cmd_models(&rest),
        "backends" => cmd_backends(),
        "targets" => cmd_targets(),
        "flow" => cmd_flow(&rest),
        "serve" => cmd_serve(&rest),
        "cache" => cmd_cache(&rest),
        "report" => cmd_report(&rest),
        "trace" => cmd_trace(&rest),
        "top" => cmd_top(&rest),
        "metrics" => cmd_metrics(&rest),
        "worker" => cmd_worker(&rest),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(0)
        }
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

fn cmd_init(rest: &[String]) -> Result<i32> {
    let dir = rest
        .first()
        .map(std::path::PathBuf::from)
        .unwrap_or(std::env::current_dir()?);
    let env = Environment::init(&dir)?;
    println!(
        "initialized environment '{}' at {}",
        env.get_str("", "name", "default"),
        env.root.display()
    );
    Ok(0)
}

fn cmd_models(rest: &[String]) -> Result<i32> {
    if rest.first().map(String::as_str) != Some("ls") {
        bail!("usage: mlonmcu models ls");
    }
    let env = Environment::discover()?;
    let models = crate::frontends::list_models(&env.model_dirs());
    if models.is_empty() {
        println!("no models found — run `make artifacts` to build the zoo");
    }
    for m in models {
        match crate::frontends::load_model(&m, &env.model_dirs()) {
            Ok(g) => println!(
                "{m:10} {:>9} params {:>9} B {:>10} MACs",
                g.param_count(),
                g.weight_bytes(),
                g.macs()
            ),
            Err(e) => println!("{m:10} (unreadable: {e})"),
        }
    }
    Ok(0)
}

fn cmd_backends() -> Result<i32> {
    for n in crate::backends::all_backend_names() {
        let b = crate::backends::by_name(n).unwrap();
        println!(
            "{n:8} framework={} schedules={}",
            b.framework(),
            if b.supports_schedules() { "yes" } else { "no" }
        );
    }
    Ok(0)
}

fn cmd_targets() -> Result<i32> {
    for n in ["etiss", "esp32c3", "stm32f4", "stm32f7", "esp32"] {
        let t = crate::targets::by_name(n).unwrap();
        let s = t.spec();
        println!(
            "{n:8} isa={:<10} {:>5} MHz flash={:>8} ram={:>7} tuning={}",
            s.isa.name,
            s.clock_mhz,
            s.flash_total,
            s.ram_total,
            if t.supports_tuning() { "yes" } else { "no" }
        );
    }
    Ok(0)
}

fn cmd_flow(rest: &[String]) -> Result<i32> {
    if rest.first().map(String::as_str) != Some("run") {
        bail!("usage: mlonmcu flow run ...");
    }
    let p = Parsed::parse(
        &rest[1..],
        &[
            ("-m", true), ("--model", true),
            ("-b", true), ("--backend", true),
            ("-t", true), ("--target", true),
            ("--schedule", true),
            ("-f", true), ("--feature", true),
            ("-c", true), ("--config", true),
            ("--postprocess", true),
            ("--parallel", true),
            ("--workers", true),
            ("--tune", false),
            ("--no-cache", false),
            ("--cache-dir", true),
            ("--cache-budget", true),
            ("--connect", true),
            ("--trace", true),
            ("--faults", true),
        ],
    )?;
    let models = p.all(&["-m", "--model"]);
    let backends = p.all(&["-b", "--backend"]);
    let targets = p.all(&["-t", "--target"]);
    if models.is_empty() || backends.is_empty() || targets.is_empty() {
        bail!("flow run needs at least -m, -b and -t\n{USAGE}");
    }
    let env = env_with_cache_flags(&p)?;
    let parallel = p
        .one("--parallel")
        .map(|s| s.parse::<usize>().context("--parallel"))
        .transpose()?
        .unwrap_or(env.get_i64("run", "parallel", 2) as usize);
    let workers = p
        .one("--workers")
        .map(|s| s.parse::<usize>().context("--workers"))
        .transpose()?
        .unwrap_or_else(|| env.dispatch_workers());

    let mut matrix = RunMatrix::new()
        .models(models)
        .backends(backends)
        .targets(targets)
        .schedules(p.all(&["--schedule"]))
        .features(p.all(&["-f", "--feature"]))
        .postprocesses(p.all(&["--postprocess"]));
    if p.flag("--tune") {
        matrix = matrix.with_tuning_sweep();
    }

    let session = Session::new(&env)?;
    let opts = RunOptions {
        parallel,
        use_cache: !p.flag("--no-cache"),
        workers,
    };
    let mut report = session.run_matrix_opts(&matrix, opts)?;
    let artifacts =
        postprocess::apply_all(matrix.postprocess_specs(), &mut report)?;
    for (name, text) in &artifacts {
        std::fs::write(session.dir.join(name), text)?;
    }
    let t = *session.last_timing.lock().unwrap_or_else(|e| e.into_inner());
    // display-only: the trace and fault notes join the report AFTER the
    // session files were written, so instrumented and plain session
    // artifacts stay byte-identical (proven by
    // tests/dispatch_equivalence.rs and tests/chaos_soak.rs)
    if let Some(path) = env.trace_file() {
        report.note(format!(
            "trace: {} span(s) exported to {} (open in a chrome://tracing \
             viewer, or run `mlonmcu trace summary`)",
            t.trace_spans,
            path.display()
        ));
    }
    if let Some(spec) = env.fault_spec() {
        report.note(format!(
            "faults_injected={} (plan {spec})",
            t.faults_injected
        ));
    }
    println!("{}", report.to_text());
    println!(
        "session {} done: {} runs in {:.1}s wall ({} thread(s){}); \
         simulated device time {:.1}s; artifacts in {}",
        session.id,
        t.runs,
        t.wall_s,
        parallel,
        // actual fleet size, not the request: 0 when dispatch fell
        // back to in-process execution (no store, --no-cache)
        if t.worker_procs > 0 {
            format!(", {} worker process(es)", t.worker_procs)
        } else {
            String::new()
        },
        t.sim_s,
        session.dir.display()
    );
    if opts.use_cache {
        println!(
            "artifact cache: {} hit(s) ({} from env store), {} miss(es), \
             {} eviction(s), {} verify failure(s); \
             executed {} load / {} tune / {} build stage(s) for {} runs",
            t.cache_hits,
            t.disk_hits,
            t.cache_misses,
            t.cache_evictions,
            t.verify_fails,
            t.stage_execs.loads,
            t.stage_execs.tunes,
            t.stage_execs.builds,
            t.runs
        );
        if env.remote_connect().is_some() {
            println!(
                "remote store: {} hit(s), {} miss(es), {} error(s)",
                t.remote_hits, t.remote_misses, t.remote_errors
            );
        }
    } else {
        println!("artifact cache: disabled (--no-cache)");
    }
    Ok(0)
}

/// Resolve the environment with `-c` overrides plus the cache flags
/// (`--cache-dir` / `--cache-budget` / `--connect` are sugar for the
/// `paths.cache` / `cache.budget_mb` / `remote.connect` config keys,
/// so precedence stays in one place).
fn env_with_cache_flags(p: &Parsed) -> Result<Environment> {
    let mut overrides = p.all(&["-c", "--config"]);
    if let Some(dir) = p.one("--cache-dir") {
        overrides.push(format!("paths.cache={dir}"));
    }
    if let Some(mb) = p.one("--cache-budget") {
        mb.parse::<u64>().context("--cache-budget (MB)")?;
        overrides.push(format!("cache.budget_mb={mb}"));
    }
    if let Some(addr) = p.one("--connect") {
        overrides.push(format!("remote.connect={addr}"));
    }
    // fault plan: MLONMCU_FAULTS is the lowest-precedence source (the
    // --faults flag is pushed after it, and later overrides win)
    if let Ok(spec) = std::env::var("MLONMCU_FAULTS") {
        if !spec.is_empty() {
            overrides.push(format!("faults.plan={spec}"));
        }
    }
    if let Some(spec) = p.one("--faults") {
        overrides.push(format!("faults.plan={spec}"));
    }
    if let Some(file) = p.one("--trace") {
        // absolutize against the invocation dir: relative `trace.file`
        // values resolve against the environment root, which is not
        // where the user typed the flag
        let path = std::path::Path::new(file);
        let abs = if path.is_absolute() {
            path.to_path_buf()
        } else {
            std::env::current_dir()?.join(path)
        };
        overrides.push(format!("trace.file={}", abs.display()));
    }
    Environment::discover()?.with_overrides(&overrides)
}

/// A wire client configured from the environment's `[remote]` section
/// for an explicit address (`--connect`).
fn remote_client(env: &Environment, addr: &str) -> Client {
    Client::new(RemoteConfig {
        addr: addr.to_string(),
        timeout_ms: env.remote_timeout_ms(),
        retries: env.remote_retries(),
        backoff_ms: env.remote_backoff_ms(),
        grace_ms: env.remote_grace_ms(),
    })
}

/// `mlonmcu serve` — export the environment store plus a shared work
/// queue over TCP to `--connect` clients. Runs until killed.
fn cmd_serve(rest: &[String]) -> Result<i32> {
    let p = Parsed::parse(
        rest,
        &[
            ("--listen", true),
            ("--cache-dir", true),
            ("--cache-budget", true),
            ("-c", true),
            ("--config", true),
        ],
    )?;
    let listen =
        p.one("--listen").map(String::as_str).unwrap_or("127.0.0.1:4917");
    let env = env_with_cache_flags(&p)?;
    let store = std::sync::Arc::new(EnvStore::open_with(
        &env.cache_dir(),
        env.cache_budget_bytes(),
        env.store_lock_stale_ms(),
    )?);
    let cfg = ServeConfig::from_env(&env);
    // the daemon's registry aggregates its own wire series plus every
    // snapshot the fleet ships via METRICS_PUT; `top` pulls from here
    if env.metrics_enabled() {
        crate::util::metrics::enable();
    }
    let (mem_bytes, max_conns, idle_ms) = (cfg.mem_bytes, cfg.max_conns, cfg.idle_ms);
    let server = Server::bind_with(std::sync::Arc::clone(&store), listen, cfg)?;
    println!(
        "serving artifact store {} (format v{}) on {}",
        store.root().display(),
        persist::FORMAT_VERSION,
        server.local_addr()
    );
    println!(
        "  mem cache {} / max {} conn(s) / idle timeout {}",
        human_bytes(mem_bytes),
        max_conns,
        if idle_ms == 0 {
            "off".to_string()
        } else {
            format!("{idle_ms} ms")
        }
    );
    server.run()?;
    Ok(0)
}

/// `mlonmcu cache stats|gc|clear` — manage the environment-level
/// artifact store without running anything.
fn cmd_cache(rest: &[String]) -> Result<i32> {
    let usage = "usage: mlonmcu cache stats|gc|clear|verify \
                 [--cache-dir DIR] [--cache-budget MB] \
                 [--connect HOST:PORT] [-c key=val ..]";
    let Some(action) = rest.first().map(String::as_str) else {
        bail!("{usage}");
    };
    let p = Parsed::parse(
        &rest[1..],
        &[
            ("--cache-dir", true),
            ("--cache-budget", true),
            ("--connect", true),
            ("-c", true),
            ("--config", true),
        ],
    )?;
    let env = env_with_cache_flags(&p)?;
    let store = EnvStore::open_with(
        &env.cache_dir(),
        env.cache_budget_bytes(),
        env.store_lock_stale_ms(),
    )?;
    match action {
        "stats" => {
            let s = store.stats();
            println!("environment cache at {}", store.root().display());
            println!(
                "  entries: {} ({} load / {} tune / {} build)",
                s.entries, s.loads, s.tunes, s.builds
            );
            println!(
                "  size:    {} of {} budget",
                human_bytes(s.total_bytes),
                human_bytes(store.budget_bytes())
            );
            // with a remote configured, report the served store too;
            // an unreachable server is a note, never an error
            if let Some(addr) = env.remote_connect() {
                let n = |j: &Json, k: &str| {
                    j.get(k).and_then(Json::as_i64).unwrap_or(0)
                };
                match remote_client(&env, &addr).stats() {
                    Ok(r) => {
                        println!("remote store at {addr} (format v{})", n(&r, "format"));
                        println!(
                            "  entries: {} ({} load / {} tune / {} build)",
                            n(&r, "entries"),
                            n(&r, "loads"),
                            n(&r, "tunes"),
                            n(&r, "builds")
                        );
                        println!(
                            "  size:    {}; {} model blob(s), {} queue(s), \
                             {} worker(s)",
                            human_bytes(n(&r, "total_bytes").max(0) as u64),
                            n(&r, "blobs"),
                            n(&r, "queues"),
                            n(&r, "workers")
                        );
                        println!(
                            "  serve:   {} op(s) ({}/s), {} served, \
                             {} store read(s)",
                            n(&r, "ops"),
                            n(&r, "ops_per_sec"),
                            human_bytes(n(&r, "bytes_served").max(0) as u64),
                            n(&r, "store_reads")
                        );
                        println!(
                            "  hot mem: {} hit(s) / {} miss(es); \
                             {} entr(ies), {} of {} budget, {} evicted",
                            n(&r, "mem_hits"),
                            n(&r, "mem_misses"),
                            n(&r, "mem_entries"),
                            human_bytes(n(&r, "mem_bytes").max(0) as u64),
                            human_bytes(n(&r, "mem_budget").max(0) as u64),
                            n(&r, "mem_evictions")
                        );
                        println!(
                            "  tasks:   {} open / {} claimed / {} done; \
                             {} queue(s) retired",
                            n(&r, "tasks_open"),
                            n(&r, "tasks_claimed"),
                            n(&r, "tasks_done"),
                            n(&r, "queues_retired")
                        );
                        // percentile lines from the server's metrics
                        // registry; absent on servers that predate the
                        // METRICS op — quietly skipped
                        if let Ok(m) = remote_client(&env, &addr).metrics()
                        {
                            let snap = m
                                .get("registry")
                                .and_then(|r| Snapshot::from_json(r).ok())
                                .unwrap_or_default();
                            for line in percentile_lines(&snap) {
                                println!("  {line}");
                            }
                        }
                    }
                    Err(e) => {
                        println!("remote store at {addr}: unreachable ({e:#})");
                    }
                }
            }
        }
        "gc" => {
            let (evicted, freed) = store.gc()?;
            println!(
                "evicted {} entries, freed {}; {} remaining",
                evicted,
                human_bytes(freed),
                store.stats().entries
            );
        }
        "clear" => {
            let before = store.stats();
            store.clear()?;
            println!(
                "cleared {} entries ({}) from {}",
                before.entries,
                human_bytes(before.total_bytes),
                store.root().display()
            );
        }
        "verify" => {
            let rep = store.verify();
            println!(
                "verified {} entries in {}: {} ok, {} missing, {} corrupt",
                rep.ok + rep.missing + rep.corrupt.len(),
                store.root().display(),
                rep.ok,
                rep.missing,
                rep.corrupt.len()
            );
            for line in &rep.corrupt {
                println!("  corrupt: {line}");
            }
            if !rep.clean() {
                println!(
                    "store is degraded (harmless: bad entries reload as \
                     misses and are recomputed); run `cache gc` or \
                     `cache clear` to drop them"
                );
                return Ok(1);
            }
            println!("store is clean");
        }
        other => bail!("unknown cache action '{other}'\n{usage}"),
    }
    Ok(0)
}

/// `mlonmcu worker` — internal subcommand: drain a Load/Tune/Build
/// work queue, exchanging artifacts through the env store of `--home`.
/// `--queue DIR` drains a local file queue (spawned by the sharded
/// dispatcher); `--connect HOST:PORT` claims tasks from a serve
/// daemon's shared queue instead.
fn cmd_worker(rest: &[String]) -> Result<i32> {
    let p = Parsed::parse(
        rest,
        &[
            ("--queue", true),
            ("--connect", true),
            ("--home", true),
            ("-c", true),
            ("--config", true),
        ],
    )?;
    let queue = p.one("--queue");
    let connect = p.one("--connect");
    if queue.is_none() && connect.is_none() {
        bail!(
            "worker needs --queue DIR or --connect HOST:PORT \
             (internal subcommand)"
        );
    }
    let home = p
        .one("--home")
        .context("worker needs --home DIR (internal subcommand)")?;
    let env = Environment::load_or_template(std::path::Path::new(home))?
        .with_overrides(&p.all(&["-c", "--config"]))?;
    match queue {
        Some(q) => {
            crate::session::dispatch::worker_main(std::path::Path::new(q), &env)
        }
        None => crate::session::dispatch::worker_main_remote(
            connect.expect("checked above"),
            &env,
        ),
    }
}

fn cmd_report(rest: &[String]) -> Result<i32> {
    let p = Parsed::parse(rest, &[("--session", true)])?;
    let env = Environment::discover()?;
    let sessions = env.sessions_dir();
    let id = match p.one("--session") {
        Some(s) => s.parse::<usize>().context("--session")?,
        None => {
            // latest session
            let mut id = 0usize;
            while sessions.join(format!("{}", id + 1)).exists() {
                id += 1;
            }
            id
        }
    };
    let path = sessions.join(format!("{id}")).join("report.md");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("no report at {}", path.display()))?;
    println!("{text}");
    Ok(0)
}

/// `mlonmcu trace summary FILE` — aggregate an exported Chrome
/// trace_event timeline into a per-stage / per-worker table.
fn cmd_trace(rest: &[String]) -> Result<i32> {
    let usage = "usage: mlonmcu trace summary <trace.json>";
    if rest.first().map(String::as_str) != Some("summary") {
        bail!("{usage}");
    }
    let Some(path) = rest.get(1) else {
        bail!("{usage}");
    };
    let spans = crate::util::trace::read_spans(std::path::Path::new(path))?;
    let mut report = Report::default();
    report.columns = [
        "span", "pid", "count", "total_ms", "mean_ms", "p50_ms", "p95_ms",
        "p99_ms", "max_ms",
    ]
    .map(String::from)
    .to_vec();
    for a in crate::util::trace::aggregate(&spans) {
        let ms = a.total_us as f64 / 1000.0;
        report.push(row(vec![
            ("span", Cell::Str(a.name.clone())),
            ("pid", Cell::Int(a.pid as i64)),
            ("count", Cell::Int(a.count as i64)),
            ("total_ms", Cell::Float(ms)),
            ("mean_ms", Cell::Float(ms / a.count.max(1) as f64)),
            ("p50_ms", Cell::Float(a.p50_us() as f64 / 1000.0)),
            ("p95_ms", Cell::Float(a.p95_us() as f64 / 1000.0)),
            ("p99_ms", Cell::Float(a.p99_us() as f64 / 1000.0)),
            ("max_ms", Cell::Float(a.max_us as f64 / 1000.0)),
        ]));
    }
    report.note(format!("{} span(s) in {path}", spans.len()));
    println!("{}", report.to_text());
    Ok(0)
}

/// One `name  p50=… p95=… p99=… n=…` line per recorded histogram,
/// sorted by series name. `.us` series render as milliseconds,
/// everything else (byte sizes) as raw values.
fn percentile_lines(snap: &Snapshot) -> Vec<String> {
    snap.hists
        .iter()
        .map(|(name, h)| {
            let v = |q: f64| {
                let p = h.percentile(q);
                if name.ends_with(".us") {
                    format!("{:.1}ms", p as f64 / 1000.0)
                } else {
                    p.to_string()
                }
            };
            format!(
                "{name}  p50={} p95={} p99={} n={}",
                v(0.50),
                v(0.95),
                v(0.99),
                h.count
            )
        })
        .collect()
}

/// `mlonmcu top --connect HOST:PORT` — fleet dashboard rendered from
/// the serve daemon's METRICS op: throughput, hot-cache hit ratio,
/// per-stage latency percentiles, task progress and per-worker
/// liveness. Redraws every `--interval` ms until interrupted; `--once`
/// prints a single frame and exits (scripts, CI).
fn cmd_top(rest: &[String]) -> Result<i32> {
    let p = Parsed::parse(
        rest,
        &[
            ("--connect", true),
            ("--once", false),
            ("--interval", true),
            ("-c", true),
            ("--config", true),
        ],
    )?;
    let addr = match p.one("--connect") {
        Some(a) => a.to_string(),
        None => Environment::discover()
            .ok()
            .and_then(|e| e.remote_connect())
            .context(
                "top needs --connect HOST:PORT (config key remote.connect)",
            )?,
    };
    let env = env_with_cache_flags(&p)?;
    let once = p.flag("--once");
    let interval = p
        .one("--interval")
        .map(|s| s.parse::<u64>().context("--interval (ms)"))
        .transpose()?
        .unwrap_or_else(|| env.metrics_interval_ms());
    let client = remote_client(&env, &addr);
    loop {
        let m = client.metrics()?;
        if !once {
            // ANSI clear + cursor home keeps the dashboard in place
            print!("\x1b[2J\x1b[H");
        }
        render_top(&addr, &m);
        if once {
            return Ok(0);
        }
        std::thread::sleep(std::time::Duration::from_millis(
            interval.max(100),
        ));
    }
}

/// One dashboard frame from a METRICS response document.
fn render_top(addr: &str, m: &Json) {
    let n = |k: &str| m.get(k).and_then(Json::as_i64).unwrap_or(0);
    println!(
        "fleet at {addr} — format v{}, uptime {:.0}s, {} conn(s)",
        n("format"),
        n("uptime_ms") as f64 / 1000.0,
        n("conns")
    );
    println!(
        "  ops:     {} total ({}/s), {} served",
        n("ops"),
        n("ops_per_sec"),
        human_bytes(n("bytes_served").max(0) as u64)
    );
    let (hits, misses) = (n("mem_hits"), n("mem_misses"));
    let ratio = if hits + misses > 0 {
        100.0 * hits as f64 / (hits + misses) as f64
    } else {
        0.0
    };
    println!(
        "  hot mem: {hits} hit(s) / {misses} miss(es) ({ratio:.0}% hit \
         ratio), {} of {}",
        human_bytes(n("mem_bytes").max(0) as u64),
        human_bytes(n("mem_budget").max(0) as u64)
    );
    println!(
        "  tasks:   {} open / {} claimed / {} done; {} queue(s) live, \
         {} retired",
        n("tasks_open"),
        n("tasks_claimed"),
        n("tasks_done"),
        n("queues"),
        n("queues_retired")
    );
    let snap = m
        .get("registry")
        .and_then(|r| Snapshot::from_json(r).ok())
        .unwrap_or_default();
    let (stages, series): (Vec<_>, Vec<_>) = percentile_lines(&snap)
        .into_iter()
        .partition(|l| l.starts_with("stage."));
    if !stages.is_empty() {
        println!("  stages:");
        for line in stages {
            println!("    {line}");
        }
    }
    if !series.is_empty() {
        println!("  series:");
        for line in series {
            println!("    {line}");
        }
    }
    let workers = m.get("workers_live").and_then(Json::as_arr).unwrap_or(&[]);
    println!("  workers: {} live", workers.len());
    for w in workers {
        let wn = |k: &str| w.get(k).and_then(Json::as_i64).unwrap_or(0);
        println!(
            "    {:<21} idle {:>6}ms  claims {:>4}  done {:>4}",
            w.get("addr").and_then(Json::as_str).unwrap_or("?"),
            wn("idle_ms"),
            wn("claims"),
            wn("done")
        );
    }
    let samples = m
        .get("ring")
        .and_then(|r| r.get("samples"))
        .and_then(Json::as_arr)
        .map(|a| a.len())
        .unwrap_or(0);
    println!("  ring:    {samples} snapshot sample(s)");
}

/// `mlonmcu metrics export` — dump recorded metrics as Prometheus
/// exposition text or JSON. With `--connect` (or `remote.connect`) the
/// source is the serve daemon's fleet-wide registry; otherwise a
/// session's exported `metrics.json` (`--session N`, default latest).
fn cmd_metrics(rest: &[String]) -> Result<i32> {
    let usage = "usage: mlonmcu metrics export \
                 [--format prometheus|json] [--session N] \
                 [--connect HOST:PORT]";
    if rest.first().map(String::as_str) != Some("export") {
        bail!("{usage}");
    }
    let p = Parsed::parse(
        &rest[1..],
        &[
            ("--format", true),
            ("--session", true),
            ("--connect", true),
            ("-c", true),
            ("--config", true),
        ],
    )?;
    let format =
        p.one("--format").map(String::as_str).unwrap_or("prometheus");
    let env = env_with_cache_flags(&p)?;
    let snap = match env.remote_connect() {
        Some(addr) => {
            let m = remote_client(&env, &addr).metrics()?;
            m.get("registry")
                .and_then(|r| Snapshot::from_json(r).ok())
                .unwrap_or_default()
        }
        None => {
            let sessions = env.sessions_dir();
            let id = match p.one("--session") {
                Some(s) => s.parse::<usize>().context("--session")?,
                None => {
                    let mut id = 0usize;
                    while sessions.join(format!("{}", id + 1)).exists() {
                        id += 1;
                    }
                    id
                }
            };
            let path = sessions.join(format!("{id}")).join("metrics.json");
            crate::util::metrics::read_snapshot(&path).with_context(|| {
                format!(
                    "no metrics at {} (is [metrics] enabled?)",
                    path.display()
                )
            })?
        }
    };
    match format {
        "prometheus" => print!("{}", snap.to_prometheus()),
        "json" => println!("{}", snap.to_json().to_string()),
        other => bail!("unknown metrics format '{other}'\n{usage}"),
    }
    Ok(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_command_errors() {
        assert!(main_with_args(&["frobnicate".into()]).is_err());
    }

    #[test]
    fn help_prints() {
        assert_eq!(main_with_args(&["help".into()]).unwrap(), 0);
    }

    #[test]
    fn backends_and_targets_ls() {
        assert_eq!(main_with_args(&["backends".into()]).unwrap(), 0);
        assert_eq!(main_with_args(&["targets".into()]).unwrap(), 0);
    }

    #[test]
    fn cache_subcommand_stats_gc_clear() {
        let dir = std::env::temp_dir().join("mlonmcu_cli_cache_test");
        let _ = std::fs::remove_dir_all(&dir);
        let args = |a: &str| {
            vec![
                "cache".to_string(),
                a.to_string(),
                "--cache-dir".to_string(),
                dir.display().to_string(),
            ]
        };
        assert_eq!(main_with_args(&args("stats")).unwrap(), 0);
        assert_eq!(main_with_args(&args("gc")).unwrap(), 0);
        assert_eq!(main_with_args(&args("clear")).unwrap(), 0);
        assert_eq!(main_with_args(&args("verify")).unwrap(), 0);
        assert!(main_with_args(&args("frobnicate")).is_err());
        assert!(main_with_args(&["cache".into()]).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn worker_requires_queue_and_home() {
        let err = main_with_args(&["worker".into()]).unwrap_err();
        assert!(err.to_string().contains("--queue"), "{err}");
        let err = main_with_args(&[
            "worker".into(),
            "--queue".into(),
            "/nonexistent".into(),
        ])
        .unwrap_err();
        assert!(err.to_string().contains("--home"), "{err}");
    }

    #[test]
    fn trace_summary_requires_action_and_file() {
        assert!(main_with_args(&["trace".into()]).is_err());
        assert!(main_with_args(&["trace".into(), "summary".into()]).is_err());
    }

    #[test]
    fn trace_summary_aggregates_a_span_file() {
        let dir = std::env::temp_dir().join("mlonmcu_cli_trace_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let file = dir.join("t.json");
        let span = crate::util::trace::Span {
            name: "build".into(),
            cat: "stage".into(),
            ts_us: 1,
            dur_us: 2000,
            pid: 42,
            tid: 0,
            args: vec![("outcome".into(), "ok".into())],
        };
        crate::util::trace::write_spans(&file, vec![span]).unwrap();
        let args = vec![
            "trace".to_string(),
            "summary".to_string(),
            file.display().to_string(),
        ];
        assert_eq!(main_with_args(&args).unwrap(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn top_requires_a_server_address() {
        let err = main_with_args(&["top".into()]).unwrap_err();
        assert!(err.to_string().contains("--connect"), "{err}");
    }

    #[test]
    fn metrics_requires_the_export_action() {
        assert!(main_with_args(&["metrics".into()]).is_err());
        assert!(main_with_args(&[
            "metrics".into(),
            "frobnicate".into(),
        ])
        .is_err());
    }

    #[test]
    fn percentile_lines_render_us_series_as_ms() {
        let mut snap = Snapshot::default();
        snap.hists.insert(
            "stage.build.us".into(),
            crate::util::metrics::Histogram::from_values([1000, 2000]),
        );
        snap.hists.insert(
            "wire.server.req.bytes".into(),
            crate::util::metrics::Histogram::from_values([64]),
        );
        let lines = percentile_lines(&snap);
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with("stage.build.us"), "{}", lines[0]);
        assert!(lines[0].contains("p95="), "{}", lines[0]);
        assert!(lines[0].contains("ms"), "{}", lines[0]);
        assert!(lines[1].contains("n=1"), "{}", lines[1]);
        assert!(!lines[1].contains("ms"), "{}", lines[1]);
    }

    #[test]
    fn flow_run_requires_mbt() {
        let err =
            main_with_args(&["flow".into(), "run".into()]).unwrap_err();
        assert!(err.to_string().contains("needs at least"));
    }
}
