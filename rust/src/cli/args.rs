//! Tiny argument parser: named flags with or without values, values
//! may repeat (`-m aww -m vww`).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed arguments: flag -> values (booleans get an empty marker).
#[derive(Debug, Clone, Default)]
pub struct Parsed {
    values: BTreeMap<String, Vec<String>>,
    flags: Vec<String>,
}

impl Parsed {
    /// `spec`: (flag, takes_value). Unknown flags are errors.
    pub fn parse(argv: &[String], spec: &[(&str, bool)]) -> Result<Parsed> {
        let mut p = Parsed::default();
        let mut it = argv.iter().peekable();
        while let Some(arg) = it.next() {
            let Some(&(name, takes_value)) =
                spec.iter().find(|(n, _)| n == arg)
            else {
                bail!("unknown argument '{arg}'");
            };
            if takes_value {
                let Some(v) = it.next() else {
                    bail!("flag {name} needs a value");
                };
                p.values.entry(name.to_string()).or_default().push(v.clone());
            } else {
                p.flags.push(name.to_string());
            }
        }
        Ok(p)
    }

    /// All values given under any alias.
    pub fn all(&self, aliases: &[&str]) -> Vec<String> {
        let mut out = Vec::new();
        for a in aliases {
            if let Some(vs) = self.values.get(*a) {
                out.extend(vs.iter().cloned());
            }
        }
        out
    }

    pub fn one(&self, flag: &str) -> Option<&String> {
        self.values.get(flag).and_then(|v| v.last())
    }

    pub fn flag(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn repeated_values_collect() {
        let p = Parsed::parse(
            &argv("-m aww -m vww -b tvmaot --tune"),
            &[("-m", true), ("-b", true), ("--tune", false)],
        )
        .unwrap();
        assert_eq!(p.all(&["-m"]), vec!["aww", "vww"]);
        assert_eq!(p.one("-b"), Some(&"tvmaot".to_string()));
        assert!(p.flag("--tune"));
    }

    #[test]
    fn aliases_merge() {
        let p = Parsed::parse(
            &argv("-m aww --model vww"),
            &[("-m", true), ("--model", true)],
        )
        .unwrap();
        assert_eq!(p.all(&["-m", "--model"]), vec!["aww", "vww"]);
    }

    #[test]
    fn unknown_flag_and_missing_value_error() {
        assert!(Parsed::parse(&argv("--wat"), &[("-m", true)]).is_err());
        assert!(Parsed::parse(&argv("-m"), &[("-m", true)]).is_err());
    }
}
