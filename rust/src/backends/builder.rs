//! Shared graph→TinyIR lowering used by every backend.
//!
//! Numerics are identical across backends (all convs lower to the same
//! zero-point-corrected int32 accumulation the Pallas/JAX golden path
//! computes); backends differ in kernel-library costs, activation
//! dtype (int16 legalization), inserted layout transforms, weight
//! packing and memory planning — which is exactly the paper's claim
//! that frameworks trade memory/latency, not accuracy (modulo the
//! golden-value validate feature that checks this).

use anyhow::{bail, Result};
use std::collections::BTreeMap;

use crate::graph::{Graph, OpCode};
use crate::kernels::{self, KernelLib};
use crate::tensor::{conv_out, DType};
use crate::tinyir::*;

/// Lowering options.
#[derive(Debug, Clone, Copy)]
pub struct LowerOpts {
    pub lib: KernelLib,
    /// int8→int16 QNN legalization of activations (TVM x86 schedules).
    pub legalize_i16: bool,
    /// Insert an input widening transform (i8 graph input → i16).
    pub transform_input: bool,
}

/// Requant multiplier computed exactly like python/compile/model.py:
/// f64(scale_in) * f64(scale_w) / f64(scale_out).
fn requant_of(g: &Graph, xid: usize, wid: usize, oid: usize, act: i64) -> Requant {
    let xin = g.tensor(xid);
    let w = g.tensor(wid);
    let out = g.tensor(oid);
    Requant {
        multiplier: xin.scale as f64 * w.scale as f64 / out.scale as f64,
        zp_in: xin.zero_point,
        zp_out: out.zero_point,
        act,
    }
}

/// Lower a validated graph into a TinyIR program (unplanned: buffer
/// offsets are assigned by the backend's memory planner afterwards).
pub fn lower(g: &Graph, name: &str, opts: LowerOpts) -> Result<Program> {
    let mut buffers: Vec<BufferDecl> = Vec::new();
    let mut consts: Vec<ConstDecl> = Vec::new();
    let mut calls: Vec<KernelCall> = Vec::new();
    // graph tensor id -> buffer id
    let mut buf_of: BTreeMap<usize, BufId> = BTreeMap::new();

    let act_dtype = |is_io: bool| -> DType {
        if opts.legalize_i16 && !is_io {
            DType::I16
        } else {
            DType::I8
        }
    };

    let mut add_buffer = |buffers: &mut Vec<BufferDecl>,
                          name: String,
                          elems: usize,
                          dtype: DType|
     -> BufId {
        buffers.push(BufferDecl {
            name,
            size: elems * dtype.size(),
            dtype,
            offset: None,
            first_use: 0,
            last_use: 0,
        });
        buffers.len() - 1
    };

    // graph input buffer (always i8 — it arrives over the wire)
    let gin = g.inputs[0];
    let in_elems = g.tensor(gin).numel();
    let input_buf = add_buffer(
        &mut buffers,
        "input".into(),
        in_elems,
        DType::I8,
    );
    buf_of.insert(gin, input_buf);

    // optional widening transform after input (legalized backends)
    let mut cur_input_buf = input_buf;
    if opts.legalize_i16 && opts.transform_input {
        let widened = add_buffer(
            &mut buffers,
            "input.i16".into(),
            in_elems,
            DType::I16,
        );
        calls.push(KernelCall {
            kind: KernelKind::Transform { elems: in_elems, widen: true },
            inputs: vec![Operand::Buf(input_buf)],
            consts: vec![],
            output: widened,
            cost: kernels::transform_cost(in_elems as u64),
            origin: "legalize.input".into(),
        });
        cur_input_buf = widened;
        buf_of.insert(gin, widened);
    }
    let _ = cur_input_buf;

    for op in &g.ops {
        let out_id = op.outputs[0];
        let out_t = g.tensor(out_id);
        let is_graph_out = out_id == g.outputs[0];
        let dtype = act_dtype(is_graph_out);
        match op.opcode {
            OpCode::Conv2D => {
                let x = g.tensor(op.inputs[0]);
                let w = g.tensor(op.inputs[1]);
                let (oc, kh, kw, ic) =
                    (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
                let (ih, iw) = (x.shape[1], x.shape[2]);
                let sh = op.attr("stride_h")? as usize;
                let sw = op.attr("stride_w")? as usize;
                let padding = op.attr("padding")? as u8;
                let oh = conv_out(ih, kh, sh, padding);
                let ow = conv_out(iw, kw, sw, padding);
                // pack weights into the GEMM matrix; row order is the
                // schedule's layout choice (cost metadata — numerics
                // are layout-invariant)
                let channels_first = matches!(
                    opts.lib,
                    KernelLib::Tvm(s) if s.layout == crate::schedules::Layout::Nchw
                );
                let wm = if channels_first {
                    crate::tensor::pack_ohwi_to_oihw_matrix(
                        w.data_i8()?, oc, kh, kw, ic,
                    )
                } else {
                    crate::tensor::pack_ohwi_to_hwio_matrix(
                        w.data_i8()?, oc, kh, kw, ic,
                    )
                };
                let wc = push_const_i8(&mut consts, format!("{}.w", op.name), wm);
                let bc = push_const_raw(
                    &mut consts,
                    format!("{}.b", op.name),
                    g.tensor(op.inputs[2]).data.clone().unwrap(),
                    DType::I32,
                );
                let out_buf = add_buffer(
                    &mut buffers,
                    out_t.name.clone(),
                    out_t.numel(),
                    dtype,
                );
                buf_of.insert(out_id, out_buf);
                let mut cost =
                    kernels::conv2d_cost(opts.lib, ih, iw, oh, ow, oc, kh, kw, ic);
                apply_tuned(&mut cost, opts.lib, op, oh, ow, oc, kh, kw, ic);
                calls.push(KernelCall {
                    kind: KernelKind::Conv2D {
                        ih, iw, ic, oh, ow, oc, kh, kw,
                        stride: (sh, sw),
                        padding,
                        channels_first,
                        requant: requant_of(
                            g, op.inputs[0], op.inputs[1], out_id,
                            op.attr_or("fused_act", 0),
                        ),
                    },
                    inputs: vec![Operand::Buf(buf_of[&op.inputs[0]])],
                    consts: vec![wc, bc],
                    output: out_buf,
                    cost,
                    origin: op.name.clone(),
                });
            }
            OpCode::DepthwiseConv2D => {
                let x = g.tensor(op.inputs[0]);
                let w = g.tensor(op.inputs[1]);
                let (kh, kw, c) = (w.shape[1], w.shape[2], w.shape[3]);
                let (ih, iw) = (x.shape[1], x.shape[2]);
                let sh = op.attr("stride_h")? as usize;
                let sw = op.attr("stride_w")? as usize;
                let padding = op.attr("padding")? as u8;
                let oh = conv_out(ih, kh, sh, padding);
                let ow = conv_out(iw, kw, sw, padding);
                let wc = push_const_i8(
                    &mut consts,
                    format!("{}.w", op.name),
                    w.data_i8()?.to_vec(),
                );
                let bc = push_const_raw(
                    &mut consts,
                    format!("{}.b", op.name),
                    g.tensor(op.inputs[2]).data.clone().unwrap(),
                    DType::I32,
                );
                let out_buf = add_buffer(
                    &mut buffers,
                    out_t.name.clone(),
                    out_t.numel(),
                    dtype,
                );
                buf_of.insert(out_id, out_buf);
                calls.push(KernelCall {
                    kind: KernelKind::DwConv2D {
                        ih, iw, c, oh, ow, kh, kw,
                        stride: (sh, sw),
                        padding,
                        requant: requant_of(
                            g, op.inputs[0], op.inputs[1], out_id,
                            op.attr_or("fused_act", 0),
                        ),
                    },
                    inputs: vec![Operand::Buf(buf_of[&op.inputs[0]])],
                    consts: vec![wc, bc],
                    output: out_buf,
                    cost: kernels::dwconv2d_cost(opts.lib, oh, ow, c, kh, kw),
                    origin: op.name.clone(),
                });
            }
            OpCode::FullyConnected => {
                let x = g.tensor(op.inputs[0]);
                let w = g.tensor(op.inputs[1]);
                let (out_n, in_n) = (w.shape[0], w.shape[1]);
                let batch = x.numel() / in_n;
                let wc = push_const_i8(
                    &mut consts,
                    format!("{}.w", op.name),
                    w.data_i8()?.to_vec(),
                );
                let bc = push_const_raw(
                    &mut consts,
                    format!("{}.b", op.name),
                    g.tensor(op.inputs[2]).data.clone().unwrap(),
                    DType::I32,
                );
                let out_buf = add_buffer(
                    &mut buffers,
                    out_t.name.clone(),
                    out_t.numel(),
                    dtype,
                );
                buf_of.insert(out_id, out_buf);
                calls.push(KernelCall {
                    kind: KernelKind::Dense {
                        batch, in_n, out_n,
                        requant: requant_of(
                            g, op.inputs[0], op.inputs[1], out_id,
                            op.attr_or("fused_act", 0),
                        ),
                    },
                    inputs: vec![Operand::Buf(buf_of[&op.inputs[0]])],
                    consts: vec![wc, bc],
                    output: out_buf,
                    cost: kernels::dense_cost(opts.lib, batch, in_n, out_n),
                    origin: op.name.clone(),
                });
            }
            OpCode::AvgPool2D | OpCode::MaxPool2D => {
                let x = g.tensor(op.inputs[0]);
                let (ih, iw, c) = (x.shape[1], x.shape[2], x.shape[3]);
                let fh = op.attr("filter_h")? as usize;
                let fw = op.attr("filter_w")? as usize;
                let sh = op.attr("stride_h")? as usize;
                let sw = op.attr("stride_w")? as usize;
                let oh = (ih - fh) / sh + 1; // VALID only (zoo invariant)
                let ow = (iw - fw) / sw + 1;
                let out_buf = add_buffer(
                    &mut buffers,
                    out_t.name.clone(),
                    out_t.numel(),
                    dtype,
                );
                buf_of.insert(out_id, out_buf);
                let kind = if op.opcode == OpCode::AvgPool2D {
                    KernelKind::AvgPool2D {
                        ih, iw, c, oh, ow, fh, fw, stride: (sh, sw),
                    }
                } else {
                    KernelKind::MaxPool2D {
                        ih, iw, c, oh, ow, fh, fw, stride: (sh, sw),
                    }
                };
                calls.push(KernelCall {
                    kind,
                    inputs: vec![Operand::Buf(buf_of[&op.inputs[0]])],
                    consts: vec![],
                    output: out_buf,
                    cost: kernels::pool_cost(
                        (ih * iw * c) as u64,
                        (oh * ow * c) as u64,
                    ),
                    origin: op.name.clone(),
                });
            }
            OpCode::Add => {
                let a = g.tensor(op.inputs[0]);
                let b = g.tensor(op.inputs[1]);
                let o = g.tensor(op.outputs[0]);
                let out_buf = add_buffer(
                    &mut buffers,
                    out_t.name.clone(),
                    out_t.numel(),
                    dtype,
                );
                buf_of.insert(out_id, out_buf);
                calls.push(KernelCall {
                    kind: KernelKind::Add {
                        elems: o.numel(),
                        s_a: a.scale as f64, zp_a: a.zero_point,
                        s_b: b.scale as f64, zp_b: b.zero_point,
                        s_o: o.scale as f64, zp_o: o.zero_point,
                        act: op.attr_or("fused_act", 0),
                    },
                    inputs: vec![
                        Operand::Buf(buf_of[&op.inputs[0]]),
                        Operand::Buf(buf_of[&op.inputs[1]]),
                    ],
                    consts: vec![],
                    output: out_buf,
                    cost: kernels::add_cost(o.numel() as u64),
                    origin: op.name.clone(),
                });
            }
            OpCode::Reshape => {
                // value-preserving copy (TFLM emits a memcpy kernel)
                let elems = out_t.numel();
                let out_buf = add_buffer(
                    &mut buffers,
                    out_t.name.clone(),
                    elems,
                    dtype,
                );
                buf_of.insert(out_id, out_buf);
                calls.push(KernelCall {
                    kind: KernelKind::Copy { elems },
                    inputs: vec![Operand::Buf(buf_of[&op.inputs[0]])],
                    consts: vec![],
                    output: out_buf,
                    cost: kernels::copy_cost(elems as u64),
                    origin: op.name.clone(),
                });
            }
            OpCode::Softmax => {
                let x = g.tensor(op.inputs[0]);
                let elems = out_t.numel();
                let out_buf = add_buffer(
                    &mut buffers,
                    out_t.name.clone(),
                    elems,
                    dtype,
                );
                buf_of.insert(out_id, out_buf);
                calls.push(KernelCall {
                    kind: KernelKind::Softmax {
                        elems,
                        s_in: x.scale as f64,
                        zp_in: x.zero_point,
                    },
                    inputs: vec![Operand::Buf(buf_of[&op.inputs[0]])],
                    consts: vec![],
                    output: out_buf,
                    cost: kernels::softmax_cost(elems as u64),
                    origin: op.name.clone(),
                });
            }
        }
    }

    let output_buf = *buf_of
        .get(&g.outputs[0])
        .ok_or_else(|| anyhow::anyhow!("graph output never lowered"))?;
    if calls.is_empty() {
        bail!("empty program");
    }

    let workspace_size =
        calls.iter().map(|c| c.cost.workspace).max().unwrap_or(0);
    let mut p = Program {
        name: name.into(),
        buffers,
        consts,
        calls,
        input: input_buf,
        output: output_buf,
        arena_size: 0,
        workspace_size,
    };
    p.recompute_lifetimes();
    Ok(p)
}

/// Apply per-op tuned knobs from the autotvm feature, recomputing the
/// cost descriptor under the tuned schedule.
fn apply_tuned(
    cost: &mut LoopCost,
    lib: KernelLib,
    op: &crate::graph::OpNode,
    oh: usize, ow: usize, oc: usize, kh: usize, kw: usize, ic: usize,
) {
    // Tuned knobs are stitched in by the tuner rebuilding with a
    // modified schedule; this hook is kept for per-op overrides.
    let _ = (cost, lib, op, oh, ow, oc, kh, kw, ic);
}

fn push_const_i8(consts: &mut Vec<ConstDecl>, name: String, data: Vec<i8>) -> ConstId {
    let bytes = data.iter().map(|&x| x as u8).collect();
    consts.push(ConstDecl { name, data: bytes, dtype: DType::I8 });
    consts.len() - 1
}

fn push_const_raw(
    consts: &mut Vec<ConstDecl>,
    name: String,
    data: Vec<u8>,
    dtype: DType,
) -> ConstId {
    consts.push(ConstDecl { name, data, dtype });
    consts.len() - 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::model::testutil::tiny_conv;
    use crate::schedules::{Family, Layout, Schedule};

    #[test]
    fn lowers_tiny_conv_tflm() {
        let g = tiny_conv();
        let p = lower(
            &g,
            "t",
            LowerOpts {
                lib: KernelLib::TflmRef,
                legalize_i16: false,
                transform_input: false,
            },
        )
        .unwrap();
        assert_eq!(p.calls.len(), 1);
        assert_eq!(p.consts.len(), 2); // weights + bias
        assert_eq!(p.buffers.len(), 2); // input + output
        assert_eq!(p.buffers[p.output].size, 4 * 4 * 3);
        assert!(p.ref_invoke_instructions() > 0);
    }

    #[test]
    fn legalized_lowering_widens_activations() {
        let g = tiny_conv();
        let s = Schedule::new(Family::DefaultX86, Layout::Nchw);
        let p = lower(
            &g,
            "t",
            LowerOpts {
                lib: KernelLib::Tvm(s),
                legalize_i16: true,
                transform_input: true,
            },
        )
        .unwrap();
        // transform + conv
        assert_eq!(p.calls.len(), 2);
        // graph I/O stays i8 (it crosses the UART)...
        assert_eq!(p.buffers[p.input].size, 4 * 4 * 2);
        assert_eq!(p.buffers[p.output].size, 4 * 4 * 3);
        // ...but the widened input copy is i16
        let widened = p
            .buffers
            .iter()
            .find(|b| b.name == "input.i16")
            .expect("legalize must insert an i16 input copy");
        assert_eq!(widened.size, 4 * 4 * 2 * 2);
        assert_eq!(widened.dtype, DType::I16);
    }

    #[test]
    fn nchw_lowering_packs_channels_first() {
        let g = tiny_conv();
        let nchw = lower(
            &g, "t",
            LowerOpts {
                lib: KernelLib::Tvm(Schedule::new(Family::DefaultX86, Layout::Nchw)),
                legalize_i16: false,
                transform_input: false,
            },
        )
        .unwrap();
        let nhwc = lower(
            &g, "t",
            LowerOpts {
                lib: KernelLib::Tvm(Schedule::new(Family::DefaultX86, Layout::Nhwc)),
                legalize_i16: false,
                transform_input: false,
            },
        )
        .unwrap();
        match (&nchw.calls[0].kind, &nhwc.calls[0].kind) {
            (
                KernelKind::Conv2D { channels_first: cf1, .. },
                KernelKind::Conv2D { channels_first: cf2, .. },
            ) => {
                assert!(*cf1);
                assert!(!*cf2);
            }
            _ => panic!("expected conv calls"),
        }
        // packed weight bytes identical (permutation)
        let mut a = nchw.consts[0].data.clone();
        let mut b = nhwc.consts[0].data.clone();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }
}
