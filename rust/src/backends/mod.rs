//! Backends (paper §II-B2): convert a loaded model graph into
//! inference code (a TinyIR `Program`) plus the ROM/RAM/setup
//! accounting of the deployment method. The five backends of Table IV:
//!
//!   tflmi    — TFLite-Micro interpreter: reference kernels, FlatBuffer
//!              parsed at runtime, greedy arena planner.
//!   tflmc    — TFLite-Micro *Compiler*: same kernels, but fully static
//!              codegen — no interpreter ROM, minimal setup.
//!   tvmaot   — TVM Ahead-of-Time executor: scheduled kernels,
//!              storage-token memory planning.
//!   tvmaot+  — tvmaot + Unified Static Memory Planner (USMP):
//!              interval-packed arena (−9…−28 % RAM in the paper).
//!   tvmrt    — TVM Graph executor: JSON graph parsed at runtime,
//!              page-pool dynamic allocation (the +605 %…+14 374 % RAM
//!              rows of Table IV).

pub mod builder;
pub mod planner;
pub mod tflm;
pub mod tvm;

use anyhow::Result;

use crate::graph::Graph;
use crate::schedules::Schedule;
use crate::tinyir::Program;

/// Build-stage output: the program plus deployment metrics.
#[derive(Debug, Clone)]
pub struct BuildResult {
    pub program: Program,
    pub metrics: BuildMetrics,
    /// The schedule this build was lowered under (TVM backends only).
    /// Enables the cheap `Backend::recost` path: a knob candidate with
    /// the same family/layout can re-cost this build instead of
    /// re-lowering the graph.
    pub schedule: Option<Schedule>,
}

/// Static deployment metrics (Table IV rows besides Invoke).
#[derive(Debug, Clone, Default)]
pub struct BuildMetrics {
    /// Setup-phase instruction count on the reference ISA.
    pub setup_instructions: u64,
    pub rom_code: u64,
    pub rom_weights: u64,
    /// Runtime/interpreter/metadata ROM (flatbuffer, JSON, ...).
    pub rom_misc: u64,
    pub ram_arena: u64,
    pub ram_workspace: u64,
    pub ram_runtime: u64,
}

impl BuildMetrics {
    pub fn rom_total(&self) -> u64 {
        self.rom_code + self.rom_weights + self.rom_misc
    }
    pub fn ram_total(&self) -> u64 {
        self.ram_arena + self.ram_workspace + self.ram_runtime
    }
}

/// Per-build configuration handed down from the run matrix.
#[derive(Debug, Clone, Default)]
pub struct BackendConfig {
    /// TVM schedule selection (Table V rows). `None` = backend default.
    pub schedule: Option<Schedule>,
    /// Tuned per-op knob overrides from the autotvm feature, keyed by
    /// graph op name.
    pub tuned_knobs: std::collections::BTreeMap<String, crate::schedules::Knobs>,
}

/// A deployment backend (Build stage).
pub trait Backend: Send + Sync {
    fn name(&self) -> &'static str;
    /// Framework the backend belongs to ("tflm" / "tvm") — Table IV's
    /// column grouping.
    fn framework(&self) -> &'static str;
    /// Whether this backend accepts TVM schedule configuration.
    fn supports_schedules(&self) -> bool {
        false
    }
    fn build(&self, graph: &Graph, cfg: &BackendConfig) -> Result<BuildResult>;

    /// Cheaply rewrite `build`'s cost descriptors in place for a knob
    /// candidate of the same schedule family/layout. Returns `false`
    /// when the backend cannot (non-TVM backends, or a family/layout
    /// change that requires a real re-lowering) — callers then fall
    /// back to a full `build`. Numerics are untouched either way: the
    /// tuner's 600-trial measure loop becomes 1 lower + N re-costs.
    fn recost(&self, build: &mut BuildResult, schedule: Schedule) -> bool {
        let _ = (build, schedule);
        false
    }
}

/// Instantiate a backend by its Table IV name.
pub fn by_name(name: &str) -> Option<Box<dyn Backend>> {
    match name {
        "tflmi" => Some(Box::new(tflm::Tflmi)),
        "tflmc" => Some(Box::new(tflm::Tflmc)),
        "tvmaot" => Some(Box::new(tvm::TvmAot { usmp: false })),
        "tvmaot+" | "tvmaotplus" => Some(Box::new(tvm::TvmAot { usmp: true })),
        "tvmrt" => Some(Box::new(tvm::TvmRt)),
        _ => None,
    }
}

/// The Table IV backend list, in paper column order.
pub fn all_backend_names() -> [&'static str; 5] {
    ["tflmi", "tflmc", "tvmaot", "tvmaot+", "tvmrt"]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_all_names() {
        for n in all_backend_names() {
            let b = by_name(n).unwrap();
            assert_eq!(b.name(), if n == "tvmaot+" { "tvmaot+" } else { n });
        }
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn framework_grouping() {
        assert_eq!(by_name("tflmi").unwrap().framework(), "tflm");
        assert_eq!(by_name("tflmc").unwrap().framework(), "tflm");
        assert_eq!(by_name("tvmaot").unwrap().framework(), "tvm");
        assert_eq!(by_name("tvmrt").unwrap().framework(), "tvm");
    }

    #[test]
    fn schedule_support() {
        assert!(!by_name("tflmi").unwrap().supports_schedules());
        assert!(by_name("tvmaot").unwrap().supports_schedules());
        assert!(by_name("tvmrt").unwrap().supports_schedules());
    }
}
