//! TVM backends: `tvmaot`, `tvmaot+` (USMP) and `tvmrt` (graph
//! executor). All three share the scheduled-kernel lowering; they
//! differ in executor runtime, memory planning and setup behaviour.
//!
//! Schedule selection: the backend default is TVM's default schedule
//! set with the NCHW relayout (the paper's Table IV configuration);
//! Table V passes explicit schedules through `BackendConfig`.

use anyhow::Result;

use crate::calib;
use crate::graph::Graph;
use crate::kernels::KernelLib;
use crate::schedules::{Family, Layout, Schedule};
use crate::tinyir::Program;

use super::builder::{lower, LowerOpts};
use super::planner::{plan, PlannerKind};
use super::{Backend, BackendConfig, BuildMetrics, BuildResult};

fn effective_schedule(cfg: &BackendConfig) -> Schedule {
    cfg.schedule
        .unwrap_or_else(|| Schedule::new(Family::DefaultX86, Layout::Nchw))
}

fn lower_tvm(g: &Graph, name: &str, s: Schedule) -> Result<Program> {
    lower(
        g,
        name,
        LowerOpts {
            lib: KernelLib::Tvm(s),
            legalize_i16: s.legalizes_to_i16(),
            transform_input: s.legalizes_to_i16(),
        },
    )
}

fn tvm_rom_code(p: &Program) -> u64 {
    p.code_bytes()
}

fn setup_instructions(m: &calib::SetupModel, g: &Graph, arena: u64) -> u64 {
    (m.fixed
        + m.per_op * g.ops.len() as f64
        + m.per_arena_byte * arena as f64
        + m.per_weight_byte * g.weight_bytes() as f64) as u64
}

/// `tvmaot` / `tvmaot+` — Ahead-of-Time executor; `usmp` enables the
/// Unified Static Memory Planner (the paper's tvmaot+ backend).
pub struct TvmAot {
    pub usmp: bool,
}

impl Backend for TvmAot {
    fn name(&self) -> &'static str {
        if self.usmp {
            "tvmaot+"
        } else {
            "tvmaot"
        }
    }
    fn framework(&self) -> &'static str {
        "tvm"
    }
    fn supports_schedules(&self) -> bool {
        true
    }

    fn build(&self, g: &Graph, cfg: &BackendConfig) -> Result<BuildResult> {
        let s = effective_schedule(cfg);
        let mut program =
            lower_tvm(g, &format!("{}-{}", g.name, self.name()), s)?;
        let planner = if self.usmp {
            PlannerKind::UsmpInterval
        } else {
            PlannerKind::StorageTokens
        };
        let arena = plan(&mut program, planner) as u64;
        // USMP also pools per-kernel workspaces into the arena plan;
        // classic AoT keeps the worst-case workspace separate.
        let workspace = if self.usmp {
            (program.workspace_size as u64) * 3 / 4
        } else {
            program.workspace_size as u64
        };
        let metrics = BuildMetrics {
            setup_instructions: setup_instructions(
                &calib::TVMAOT_SETUP, g, arena,
            ),
            rom_code: calib::TVMAOT_RUNTIME_ROM
                + calib::MLIF_ROM
                + tvm_rom_code(&program),
            rom_weights: program.const_bytes() as u64,
            rom_misc: 0,
            ram_arena: arena,
            ram_workspace: workspace,
            ram_runtime: calib::TVMAOT_RUNTIME_RAM_FIXED + calib::MLIF_RAM,
        };
        Ok(BuildResult { program, metrics, schedule: Some(s) })
    }

    fn recost(&self, build: &mut BuildResult, schedule: Schedule) -> bool {
        if !same_template(build, schedule) {
            return false;
        }
        build.program.recost(schedule);
        // knobs move only the workspace requirement: code size, arena
        // and weights are schedule-family properties, already correct
        build.metrics.ram_workspace = if self.usmp {
            (build.program.workspace_size as u64) * 3 / 4
        } else {
            build.program.workspace_size as u64
        };
        build.schedule = Some(schedule);
        true
    }
}

/// A knob candidate can re-cost an existing build only when the
/// lowering template (family × layout) is unchanged — anything else
/// alters packing/legalization and needs a real build.
fn same_template(build: &BuildResult, schedule: Schedule) -> bool {
    match build.schedule {
        Some(base) => {
            base.family == schedule.family && base.layout == schedule.layout
        }
        None => false,
    }
}

/// `tvmrt` — the Graph executor: parses a JSON graph at runtime,
/// allocates every tensor from a page-based heap pool. Powerful for
/// profiling/AutoTVM, terrible for RAM (Table IV).
pub struct TvmRt;

impl Backend for TvmRt {
    fn name(&self) -> &'static str {
        "tvmrt"
    }
    fn framework(&self) -> &'static str {
        "tvm"
    }
    fn supports_schedules(&self) -> bool {
        true
    }

    fn build(&self, g: &Graph, cfg: &BackendConfig) -> Result<BuildResult> {
        let s = effective_schedule(cfg);
        let mut program = lower_tvm(g, &format!("{}-tvmrt", g.name), s)?;
        // graph executor: no static planning — every tensor distinct
        let arena = plan(&mut program, PlannerKind::NoReuse) as u64;
        let n_tensors = program.buffers.len() as u64;
        let metrics = BuildMetrics {
            setup_instructions: setup_instructions(
                &calib::TVMRT_SETUP, g, arena,
            ),
            rom_code: calib::TVMRT_RUNTIME_ROM
                + calib::MLIF_ROM
                + tvm_rom_code(&program),
            rom_weights: program.const_bytes() as u64,
            // the JSON graph string lives in flash
            rom_misc: g.ops.len() as u64 * calib::TVMRT_JSON_PER_OP,
            // tensors live inside the heap pool; the pool dominates
            ram_arena: calib::TVMRT_HEAP_POOL.max(arena),
            ram_workspace: program.workspace_size as u64,
            ram_runtime: calib::TVMRT_RUNTIME_RAM_FIXED
                + n_tensors * calib::TVMRT_RUNTIME_RAM_PER_TENSOR
                + calib::MLIF_RAM,
        };
        Ok(BuildResult { program, metrics, schedule: Some(s) })
    }

    fn recost(&self, build: &mut BuildResult, schedule: Schedule) -> bool {
        if !same_template(build, schedule) {
            return false;
        }
        build.program.recost(schedule);
        build.metrics.ram_workspace = build.program.workspace_size as u64;
        build.schedule = Some(schedule);
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::model::testutil::tiny_conv;

    #[test]
    fn usmp_never_increases_ram() {
        let g = tiny_conv();
        let cfg = BackendConfig::default();
        let aot = TvmAot { usmp: false }.build(&g, &cfg).unwrap();
        let plus = TvmAot { usmp: true }.build(&g, &cfg).unwrap();
        assert!(plus.metrics.ram_total() <= aot.metrics.ram_total());
        // invoke cost identical — USMP is memory-only
        assert_eq!(
            aot.program.ref_invoke_instructions(),
            plus.program.ref_invoke_instructions()
        );
    }

    #[test]
    fn tvmrt_ram_dominated_by_heap_pool() {
        let g = tiny_conv();
        let r = TvmRt.build(&g, &BackendConfig::default()).unwrap();
        assert!(r.metrics.ram_total() >= calib::TVMRT_HEAP_POOL);
        // and setup is orders of magnitude above tvmaot
        let aot = TvmAot { usmp: false }
            .build(&g, &BackendConfig::default())
            .unwrap();
        assert!(
            r.metrics.setup_instructions
                > 100 * aot.metrics.setup_instructions.max(1)
        );
    }

    #[test]
    fn schedule_config_changes_cost() {
        let g = tiny_conv();
        let mut cfg = BackendConfig::default();
        cfg.schedule = Some(Schedule::new(Family::DefaultX86, Layout::Nchw));
        let nchw = TvmAot { usmp: false }.build(&g, &cfg).unwrap();
        cfg.schedule = Some(Schedule::new(Family::DefaultX86, Layout::Nhwc));
        let nhwc = TvmAot { usmp: false }.build(&g, &cfg).unwrap();
        assert!(
            nhwc.program.ref_invoke_instructions()
                > nchw.program.ref_invoke_instructions()
        );
    }

    #[test]
    fn recost_matches_full_build_for_knob_candidates() {
        let g = tiny_conv();
        let base = Schedule::new(Family::DefaultX86, Layout::Nchw);
        let backend = TvmAot { usmp: false };
        let mut cfg = BackendConfig::default();
        cfg.schedule = Some(base);
        let built = backend.build(&g, &cfg).unwrap();
        for knobs in base.conv_knob_space(8).into_iter().take(16) {
            let cand = base.with_knobs(knobs);
            let mut re = built.clone();
            assert!(backend.recost(&mut re, cand));
            cfg.schedule = Some(cand);
            let full = backend.build(&g, &cfg).unwrap();
            assert_eq!(
                re.program.ref_invoke_instructions(),
                full.program.ref_invoke_instructions(),
                "{knobs:?}"
            );
            assert_eq!(re.program.workspace_size, full.program.workspace_size);
            assert_eq!(re.metrics.ram_total(), full.metrics.ram_total());
            assert_eq!(re.metrics.rom_total(), full.metrics.rom_total());
            assert_eq!(re.schedule, Some(cand));
        }
    }

    #[test]
    fn recost_refuses_template_changes() {
        let g = tiny_conv();
        let base = Schedule::new(Family::DefaultX86, Layout::Nchw);
        let backend = TvmAot { usmp: true };
        let mut cfg = BackendConfig::default();
        cfg.schedule = Some(base);
        let built = backend.build(&g, &cfg).unwrap();
        let mut re = built.clone();
        assert!(!backend.recost(&mut re, Schedule::new(Family::Arm, Layout::Nchw)));
        assert!(!backend.recost(
            &mut re,
            Schedule::new(Family::DefaultX86, Layout::Nhwc)
        ));
        // and a build without a recorded schedule can never recost
        re.schedule = None;
        assert!(!backend.recost(&mut re, base));
    }

    #[test]
    fn arm_schedules_skip_legalization_ram() {
        let g = tiny_conv();
        let mut cfg = BackendConfig::default();
        cfg.schedule = Some(Schedule::new(Family::DefaultX86, Layout::Nchw));
        let x86 = TvmAot { usmp: false }.build(&g, &cfg).unwrap();
        cfg.schedule = Some(Schedule::new(Family::Arm, Layout::Nchw));
        let arm = TvmAot { usmp: false }.build(&g, &cfg).unwrap();
        assert!(arm.metrics.ram_arena < x86.metrics.ram_arena);
    }
}
