//! TFLite-Micro backends: `tflmi` (interpreter) and `tflmc` (TFLite
//! Micro Compiler codegen). Both loop over the same reference kernels,
//! so their invoke instruction counts are identical by construction
//! (Table IV ±0 %); they differ in ROM (interpreter + flatbuffer vs
//! static code), RAM (interpreter state) and setup (parse vs none).

use anyhow::Result;

use crate::calib;
use crate::graph::Graph;
use crate::kernels::{distinct_kernel_types, KernelLib};
use crate::tinyir::Program;

use super::builder::{lower, LowerOpts};
use super::planner::{plan, PlannerKind};
use super::{Backend, BackendConfig, BuildMetrics, BuildResult};

fn conv_channels(g: &Graph) -> u64 {
    g.ops
        .iter()
        .filter(|o| o.opcode.is_conv_like())
        .map(|o| *g.tensor(o.outputs[0]).shape.last().unwrap_or(&0) as u64)
        .sum()
}

fn setup_instructions(m: &calib::SetupModel, g: &Graph, arena: u64) -> u64 {
    (m.fixed
        + m.per_op * g.ops.len() as f64
        + m.per_conv_channel * conv_channels(g) as f64
        + m.per_arena_byte * arena as f64
        + m.per_weight_byte * g.weight_bytes() as f64) as u64
}

fn tflm_common(g: &Graph, name: &str) -> Result<Program> {
    lower(
        g,
        name,
        LowerOpts {
            lib: KernelLib::TflmRef,
            legalize_i16: false,
            transform_input: false,
        },
    )
}

/// `tflmi` — the TFLite Micro Interpreter backend.
pub struct Tflmi;

impl Backend for Tflmi {
    fn name(&self) -> &'static str {
        "tflmi"
    }
    fn framework(&self) -> &'static str {
        "tflm"
    }

    fn build(&self, g: &Graph, _cfg: &BackendConfig) -> Result<BuildResult> {
        let mut program = tflm_common(g, &format!("{}-tflmi", g.name))?;
        let arena = plan(&mut program, PlannerKind::GreedyArena) as u64;
        // kernel library: one reference kernel per op *type*
        let kernel_code =
            distinct_kernel_types(g) as u64 * calib::TFLM_KERNEL_CODE_PER_TYPE;
        let n_tensors = g.tensors.len() as u64;
        let metrics = BuildMetrics {
            setup_instructions: setup_instructions(&calib::TFLMI_SETUP, g, arena),
            rom_code: calib::TFLMI_RUNTIME_ROM + calib::MLIF_ROM + kernel_code,
            // the interpreter embeds the whole model container:
            // weights + flatbuffer metadata per tensor/op
            rom_weights: g.weight_bytes() as u64,
            rom_misc: n_tensors * calib::FLATBUFFER_OVERHEAD_PER_TENSOR,
            ram_arena: arena,
            ram_workspace: program.workspace_size as u64,
            ram_runtime: calib::TFLMI_RUNTIME_RAM_FIXED
                + n_tensors * calib::TFLMI_RUNTIME_RAM_PER_TENSOR
                + calib::MLIF_RAM,
        };
        Ok(BuildResult { program, metrics, schedule: None })
    }
}

/// `tflmc` — the TFLite Micro Compiler backend [paper ref 4]: static
/// inference code, interpreter eliminated.
pub struct Tflmc;

impl Backend for Tflmc {
    fn name(&self) -> &'static str {
        "tflmc"
    }
    fn framework(&self) -> &'static str {
        "tflm"
    }

    fn build(&self, g: &Graph, _cfg: &BackendConfig) -> Result<BuildResult> {
        let mut program = tflm_common(g, &format!("{}-tflmc", g.name))?;
        let arena = plan(&mut program, PlannerKind::GreedyArena) as u64;
        let kernel_code =
            distinct_kernel_types(g) as u64 * calib::TFLM_KERNEL_CODE_PER_TYPE;
        // generated dispatch code replaces the interpreter: ~90 B/op
        let gen_code = 90 * g.ops.len() as u64;
        let metrics = BuildMetrics {
            setup_instructions: setup_instructions(&calib::TFLMC_SETUP, g, arena),
            rom_code: calib::TFLMC_RUNTIME_ROM
                + calib::MLIF_ROM
                + kernel_code
                + gen_code,
            // raw weight arrays only — flatbuffer stripped
            rom_weights: g.weight_bytes() as u64,
            rom_misc: 0,
            ram_arena: arena,
            ram_workspace: program.workspace_size as u64,
            ram_runtime: calib::TFLMC_RUNTIME_RAM_FIXED + calib::MLIF_RAM,
        };
        Ok(BuildResult { program, metrics, schedule: None })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::model::testutil::tiny_conv;

    #[test]
    fn tflmc_strictly_cheaper_than_tflmi_same_invoke() {
        let g = tiny_conv();
        let cfg = BackendConfig::default();
        let i = Tflmi.build(&g, &cfg).unwrap();
        let c = Tflmc.build(&g, &cfg).unwrap();
        // identical kernels => identical invoke cost (Table IV ±0 %)
        assert_eq!(
            i.program.ref_invoke_instructions(),
            c.program.ref_invoke_instructions()
        );
        // tflmc: less ROM, less RAM, much less setup
        assert!(c.metrics.rom_total() < i.metrics.rom_total());
        assert!(c.metrics.ram_total() < i.metrics.ram_total());
        assert!(
            (c.metrics.setup_instructions as f64)
                < 0.3 * i.metrics.setup_instructions as f64
        );
        // the ROM delta is interpreter-sized: 15–40 kB (paper: 15–30)
        let delta = i.metrics.rom_total() - c.metrics.rom_total();
        assert!((15_000..45_000).contains(&delta), "{delta}");
    }

    #[test]
    fn arena_planned_and_valid() {
        let g = tiny_conv();
        let r = Tflmi.build(&g, &BackendConfig::default()).unwrap();
        r.program.check_plan().unwrap();
        assert!(r.metrics.ram_arena >= (4 * 4 * 3) as u64);
    }
}
