//! Memory planners — the RAM story of Table IV.
//!
//! * `greedy_arena` — TFLite-Micro's GreedyMemoryPlanner: place
//!   buffers in decreasing size order at the lowest offset that does
//!   not collide with an already-placed, lifetime-overlapping buffer.
//! * `storage_tokens` — TVM's classic GraphPlanMemory: freed storage
//!   "tokens" are reused only by tensors that fit an existing token
//!   (tokens are never split or merged) — decent but conservative.
//! * `usmp_interval` — TVM's Unified Static Memory Planner: full
//!   interval packing (first-fit over live ranges), the tvmaot+
//!   improvement (−9…−28 % RAM in the paper).
//! * `no_reuse` — every buffer gets its own slot (tvmrt's behaviour:
//!   the graph executor allocates all storage up front).
//!
//! All planners fill `BufferDecl::offset` and `Program::arena_size`,
//! and every plan must pass `Program::check_plan()` (no live-range
//! overlap) — property-tested in tests/planner_props.rs.

use crate::tinyir::Program;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlannerKind {
    GreedyArena,
    StorageTokens,
    UsmpInterval,
    NoReuse,
}

impl PlannerKind {
    pub fn name(self) -> &'static str {
        match self {
            PlannerKind::GreedyArena => "greedy_arena",
            PlannerKind::StorageTokens => "storage_tokens",
            PlannerKind::UsmpInterval => "usmp_interval",
            PlannerKind::NoReuse => "no_reuse",
        }
    }
}

/// Plan a program in place; returns the arena size.
pub fn plan(p: &mut Program, kind: PlannerKind) -> usize {
    match kind {
        PlannerKind::GreedyArena => greedy_arena(p),
        PlannerKind::StorageTokens => storage_tokens(p),
        PlannerKind::UsmpInterval => usmp_interval(p),
        PlannerKind::NoReuse => no_reuse(p),
    }
    debug_assert!(p.check_plan().is_ok(), "planner produced colliding plan");
    p.arena_size
}

fn lifetimes_overlap(p: &Program, a: usize, b: usize) -> bool {
    let (ba, bb) = (&p.buffers[a], &p.buffers[b]);
    ba.first_use <= bb.last_use && bb.first_use <= ba.last_use
}

/// TFLM GreedyMemoryPlanner (decreasing size, first gap that fits).
fn greedy_arena(p: &mut Program) {
    let mut order: Vec<usize> = (0..p.buffers.len()).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(p.buffers[i].size));
    let mut placed: Vec<usize> = Vec::new();
    let mut arena = 0usize;
    for &i in &order {
        // collect intervals of lifetime-overlapping, already-placed bufs
        let mut busy: Vec<(usize, usize)> = placed
            .iter()
            .filter(|&&j| lifetimes_overlap(p, i, j))
            .map(|&j| {
                let o = p.buffers[j].offset.unwrap();
                (o, o + p.buffers[j].size)
            })
            .collect();
        busy.sort_unstable();
        let size = p.buffers[i].size;
        let mut cand = 0usize;
        for (s, e) in busy {
            if cand + size <= s {
                break;
            }
            cand = cand.max(e);
        }
        p.buffers[i].offset = Some(cand);
        arena = arena.max(cand + size);
        placed.push(i);
    }
    p.arena_size = arena;
}

/// TVM GraphPlanMemory-style storage tokens: walk buffers in first-use
/// order; a token freed at last_use can be reused by any later tensor
/// with size <= token size; tokens never split.
fn storage_tokens(p: &mut Program) {
    #[derive(Clone)]
    struct Token {
        offset: usize,
        size: usize,
        free_after: usize, // call index after which the token is free
    }
    let mut order: Vec<usize> = (0..p.buffers.len()).collect();
    order.sort_by_key(|&i| (p.buffers[i].first_use, std::cmp::Reverse(p.buffers[i].size)));
    let mut tokens: Vec<Token> = Vec::new();
    let mut arena = 0usize;
    for &i in &order {
        let b = &p.buffers[i];
        // find the *smallest* free token that fits (best-fit, like TVM)
        let mut best: Option<usize> = None;
        for (ti, t) in tokens.iter().enumerate() {
            if t.free_after < b.first_use && t.size >= b.size {
                if best.is_none_or(|bi| tokens[bi].size > t.size) {
                    best = Some(ti);
                }
            }
        }
        let off = match best {
            Some(ti) => {
                tokens[ti].free_after = b.last_use;
                tokens[ti].offset
            }
            None => {
                let off = arena;
                arena += b.size;
                tokens.push(Token { offset: off, size: b.size, free_after: b.last_use });
                off
            }
        };
        p.buffers[i].offset = Some(off);
    }
    p.arena_size = arena;
}

/// USMP: first-fit interval packing over exact live ranges — strictly
/// better than (or equal to) storage tokens.
fn usmp_interval(p: &mut Program) {
    // identical placement rule to greedy_arena but ordered by
    // (size desc) over *exact* byte intervals — the difference from
    // storage_tokens is that space is shared at byte granularity.
    greedy_arena(p);
}

/// tvmrt: all buffers statically distinct, no reuse.
fn no_reuse(p: &mut Program) {
    let mut off = 0usize;
    for b in &mut p.buffers {
        b.offset = Some(off);
        off += b.size;
    }
    p.arena_size = off;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::DType;
    use crate::tinyir::*;

    /// Build a program with a linear chain of N copy calls (classic
    /// ping-pong reuse pattern).
    fn chain(sizes: &[usize]) -> Program {
        let buffers: Vec<BufferDecl> = sizes
            .iter()
            .enumerate()
            .map(|(i, &s)| BufferDecl {
                name: format!("b{i}"),
                size: s,
                dtype: DType::I8,
                offset: None,
                first_use: 0,
                last_use: 0,
            })
            .collect();
        let calls: Vec<KernelCall> = (1..sizes.len())
            .map(|i| KernelCall {
                kind: KernelKind::Copy { elems: sizes[i] },
                inputs: vec![Operand::Buf(i - 1)],
                consts: vec![],
                output: i,
                cost: crate::kernels::copy_cost(sizes[i] as u64),
                origin: format!("c{i}"),
            })
            .collect();
        let n = sizes.len();
        let mut p = Program {
            name: "chain".into(),
            buffers,
            consts: vec![],
            calls,
            input: 0,
            output: n - 1,
            arena_size: 0,
            workspace_size: 0,
        };
        p.recompute_lifetimes();
        p
    }

    #[test]
    fn greedy_reuses_pingpong() {
        let mut p = chain(&[100, 100, 100, 100, 100]);
        let arena = plan(&mut p, PlannerKind::GreedyArena);
        p.check_plan().unwrap();
        // adjacent buffers overlap in time, but b0 and b2 can alias:
        // optimal = 2 slots of 100... wait: call i uses b[i-1] and
        // b[i]; b1 is live calls 0..1, b3 live 2..3 — 2-3 slots
        assert!(arena <= 300, "arena {arena}");
        assert!(arena >= 200);
    }

    #[test]
    fn no_reuse_is_sum_of_sizes() {
        let mut p = chain(&[10, 20, 30]);
        assert_eq!(plan(&mut p, PlannerKind::NoReuse), 60);
        p.check_plan().unwrap();
    }

    #[test]
    fn usmp_never_worse_than_tokens() {
        for sizes in [
            vec![128usize, 64, 256, 64, 32],
            vec![1000, 10, 1000, 10, 1000],
            vec![5, 50, 500, 50, 5, 500],
        ] {
            let mut a = chain(&sizes);
            let mut b = chain(&sizes);
            let usmp = plan(&mut a, PlannerKind::UsmpInterval);
            let tok = plan(&mut b, PlannerKind::StorageTokens);
            assert!(usmp <= tok, "usmp {usmp} > tokens {tok} for {sizes:?}");
        }
    }

    #[test]
    fn tokens_reuse_requires_fit() {
        // big -> small -> big: token of 1000 reused by 10? yes (fits),
        // but second 1000 can reuse the first's token after it frees
        let mut p = chain(&[1000, 10, 1000]);
        let arena = plan(&mut p, PlannerKind::StorageTokens);
        p.check_plan().unwrap();
        // b0 live [0,1), b2 live [1,2): b0's token frees after call 0?
        // last_use(b0)=0 < first_use(b2)=1 -> reused
        assert!(arena <= 1010 + 1000, "{arena}");
    }

    #[test]
    fn all_planners_produce_valid_plans() {
        for kind in [
            PlannerKind::GreedyArena,
            PlannerKind::StorageTokens,
            PlannerKind::UsmpInterval,
            PlannerKind::NoReuse,
        ] {
            let mut p = chain(&[64, 128, 32, 256, 16, 8]);
            plan(&mut p, kind);
            p.check_plan()
                .unwrap_or_else(|e| panic!("{}: {e}", kind.name()));
        }
    }
}
