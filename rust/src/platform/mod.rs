//! Platforms (paper §II-B3): toolchain + flash + serial handling for
//! hardware-style targets. The paper uses the Zephyr project to reach
//! many boards "out of the box"; our `ZephyrSim` reproduces that
//! role over the virtual MCU, including the build/flash latency model
//! that makes Table III's Load–Run column dominated by factors
//! "MLonMCU cannot optimize" (cross-compiling, flashing, running).

pub mod mlif;
pub mod zephyr;

pub use zephyr::{Deployment, ZephyrSim};
