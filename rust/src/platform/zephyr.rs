//! ZephyrSim — the Zephyr-project platform over virtual MCUs:
//! cross-compile (link MLIF + program into a flash image, with a
//! deterministic toolchain-latency model), flash (serial bandwidth
//! model) and run (execute on the virtual MCU, capture UART text).
//!
//! The latency models are *simulated seconds* reported in the run
//! metrics (`sim_*`), not host sleeps — Table III's shape (hardware
//! sessions dominated by flash+run) is reproduced without wasting
//! wall-clock time.

use std::sync::{Arc, OnceLock};

use anyhow::{bail, Result};

use crate::backends::BuildResult;
use crate::mcu::{account_program, ExecPlan, ExecStats, FlashImage, McuSpec};
use crate::platform::mlif::{self, MlifReport};
use crate::tinyir::Program;

/// A compiled + linked application ready to flash.
///
/// A deployment also owns the invoke-side caches: the pre-summed
/// cost-only `ExecStats` (computed at deploy, one struct copy per
/// cost-only invoke — the tuner's measure loop) and a lazily-compiled
/// [`ExecPlan`] shared across repeated compute invokes.
#[derive(Debug, Clone)]
pub struct Deployment {
    pub image: FlashImage,
    pub rom_total: u64,
    pub ram_total: u64,
    /// Simulated toolchain seconds (Compile stage).
    pub sim_build_s: f64,
    /// Simulated flash-programming seconds (Run stage prefix).
    pub sim_flash_s: f64,
    /// Data-independent accounting of one invoke on this target.
    pub invoke_stats: ExecStats,
    /// Compile-once execution plan, built on the first compute invoke.
    plan: OnceLock<Arc<ExecPlan>>,
}

impl Deployment {
    pub fn new(
        image: FlashImage,
        rom_total: u64,
        ram_total: u64,
        sim_build_s: f64,
        sim_flash_s: f64,
        invoke_stats: ExecStats,
    ) -> Deployment {
        Deployment {
            image,
            rom_total,
            ram_total,
            sim_build_s,
            sim_flash_s,
            invoke_stats,
            plan: OnceLock::new(),
        }
    }

    /// The deployment's execution plan, compiled on first use and
    /// reused by every subsequent invoke.
    pub fn exec_plan(&self, p: &Program, spec: &McuSpec) -> Result<Arc<ExecPlan>> {
        if let Some(pl) = self.plan.get() {
            return Ok(pl.clone());
        }
        let pl = Arc::new(ExecPlan::compile(p, spec)?);
        Ok(self.plan.get_or_init(|| pl).clone())
    }
}

/// The Zephyr-like platform.
#[derive(Debug, Clone, Copy, Default)]
pub struct ZephyrSim;

impl ZephyrSim {
    /// Compile stage: link the program against the MLIF, produce the
    /// flash image, enforce the **flash** capacity gate.
    ///
    /// Toolchain latency model: Zephyr builds compile ~400 source
    /// files of RTOS + app glue; TFLM adds many more than TVM (the
    /// paper's 17 s/run vs 9 s/run build-time observation).
    pub fn build(
        &self,
        b: &BuildResult,
        spec: &McuSpec,
        framework: &str,
    ) -> Result<Deployment> {
        let image = FlashImage::link(
            &b.program,
            b.metrics.rom_code,
            b.metrics.rom_misc,
        );
        let rom_total = image.total_bytes();
        let ram_total = b.metrics.ram_total();
        if rom_total > spec.flash_available() {
            bail!(
                "flash overflow on {}: image {} B > available {} B",
                spec.name,
                rom_total,
                spec.flash_available()
            );
        }
        if ram_total > spec.ram_available() {
            bail!(
                "RAM overflow on {}: need {} B > available {} B",
                spec.name,
                ram_total,
                spec.ram_available()
            );
        }
        // deterministic toolchain model: base RTOS build + per-source
        // compile time; TFLM's kernel library is many more files
        let sources = match framework {
            "tflm" => 340.0,
            _ => 60.0,
        } + b.program.calls.len() as f64;
        let sim_build_s = 2.5 + sources * 0.04;
        // flashing at ~48 KiB/s effective serial/JTAG bandwidth
        let sim_flash_s = 1.2 + rom_total as f64 / 48_000.0;
        Ok(Deployment::new(
            image,
            rom_total,
            ram_total,
            sim_build_s,
            sim_flash_s,
            account_program(&b.program, spec),
        ))
    }

    /// Run stage: "flash" the image, execute setup + one invoke on the
    /// virtual MCU, capture the MLIF UART output, and parse it.
    pub fn flash_and_run(
        &self,
        b: &BuildResult,
        dep: &Deployment,
        spec: &McuSpec,
        input: &[i8],
        compute: bool,
    ) -> Result<(MlifReport, f64)> {
        let (output, stats) = if compute {
            let plan = dep.exec_plan(&b.program, spec)?;
            plan.run(&b.program, input)?
        } else {
            // cost-only (tuner measure loop): the accounting was
            // pre-summed at deploy time — no call walk at all
            (Vec::new(), dep.invoke_stats)
        };
        // setup phase runs on the same core: scale the reference count
        // by the ISA's aggregate density (approximate: alu factor)
        let setup_target = (b.metrics.setup_instructions as f64
            * spec.isa.alu_factor) as u64;
        let invoke_cycles = stats.total_cycles()
            + spec.isa.core_cycles(setup_target as f64);
        let report = MlifReport {
            model: b.program.name.clone(),
            setup_instructions: setup_target,
            invoke_instructions: stats.instructions,
            invoke_cycles: stats.total_cycles() as u64,
            invoke_us: (stats.seconds(spec.clock_mhz) * 1e6) as u64,
            output,
        };
        // the firmware prints; the host parses — real code path
        let uart = format!(
            "*** Booting Zephyr OS (virtual {}) ***\n{}",
            spec.name,
            mlif::render(&report)
        );
        let parsed = mlif::parse(&uart)?;
        // simulated run wall time: flash + boot + setup + invoke
        let sim_run_s = dep.sim_flash_s
            + 0.4
            + invoke_cycles / (spec.clock_mhz * 1e6);
        Ok((parsed, sim_run_s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backends::{by_name, BackendConfig};
    use crate::graph::model::testutil::tiny_conv;
    use crate::isa;
    use crate::mcu::MemSystem;

    fn spec(flash: u64, ram: u64) -> McuSpec {
        McuSpec {
            name: "testmcu",
            isa: &isa::CORTEX_M4,
            clock_mhz: 100.0,
            flash_total: flash,
            flash_reserved: 0,
            ram_total: ram,
            ram_reserved: 0,
            memsys: MemSystem::stm32_internal(),
        }
    }

    #[test]
    fn build_and_run_roundtrip() {
        let g = tiny_conv();
        let b = by_name("tflmc")
            .unwrap()
            .build(&g, &BackendConfig::default())
            .unwrap();
        let p = ZephyrSim;
        let dep = p.build(&b, &spec(1 << 22, 1 << 20), "tflm").unwrap();
        assert!(dep.sim_build_s > 10.0, "tflm builds are slow (Table III)");
        let input = vec![1i8; 32];
        let (report, sim_run) = p
            .flash_and_run(&b, &dep, &spec(1 << 22, 1 << 20), &input, true)
            .unwrap();
        assert_eq!(report.output.len(), 4 * 4 * 3);
        assert!(report.invoke_cycles > 0);
        assert!(sim_run > dep.sim_flash_s);
    }

    #[test]
    fn tvm_builds_faster_than_tflm() {
        let g = tiny_conv();
        let bt = by_name("tflmi").unwrap().build(&g, &BackendConfig::default()).unwrap();
        let bv = by_name("tvmaot").unwrap().build(&g, &BackendConfig::default()).unwrap();
        let p = ZephyrSim;
        let s = spec(1 << 22, 1 << 21);
        let dt = p.build(&bt, &s, "tflm").unwrap();
        let dv = p.build(&bv, &s, "tvm").unwrap();
        assert!(
            dv.sim_build_s < 0.6 * dt.sim_build_s,
            "tvm {} vs tflm {}",
            dv.sim_build_s,
            dt.sim_build_s
        );
    }

    #[test]
    fn flash_gate_rejects_oversized_image() {
        let g = tiny_conv();
        let b = by_name("tflmi").unwrap().build(&g, &BackendConfig::default()).unwrap();
        let err = ZephyrSim.build(&b, &spec(1000, 1 << 20), "tflm").unwrap_err();
        assert!(err.to_string().contains("flash overflow"));
    }

    #[test]
    fn ram_gate_rejects_oversized_arena() {
        let g = tiny_conv();
        let b = by_name("tvmrt").unwrap().build(&g, &BackendConfig::default()).unwrap();
        // tvmrt needs its ~1MB heap pool — 128 kB RAM must fail
        let err = ZephyrSim.build(&b, &spec(1 << 22, 128 * 1024), "tvm").unwrap_err();
        assert!(err.to_string().contains("RAM overflow"), "{err}");
    }
}
