//! MLIF — the *Machine Learning Interface* (paper §II-B3): the target
//! software layer that standardizes how models are executed and how
//! benchmark results are reported over the serial port, platform-
//! independently. This module defines the wire protocol: the virtual
//! target prints it on its UART, the host parses it back. Keeping a
//! real text protocol (rather than returning structs) preserves the
//! paper's code-path shape: flash → run → parse serial output.

use anyhow::{bail, Context, Result};

/// Metrics the target firmware reports after a benchmark run.
#[derive(Debug, Clone, PartialEq)]
pub struct MlifReport {
    pub model: String,
    pub setup_instructions: u64,
    pub invoke_instructions: u64,
    pub invoke_cycles: u64,
    /// Microseconds for one invoke at the target clock.
    pub invoke_us: u64,
    /// int8 output tensor of the last inference.
    pub output: Vec<i8>,
}

/// Render the UART text the MLIF firmware prints.
pub fn render(r: &MlifReport) -> String {
    let mut s = String::new();
    s.push_str("MLIF-BEGIN v1\n");
    s.push_str(&format!("model={}\n", r.model));
    s.push_str(&format!("setup_instructions={}\n", r.setup_instructions));
    s.push_str(&format!("invoke_instructions={}\n", r.invoke_instructions));
    s.push_str(&format!("invoke_cycles={}\n", r.invoke_cycles));
    s.push_str(&format!("invoke_us={}\n", r.invoke_us));
    s.push_str("output=");
    for (i, v) in r.output.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&v.to_string());
    }
    s.push('\n');
    s.push_str("MLIF-END OK\n");
    s
}

/// Parse a UART capture back into a report. Tolerates boot noise
/// before MLIF-BEGIN (real consoles print banners).
pub fn parse(uart: &str) -> Result<MlifReport> {
    let body = uart
        .split("MLIF-BEGIN v1")
        .nth(1)
        .context("no MLIF-BEGIN marker in UART output")?;
    if !body.contains("MLIF-END OK") {
        bail!("target did not complete: no MLIF-END OK (crash? OOM?)");
    }
    let mut model = None;
    let mut setup = None;
    let mut invoke = None;
    let mut cycles = None;
    let mut us = None;
    let mut output = None;
    for line in body.lines() {
        if let Some((k, v)) = line.split_once('=') {
            match k.trim() {
                "model" => model = Some(v.trim().to_string()),
                "setup_instructions" => setup = Some(v.trim().parse()?),
                "invoke_instructions" => invoke = Some(v.trim().parse()?),
                "invoke_cycles" => cycles = Some(v.trim().parse()?),
                "invoke_us" => us = Some(v.trim().parse()?),
                "output" => {
                    let vals: Result<Vec<i8>, _> = v
                        .trim()
                        .split(',')
                        .filter(|x| !x.is_empty())
                        .map(|x| x.trim().parse::<i8>())
                        .collect();
                    output = Some(vals?);
                }
                _ => {} // ignore unknown keys (forward compat)
            }
        }
    }
    Ok(MlifReport {
        model: model.context("missing model=")?,
        setup_instructions: setup.context("missing setup_instructions=")?,
        invoke_instructions: invoke.context("missing invoke_instructions=")?,
        invoke_cycles: cycles.context("missing invoke_cycles=")?,
        invoke_us: us.context("missing invoke_us=")?,
        output: output.context("missing output=")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> MlifReport {
        MlifReport {
            model: "aww".into(),
            setup_instructions: 1234,
            invoke_instructions: 29_819_000,
            invoke_cycles: 31_000_000,
            invoke_us: 113_000,
            output: vec![-128, 0, 127, 5],
        }
    }

    #[test]
    fn roundtrip() {
        let r = sample();
        assert_eq!(parse(&render(&r)).unwrap(), r);
    }

    #[test]
    fn tolerates_boot_banner() {
        let uart = format!(
            "*** Booting Zephyr OS v3.3 ***\nuart init ok\n{}",
            render(&sample())
        );
        assert_eq!(parse(&uart).unwrap(), sample());
    }

    #[test]
    fn detects_crash_without_end_marker() {
        let mut text = render(&sample());
        text.truncate(text.find("MLIF-END").unwrap());
        let err = parse(&text).unwrap_err();
        assert!(err.to_string().contains("did not complete"));
    }

    #[test]
    fn missing_fields_are_errors() {
        assert!(parse("MLIF-BEGIN v1\nMLIF-END OK\n").is_err());
    }
}
