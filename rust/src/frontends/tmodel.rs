//! Binary `.tmodel` parser — the rust half of the interchange format
//! defined in python/compile/tmodel.py (see that file for the full
//! layout). Little-endian throughout.

use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::graph::op::{Attrs, OpCode, OpNode};
use crate::graph::{Graph, TensorInfo};
use crate::tensor::DType;

const MAGIC: &[u8; 4] = b"TMDL";
const VERSION: u32 = 1;

pub fn parse_file(path: &Path) -> Result<Graph> {
    let raw = std::fs::read(path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse(&raw)
}

pub fn parse(raw: &[u8]) -> Result<Graph> {
    let mut r = Reader { b: raw, i: 0 };
    ensure!(r.bytes(4)? == MAGIC, "bad magic: not a TModel file");
    let version = r.u32()?;
    ensure!(version == VERSION, "unsupported TModel version {version}");
    let name = r.string()?;
    let n_tensors = r.u32()? as usize;
    let n_ops = r.u32()? as usize;
    ensure!(
        n_tensors < 100_000 && n_ops < 100_000,
        "implausible tensor/op counts"
    );
    let n_in = r.u32()? as usize;
    let inputs: Vec<usize> =
        (0..n_in).map(|_| r.u32().map(|x| x as usize)).collect::<Result<_>>()?;
    let n_out = r.u32()? as usize;
    let outputs: Vec<usize> =
        (0..n_out).map(|_| r.u32().map(|x| x as usize)).collect::<Result<_>>()?;

    let mut tensors = Vec::with_capacity(n_tensors);
    for _ in 0..n_tensors {
        let tname = r.string()?;
        let dtype = DType::from_u8(r.u8()?)?;
        let ndim = r.u8()? as usize;
        let shape: Vec<usize> =
            (0..ndim).map(|_| r.u32().map(|x| x as usize)).collect::<Result<_>>()?;
        let scale = r.f32()?;
        let zero_point = r.i32()?;
        let has_data = r.u8()?;
        let data = if has_data == 1 {
            let len = r.u64()? as usize;
            let expected: usize =
                shape.iter().product::<usize>() * dtype.size();
            ensure!(
                len == expected,
                "{tname}: data len {len} != shape-implied {expected}"
            );
            Some(r.bytes(len)?.to_vec())
        } else {
            None
        };
        tensors.push(TensorInfo { name: tname, shape, dtype, scale, zero_point, data });
    }

    let mut ops = Vec::with_capacity(n_ops);
    for _ in 0..n_ops {
        let opcode = OpCode::from_u8(r.u8()?)?;
        let oname = r.string()?;
        let ni = r.u8()? as usize;
        let op_in: Vec<usize> =
            (0..ni).map(|_| r.u32().map(|x| x as usize)).collect::<Result<_>>()?;
        let no = r.u8()? as usize;
        let op_out: Vec<usize> =
            (0..no).map(|_| r.u32().map(|x| x as usize)).collect::<Result<_>>()?;
        let na = r.u8()? as usize;
        let mut attrs = Attrs::new();
        for _ in 0..na {
            let klen = r.u8()? as usize;
            let key = String::from_utf8(r.bytes(klen)?.to_vec())?;
            let val = r.i64()?;
            attrs.insert(key, val);
        }
        ops.push(OpNode { opcode, name: oname, inputs: op_in, outputs: op_out, attrs });
    }

    ensure!(r.i == raw.len(), "trailing bytes after model body");
    Ok(Graph { name, tensors, ops, inputs, outputs })
}

/// Serialize a graph to .tmodel bytes — the exact inverse of `parse`,
/// byte-compatible with the python writer (tmodel.py). Lets rust-side
/// tests and tools generate model files without the python toolchain.
///
/// Panics if a count exceeds its on-disk field width (u8 for rank,
/// op arity and attr keys) — better a writer assert naming the
/// problem than a truncated file the parser rejects obscurely.
pub fn write(g: &Graph) -> Vec<u8> {
    for t in &g.tensors {
        assert!(t.shape.len() <= u8::MAX as usize, "{}: rank > 255", t.name);
    }
    for op in &g.ops {
        assert!(
            op.inputs.len() <= u8::MAX as usize
                && op.outputs.len() <= u8::MAX as usize
                && op.attrs.len() <= u8::MAX as usize,
            "{}: op arity/attrs > 255",
            op.name
        );
        for k in op.attrs.keys() {
            assert!(k.len() <= u8::MAX as usize, "{}: attr key > 255 B", op.name);
        }
    }
    let mut v = Vec::new();
    v.extend(MAGIC);
    v.extend(VERSION.to_le_bytes());
    put_string(&mut v, &g.name);
    v.extend((g.tensors.len() as u32).to_le_bytes());
    v.extend((g.ops.len() as u32).to_le_bytes());
    v.extend((g.inputs.len() as u32).to_le_bytes());
    for &i in &g.inputs {
        v.extend((i as u32).to_le_bytes());
    }
    v.extend((g.outputs.len() as u32).to_le_bytes());
    for &o in &g.outputs {
        v.extend((o as u32).to_le_bytes());
    }
    for t in &g.tensors {
        put_string(&mut v, &t.name);
        v.push(t.dtype.to_u8());
        v.push(t.shape.len() as u8);
        for &d in &t.shape {
            v.extend((d as u32).to_le_bytes());
        }
        v.extend(t.scale.to_le_bytes());
        v.extend(t.zero_point.to_le_bytes());
        match &t.data {
            Some(d) => {
                v.push(1);
                v.extend((d.len() as u64).to_le_bytes());
                v.extend(d);
            }
            None => v.push(0),
        }
    }
    for op in &g.ops {
        v.push(op.opcode.to_u8());
        put_string(&mut v, &op.name);
        v.push(op.inputs.len() as u8);
        for &i in &op.inputs {
            v.extend((i as u32).to_le_bytes());
        }
        v.push(op.outputs.len() as u8);
        for &o in &op.outputs {
            v.extend((o as u32).to_le_bytes());
        }
        v.push(op.attrs.len() as u8);
        for (k, &val) in &op.attrs {
            v.push(k.len() as u8);
            v.extend(k.as_bytes());
            v.extend(val.to_le_bytes());
        }
    }
    v
}

pub fn write_file(g: &Graph, path: &Path) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, write(g))
        .with_context(|| format!("writing {}", path.display()))
}

fn put_string(v: &mut Vec<u8>, s: &str) {
    v.extend((s.len() as u32).to_le_bytes());
    v.extend(s.as_bytes());
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("truncated TModel at byte {}", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }
    fn u32(&mut self) -> Result<u32> {
        let b = self.bytes(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
    fn i32(&mut self) -> Result<i32> {
        let b = self.bytes(4)?;
        Ok(i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
    fn u64(&mut self) -> Result<u64> {
        let b = self.bytes(8)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }
    fn i64(&mut self) -> Result<i64> {
        let b = self.bytes(8)?;
        Ok(i64::from_le_bytes(b.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32> {
        let b = self.bytes(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
    fn string(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        ensure!(n < 1 << 20, "implausible string length {n}");
        Ok(String::from_utf8(self.bytes(n)?.to_vec())?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serialize a minimal model by hand, matching the python writer.
    fn tiny_bytes() -> Vec<u8> {
        let mut v = Vec::new();
        v.extend(MAGIC);
        v.extend(1u32.to_le_bytes()); // version
        v.extend(4u32.to_le_bytes());
        v.extend(b"tiny");
        v.extend(2u32.to_le_bytes()); // n_tensors
        v.extend(1u32.to_le_bytes()); // n_ops
        v.extend(1u32.to_le_bytes()); // n_inputs
        v.extend(0u32.to_le_bytes());
        v.extend(1u32.to_le_bytes()); // n_outputs
        v.extend(1u32.to_le_bytes());
        // tensor 0: input [1,4] i8 scale 0.5 zp 3, no data
        v.extend(5u32.to_le_bytes());
        v.extend(b"input");
        v.push(0); // i8
        v.push(2); // ndim
        v.extend(1u32.to_le_bytes());
        v.extend(4u32.to_le_bytes());
        v.extend(0.5f32.to_le_bytes());
        v.extend(3i32.to_le_bytes());
        v.push(0); // no data
        // tensor 1: out [1,4] i8
        v.extend(3u32.to_le_bytes());
        v.extend(b"out");
        v.push(0);
        v.push(2);
        v.extend(1u32.to_le_bytes());
        v.extend(4u32.to_le_bytes());
        v.extend(0.25f32.to_le_bytes());
        v.extend((-1i32).to_le_bytes());
        v.push(0);
        // op: SOFTMAX "sm" [0] -> [1], 0 attrs
        v.push(7);
        v.extend(2u32.to_le_bytes());
        v.extend(b"sm");
        v.push(1);
        v.extend(0u32.to_le_bytes());
        v.push(1);
        v.extend(1u32.to_le_bytes());
        v.push(0);
        v
    }

    #[test]
    fn parses_hand_built_model() {
        let g = parse(&tiny_bytes()).unwrap();
        assert_eq!(g.name, "tiny");
        assert_eq!(g.tensors.len(), 2);
        assert_eq!(g.tensors[0].shape, vec![1, 4]);
        assert_eq!(g.tensors[0].zero_point, 3);
        assert_eq!(g.ops[0].opcode, OpCode::Softmax);
        g.validate().unwrap();
    }

    #[test]
    fn rejects_truncation_everywhere() {
        let full = tiny_bytes();
        // every strict prefix must fail cleanly, never panic
        for cut in 0..full.len() {
            assert!(parse(&full[..cut]).is_err(), "prefix {cut} accepted");
        }
    }

    #[test]
    fn rejects_bad_magic_and_version() {
        let mut v = tiny_bytes();
        v[0] = b'X';
        assert!(parse(&v).is_err());
        let mut v = tiny_bytes();
        v[4] = 9;
        assert!(parse(&v).is_err());
    }

    #[test]
    fn rejects_trailing_garbage() {
        let mut v = tiny_bytes();
        v.push(0);
        assert!(parse(&v).is_err());
    }

    #[test]
    fn write_parse_roundtrip_hand_built() {
        // writer must emit exactly the hand-serialized reference bytes
        let g = parse(&tiny_bytes()).unwrap();
        assert_eq!(write(&g), tiny_bytes());
    }

    #[test]
    fn write_parse_roundtrip_conv_graph() {
        let g = crate::graph::model::testutil::tiny_conv();
        let bytes = write(&g);
        let back = parse(&bytes).unwrap();
        back.validate().unwrap();
        assert_eq!(back.name, g.name);
        assert_eq!(back.tensors.len(), g.tensors.len());
        assert_eq!(back.ops[0].attrs, g.ops[0].attrs);
        assert_eq!(back.content_hash(), g.content_hash());
    }
}
