//! Frontends (paper §II-B1): resolve a model name or path into a
//! loaded `Graph` during the **Load** stage. The only on-disk format is
//! `.tmodel` (our TFLite-flatbuffer substitute, written by
//! python/compile/zoo.py).

pub mod tmodel;

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::graph::Graph;

/// Resolve a model name ("aww") or explicit path ("/x/y.tmodel")
/// against the model directories, then parse it.
pub fn load_model(name_or_path: &str, model_dirs: &[PathBuf]) -> Result<Graph> {
    let path = resolve(name_or_path, model_dirs)?;
    let graph = tmodel::parse_file(&path)
        .with_context(|| format!("loading {}", path.display()))?;
    graph.validate()?;
    Ok(graph)
}

/// Parse + validate a model from already-read .tmodel bytes. The
/// session scheduler fingerprints the file contents for its cache
/// keys and hands the same bytes to the Load stage, so each model
/// file is read exactly once and the loaded graph always matches the
/// fingerprinted content.
pub fn load_model_from_bytes(raw: &[u8], origin: &str) -> Result<Graph> {
    let graph =
        tmodel::parse(raw).with_context(|| format!("loading {origin}"))?;
    graph.validate()?;
    Ok(graph)
}

/// Model lookup: explicit path wins; otherwise `<dir>/<name>.tmodel`
/// over the search path.
pub fn resolve(name_or_path: &str, model_dirs: &[PathBuf]) -> Result<PathBuf> {
    let p = Path::new(name_or_path);
    if p.extension().is_some() {
        if p.is_file() {
            return Ok(p.to_path_buf());
        }
        bail!("model file not found: {name_or_path}");
    }
    for dir in model_dirs {
        let cand = dir.join(format!("{name_or_path}.tmodel"));
        if cand.is_file() {
            return Ok(cand);
        }
    }
    bail!(
        "model '{name_or_path}' not found in {:?} — run `make artifacts` \
         to generate the zoo",
        model_dirs
    )
}

/// List models available in the search path (CLI `models ls`).
pub fn list_models(model_dirs: &[PathBuf]) -> Vec<String> {
    let mut names = Vec::new();
    for dir in model_dirs {
        if let Ok(entries) = std::fs::read_dir(dir) {
            for e in entries.flatten() {
                let p = e.path();
                if p.extension().is_some_and(|x| x == "tmodel") {
                    if let Some(stem) = p.file_stem() {
                        names.push(stem.to_string_lossy().to_string());
                    }
                }
            }
        }
    }
    names.sort();
    names.dedup();
    names
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_missing_is_helpful() {
        let err = resolve("nosuch", &[PathBuf::from("/tmp")]).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn resolve_explicit_path_must_exist() {
        assert!(resolve("/does/not/exist.tmodel", &[]).is_err());
    }
}
