//! Features (paper §II-B5): components that change how the other
//! components interact. Each feature hooks into specific stages:
//!
//!   * `validate` — compare target outputs against the JAX/Pallas
//!     golden reference via PJRT ("golden reference values ... useful
//!     to detect if a framework degrades the models' accuracy").
//!   * `autotvm` — insert the Tune stage before Build.
//!   * `usmp` — alias: retarget tvmaot to tvmaot+ behaviour.
//!   * `debug-arena` — verify the memory plan and record arena stats.
//!
//! Features are parsed from CLI strings ("validate", "autotvm") and
//! applied by the session to each run.

use std::collections::BTreeSet;

use anyhow::{bail, Result};

/// The feature set of a run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Features {
    set: BTreeSet<String>,
}

pub const KNOWN: [&str; 4] = ["validate", "autotvm", "usmp", "debug-arena"];

impl Features {
    pub fn parse(names: &[String]) -> Result<Features> {
        let mut set = BTreeSet::new();
        for n in names {
            if !KNOWN.contains(&n.as_str()) {
                bail!("unknown feature '{n}' (known: {KNOWN:?})");
            }
            set.insert(n.clone());
        }
        Ok(Features { set })
    }

    pub fn has(&self, name: &str) -> bool {
        self.set.contains(name)
    }

    pub fn validate(&self) -> bool {
        self.has("validate")
    }
    pub fn autotvm(&self) -> bool {
        self.has("autotvm")
    }
    pub fn usmp(&self) -> bool {
        self.has("usmp")
    }
    pub fn debug_arena(&self) -> bool {
        self.has("debug-arena")
    }

    pub fn names(&self) -> Vec<String> {
        self.set.iter().cloned().collect()
    }
}

/// Result of the validate feature on one run.
#[derive(Debug, Clone, PartialEq)]
pub enum Validation {
    /// Max |device − golden| quantum difference observed.
    Pass { max_diff: i32 },
    Fail { max_diff: i32, first_mismatch: usize },
    /// Feature disabled or golden unavailable.
    Skipped,
}

impl Validation {
    pub fn label(&self) -> String {
        match self {
            Validation::Pass { max_diff } => format!("pass(\u{0394}{max_diff})"),
            Validation::Fail { max_diff, first_mismatch } => {
                format!("FAIL(\u{0394}{max_diff}@{first_mismatch})")
            }
            Validation::Skipped => "-".to_string(),
        }
    }
}

/// Compare device output vs golden with a quantum tolerance
/// (±1 covers the softmax f32-exp ulp difference; everything else is
/// bit-exact — see DESIGN.md §1).
pub fn compare_outputs(device: &[i8], golden: &[i8], atol: i32) -> Validation {
    if device.len() != golden.len() {
        return Validation::Fail {
            max_diff: i32::MAX,
            first_mismatch: device.len().min(golden.len()),
        };
    }
    let mut max_diff = 0i32;
    let mut first = None;
    for (i, (&d, &g)) in device.iter().zip(golden).enumerate() {
        let diff = (d as i32 - g as i32).abs();
        if diff > max_diff {
            max_diff = diff;
        }
        if diff > atol && first.is_none() {
            first = Some(i);
        }
    }
    match first {
        None => Validation::Pass { max_diff },
        Some(i) => Validation::Fail { max_diff, first_mismatch: i },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_known_features() {
        let f = Features::parse(&["validate".into(), "autotvm".into()]).unwrap();
        assert!(f.validate() && f.autotvm() && !f.usmp());
        assert_eq!(f.names(), vec!["autotvm", "validate"]);
    }

    #[test]
    fn unknown_feature_rejected() {
        assert!(Features::parse(&["warp-drive".into()]).is_err());
    }

    #[test]
    fn compare_exact_pass() {
        let v = compare_outputs(&[1, 2, 3], &[1, 2, 3], 0);
        assert_eq!(v, Validation::Pass { max_diff: 0 });
    }

    #[test]
    fn compare_within_tolerance() {
        let v = compare_outputs(&[1, 2, 4], &[1, 2, 3], 1);
        assert_eq!(v, Validation::Pass { max_diff: 1 });
    }

    #[test]
    fn compare_fail_reports_position() {
        let v = compare_outputs(&[1, 9, 3], &[1, 2, 3], 1);
        assert_eq!(v, Validation::Fail { max_diff: 7, first_mismatch: 1 });
    }

    #[test]
    fn length_mismatch_fails() {
        assert!(matches!(
            compare_outputs(&[1], &[1, 2], 0),
            Validation::Fail { .. }
        ));
    }
}
