//! Postprocesses (paper §II-B4): predefined procedures applied in the
//! final stage — report transforms (filter/rename/sort) and artifact
//! generators (ASCII bar-chart visualization).

use anyhow::{bail, Result};

use crate::report::{Cell, Report};

/// A postprocess step, parsed from "name" or "name:arg1,arg2".
#[derive(Debug, Clone, PartialEq)]
pub enum Postprocess {
    /// Keep only these columns.
    FilterCols(Vec<String>),
    /// Rename column old→new.
    RenameCol(String, String),
    /// Sort rows by a column (ascending; Missing last).
    SortBy(String),
    /// Render an ASCII bar chart of a numeric column into an artifact.
    Visualize(String),
}

impl Postprocess {
    pub fn parse(spec: &str) -> Result<Postprocess> {
        let (name, args) = match spec.split_once(':') {
            Some((n, a)) => (n, a.split(',').map(str::trim).collect::<Vec<_>>()),
            None => (spec, Vec::new()),
        };
        Ok(match name {
            "filter_cols" => {
                if args.is_empty() {
                    bail!("filter_cols needs columns: filter_cols:a,b");
                }
                Postprocess::FilterCols(
                    args.iter().map(|s| s.to_string()).collect(),
                )
            }
            "rename_col" => {
                if args.len() != 2 {
                    bail!("rename_col:old,new");
                }
                Postprocess::RenameCol(args[0].into(), args[1].into())
            }
            "sort_by" => {
                if args.len() != 1 {
                    bail!("sort_by:column");
                }
                Postprocess::SortBy(args[0].into())
            }
            "visualize" => {
                if args.len() != 1 {
                    bail!("visualize:column");
                }
                Postprocess::Visualize(args[0].into())
            }
            other => bail!("unknown postprocess '{other}'"),
        })
    }

    /// Apply to a report; may return an extra artifact (name, text).
    pub fn apply(&self, report: &mut Report) -> Result<Option<(String, String)>> {
        match self {
            Postprocess::FilterCols(cols) => {
                let refs: Vec<&str> = cols.iter().map(String::as_str).collect();
                *report = report.select(&refs);
                Ok(None)
            }
            Postprocess::RenameCol(old, new) => {
                for c in report.columns.iter_mut() {
                    if c == old {
                        *c = new.clone();
                    }
                }
                for row in report.rows.iter_mut() {
                    if let Some(v) = row.remove(old) {
                        row.insert(new.clone(), v);
                    }
                }
                Ok(None)
            }
            Postprocess::SortBy(col) => {
                report.rows.sort_by(|a, b| {
                    let av = a.get(col).and_then(|c| c.as_f64());
                    let bv = b.get(col).and_then(|c| c.as_f64());
                    match (av, bv) {
                        (Some(x), Some(y)) => x.total_cmp(&y),
                        (Some(_), None) => std::cmp::Ordering::Less,
                        (None, Some(_)) => std::cmp::Ordering::Greater,
                        (None, None) => std::cmp::Ordering::Equal,
                    }
                });
                Ok(None)
            }
            Postprocess::Visualize(col) => {
                Ok(Some((format!("{col}.chart.txt"), bar_chart(report, col))))
            }
        }
    }
}

/// ASCII horizontal bar chart of a numeric column, labelled by the
/// first string-ish column.
pub fn bar_chart(report: &Report, col: &str) -> String {
    let label_col = report
        .columns
        .iter()
        .find(|c| c.as_str() != col)
        .cloned()
        .unwrap_or_default();
    let vals: Vec<(String, Option<f64>)> = report
        .rows
        .iter()
        .map(|r| {
            (
                r.get(&label_col).map_or(String::new(), |c| c.render()),
                r.get(col).and_then(|c| c.as_f64()),
            )
        })
        .collect();
    let max = vals
        .iter()
        .filter_map(|(_, v)| *v)
        .fold(f64::MIN, f64::max)
        .max(1e-12);
    let width = 50usize;
    let mut s = format!("{col} (max {max:.4})\n");
    for (label, v) in vals {
        match v {
            Some(v) => {
                let n = ((v / max) * width as f64).round().clamp(0.0, width as f64)
                    as usize;
                s.push_str(&format!(
                    "{label:>16} | {}{} {v:.4}\n",
                    "#".repeat(n),
                    " ".repeat(width - n)
                ));
            }
            None => s.push_str(&format!("{label:>16} | — (failed)\n")),
        }
    }
    s
}

/// Parse and apply a pipeline of postprocess specs.
pub fn apply_all(
    specs: &[String],
    report: &mut Report,
) -> Result<Vec<(String, String)>> {
    let mut artifacts = Vec::new();
    for spec in specs {
        let p = Postprocess::parse(spec)?;
        if let Some(a) = p.apply(report)? {
            artifacts.push(a);
        }
    }
    Ok(artifacts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::report::row;

    fn sample() -> Report {
        let mut r = Report::default();
        for (m, t) in [("aww", 0.2), ("vww", 1.4), ("toycar", 0.05)] {
            r.push(row(vec![
                ("model", Cell::Str(m.into())),
                ("time_s", Cell::Float(t)),
            ]));
        }
        r.push(row(vec![
            ("model", Cell::Str("fail".into())),
            ("time_s", Cell::Missing),
        ]));
        r
    }

    #[test]
    fn parse_specs() {
        assert_eq!(
            Postprocess::parse("filter_cols:a,b").unwrap(),
            Postprocess::FilterCols(vec!["a".into(), "b".into()])
        );
        assert!(Postprocess::parse("rename_col:only-one").is_err());
        assert!(Postprocess::parse("bogus").is_err());
    }

    #[test]
    fn sort_puts_missing_last() {
        let mut r = sample();
        Postprocess::parse("sort_by:time_s").unwrap().apply(&mut r).unwrap();
        assert_eq!(r.rows[0]["model"].render(), "toycar");
        assert_eq!(r.rows[3]["model"].render(), "fail");
    }

    #[test]
    fn visualize_produces_chart_artifact() {
        let mut r = sample();
        let arts = apply_all(&["visualize:time_s".into()], &mut r).unwrap();
        assert_eq!(arts.len(), 1);
        assert!(arts[0].1.contains('#'));
        assert!(arts[0].1.contains("failed"));
    }

    #[test]
    fn pipeline_filter_then_rename() {
        let mut r = sample();
        apply_all(
            &["filter_cols:model,time_s".into(), "rename_col:time_s,latency".into()],
            &mut r,
        )
        .unwrap();
        assert_eq!(r.columns, vec!["model", "latency"]);
    }
}
