//! Targets (paper §II-B3): the devices/simulators a run executes on.
//!
//! * `etiss` — the ETISS instruction-set simulator target (RV32GC):
//!   reports exact instruction counts, no memory-stall modelling, no
//!   real memory limits. Used for the Table IV backend comparison.
//! * `esp32c3`, `stm32f4`, `stm32f7`, `esp32` — the Table II hardware
//!   targets, reached through the ZephyrSim platform: flash/RAM gates,
//!   per-ISA cycle accounting, memory-system stalls, UART reporting.

use anyhow::Result;

use crate::backends::BuildResult;
use crate::isa;
use crate::mcu::{account_program, McuSpec, MemSystem};
use crate::platform::{Deployment, ZephyrSim};

/// Everything a run reports back from the target (report columns).
#[derive(Debug, Clone, Default)]
pub struct RunOutcome {
    pub setup_instructions: u64,
    pub invoke_instructions: u64,
    pub invoke_cycles: u64,
    pub invoke_seconds: f64,
    pub output: Vec<i8>,
    /// Simulated stage durations (Table III shape).
    pub sim_build_s: f64,
    pub sim_flash_s: f64,
    pub sim_run_s: f64,
}

/// A benchmark target.
pub trait Target: Send + Sync {
    fn name(&self) -> &'static str;
    fn spec(&self) -> &McuSpec;
    /// Whether the autotvm feature can measure on this target — the
    /// paper could not tune on the esp32 (Table V's all-"—" column).
    fn supports_tuning(&self) -> bool {
        true
    }
    /// Compile stage: link + capacity gates. Errors mean "—" cells.
    fn deploy(&self, build: &BuildResult, framework: &str) -> Result<Deployment>;
    /// Run stage.
    fn run(
        &self,
        build: &BuildResult,
        dep: &Deployment,
        input: &[i8],
        compute: bool,
    ) -> Result<RunOutcome>;
}

// ---------------------------------------------------------------- ETISS --

/// The ETISS ISS target [paper ref 7]: RV32GC @ 100 MHz, host memory.
pub struct Etiss {
    spec: McuSpec,
}

impl Default for Etiss {
    fn default() -> Self {
        Etiss {
            spec: McuSpec {
                name: "etiss",
                isa: &isa::RV32GC,
                clock_mhz: 100.0,
                flash_total: 1 << 31,
                flash_reserved: 0,
                ram_total: 1 << 31,
                ram_reserved: 0,
                memsys: MemSystem::ideal(),
            },
        }
    }
}

impl Target for Etiss {
    fn name(&self) -> &'static str {
        "etiss"
    }
    fn spec(&self) -> &McuSpec {
        &self.spec
    }

    fn deploy(&self, build: &BuildResult, _framework: &str) -> Result<Deployment> {
        // ISS: no real flash process; still produce the image for
        // artifact inspection, without capacity gates.
        let image = crate::mcu::FlashImage::link(
            &build.program,
            build.metrics.rom_code,
            build.metrics.rom_misc,
        );
        let rom_total = image.total_bytes();
        Ok(Deployment::new(
            image,
            rom_total,
            build.metrics.ram_total(),
            1.0 + build.program.calls.len() as f64 * 0.02,
            0.0,
            account_program(&build.program, &self.spec),
        ))
    }

    fn run(
        &self,
        build: &BuildResult,
        dep: &Deployment,
        input: &[i8],
        compute: bool,
    ) -> Result<RunOutcome> {
        let (output, stats) = if compute {
            let plan = dep.exec_plan(&build.program, &self.spec)?;
            plan.run(&build.program, input)?
        } else {
            (Vec::new(), dep.invoke_stats)
        };
        Ok(RunOutcome {
            setup_instructions: build.metrics.setup_instructions,
            invoke_instructions: stats.ref_instructions,
            invoke_cycles: stats.total_cycles() as u64,
            invoke_seconds: stats.seconds(self.spec.clock_mhz),
            output,
            sim_build_s: dep.sim_build_s,
            sim_flash_s: 0.0,
            // ISS run time scales with simulated instructions
            // (~30 MIPS simulation speed)
            sim_run_s: stats.ref_instructions as f64 / 30e6,
        })
    }
}

// ------------------------------------------------------------- hardware --

/// A Table II hardware target behind the ZephyrSim platform.
pub struct HwTarget {
    spec: McuSpec,
    platform: ZephyrSim,
    tuning: bool,
}

impl Target for HwTarget {
    fn name(&self) -> &'static str {
        self.spec.name
    }
    fn spec(&self) -> &McuSpec {
        &self.spec
    }
    fn supports_tuning(&self) -> bool {
        self.tuning
    }

    fn deploy(&self, build: &BuildResult, framework: &str) -> Result<Deployment> {
        self.platform.build(build, &self.spec, framework)
    }

    fn run(
        &self,
        build: &BuildResult,
        dep: &Deployment,
        input: &[i8],
        compute: bool,
    ) -> Result<RunOutcome> {
        let (report, sim_run_s) =
            self.platform
                .flash_and_run(build, dep, &self.spec, input, compute)?;
        Ok(RunOutcome {
            setup_instructions: report.setup_instructions,
            invoke_instructions: report.invoke_instructions,
            invoke_cycles: report.invoke_cycles,
            invoke_seconds: report.invoke_us as f64 / 1e6,
            output: report.output,
            sim_build_s: dep.sim_build_s,
            sim_flash_s: dep.sim_flash_s,
            sim_run_s,
        })
    }
}

/// Table II: esp32c3 — RV32IMC @ 160 MHz, 2 MB flash (SPI, cached),
/// 384 kB SRAM.
pub fn esp32c3() -> HwTarget {
    HwTarget {
        spec: McuSpec {
            name: "esp32c3",
            isa: &isa::RV32IMC_ESP32C3,
            clock_mhz: 160.0,
            flash_total: 2_000_000,
            flash_reserved: 120_000, // bootloader + partition table
            ram_total: 384_000,
            ram_reserved: 50_000, // IDF/Zephyr runtime reserve
            memsys: MemSystem::esp_spi(),
        },
        platform: ZephyrSim,
        tuning: true,
    }
}

/// Table II: stm32f4 — Cortex-M4 @ 100 MHz, 1.5 MB flash, 320 kB RAM.
pub fn stm32f4() -> HwTarget {
    HwTarget {
        spec: McuSpec {
            name: "stm32f4",
            isa: &isa::CORTEX_M4,
            clock_mhz: 100.0,
            flash_total: 1_500_000,
            flash_reserved: 60_000,
            ram_total: 320_000,
            ram_reserved: 65_000,
            memsys: MemSystem::stm32_internal(),
        },
        platform: ZephyrSim,
        tuning: true,
    }
}

/// Table II: stm32f7 — Cortex-M7 @ 216 MHz (dual issue), 2 MB flash,
/// 512 kB RAM.
pub fn stm32f7() -> HwTarget {
    HwTarget {
        spec: McuSpec {
            name: "stm32f7",
            isa: &isa::CORTEX_M7,
            clock_mhz: 216.0,
            flash_total: 2_000_000,
            flash_reserved: 60_000,
            ram_total: 512_000,
            ram_reserved: 40_000,
            memsys: MemSystem::stm32_internal(),
        },
        platform: ZephyrSim,
        tuning: true,
    }
}

/// Table II: esp32 — Xtensa LX6 @ 240 MHz, 448 kB usable flash
/// partition, 328 kB RAM. MicroTVM cannot tune on this target
/// (Table V's tuned column is all "—").
pub fn esp32() -> HwTarget {
    HwTarget {
        spec: McuSpec {
            name: "esp32",
            isa: &isa::XTENSA_LX6,
            clock_mhz: 240.0,
            flash_total: 448_000,
            flash_reserved: 70_000,
            ram_total: 328_000,
            ram_reserved: 60_000,
            memsys: MemSystem::esp_spi(),
        },
        platform: ZephyrSim,
        tuning: false,
    }
}

/// Instantiate a target by name.
pub fn by_name(name: &str) -> Option<Box<dyn Target>> {
    match name {
        "etiss" => Some(Box::new(Etiss::default())),
        "esp32c3" => Some(Box::new(esp32c3())),
        "stm32f4" => Some(Box::new(stm32f4())),
        "stm32f7" => Some(Box::new(stm32f7())),
        "esp32" => Some(Box::new(esp32())),
        _ => None,
    }
}

/// The Table V hardware target list, in paper column order.
pub fn table5_targets() -> [&'static str; 4] {
    ["esp32c3", "stm32f4", "stm32f7", "esp32"]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backends::{by_name as backend, BackendConfig};
    use crate::graph::model::testutil::tiny_conv;

    #[test]
    fn registry_and_specs_match_table2() {
        for (name, clock, flash, ram) in [
            ("esp32c3", 160.0, 2_000_000u64, 384_000u64),
            ("stm32f4", 100.0, 1_500_000, 320_000),
            ("stm32f7", 216.0, 2_000_000, 512_000),
            ("esp32", 240.0, 448_000, 328_000),
        ] {
            let t = by_name(name).unwrap();
            assert_eq!(t.spec().clock_mhz, clock);
            assert_eq!(t.spec().flash_total, flash);
            assert_eq!(t.spec().ram_total, ram);
        }
    }

    #[test]
    fn esp32_cannot_tune() {
        assert!(!by_name("esp32").unwrap().supports_tuning());
        assert!(by_name("esp32c3").unwrap().supports_tuning());
        assert!(by_name("etiss").unwrap().supports_tuning());
    }

    #[test]
    fn etiss_runs_without_memory_gates() {
        let g = tiny_conv();
        let b = backend("tvmrt").unwrap().build(&g, &BackendConfig::default()).unwrap();
        let t = by_name("etiss").unwrap();
        let dep = t.deploy(&b, "tvm").unwrap(); // 1MB pool OK on ISS
        let out = t.run(&b, &dep, &vec![3i8; 32], true).unwrap();
        assert_eq!(out.output.len(), 48);
        assert!(out.invoke_instructions > 0);
    }

    #[test]
    fn cross_target_same_numerics() {
        let g = tiny_conv();
        let b = backend("tvmaot").unwrap().build(&g, &BackendConfig::default()).unwrap();
        let input = vec![-5i8; 32];
        let mut outputs = Vec::new();
        for name in ["etiss", "esp32c3", "stm32f4", "stm32f7", "esp32"] {
            let t = by_name(name).unwrap();
            let dep = t.deploy(&b, "tvm").unwrap();
            outputs.push(t.run(&b, &dep, &input, true).unwrap().output);
        }
        for o in &outputs[1..] {
            assert_eq!(o, &outputs[0], "targets must agree numerically");
        }
    }

    #[test]
    fn faster_clock_lower_latency_same_isa_family() {
        let g = tiny_conv();
        let b = backend("tvmaot").unwrap().build(&g, &BackendConfig::default()).unwrap();
        let input = vec![0i8; 32];
        let f4 = by_name("stm32f4").unwrap();
        let f7 = by_name("stm32f7").unwrap();
        let d4 = f4.deploy(&b, "tvm").unwrap();
        let d7 = f7.deploy(&b, "tvm").unwrap();
        let r4 = f4.run(&b, &d4, &input, true).unwrap();
        let r7 = f7.run(&b, &d7, &input, true).unwrap();
        assert!(r7.invoke_seconds < r4.invoke_seconds);
    }
}
