//! Compile-once execution plan (§Perf iteration 4).
//!
//! `execute()` (exec.rs) re-derives everything on every invoke: it
//! resolves buffer offsets through `McuMemory`'s per-element dispatch,
//! re-decodes the i32 bias constants, recomputes SAME-pads, allocates
//! fresh widen/accumulator scratch per call, and re-walks the whole
//! call list for accounting even in cost-only mode. That is exactly
//! the prepare-once/invoke-many split TFLM's interpreter design makes:
//! all of it is invariant across invokes.
//!
//! `ExecPlan::compile` hoists the invariants out once:
//!
//!   * buffer offsets become typed arena views (`BufView`),
//!   * biases are decoded into `Vec<i32>` once,
//!   * SAME-pads, weight-row index tables and requant clamp floors are
//!     precomputed,
//!   * the data-independent `ExecStats` accounting is pre-summed, so a
//!     cost-only invoke is a single struct copy,
//!   * widen/accumulator/softmax scratch and the arena itself are
//!     owned by the plan and reused, so steady-state invokes are
//!     allocation-free (beyond the returned output vector),
//!   * dtype dispatch happens once per kernel call (bulk widen in,
//!     bulk narrow out), never per element.
//!
//! The invariant — enforced by `tests/plan_equivalence.rs` — is
//! bit-identical outputs and identical `ExecStats` versus the
//! reference interpreter in exec.rs.

use std::sync::Mutex;

use anyhow::{ensure, Result};

use crate::mcu::exec::{account_program, const_i32, pads};
use crate::mcu::memory::narrow_i8;
use crate::mcu::{ExecStats, McuSpec};
use crate::tensor::DType;
use crate::tinyir::*;
use crate::util::round_half_even;

/// A resolved activation buffer: arena offset + element type/count.
#[derive(Debug, Clone, Copy)]
struct BufView {
    off: usize,
    elems: usize,
    dtype: DType,
}

/// Requantization with the clamp floor resolved at compile time
/// (exec.rs recomputes the ReLU floor per output element).
#[derive(Debug, Clone, Copy)]
struct PlannedRequant {
    multiplier: f64,
    zp_out: i32,
    lo: i64,
}

impl PlannedRequant {
    fn of(rq: &Requant) -> PlannedRequant {
        let lo = if rq.act == 1 { rq.zp_out.max(-128) } else { -128 };
        PlannedRequant {
            multiplier: rq.multiplier,
            zp_out: rq.zp_out,
            lo: lo as i64,
        }
    }

    /// Bit-identical to exec.rs::requant.
    #[inline]
    fn apply(&self, acc: i64) -> i32 {
        let y = round_half_even(acc as f64 * self.multiplier) + self.zp_out as f64;
        (y as i64).clamp(self.lo, 127) as i32
    }
}

/// One kernel call with every data-independent quantity precomputed.
#[derive(Debug)]
enum PlannedOp {
    Conv {
        x: BufView,
        out: BufView,
        w: ConstId,
        bias: Vec<i32>,
        /// Packed-weight byte offset (row * oc) per (ky*kw+kx)*ic+ci —
        /// replaces the per-MAC channels_first index arithmetic.
        wrow: Vec<usize>,
        zp_in: i32,
        rq: PlannedRequant,
        ih: usize,
        iw: usize,
        ic: usize,
        oh: usize,
        ow: usize,
        oc: usize,
        kh: usize,
        kw: usize,
        stride: (usize, usize),
        pads: (usize, usize),
    },
    DwConv {
        x: BufView,
        out: BufView,
        w: ConstId,
        bias: Vec<i32>,
        zp_in: i32,
        rq: PlannedRequant,
        ih: usize,
        iw: usize,
        c: usize,
        oh: usize,
        ow: usize,
        kh: usize,
        kw: usize,
        stride: (usize, usize),
        pads: (usize, usize),
    },
    Dense {
        x: BufView,
        out: BufView,
        w: ConstId,
        bias: Vec<i32>,
        zp_in: i32,
        rq: PlannedRequant,
        batch: usize,
        in_n: usize,
        out_n: usize,
    },
    AvgPool {
        x: BufView,
        out: BufView,
        iw: usize,
        c: usize,
        oh: usize,
        ow: usize,
        fh: usize,
        fw: usize,
        stride: (usize, usize),
        count: f64,
    },
    MaxPool {
        x: BufView,
        out: BufView,
        iw: usize,
        c: usize,
        oh: usize,
        ow: usize,
        fh: usize,
        fw: usize,
        stride: (usize, usize),
    },
    Add {
        a: BufView,
        b: BufView,
        out: BufView,
        elems: usize,
        /// s_a / s_o and s_b / s_o (exec.rs recomputes per element).
        ra: f64,
        rb: f64,
        zp_a: i32,
        zp_b: i32,
        zp_o: i32,
        lo: i64,
    },
    /// Same-dtype copy: one bulk byte move.
    CopyRaw { src: usize, dst: usize, bytes: usize },
    /// Dtype-converting copy (legalization widen/narrow transforms).
    CopyConvert { x: BufView, out: BufView, elems: usize },
    Softmax {
        x: BufView,
        out: BufView,
        elems: usize,
        s_in: f32,
        zp_in: i32,
    },
}

/// Reusable per-invoke working memory (allocated once at compile).
#[derive(Debug, Default)]
struct Scratch {
    /// The simulated SRAM arena (+ workspace tail).
    ram: Vec<u8>,
    /// Widened-input scratch (i32), sized to the largest buffer.
    xin: Vec<i32>,
    /// Second widened input (Add's rhs).
    xin2: Vec<i32>,
    /// Requantized outputs staged as i32 before the bulk narrow.
    ybuf: Vec<i32>,
    /// Per-output-channel accumulators (conv/dwconv).
    acc: Vec<i64>,
    /// Softmax f32 working buffer.
    fbuf: Vec<f32>,
}

/// A compiled, reusable execution plan for one (program, target spec)
/// pair. Compile once with [`ExecPlan::compile`], invoke many times
/// with [`ExecPlan::run`]; cost-only consumers read [`ExecPlan::stats`]
/// without touching the executor at all.
#[derive(Debug)]
pub struct ExecPlan {
    name: String,
    n_calls: usize,
    ram_len: usize,
    cost_fp: u64,
    input: BufView,
    output: BufView,
    ops: Vec<PlannedOp>,
    stats: ExecStats,
    scratch: Mutex<Scratch>,
}

fn view(p: &Program, id: BufId) -> BufView {
    let b = &p.buffers[id];
    BufView {
        off: b.offset.expect("checked by check_plan"),
        elems: b.size / b.dtype.size(),
        dtype: b.dtype,
    }
}

fn in_view(p: &Program, call: &KernelCall, i: usize) -> Result<BufView> {
    match call.inputs.get(i) {
        Some(Operand::Buf(id)) => Ok(view(p, *id)),
        other => anyhow::bail!(
            "call {}: expected buffer operand, got {other:?}",
            call.origin
        ),
    }
}

/// Fingerprint of the cost descriptors the plan's pre-summed
/// `ExecStats` were computed from. A knob re-cost (`Program::recost`)
/// changes these without changing the program's name or call
/// structure, so `run` re-checks this to reject a stale plan.
fn cost_fingerprint(p: &Program) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    let mut mix = |h: &mut u64, v: u64| {
        *h ^= v;
        *h = h.wrapping_mul(0x100_0000_01b3);
    };
    for call in &p.calls {
        let c = &call.cost;
        for v in [
            c.macs,
            c.out_elems,
            c.fixed.to_bits(),
            c.per_mac.total().to_bits(),
            c.per_mac.load.to_bits(),
            c.per_mac.branch.to_bits(),
            c.per_out.total().to_bits(),
            c.weights.bytes_streamed,
            c.weights.reuse_window,
            c.code_bytes,
            c.workspace as u64,
        ] {
            mix(&mut h, v);
        }
    }
    h
}

/// Widen the first `out.len()` elements of `v` into i32 (one dtype
/// dispatch for the whole buffer).
fn widen_into(ram: &[u8], v: BufView, out: &mut [i32]) {
    let n = out.len();
    match v.dtype {
        DType::I8 => {
            for (o, &b) in out.iter_mut().zip(&ram[v.off..v.off + n]) {
                *o = b as i8 as i32;
            }
        }
        DType::I16 => {
            let src = &ram[v.off..v.off + 2 * n];
            for (o, c) in out.iter_mut().zip(src.chunks_exact(2)) {
                *o = i16::from_le_bytes([c[0], c[1]]) as i32;
            }
        }
        DType::I32 | DType::F32 => {
            let src = &ram[v.off..v.off + 4 * n];
            for (o, c) in out.iter_mut().zip(src.chunks_exact(4)) {
                *o = i32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            }
        }
    }
}

/// Narrow i32 values back into the buffer's dtype (one dispatch).
/// Identical truncation semantics to `McuMemory::store`.
fn narrow_from(ram: &mut [u8], v: BufView, vals: &[i32]) {
    match v.dtype {
        DType::I8 => {
            let dst = &mut ram[v.off..v.off + vals.len()];
            for (d, &x) in dst.iter_mut().zip(vals) {
                *d = x as i8 as u8;
            }
        }
        DType::I16 => {
            let dst = &mut ram[v.off..v.off + 2 * vals.len()];
            for (d, &x) in dst.chunks_exact_mut(2).zip(vals) {
                d.copy_from_slice(&(x as i16).to_le_bytes());
            }
        }
        DType::I32 | DType::F32 => {
            let dst = &mut ram[v.off..v.off + 4 * vals.len()];
            for (d, &x) in dst.chunks_exact_mut(4).zip(vals) {
                d.copy_from_slice(&x.to_le_bytes());
            }
        }
    }
}

impl ExecPlan {
    /// Resolve, decode and pre-size everything `run` will need.
    pub fn compile(p: &Program, spec: &McuSpec) -> Result<ExecPlan> {
        p.check_plan()?;
        let input = view(p, p.input);
        let output = view(p, p.output);
        ensure!(
            input.dtype == DType::I8,
            "graph input buffer must be i8, got {:?}",
            input.dtype
        );

        let mut ops = Vec::with_capacity(p.calls.len());
        let mut max_acc = 1usize;
        let mut max_soft = 0usize;
        for call in &p.calls {
            ops.push(Self::compile_call(p, call, &mut max_acc, &mut max_soft)?);
        }

        let max_elems = p
            .buffers
            .iter()
            .map(|b| b.size / b.dtype.size())
            .max()
            .unwrap_or(0);
        let scratch = Scratch {
            ram: vec![0u8; p.arena_size + p.workspace_size],
            xin: vec![0i32; max_elems],
            xin2: vec![0i32; max_elems],
            ybuf: vec![0i32; max_elems],
            acc: vec![0i64; max_acc],
            fbuf: vec![0f32; max_soft],
        };
        Ok(ExecPlan {
            name: p.name.clone(),
            n_calls: p.calls.len(),
            ram_len: p.arena_size + p.workspace_size,
            cost_fp: cost_fingerprint(p),
            input,
            output,
            ops,
            stats: account_program(p, spec),
            scratch: Mutex::new(scratch),
        })
    }

    fn compile_call(
        p: &Program,
        call: &KernelCall,
        max_acc: &mut usize,
        max_soft: &mut usize,
    ) -> Result<PlannedOp> {
        Ok(match &call.kind {
            KernelKind::Conv2D {
                ih, iw, ic, oh, ow, oc, kh, kw, stride, padding,
                channels_first, requant: rq,
            } => {
                let x = in_view(p, call, 0)?;
                let w = call.consts[0];
                let bias = const_i32(p, call.consts[1]);
                ensure!(bias.len() >= *oc, "{}: short bias", call.origin);
                ensure!(
                    p.consts[w].data.len() >= kh * kw * ic * oc,
                    "{}: short weight matrix",
                    call.origin
                );
                ensure!(
                    x.elems >= ih * iw * ic,
                    "{}: input buffer too small",
                    call.origin
                );
                let mut wrow = Vec::with_capacity(kh * kw * ic);
                for ky in 0..*kh {
                    for kx in 0..*kw {
                        for ci in 0..*ic {
                            let row = if *channels_first {
                                ci * kh * kw + ky * kw + kx
                            } else {
                                (ky * kw + kx) * ic + ci
                            };
                            wrow.push(row * oc);
                        }
                    }
                }
                *max_acc = (*max_acc).max(*oc);
                PlannedOp::Conv {
                    x,
                    out: view(p, call.output),
                    w,
                    bias,
                    wrow,
                    zp_in: rq.zp_in,
                    rq: PlannedRequant::of(rq),
                    ih: *ih,
                    iw: *iw,
                    ic: *ic,
                    oh: *oh,
                    ow: *ow,
                    oc: *oc,
                    kh: *kh,
                    kw: *kw,
                    stride: *stride,
                    pads: pads(*ih, *iw, *kh, *kw, stride.0, stride.1, *padding),
                }
            }
            KernelKind::DwConv2D {
                ih, iw, c, oh, ow, kh, kw, stride, padding, requant: rq,
            } => {
                let x = in_view(p, call, 0)?;
                let bias = const_i32(p, call.consts[1]);
                ensure!(bias.len() >= *c, "{}: short bias", call.origin);
                ensure!(
                    p.consts[call.consts[0]].data.len() >= kh * kw * c,
                    "{}: short dw weights",
                    call.origin
                );
                *max_acc = (*max_acc).max(*c);
                PlannedOp::DwConv {
                    x,
                    out: view(p, call.output),
                    w: call.consts[0],
                    bias,
                    zp_in: rq.zp_in,
                    rq: PlannedRequant::of(rq),
                    ih: *ih,
                    iw: *iw,
                    c: *c,
                    oh: *oh,
                    ow: *ow,
                    kh: *kh,
                    kw: *kw,
                    stride: *stride,
                    pads: pads(*ih, *iw, *kh, *kw, stride.0, stride.1, *padding),
                }
            }
            KernelKind::Dense { batch, in_n, out_n, requant: rq } => {
                let x = in_view(p, call, 0)?;
                let bias = const_i32(p, call.consts[1]);
                ensure!(bias.len() >= *out_n, "{}: short bias", call.origin);
                ensure!(
                    p.consts[call.consts[0]].data.len() >= in_n * out_n,
                    "{}: short dense weights",
                    call.origin
                );
                ensure!(
                    x.elems >= batch * in_n,
                    "{}: input buffer too small",
                    call.origin
                );
                PlannedOp::Dense {
                    x,
                    out: view(p, call.output),
                    w: call.consts[0],
                    bias,
                    zp_in: rq.zp_in,
                    rq: PlannedRequant::of(rq),
                    batch: *batch,
                    in_n: *in_n,
                    out_n: *out_n,
                }
            }
            KernelKind::AvgPool2D { ih, iw, c, oh, ow, fh, fw, stride } => {
                let x = in_view(p, call, 0)?;
                ensure!(
                    x.elems >= ih * iw * c,
                    "{}: input buffer too small",
                    call.origin
                );
                PlannedOp::AvgPool {
                    x,
                    out: view(p, call.output),
                    iw: *iw,
                    c: *c,
                    oh: *oh,
                    ow: *ow,
                    fh: *fh,
                    fw: *fw,
                    stride: *stride,
                    count: (fh * fw) as f64,
                }
            }
            KernelKind::MaxPool2D { ih, iw, c, oh, ow, fh, fw, stride } => {
                let x = in_view(p, call, 0)?;
                ensure!(
                    x.elems >= ih * iw * c,
                    "{}: input buffer too small",
                    call.origin
                );
                PlannedOp::MaxPool {
                    x,
                    out: view(p, call.output),
                    iw: *iw,
                    c: *c,
                    oh: *oh,
                    ow: *ow,
                    fh: *fh,
                    fw: *fw,
                    stride: *stride,
                }
            }
            KernelKind::Add { elems, s_a, zp_a, s_b, zp_b, s_o, zp_o, act } => {
                let a = in_view(p, call, 0)?;
                let b = in_view(p, call, 1)?;
                ensure!(
                    a.elems >= *elems && b.elems >= *elems,
                    "{}: add operand too small",
                    call.origin
                );
                PlannedOp::Add {
                    a,
                    b,
                    out: view(p, call.output),
                    elems: *elems,
                    ra: s_a / s_o,
                    rb: s_b / s_o,
                    zp_a: *zp_a,
                    zp_b: *zp_b,
                    zp_o: *zp_o,
                    lo: if *act == 1 { *zp_o as i64 } else { -128 },
                }
            }
            KernelKind::Copy { elems } | KernelKind::Transform { elems, .. } => {
                let x = in_view(p, call, 0)?;
                let out = view(p, call.output);
                ensure!(
                    x.elems >= *elems && out.elems >= *elems,
                    "{}: copy operand too small",
                    call.origin
                );
                if x.dtype == out.dtype {
                    PlannedOp::CopyRaw {
                        src: x.off,
                        dst: out.off,
                        bytes: elems * x.dtype.size(),
                    }
                } else {
                    PlannedOp::CopyConvert { x, out, elems: *elems }
                }
            }
            KernelKind::Softmax { elems, s_in, zp_in } => {
                let x = in_view(p, call, 0)?;
                ensure!(
                    x.elems >= *elems,
                    "{}: softmax operand too small",
                    call.origin
                );
                *max_soft = (*max_soft).max(*elems);
                PlannedOp::Softmax {
                    x,
                    out: view(p, call.output),
                    elems: *elems,
                    s_in: *s_in as f32,
                    zp_in: *zp_in,
                }
            }
        })
    }

    /// The pre-summed, data-independent accounting of one invoke.
    /// Cost-only consumers (the tuner's measure loop) use this instead
    /// of walking the call list.
    pub fn stats(&self) -> ExecStats {
        self.stats
    }

    /// Execute one invoke against the plan's own arena. `program` must
    /// be the program this plan was compiled from (the plan holds
    /// derived metadata; weights stay in the program's flash consts).
    pub fn run(&self, p: &Program, input: &[i8]) -> Result<(Vec<i8>, ExecStats)> {
        ensure!(
            p.name == self.name
                && p.calls.len() == self.n_calls
                && p.arena_size + p.workspace_size == self.ram_len
                && cost_fingerprint(p) == self.cost_fp,
            "plan was compiled from a different (or re-costed) program \
             ({} vs {})",
            self.name,
            p.name
        );
        ensure!(
            input.len() == self.input.elems,
            "input size mismatch: buffer {} elems vs data {} B",
            self.input.elems,
            input.len()
        );
        let mut guard = self.scratch.lock().unwrap_or_else(|e| e.into_inner());
        let Scratch { ram, xin, xin2, ybuf, acc, fbuf } = &mut *guard;
        // fresh-RAM semantics, identical to a new McuMemory per invoke
        ram.fill(0);
        let dst = &mut ram[self.input.off..self.input.off + input.len()];
        for (d, &v) in dst.iter_mut().zip(input) {
            *d = v as u8;
        }

        for op in &self.ops {
            match op {
                PlannedOp::Conv {
                    x, out, w, bias, wrow, zp_in, rq,
                    ih, iw, ic, oh, ow, oc, kh, kw, stride, pads,
                } => {
                    let xin = &mut xin[..x.elems];
                    widen_into(ram, *x, xin);
                    for v in xin.iter_mut() {
                        *v -= zp_in;
                    }
                    let wd = &p.consts[*w].data;
                    let acc = &mut acc[..*oc];
                    let yout = &mut ybuf[..oh * ow * oc];
                    let (pt, pl) = *pads;
                    for oy in 0..*oh {
                        for ox in 0..*ow {
                            let out_base = ((oy * ow) + ox) * oc;
                            for (co, a) in acc.iter_mut().enumerate() {
                                *a = bias[co] as i64;
                            }
                            for ky in 0..*kh {
                                let iy =
                                    (oy * stride.0 + ky) as isize - pt as isize;
                                if iy < 0 || iy >= *ih as isize {
                                    continue;
                                }
                                for kx in 0..*kw {
                                    let ix = (ox * stride.1 + kx) as isize
                                        - pl as isize;
                                    if ix < 0 || ix >= *iw as isize {
                                        continue;
                                    }
                                    let base =
                                        ((iy as usize * iw) + ix as usize) * ic;
                                    let xrow = &xin[base..base + ic];
                                    let rows = &wrow[(ky * kw + kx) * ic..];
                                    for (ci, &xv) in xrow.iter().enumerate() {
                                        if xv == 0 {
                                            continue; // zp-padding fast path
                                        }
                                        let ro = rows[ci];
                                        let ws = &wd[ro..ro + oc];
                                        let xv = xv as i64;
                                        for (a, &wv) in acc.iter_mut().zip(ws) {
                                            *a += xv * (wv as i8 as i64);
                                        }
                                    }
                                }
                            }
                            for (co, &a) in acc.iter().enumerate() {
                                yout[out_base + co] = rq.apply(a);
                            }
                        }
                    }
                    narrow_from(ram, *out, yout);
                }
                PlannedOp::DwConv {
                    x, out, w, bias, zp_in, rq,
                    ih, iw, c, oh, ow, kh, kw, stride, pads,
                } => {
                    let xin = &mut xin[..x.elems];
                    widen_into(ram, *x, xin);
                    for v in xin.iter_mut() {
                        *v -= zp_in;
                    }
                    let wd = &p.consts[*w].data;
                    let acc = &mut acc[..*c];
                    let yout = &mut ybuf[..oh * ow * c];
                    let (pt, pl) = *pads;
                    for oy in 0..*oh {
                        for ox in 0..*ow {
                            let out_base = ((oy * ow) + ox) * c;
                            for (ch, a) in acc.iter_mut().enumerate() {
                                *a = bias[ch] as i64;
                            }
                            for ky in 0..*kh {
                                let iy =
                                    (oy * stride.0 + ky) as isize - pt as isize;
                                if iy < 0 || iy >= *ih as isize {
                                    continue;
                                }
                                for kx in 0..*kw {
                                    let ix = (ox * stride.1 + kx) as isize
                                        - pl as isize;
                                    if ix < 0 || ix >= *iw as isize {
                                        continue;
                                    }
                                    let base =
                                        ((iy as usize * iw) + ix as usize) * c;
                                    let xrow = &xin[base..base + c];
                                    let ws =
                                        &wd[(ky * kw + kx) * c..(ky * kw + kx + 1) * c];
                                    for ((a, &xv), &wv) in
                                        acc.iter_mut().zip(xrow).zip(ws)
                                    {
                                        *a += xv as i64 * (wv as i8 as i64);
                                    }
                                }
                            }
                            for (ch, &a) in acc.iter().enumerate() {
                                yout[out_base + ch] = rq.apply(a);
                            }
                        }
                    }
                    narrow_from(ram, *out, yout);
                }
                PlannedOp::Dense {
                    x, out, w, bias, zp_in, rq, batch, in_n, out_n,
                } => {
                    let xin = &mut xin[..x.elems];
                    widen_into(ram, *x, xin);
                    for v in xin.iter_mut() {
                        *v -= zp_in;
                    }
                    let wd = &p.consts[*w].data;
                    let yout = &mut ybuf[..batch * out_n];
                    for b in 0..*batch {
                        let xrow = &xin[b * in_n..(b + 1) * in_n];
                        for o in 0..*out_n {
                            let ws = &wd[o * in_n..(o + 1) * in_n];
                            let mut a = bias[o] as i64;
                            for (xv, wv) in xrow.iter().zip(ws) {
                                a += *xv as i64 * (*wv as i8 as i64);
                            }
                            yout[b * out_n + o] = rq.apply(a);
                        }
                    }
                    narrow_from(ram, *out, yout);
                }
                PlannedOp::AvgPool {
                    x, out, iw, c, oh, ow, fh, fw, stride, count,
                } => {
                    let xin = &mut xin[..x.elems];
                    widen_into(ram, *x, xin);
                    let yout = &mut ybuf[..oh * ow * c];
                    for oy in 0..*oh {
                        for ox in 0..*ow {
                            for ch in 0..*c {
                                let mut sum = 0i64;
                                for ky in 0..*fh {
                                    for kx in 0..*fw {
                                        let iy = oy * stride.0 + ky;
                                        let ix = ox * stride.1 + kx;
                                        sum += xin[((iy * iw) + ix) * c + ch]
                                            as i64;
                                    }
                                }
                                let v = round_half_even(sum as f64 / count)
                                    .clamp(-128.0, 127.0)
                                    as i32;
                                yout[((oy * ow) + ox) * c + ch] = v;
                            }
                        }
                    }
                    narrow_from(ram, *out, yout);
                }
                PlannedOp::MaxPool { x, out, iw, c, oh, ow, fh, fw, stride } => {
                    let xin = &mut xin[..x.elems];
                    widen_into(ram, *x, xin);
                    let yout = &mut ybuf[..oh * ow * c];
                    for oy in 0..*oh {
                        for ox in 0..*ow {
                            for ch in 0..*c {
                                let mut m = i32::MIN;
                                for ky in 0..*fh {
                                    for kx in 0..*fw {
                                        let iy = oy * stride.0 + ky;
                                        let ix = ox * stride.1 + kx;
                                        m = m.max(xin[((iy * iw) + ix) * c + ch]);
                                    }
                                }
                                yout[((oy * ow) + ox) * c + ch] = m;
                            }
                        }
                    }
                    narrow_from(ram, *out, yout);
                }
                PlannedOp::Add {
                    a, b, out, elems, ra, rb, zp_a, zp_b, zp_o, lo,
                } => {
                    let xa = &mut xin[..*elems];
                    widen_into(ram, *a, xa);
                    let xb = &mut xin2[..*elems];
                    widen_into(ram, *b, xb);
                    let yout = &mut ybuf[..*elems];
                    for i in 0..*elems {
                        let fa = (xa[i] - zp_a) as f64 * ra;
                        let fb = (xb[i] - zp_b) as f64 * rb;
                        let y = round_half_even(fa + fb) + *zp_o as f64;
                        yout[i] = (y as i64).clamp(*lo, 127) as i32;
                    }
                    narrow_from(ram, *out, yout);
                }
                PlannedOp::CopyRaw { src, dst, bytes } => {
                    ram.copy_within(*src..*src + *bytes, *dst);
                }
                PlannedOp::CopyConvert { x, out, elems } => {
                    let xin = &mut xin[..*elems];
                    widen_into(ram, *x, xin);
                    narrow_from(ram, *out, xin);
                }
                PlannedOp::Softmax { x, out, elems, s_in, zp_in } => {
                    let xin = &mut xin[..*elems];
                    widen_into(ram, *x, xin);
                    let f = &mut fbuf[..*elems];
                    for (fv, &v) in f.iter_mut().zip(xin.iter()) {
                        *fv = (v - zp_in) as f32 * s_in;
                    }
                    let max =
                        f.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                    let mut sum = 0f32;
                    for v in f.iter_mut() {
                        *v = (*v - max).exp();
                        sum += *v;
                    }
                    let yout = &mut ybuf[..*elems];
                    for (y, &v) in yout.iter_mut().zip(f.iter()) {
                        let q = round_half_even((v / sum) as f64 * 256.0) - 128.0;
                        *y = q.clamp(-128.0, 127.0) as i32;
                    }
                    narrow_from(ram, *out, yout);
                }
            }
        }

        // dtype-aware narrow of the output buffer — the same shared
        // helper `McuMemory::read_output` uses
        let v = self.output;
        let out = narrow_i8(ram, v.off, v.elems, v.dtype);
        Ok((out, self.stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backends::builder::{lower, LowerOpts};
    use crate::backends::planner::{plan, PlannerKind};
    use crate::graph::model::testutil::tiny_conv;
    use crate::isa;
    use crate::kernels::KernelLib;
    use crate::mcu::{execute, ExecOpts, MemSystem};

    fn etiss_spec() -> McuSpec {
        McuSpec {
            name: "etiss",
            isa: &isa::RV32GC,
            clock_mhz: 100.0,
            flash_total: u64::MAX / 2,
            flash_reserved: 0,
            ram_total: u64::MAX / 2,
            ram_reserved: 0,
            memsys: MemSystem::ideal(),
        }
    }

    fn tiny_program(lib: KernelLib, legalize: bool) -> Program {
        let g = tiny_conv();
        let mut p = lower(
            &g,
            "t",
            LowerOpts { lib, legalize_i16: legalize, transform_input: legalize },
        )
        .unwrap();
        plan(&mut p, PlannerKind::GreedyArena);
        p
    }

    #[test]
    fn plan_matches_interpreter_on_tiny_conv() {
        let p = tiny_program(KernelLib::TflmRef, false);
        let spec = etiss_spec();
        let input: Vec<i8> = (0..32).map(|x| (x * 11 % 256) as i8).collect();
        let (ref_out, ref_stats) =
            execute(&p, &spec, &input, ExecOpts::default()).unwrap();
        let plan = ExecPlan::compile(&p, &spec).unwrap();
        let (out, stats) = plan.run(&p, &input).unwrap();
        assert_eq!(out, ref_out);
        assert_eq!(stats, ref_stats);
    }

    #[test]
    fn cost_only_stats_are_presummed() {
        let p = tiny_program(KernelLib::TflmRef, false);
        let spec = etiss_spec();
        let plan = ExecPlan::compile(&p, &spec).unwrap();
        let (_, dry) =
            execute(&p, &spec, &[0i8; 32], ExecOpts { compute: false }).unwrap();
        assert_eq!(plan.stats(), dry);
    }

    #[test]
    fn run_rejects_mismatched_program() {
        use crate::schedules::{Family, Layout, Schedule};
        let p = tiny_program(KernelLib::TflmRef, false);
        let spec = etiss_spec();
        let plan = ExecPlan::compile(&p, &spec).unwrap();
        let mut other = p.clone();
        other.name = "other".into();
        assert!(plan.run(&other, &[0i8; 32]).is_err());
        assert!(plan.run(&p, &[0i8; 3]).is_err());
        // a re-costed program has stale pre-summed stats in this plan:
        // same name/calls/arena, but the cost fingerprint must reject it
        let mut recosted = p.clone();
        recosted.recost(Schedule::new(Family::DefaultX86, Layout::Nchw));
        assert!(plan.run(&recosted, &[0i8; 32]).is_err());
    }

    #[test]
    fn unplanned_program_fails_compile() {
        let g = tiny_conv();
        let p = lower(
            &g,
            "t",
            LowerOpts {
                lib: KernelLib::TflmRef,
                legalize_i16: false,
                transform_input: false,
            },
        )
        .unwrap();
        assert!(ExecPlan::compile(&p, &etiss_spec()).is_err());
    }
}
