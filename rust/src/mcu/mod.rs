//! The virtual MCU — the substrate standing in for the paper's four
//! physical boards and the ETISS host simulator.
//!
//! A `Mcu` owns a memory map (flash + SRAM per Table II), a memory
//! system model (internal flash wait-states vs external SPI flash
//! with a small cache — the Table V NHWC-blowup mechanism), and an
//! executor that *numerically* runs TinyIR programs against simulated
//! RAM while accounting instructions and cycles.

pub mod memsys;
pub mod memory;
pub mod exec;
pub mod plan;

pub use exec::{account_program, execute, ExecOpts, ExecStats};
pub use memory::{FlashImage, McuMemory};
pub use memsys::{FlashKind, MemSystem};
pub use plan::ExecPlan;

use crate::isa::IsaModel;

/// Static description of one MCU (Table II row).
#[derive(Debug, Clone, Copy)]
pub struct McuSpec {
    pub name: &'static str,
    pub isa: &'static IsaModel,
    pub clock_mhz: f64,
    /// Total flash (bytes) and the slice the platform reserves
    /// (bootloader, RTOS, partitions) — the rest holds the app image.
    pub flash_total: u64,
    pub flash_reserved: u64,
    /// Total SRAM and the platform reserve (RTOS heap, radio stacks).
    pub ram_total: u64,
    pub ram_reserved: u64,
    pub memsys: MemSystem,
}

impl McuSpec {
    pub fn flash_available(&self) -> u64 {
        self.flash_total - self.flash_reserved
    }
    pub fn ram_available(&self) -> u64 {
        self.ram_total - self.ram_reserved
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa;

    #[test]
    fn spec_accounting() {
        let spec = McuSpec {
            name: "t",
            isa: &isa::RV32GC,
            clock_mhz: 100.0,
            flash_total: 1000,
            flash_reserved: 100,
            ram_total: 500,
            ram_reserved: 50,
            memsys: MemSystem::ideal(),
        };
        assert_eq!(spec.flash_available(), 900);
        assert_eq!(spec.ram_available(), 450);
    }
}
