//! Simulated MCU memory: the flash image produced by the Compile
//! stage and the RAM the program runs against. Buffer reads/writes go
//! through this module so arena-planning bugs corrupt real data (and
//! get caught by the validate feature) instead of being invisible.

use anyhow::{ensure, Result};

use crate::tensor::DType;
use crate::tinyir::{BufId, Program};

/// The linked flash image: constants laid out at offsets, plus the
/// code/metadata sizes from the build metrics.
#[derive(Debug, Clone)]
pub struct FlashImage {
    pub const_offsets: Vec<u64>,
    pub const_bytes: u64,
    pub code_bytes: u64,
    pub misc_bytes: u64,
}

impl FlashImage {
    pub fn link(p: &Program, code_bytes: u64, misc_bytes: u64) -> FlashImage {
        let mut off = 0u64;
        let mut const_offsets = Vec::with_capacity(p.consts.len());
        for c in &p.consts {
            const_offsets.push(off);
            off += c.data.len() as u64;
            off = (off + 3) & !3; // word alignment
        }
        FlashImage { const_offsets, const_bytes: off, code_bytes, misc_bytes }
    }

    pub fn total_bytes(&self) -> u64 {
        self.const_bytes + self.code_bytes + self.misc_bytes
    }
}

/// Simulated SRAM: one flat arena (+ workspace region at the end).
#[derive(Debug)]
pub struct McuMemory {
    ram: Vec<u8>,
}

/// Narrow `elems` stored values at `off` to i8, one dtype dispatch for
/// the whole buffer. Truncation semantics match `McuMemory::load`
/// followed by an `as i8` cast. Shared by `read_output` and the
/// compiled plan's output read (`plan.rs`) so they cannot diverge.
pub(crate) fn narrow_i8(ram: &[u8], off: usize, elems: usize, dtype: DType) -> Vec<i8> {
    match dtype {
        DType::I8 => ram[off..off + elems].iter().map(|&v| v as i8).collect(),
        DType::I16 => ram[off..off + 2 * elems]
            .chunks_exact(2)
            .map(|c| i16::from_le_bytes([c[0], c[1]]) as i8)
            .collect(),
        DType::I32 | DType::F32 => ram[off..off + 4 * elems]
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]) as i8)
            .collect(),
    }
}

impl McuMemory {
    /// Allocate RAM for a planned program. Fails if any buffer is
    /// unplanned — running an unplanned program is a backend bug.
    pub fn for_program(p: &Program) -> Result<McuMemory> {
        p.check_plan()?;
        Ok(McuMemory { ram: vec![0u8; p.arena_size + p.workspace_size] })
    }

    #[inline]
    fn buf_range(&self, p: &Program, id: BufId) -> (usize, usize, DType) {
        let b = &p.buffers[id];
        let off = b.offset.expect("checked by for_program");
        (off, b.size, b.dtype)
    }

    /// Load element `idx` of buffer `id` as a widened i32 value.
    #[inline]
    pub fn load(&self, p: &Program, id: BufId, idx: usize) -> i32 {
        let (off, size, dtype) = self.buf_range(p, id);
        match dtype {
            DType::I8 => {
                debug_assert!(idx < size);
                self.ram[off + idx] as i8 as i32
            }
            DType::I16 => {
                let i = off + idx * 2;
                debug_assert!(idx * 2 + 1 < size);
                i16::from_le_bytes([self.ram[i], self.ram[i + 1]]) as i32
            }
            DType::I32 | DType::F32 => {
                let i = off + idx * 4;
                i32::from_le_bytes([
                    self.ram[i], self.ram[i + 1], self.ram[i + 2], self.ram[i + 3],
                ])
            }
        }
    }

    /// Store a (quantized, int8-range) value into buffer `id`.
    #[inline]
    pub fn store(&mut self, p: &Program, id: BufId, idx: usize, val: i32) {
        let (off, size, dtype) = self.buf_range(p, id);
        match dtype {
            DType::I8 => {
                debug_assert!(idx < size);
                self.ram[off + idx] = val as i8 as u8;
            }
            DType::I16 => {
                let i = off + idx * 2;
                debug_assert!(idx * 2 + 1 < size);
                self.ram[i..i + 2].copy_from_slice(&(val as i16).to_le_bytes());
            }
            DType::I32 | DType::F32 => {
                let i = off + idx * 4;
                self.ram[i..i + 4].copy_from_slice(&val.to_le_bytes());
            }
        }
    }

    /// Bulk-write the graph input (arrives as i8 over the "UART").
    pub fn write_input(&mut self, p: &Program, data: &[i8]) -> Result<()> {
        let b = &p.buffers[p.input];
        ensure!(
            b.dtype == DType::I8 && b.size == data.len(),
            "input size mismatch: buffer {} B vs data {} B",
            b.size,
            data.len()
        );
        let off = b.offset.unwrap();
        // bulk slice copy (i8 -> u8 is a bitwise reinterpretation);
        // the zipped loop compiles to a memcpy, unlike the old
        // indexed byte-at-a-time write
        let dst = &mut self.ram[off..off + data.len()];
        for (d, &v) in dst.iter_mut().zip(data) {
            *d = v as u8;
        }
        Ok(())
    }

    /// Read the graph output back as i8 values (dtype-aware narrow).
    /// One dtype dispatch for the whole buffer instead of a full
    /// `load()` per element (§Perf).
    pub fn read_output(&self, p: &Program) -> Vec<i8> {
        let b = &p.buffers[p.output];
        let off = b.offset.expect("checked by for_program");
        let n = b.size / b.dtype.size();
        narrow_i8(&self.ram, off, n, b.dtype)
    }

    /// Number of elements of a buffer.
    pub fn elems(&self, p: &Program, id: BufId) -> usize {
        let b = &p.buffers[id];
        b.size / b.dtype.size()
    }

    /// Widen a whole buffer to i32 once (executor hot-path: per-MAC
    /// `load()` calls pay buffer-meta lookup + dtype dispatch on every
    /// access; kernels instead widen inputs once per call — §Perf).
    pub fn read_all(&self, p: &Program, id: BufId) -> Vec<i32> {
        let b = &p.buffers[id];
        let off = b.offset.expect("checked by for_program");
        let n = b.size / b.dtype.size();
        match b.dtype {
            DType::I8 => self.ram[off..off + n]
                .iter()
                .map(|&v| v as i8 as i32)
                .collect(),
            DType::I16 => self.ram[off..off + 2 * n]
                .chunks_exact(2)
                .map(|c| i16::from_le_bytes([c[0], c[1]]) as i32)
                .collect(),
            DType::I32 | DType::F32 => self.ram[off..off + 4 * n]
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tinyir::{BufferDecl, KernelCall, KernelKind, Operand};

    fn two_buf_program(d0: DType, d1: DType) -> Program {
        let mut p = Program {
            name: "m".into(),
            buffers: vec![
                BufferDecl {
                    name: "a".into(),
                    size: 8 * d0.size(),
                    dtype: d0,
                    offset: Some(0),
                    first_use: 0,
                    last_use: 0,
                },
                BufferDecl {
                    name: "b".into(),
                    size: 8 * d1.size(),
                    dtype: d1,
                    offset: Some(8 * d0.size()),
                    first_use: 0,
                    last_use: 0,
                },
            ],
            consts: vec![],
            calls: vec![KernelCall {
                kind: KernelKind::Copy { elems: 8 },
                inputs: vec![Operand::Buf(0)],
                consts: vec![],
                output: 1,
                cost: crate::kernels::copy_cost(8),
                origin: "c".into(),
            }],
            input: 0,
            output: 1,
            arena_size: 8 * (d0.size() + d1.size()),
            workspace_size: 0,
        };
        p.recompute_lifetimes();
        // re-plan offsets trivially (sequential) for the test
        p.buffers[0].offset = Some(0);
        p.buffers[1].offset = Some(8 * d0.size());
        p
    }

    #[test]
    fn i8_roundtrip() {
        let p = two_buf_program(DType::I8, DType::I8);
        let mut m = McuMemory::for_program(&p).unwrap();
        m.store(&p, 0, 3, -77);
        assert_eq!(m.load(&p, 0, 3), -77);
    }

    #[test]
    fn i16_widening_preserves_values() {
        let p = two_buf_program(DType::I8, DType::I16);
        let mut m = McuMemory::for_program(&p).unwrap();
        m.store(&p, 1, 7, -128);
        assert_eq!(m.load(&p, 1, 7), -128);
        m.store(&p, 1, 0, 127);
        assert_eq!(m.load(&p, 1, 0), 127);
    }

    #[test]
    fn input_output_roundtrip() {
        let p = two_buf_program(DType::I8, DType::I8);
        let mut m = McuMemory::for_program(&p).unwrap();
        let data: Vec<i8> = (-4..4).collect();
        m.write_input(&p, &data).unwrap();
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(m.load(&p, 0, i), v as i32);
        }
    }

    #[test]
    fn input_size_mismatch_rejected() {
        let p = two_buf_program(DType::I8, DType::I8);
        let mut m = McuMemory::for_program(&p).unwrap();
        assert!(m.write_input(&p, &[1, 2]).is_err());
    }

    #[test]
    fn flash_image_alignment() {
        use crate::tinyir::ConstDecl;
        let mut p = two_buf_program(DType::I8, DType::I8);
        p.consts = vec![
            ConstDecl { name: "w".into(), data: vec![0; 5], dtype: DType::I8 },
            ConstDecl { name: "b".into(), data: vec![0; 8], dtype: DType::I32 },
        ];
        let img = FlashImage::link(&p, 100, 10);
        assert_eq!(img.const_offsets, vec![0, 8]); // 5 aligned to 8
        assert_eq!(img.const_bytes, 16);
        assert_eq!(img.total_bytes(), 126);
    }
}
