//! The TinyIR executor: numerically runs a program against simulated
//! memory while accounting instructions (per-ISA) and cycles (core +
//! memory stalls).
//!
//! Numerics are bit-identical to the JAX/Pallas golden path
//! (python/compile/): int32 accumulation, f64-multiplier requantization
//! with round-half-even, zero-point padding. The single exception is
//! softmax (f32 `exp` may differ by 1 ulp across libms), which the
//! validate feature covers with ±1 quantum tolerance.

use anyhow::{bail, Result};

use crate::mcu::{McuMemory, McuSpec};
use crate::tinyir::*;
use crate::util::round_half_even;

/// Execution options.
#[derive(Debug, Clone, Copy)]
pub struct ExecOpts {
    /// Compute real values (Run stage) or account cost only (the
    /// tuner's measure loop — numerics are data-independent).
    pub compute: bool,
}

impl Default for ExecOpts {
    fn default() -> Self {
        ExecOpts { compute: true }
    }
}

/// Accounting result of one invocation.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ExecStats {
    /// Reference-ISA (RV32GC) instruction count — what ETISS reports.
    pub ref_instructions: u64,
    /// Target-ISA instruction count.
    pub instructions: u64,
    /// Core cycles (CPI / dual-issue applied).
    pub core_cycles: f64,
    /// Memory-system stall cycles (flash weight streaming).
    pub stall_cycles: f64,
}

impl ExecStats {
    pub fn total_cycles(&self) -> f64 {
        self.core_cycles + self.stall_cycles
    }

    /// Wall-clock seconds at the target clock.
    pub fn seconds(&self, clock_mhz: f64) -> f64 {
        self.total_cycles() / (clock_mhz * 1e6)
    }
}

#[inline]
fn requant(acc: i64, rq: &Requant) -> i32 {
    let y = round_half_even(acc as f64 * rq.multiplier) + rq.zp_out as f64;
    let lo = if rq.act == 1 { rq.zp_out.max(-128) } else { -128 };
    (y as i64).clamp(lo as i64, 127) as i32
}

/// Account one call on the target micro-architecture.
fn account(call: &KernelCall, spec: &McuSpec, stats: &mut ExecStats) {
    let c = &call.cost;
    stats.ref_instructions += c.ref_instructions();
    let isa = spec.isa;
    let instr = isa.instructions(&c.per_mac, c.macs as f64)
        + isa.instructions(&c.per_out, c.out_elems as f64)
        + c.fixed;
    stats.instructions += instr as u64;
    stats.core_cycles += isa.core_cycles(instr);
    stats.stall_cycles += spec.memsys.weight_stall_cycles(&c.weights);
}

/// Account a whole program without executing it. The accounting is
/// data-independent, so this is also what `ExecPlan` pre-sums at
/// compile time and what deployments cache for cost-only invokes.
pub fn account_program(p: &Program, spec: &McuSpec) -> ExecStats {
    let mut stats = ExecStats::default();
    for call in &p.calls {
        account(call, spec, &mut stats);
    }
    stats
}

/// Run the program once. Returns the int8 output vector (empty when
/// `opts.compute` is false) and the accounting stats.
///
/// This is the reference interpreter: it re-resolves buffers, decodes
/// biases and allocates scratch on every invoke. Hot paths (targets,
/// benches) go through `plan::ExecPlan`, which hoists all of that out
/// and must stay bit-identical to this function.
pub fn execute(
    p: &Program,
    spec: &McuSpec,
    input: &[i8],
    opts: ExecOpts,
) -> Result<(Vec<i8>, ExecStats)> {
    let stats = account_program(p, spec);
    if !opts.compute {
        return Ok((Vec::new(), stats));
    }

    let mut mem = McuMemory::for_program(p)?;
    mem.write_input(p, input)?;

    for call in &p.calls {
        run_call(p, call, &mut mem)?;
    }
    Ok((mem.read_output(p), stats))
}

fn in_buf(call: &KernelCall, i: usize) -> Result<BufId> {
    match call.inputs.get(i) {
        Some(Operand::Buf(id)) => Ok(*id),
        other => bail!("call {}: expected buffer operand, got {other:?}", call.origin),
    }
}

fn run_call(p: &Program, call: &KernelCall, mem: &mut McuMemory) -> Result<()> {
    match &call.kind {
        KernelKind::Conv2D {
            ih, iw, ic, oh, ow, oc, kh, kw, stride, padding,
            channels_first, requant: rq,
        } => {
            let x = in_buf(call, 0)?;
            let w = &p.consts[call.consts[0]];
            let bias = const_i32(p, call.consts[1]);
            let (pt, pl) = pads(*ih, *iw, *kh, *kw, stride.0, stride.1, *padding);
            let wd = &w.data;
            // §Perf: widen the input once and subtract the zero point
            // up front — the inner loop then reads a flat i32 slice
            // instead of paying buffer-meta + dtype dispatch per MAC
            let mut xin = mem.read_all(p, x);
            for v in xin.iter_mut() {
                *v -= rq.zp_in;
            }
            // §Perf iteration 2: loop interchange — accumulate all
            // output channels of one pixel together so weight-matrix
            // rows are read contiguously (GEMM row order), instead of
            // striding by `oc` per MAC
            let mut acc = vec![0i64; *oc];
            for oy in 0..*oh {
                for ox in 0..*ow {
                    let out_base = ((oy * ow) + ox) * oc;
                    for (co, a) in acc.iter_mut().enumerate() {
                        *a = bias[co] as i64;
                    }
                    for ky in 0..*kh {
                        let iy = (oy * stride.0 + ky) as isize - pt as isize;
                        if iy < 0 || iy >= *ih as isize {
                            continue;
                        }
                        for kx in 0..*kw {
                            let ix = (ox * stride.1 + kx) as isize - pl as isize;
                            if ix < 0 || ix >= *iw as isize {
                                continue;
                            }
                            let base = ((iy as usize * iw) + ix as usize) * ic;
                            let xrow = &xin[base..base + ic];
                            // packed weight matrix row order: (ky,kx,ci)
                            // for NHWC, (ci,ky,kx) for NCHW; cols = oc
                            for (ci, &xv) in xrow.iter().enumerate() {
                                if xv == 0 {
                                    continue; // zp-padding fast path
                                }
                                let row = if *channels_first {
                                    ci * kh * kw + ky * kw + kx
                                } else {
                                    (ky * kw + kx) * ic + ci
                                };
                                let wrow = &wd[row * oc..(row + 1) * oc];
                                let xv = xv as i64;
                                for (a, &wv) in acc.iter_mut().zip(wrow) {
                                    *a += xv * (wv as i8 as i64);
                                }
                            }
                        }
                    }
                    for (co, &a) in acc.iter().enumerate() {
                        mem.store(p, call.output, out_base + co, requant(a, rq));
                    }
                }
            }
        }
        KernelKind::DwConv2D {
            ih, iw, c, oh, ow, kh, kw, stride, padding, requant: rq,
        } => {
            let x = in_buf(call, 0)?;
            let w = &p.consts[call.consts[0]];
            let bias = const_i32(p, call.consts[1]);
            let (pt, pl) = pads(*ih, *iw, *kh, *kw, stride.0, stride.1, *padding);
            let mut xin = mem.read_all(p, x);
            for v in xin.iter_mut() {
                *v -= rq.zp_in;
            }
            // §Perf iteration 3: channel-vector accumulation — both
            // the input row and the 1HWC weight row are contiguous
            // over channels, so the tap loop vectorizes
            let mut acc = vec![0i64; *c];
            for oy in 0..*oh {
                for ox in 0..*ow {
                    let out_base = ((oy * ow) + ox) * c;
                    for (ch, a) in acc.iter_mut().enumerate() {
                        *a = bias[ch] as i64;
                    }
                    for ky in 0..*kh {
                        let iy = (oy * stride.0 + ky) as isize - pt as isize;
                        if iy < 0 || iy >= *ih as isize {
                            continue;
                        }
                        for kx in 0..*kw {
                            let ix = (ox * stride.1 + kx) as isize - pl as isize;
                            if ix < 0 || ix >= *iw as isize {
                                continue;
                            }
                            let base = ((iy as usize * iw) + ix as usize) * c;
                            let xrow = &xin[base..base + c];
                            // weights stored 1HWC: [ky][kx][·]
                            let wrow = &w.data[(ky * kw + kx) * c..(ky * kw + kx + 1) * c];
                            for ((a, &xv), &wv) in
                                acc.iter_mut().zip(xrow).zip(wrow)
                            {
                                *a += xv as i64 * (wv as i8 as i64);
                            }
                        }
                    }
                    for (ch, &a) in acc.iter().enumerate() {
                        mem.store(p, call.output, out_base + ch, requant(a, rq));
                    }
                }
            }
        }
        KernelKind::Dense { batch, in_n, out_n, requant: rq } => {
            let x = in_buf(call, 0)?;
            let w = &p.consts[call.consts[0]]; // [out, in] row-major
            let bias = const_i32(p, call.consts[1]);
            let mut xin = mem.read_all(p, x);
            for v in xin.iter_mut() {
                *v -= rq.zp_in;
            }
            for b in 0..*batch {
                let xrow = &xin[b * in_n..(b + 1) * in_n];
                for o in 0..*out_n {
                    let wrow = &w.data[o * in_n..(o + 1) * in_n];
                    let mut acc = bias[o] as i64;
                    for (xv, wv) in xrow.iter().zip(wrow) {
                        acc += *xv as i64 * (*wv as i8 as i64);
                    }
                    mem.store(p, call.output, b * out_n + o, requant(acc, rq));
                }
            }
        }
        KernelKind::AvgPool2D { ih: _, iw, c, oh, ow, fh, fw, stride } => {
            let x = in_buf(call, 0)?;
            let count = (fh * fw) as f64;
            for oy in 0..*oh {
                for ox in 0..*ow {
                    for ch in 0..*c {
                        let mut sum = 0i64;
                        for ky in 0..*fh {
                            for kx in 0..*fw {
                                let iy = oy * stride.0 + ky;
                                let ix = ox * stride.1 + kx;
                                sum += mem.load(p, x, ((iy * iw) + ix) * c + ch)
                                    as i64;
                            }
                        }
                        let v = round_half_even(sum as f64 / count)
                            .clamp(-128.0, 127.0) as i32;
                        mem.store(p, call.output, ((oy * ow) + ox) * c + ch, v);
                    }
                }
            }
        }
        KernelKind::MaxPool2D { ih: _, iw, c, oh, ow, fh, fw, stride } => {
            let x = in_buf(call, 0)?;
            for oy in 0..*oh {
                for ox in 0..*ow {
                    for ch in 0..*c {
                        let mut m = i32::MIN;
                        for ky in 0..*fh {
                            for kx in 0..*fw {
                                let iy = oy * stride.0 + ky;
                                let ix = ox * stride.1 + kx;
                                m = m.max(mem.load(p, x, ((iy * iw) + ix) * c + ch));
                            }
                        }
                        mem.store(p, call.output, ((oy * ow) + ox) * c + ch, m);
                    }
                }
            }
        }
        KernelKind::Add { elems, s_a, zp_a, s_b, zp_b, s_o, zp_o, act } => {
            let a = in_buf(call, 0)?;
            let b = in_buf(call, 1)?;
            for i in 0..*elems {
                let fa = (mem.load(p, a, i) - zp_a) as f64 * (s_a / s_o);
                let fb = (mem.load(p, b, i) - zp_b) as f64 * (s_b / s_o);
                let y = round_half_even(fa + fb) + *zp_o as f64;
                let lo = if *act == 1 { *zp_o } else { -128 };
                let v = (y as i64).clamp(lo as i64, 127) as i32;
                mem.store(p, call.output, i, v);
            }
        }
        KernelKind::Copy { elems } | KernelKind::Transform { elems, .. } => {
            let x = in_buf(call, 0)?;
            for i in 0..*elems {
                let v = mem.load(p, x, i);
                mem.store(p, call.output, i, v);
            }
        }
        KernelKind::Softmax { elems, s_in, zp_in } => {
            let x = in_buf(call, 0)?;
            // f32 softmax matching kernels/ref.py::softmax_int8
            let mut f: Vec<f32> = (0..*elems)
                .map(|i| (mem.load(p, x, i) - zp_in) as f32 * *s_in as f32)
                .collect();
            let max = f.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0f32;
            for v in f.iter_mut() {
                *v = (*v - max).exp();
                sum += *v;
            }
            for (i, v) in f.iter().enumerate() {
                let q = round_half_even((*v / sum) as f64 * 256.0) - 128.0;
                mem.store(p, call.output, i, q.clamp(-128.0, 127.0) as i32);
            }
        }
    }
    Ok(())
}

/// Decode an i32 constant (bias vectors). Shared with `plan.rs` so
/// the interpreter and the compiled plan can never diverge.
pub(crate) fn const_i32(p: &Program, id: ConstId) -> Vec<i32> {
    p.consts[id]
        .data
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

/// SAME-padding (top, left) amounts; VALID = 0. Shared with `plan.rs`.
pub(crate) fn pads(
    ih: usize, iw: usize, kh: usize, kw: usize,
    sh: usize, sw: usize, padding: u8,
) -> (usize, usize) {
    if padding == 1 {
        return (0, 0);
    }
    let (pt, _) = crate::tensor::same_pads(ih, kh, sh);
    let (pl, _) = crate::tensor::same_pads(iw, kw, sw);
    (pt, pl)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backends::builder::{lower, LowerOpts};
    use crate::backends::planner::{plan, PlannerKind};
    use crate::graph::model::testutil::tiny_conv;
    use crate::isa;
    use crate::kernels::KernelLib;
    use crate::mcu::MemSystem;

    fn etiss_spec() -> McuSpec {
        McuSpec {
            name: "etiss",
            isa: &isa::RV32GC,
            clock_mhz: 100.0,
            flash_total: u64::MAX / 2,
            flash_reserved: 0,
            ram_total: u64::MAX / 2,
            ram_reserved: 0,
            memsys: MemSystem::ideal(),
        }
    }

    fn tiny_program(lib: KernelLib, legalize: bool) -> Program {
        let g = tiny_conv();
        let mut p = lower(
            &g,
            "t",
            LowerOpts { lib, legalize_i16: legalize, transform_input: legalize },
        )
        .unwrap();
        plan(&mut p, PlannerKind::GreedyArena);
        p
    }

    /// Reference conv implementation straight from the math.
    fn conv_reference(input: &[i8]) -> Vec<i8> {
        let g = tiny_conv();
        let w = g.tensor(1).data_i8().unwrap().to_vec();
        // scales are stored as f32 — convert exactly like the lowering
        let mult = 0.5f32 as f64 * 0.01f32 as f64 / 0.25f32 as f64;
        let mut out = vec![0i8; 4 * 4 * 3];
        for oy in 0..4usize {
            for ox in 0..4usize {
                for oc in 0..3usize {
                    let mut acc = 0i64;
                    for ky in 0..3usize {
                        for kx in 0..3usize {
                            let iy = oy as isize + ky as isize - 1;
                            let ix = ox as isize + kx as isize - 1;
                            if iy < 0 || iy > 3 || ix < 0 || ix > 3 {
                                continue;
                            }
                            for ic in 0..2usize {
                                let x = input
                                    [(iy as usize * 4 + ix as usize) * 2 + ic]
                                    as i64;
                                let wv = w[((oc * 3 + ky) * 3 + kx) * 2 + ic]
                                    as i64;
                                acc += x * wv;
                            }
                        }
                    }
                    let y = round_half_even(acc as f64 * mult) - 128.0;
                    out[(oy * 4 + ox) * 3 + oc] =
                        (y.max(-128.0).min(127.0)) as i8;
                }
            }
        }
        out
    }

    #[test]
    fn conv_matches_hand_reference() {
        let p = tiny_program(KernelLib::TflmRef, false);
        let input: Vec<i8> = (0..32).map(|x| (x * 7 % 256) as i8).collect();
        let (out, stats) =
            execute(&p, &etiss_spec(), &input, ExecOpts::default()).unwrap();
        assert_eq!(out, conv_reference(&input));
        assert!(stats.ref_instructions > 0);
    }

    #[test]
    fn all_lowerings_agree_numerically() {
        use crate::schedules::{Family, Layout, Schedule};
        let input: Vec<i8> = (0..32).map(|x| (x as i8).wrapping_mul(13)).collect();
        let base = {
            let p = tiny_program(KernelLib::TflmRef, false);
            execute(&p, &etiss_spec(), &input, ExecOpts::default()).unwrap().0
        };
        for (fam, lay) in [
            (Family::DefaultX86, Layout::Nhwc),
            (Family::DefaultX86, Layout::Nchw),
            (Family::Arm, Layout::Nhwc),
            (Family::Arm, Layout::Nchw),
        ] {
            let s = Schedule::new(fam, lay);
            let p = tiny_program(KernelLib::Tvm(s), s.legalizes_to_i16());
            let (out, _) =
                execute(&p, &etiss_spec(), &input, ExecOpts::default()).unwrap();
            assert_eq!(out, base, "{fam:?}/{lay:?} diverged");
        }
    }

    #[test]
    fn cost_only_mode_matches_accounting() {
        let p = tiny_program(KernelLib::TflmRef, false);
        let input = vec![0i8; 32];
        let (_, full) =
            execute(&p, &etiss_spec(), &input, ExecOpts::default()).unwrap();
        let (out, dry) =
            execute(&p, &etiss_spec(), &input, ExecOpts { compute: false })
                .unwrap();
        assert!(out.is_empty());
        assert_eq!(full.ref_instructions, dry.ref_instructions);
        assert_eq!(full.instructions, dry.instructions);
    }

    #[test]
    fn requant_matches_python_round_half_even() {
        // acc * 0.5 hits ties: np.round(2.5)=2, np.round(3.5)=4
        let rq = Requant { multiplier: 0.5, zp_in: 0, zp_out: 0, act: 0 };
        assert_eq!(requant(5, &rq), 2);
        assert_eq!(requant(7, &rq), 4);
        assert_eq!(requant(-5, &rq), -2);
        // saturation
        assert_eq!(requant(10_000, &rq), 127);
        assert_eq!(requant(-10_000, &rq), -128);
        // relu clamps at zp_out
        let rq = Requant { multiplier: 0.5, zp_in: 0, zp_out: 3, act: 1 };
        assert_eq!(requant(-10, &rq), 3);
    }
}
