//! Memory-system model: where Table V's catastrophic NHWC rows come
//! from.
//!
//! Weights live in flash. The ESP32 family executes from **external
//! SPI flash through a small cache** (32 KiB): a kernel whose weight
//! reuse window exceeds the cache re-fetches every pass over SPI at a
//! huge per-line penalty — the 16–25 s NHWC rows on esp32c3/esp32
//! (vs ~2× on the STM32s, whose **internal** flash with ART prefetch
//! has single-digit wait states). The model is analytic (no per-access
//! simulation): the kernel's `WeightStream` descriptor gives streamed
//! bytes, reuse window and contiguity; we compute expected stall
//! cycles per kernel call.

use crate::tinyir::WeightStream;

/// Kind of flash the weights are fetched from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlashKind {
    /// On-die flash behind a prefetcher (STM32 ART): short, mostly
    /// hidden wait states; strided access defeats prefetch.
    Internal,
    /// External SPI/QSPI flash behind a unified cache (ESP32 family).
    SpiCached,
    /// Host simulation (ETISS): memory is flat, no stall modelling —
    /// Table IV reports pure instruction counts.
    Ideal,
}

/// Memory-system parameters of one target.
#[derive(Debug, Clone, Copy)]
pub struct MemSystem {
    pub flash: FlashKind,
    /// Flash cache (SpiCached) or prefetch window (Internal), bytes.
    pub cache_bytes: u64,
    /// Cache line / prefetch burst size, bytes.
    pub line_bytes: u64,
    /// Cycles to refill one line from backing flash.
    pub miss_cycles: f64,
    /// SRAM access is single-cycle on all Table II parts.
    pub sram_wait: f64,
}

impl MemSystem {
    pub fn ideal() -> MemSystem {
        MemSystem {
            flash: FlashKind::Ideal,
            cache_bytes: u64::MAX,
            line_bytes: 32,
            miss_cycles: 0.0,
            sram_wait: 0.0,
        }
    }

    /// STM32 internal flash: 5–7 wait states, ART prefetcher hides
    /// sequential fetch almost completely.
    pub fn stm32_internal() -> MemSystem {
        MemSystem {
            flash: FlashKind::Internal,
            cache_bytes: 1024, // prefetch queue + ART cache lines
            line_bytes: 16,
            miss_cycles: 6.0,
            sram_wait: 0.0,
        }
    }

    /// ESP32/ESP32-C3 SPI flash behind the 32 KiB cache; a miss costs
    /// an SPI burst (~80 core cycles at these clock ratios).
    pub fn esp_spi() -> MemSystem {
        MemSystem {
            flash: FlashKind::SpiCached,
            cache_bytes: 32 * 1024,
            line_bytes: 32,
            miss_cycles: 80.0,
            sram_wait: 0.0,
        }
    }

    /// Expected stall cycles for one kernel call's weight traffic.
    ///
    /// If the reuse window fits the *effective* cache, only the first
    /// pass misses (compulsory): `window / line` refills. Strided
    /// walks degrade the effective cache by 8× (power-of-two strides
    /// concentrate on few sets — conflict misses long before
    /// capacity). Past that window, a strided stream misses on every
    /// access (1 useful byte per fetched line: the Table V NHWC
    /// catastrophe on SPI-flash parts), while a packed stream still
    /// amortizes whole lines.
    pub fn weight_stall_cycles(&self, w: &WeightStream) -> f64 {
        if w.bytes_streamed == 0 {
            return 0.0;
        }
        match self.flash {
            FlashKind::Ideal => 0.0,
            FlashKind::Internal | FlashKind::SpiCached => {
                let effective_cache = if w.contiguous {
                    self.cache_bytes
                } else {
                    self.cache_bytes / 8
                };
                if w.reuse_window <= effective_cache {
                    // compulsory misses only: each window byte once
                    (w.reuse_window as f64 / self.line_bytes as f64)
                        * self.miss_cycles
                } else if w.contiguous {
                    (w.bytes_streamed as f64 / self.line_bytes as f64)
                        * self.miss_cycles
                } else {
                    // strided thrash: every access its own refill
                    w.bytes_streamed as f64 * self.miss_cycles
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stream(bytes: u64, window: u64, contiguous: bool) -> WeightStream {
        WeightStream { bytes_streamed: bytes, reuse_window: window, contiguous }
    }

    #[test]
    fn ideal_never_stalls() {
        let m = MemSystem::ideal();
        assert_eq!(m.weight_stall_cycles(&stream(1 << 30, 1 << 30, false)), 0.0);
    }

    #[test]
    fn fitting_window_pays_compulsory_only() {
        let m = MemSystem::esp_spi();
        // 4 kB window fits the 32 kB cache: ~4k/32 * 80 = 10k cycles
        let s = m.weight_stall_cycles(&stream(10_000_000, 4096, true));
        assert!(s < 15_000.0, "{s}");
    }

    #[test]
    fn thrashing_strided_stream_is_catastrophic_on_spi() {
        let m = MemSystem::esp_spi();
        // resnet stack3-like: 36 kB window > 32 kB cache, strided,
        // streamed 64 times (once per output row) = 2.3 MB
        let s = m.weight_stall_cycles(&stream(2_300_000, 36_864, false));
        // ~2.3e6/4*80 = 46M stall cycles = ~0.3 s @160 MHz per layer —
        // summed over layers this is the paper's 16–25 s NHWC rows
        assert!(s > 4.0e7, "{s}");
    }

    #[test]
    fn internal_flash_much_milder_than_spi() {
        let s = stream(2_300_000, 36_864, false);
        let spi = MemSystem::esp_spi().weight_stall_cycles(&s);
        let stm = MemSystem::stm32_internal().weight_stall_cycles(&s);
        assert!(
            stm < spi / 10.0,
            "stm {stm} should be >10x milder than spi {spi}"
        );
    }

    #[test]
    fn contiguous_streams_amortize_lines() {
        let m = MemSystem::esp_spi();
        let strided = m.weight_stall_cycles(&stream(1_000_000, 64 * 1024, false));
        let packed = m.weight_stall_cycles(&stream(1_000_000, 64 * 1024, true));
        assert!(packed < strided / 5.0);
    }
}
