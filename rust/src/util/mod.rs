//! Small shared utilities: seeded RNG, logging, formatting helpers.
//!
//! Nothing beyond `xla` and `anyhow` is reachable offline in this
//! environment, so these substitute for the usual `rand`/`log` crates.

pub mod rng;
pub mod log;
pub mod fmt;
pub mod hash;
pub mod proc;
pub mod trace;
pub mod faults;
pub mod metrics;

pub use hash::{fnv1a64, StableHasher};
pub use rng::XorShift64;

/// Monotonic stopwatch for stage timing (Table III reproduction).
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(std::time::Instant::now())
    }
    pub fn elapsed_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
    pub fn elapsed_ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

/// Integer ceiling division (used throughout shape/padding math).
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Round-half-to-even on f64 — IEEE `roundTiesToEven`, matching
/// `np.round`/`jnp.round` so requantization is bit-identical to the
/// python golden path (see python/compile/quant.py).
pub fn round_half_even(x: f64) -> f64 {
    let r = x.round(); // half away from zero
    if (x - x.trunc()).abs() == 0.5 {
        // tie: pick the even neighbour
        if r % 2.0 == 0.0 {
            r
        } else {
            r - (r - x).signum()
        }
    } else {
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_half_even_matches_numpy() {
        // np.round([0.5, 1.5, 2.5, -0.5, -1.5, -2.5]) = [0,2,2,-0,-2,-2]
        assert_eq!(round_half_even(0.5), 0.0);
        assert_eq!(round_half_even(1.5), 2.0);
        assert_eq!(round_half_even(2.5), 2.0);
        assert_eq!(round_half_even(-0.5), 0.0);
        assert_eq!(round_half_even(-1.5), -2.0);
        assert_eq!(round_half_even(-2.5), -2.0);
        assert_eq!(round_half_even(3.7), 4.0);
        assert_eq!(round_half_even(-3.7), -4.0);
        assert_eq!(round_half_even(2.0), 2.0);
        assert_eq!(round_half_even(1234567.5), 1234568.0);
        assert_eq!(round_half_even(1234566.5), 1234566.0);
    }

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_div(1, 8), 1);
        assert_eq!(ceil_div(0, 8), 0);
    }
}
