//! Fleet-wide span tracer: per-stage profiling with Chrome
//! `trace_event` export.
//!
//! Every pipeline boundary — Load/Tune/Build execution, per-run
//! Compile/Run tails, cache lookups, store I/O, transport requests,
//! lease claims and heartbeats — opens a [`SpanGuard`] that records a
//! wall-clock span into a process-global collector when tracing is
//! enabled (`trace.file` config / `--trace`). Disabled (the default),
//! [`span`] is one relaxed atomic load and the guard is inert, so the
//! hot path pays nothing measurable.
//!
//! Spans use **epoch microseconds** (not a process-local monotonic
//! clock) so spans recorded by `mlonmcu worker` child processes and
//! `--connect` remote workers merge onto one session timeline: local
//! workers write `trace-<pid>.json` span files into their queue dir,
//! remote workers ship drained spans over the transport
//! (`OP_TRACE_PUT`), and the parent merges everything into a single
//! Chrome `trace_event` JSON file (load it in `chrome://tracing` or
//! Perfetto). `mlonmcu trace summary <file>` aggregates the same file
//! into a per-stage/per-worker table via [`aggregate`].
//!
//! Tracing never touches report bytes: the serial-vs-sharded-vs-remote
//! byte-identical report guarantee holds with tracing on
//! (`tests/dispatch_equivalence.rs`).

use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{SystemTime, UNIX_EPOCH};

use anyhow::{anyhow, Context, Result};

use super::metrics;
use crate::data::Json;

/// Process-global on/off switch. Off by default; the only cost of a
/// disabled tracer is the relaxed load in [`enabled`].
static ENABLED: AtomicBool = AtomicBool::new(false);

/// Spans recorded by this process since the last [`drain`].
static SPANS: Mutex<Vec<Span>> = Mutex::new(Vec::new());

/// Small dense thread ids for the Chrome `tid` field (thread names
/// are not stable across runs; indices are good enough to separate
/// scheduler lanes visually).
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
}

pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

pub fn enable() {
    ENABLED.store(true, Ordering::Relaxed);
}

pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
}

/// Take every span recorded so far out of the collector.
pub fn drain() -> Vec<Span> {
    std::mem::take(&mut *SPANS.lock().unwrap())
}

/// Merge externally produced spans (worker span files, spans shipped
/// over the transport) into this process's collector. No-op while
/// tracing is disabled, so stray late arrivals cannot leak into an
/// untraced run.
pub fn record_all(spans: Vec<Span>) {
    if enabled() && !spans.is_empty() {
        SPANS.lock().unwrap().extend(spans);
    }
}

fn now_us() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_micros() as u64)
        .unwrap_or(0)
}

fn tid() -> u64 {
    TID.with(|t| *t)
}

/// One completed wall-clock span ("X" complete event in Chrome
/// `trace_event` terms).
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    /// Stage or operation name ("load", "build", "run", "claim", …).
    pub name: String,
    /// Subsystem category ("stage", "cache", "store", "transport",
    /// "lease", "worker", "session").
    pub cat: String,
    /// Start, epoch microseconds (comparable across processes).
    pub ts_us: u64,
    pub dur_us: u64,
    /// Recording process — worker spans carry the worker's pid.
    pub pid: u32,
    pub tid: u64,
    /// Free-form tags: run index, backend, schedule, worker, outcome.
    pub args: Vec<(String, String)>,
}

/// RAII recorder returned by [`span`]: measures from construction to
/// drop and records the result iff tracing was enabled at open time.
pub struct SpanGuard {
    cat: &'static str,
    name: String,
    start_us: u64,
    args: Vec<(String, String)>,
    active: bool,
}

/// Open a span. When tracing is disabled this is a single atomic load
/// and the returned guard does nothing on drop.
pub fn span(cat: &'static str, name: impl Into<String>) -> SpanGuard {
    let active = enabled();
    SpanGuard {
        cat,
        name: if active { name.into() } else { String::new() },
        start_us: if active { now_us() } else { 0 },
        args: Vec::new(),
        active,
    }
}

impl SpanGuard {
    /// Attach a tag at open time (builder style).
    pub fn arg(mut self, key: &str, value: impl Into<String>) -> Self {
        self.note(key, value);
        self
    }

    /// Attach a lazily computed tag: the closure only runs when the
    /// span is live, so disabled-tracer call sites never pay for
    /// `format!`/hex allocations.
    pub fn arg_with(mut self, key: &str, value: impl FnOnce() -> String) -> Self {
        if self.active {
            self.args.push((key.to_string(), value()));
        }
        self
    }

    /// Attach a tag after the fact (outcomes known only at the end,
    /// e.g. cache hit vs miss).
    pub fn note(&mut self, key: &str, value: impl Into<String>) {
        if self.active {
            self.args.push((key.to_string(), value.into()));
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if !self.active {
            return;
        }
        let end = now_us();
        let span = Span {
            name: std::mem::take(&mut self.name),
            cat: self.cat.to_string(),
            ts_us: self.start_us,
            dur_us: end.saturating_sub(self.start_us),
            pid: std::process::id(),
            tid: tid(),
            args: std::mem::take(&mut self.args),
        };
        SPANS.lock().unwrap().push(span);
    }
}

// ------------------------------------------------------------ export --

/// Render spans as a Chrome `trace_event` JSON document (complete
/// "X" events). Spans are sorted by start time (then pid/tid/name)
/// so the output is deterministic for a given span set.
pub fn to_chrome_json(mut spans: Vec<Span>) -> String {
    spans.sort_by(|a, b| {
        (a.ts_us, a.pid, a.tid, &a.name).cmp(&(b.ts_us, b.pid, b.tid, &b.name))
    });
    let events: Vec<Json> = spans
        .iter()
        .map(|s| {
            let args = Json::Obj(
                s.args
                    .iter()
                    .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                    .collect(),
            );
            Json::obj(vec![
                ("name", Json::Str(s.name.clone())),
                ("cat", Json::Str(s.cat.clone())),
                ("ph", Json::Str("X".into())),
                ("ts", Json::Num(s.ts_us as f64)),
                ("dur", Json::Num(s.dur_us as f64)),
                ("pid", Json::Num(s.pid as f64)),
                ("tid", Json::Num(s.tid as f64)),
                ("args", args),
            ])
        })
        .collect();
    Json::obj(vec![
        ("displayTimeUnit", Json::Str("ms".into())),
        ("traceEvents", Json::Arr(events)),
    ])
    .to_string()
}

/// Parse a Chrome `trace_event` document produced by
/// [`to_chrome_json`] (used by `trace summary`, worker span-file
/// collection and the transport's span shipping).
pub fn parse_chrome_json(text: &str) -> Result<Vec<Span>> {
    let doc = Json::parse(text).context("parsing trace JSON")?;
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("trace JSON lacks a traceEvents array"))?;
    events
        .iter()
        .enumerate()
        .map(|(i, e)| {
            span_from_event(e)
                .with_context(|| format!("trace event #{i} is malformed"))
        })
        .collect()
}

/// Decode one `traceEvents` entry back into a [`Span`].
pub fn span_from_event(e: &Json) -> Result<Span> {
    let field = |k: &str| {
        e.get(k)
            .and_then(Json::as_i64)
            .ok_or_else(|| anyhow!("trace event lacks numeric '{k}'"))
    };
    let name = e
        .get("name")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("trace event lacks a name"))?;
    let args = match e.get("args") {
        Some(Json::Obj(m)) => m
            .iter()
            .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
            .collect(),
        _ => Vec::new(),
    };
    Ok(Span {
        name: name.to_string(),
        cat: e
            .get("cat")
            .and_then(Json::as_str)
            .unwrap_or_default()
            .to_string(),
        ts_us: field("ts")? as u64,
        dur_us: field("dur")? as u64,
        pid: field("pid")? as u32,
        tid: field("tid")? as u64,
        args,
    })
}

/// Write spans to `path` as Chrome trace JSON, creating parent dirs.
pub fn write_spans(path: &Path, spans: Vec<Span>) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {}", dir.display()))?;
        }
    }
    std::fs::write(path, to_chrome_json(spans))
        .with_context(|| format!("writing trace to {}", path.display()))
}

/// Read a span file written by [`write_spans`].
pub fn read_spans(path: &Path) -> Result<Vec<Span>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace {}", path.display()))?;
    parse_chrome_json(&text)
}

/// The span-file name a worker process writes into its queue dir.
pub fn worker_file_name() -> String {
    format!("trace-{}.json", std::process::id())
}

/// Collect every `trace-*.json` span file directly under `dir`
/// (a session queue dir). Unreadable or partially written files —
/// e.g. left by a worker killed mid-write — are skipped with a
/// warning naming the offending file; collection stays best-effort
/// but never discards silently.
pub fn collect_dir(dir: &Path) -> Vec<Span> {
    let mut out = Vec::new();
    let Ok(entries) = std::fs::read_dir(dir) else {
        return out;
    };
    let mut files: Vec<_> = entries
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("trace-") && n.ends_with(".json"))
        })
        .collect();
    files.sort();
    for f in files {
        match read_spans(&f) {
            Ok(spans) => out.extend(spans),
            Err(e) => crate::log_warn!(
                "trace: skipping malformed span file {} ({e:#})",
                f.display()
            ),
        }
    }
    out
}

// --------------------------------------------------------- aggregate --

/// One `(stage name, pid)` aggregate row of [`aggregate`].
///
/// Durations also feed a [`metrics::Histogram`] so `trace summary`
/// shares its percentile estimator (p50/p95/p99) with the metrics
/// registry instead of growing a second implementation.
#[derive(Debug, Clone, PartialEq)]
pub struct StageAgg {
    pub name: String,
    pub pid: u32,
    pub count: usize,
    pub total_us: u64,
    pub max_us: u64,
    pub hist: metrics::Histogram,
}

impl StageAgg {
    pub fn p50_us(&self) -> u64 {
        self.hist.percentile(0.50)
    }

    pub fn p95_us(&self) -> u64 {
        self.hist.percentile(0.95)
    }

    pub fn p99_us(&self) -> u64 {
        self.hist.percentile(0.99)
    }
}

/// Aggregate spans into per-stage/per-worker rows, sorted by name
/// then pid (`mlonmcu trace summary`).
pub fn aggregate(spans: &[Span]) -> Vec<StageAgg> {
    let mut by_key: std::collections::BTreeMap<(String, u32), StageAgg> =
        std::collections::BTreeMap::new();
    for s in spans {
        let agg = by_key
            .entry((s.name.clone(), s.pid))
            .or_insert_with(|| StageAgg {
                name: s.name.clone(),
                pid: s.pid,
                count: 0,
                total_us: 0,
                max_us: 0,
                hist: metrics::Histogram::default(),
            });
        agg.count += 1;
        agg.total_us += s.dur_us;
        agg.max_us = agg.max_us.max(s.dur_us);
        agg.hist.observe(s.dur_us);
    }
    by_key.into_values().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The collector and the ENABLED switch are process-global, and
    /// cargo runs tests on parallel threads — serialize the tests
    /// that toggle them.
    static GATE: Mutex<()> = Mutex::new(());

    fn locked() -> std::sync::MutexGuard<'static, ()> {
        GATE.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let _g = locked();
        disable();
        drain();
        {
            let mut s = span("stage", "load");
            s.note("backend", "tflmi");
        }
        assert!(drain().is_empty());
    }

    #[test]
    fn spans_roundtrip_through_chrome_json() {
        let _g = locked();
        enable();
        drain();
        {
            let _outer = span("stage", "build").arg("backend", "tvmaot");
            let _inner = span("cache", "lookup").arg("outcome", "miss");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        disable();
        let spans = drain();
        assert_eq!(spans.len(), 2);
        let text = to_chrome_json(spans.clone());
        // well-formed JSON with the trace_event envelope
        let doc = Json::parse(&text).unwrap();
        let events = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        assert_eq!(events.len(), 2);
        for e in events {
            assert_eq!(e.get("ph").unwrap().as_str(), Some("X"));
            assert!(e.get("ts").unwrap().as_i64().unwrap() > 0);
            assert!(e.get("dur").unwrap().as_i64().unwrap() >= 0);
            assert_eq!(
                e.get("pid").unwrap().as_i64().unwrap(),
                std::process::id() as i64
            );
        }
        let parsed = parse_chrome_json(&text).unwrap();
        assert_eq!(parsed.len(), 2);
        let mut expect = spans;
        expect.sort_by_key(|s| s.ts_us);
        for (a, b) in parsed.iter().zip(&expect) {
            assert_eq!((a.ts_us, a.dur_us, &a.name), (b.ts_us, b.dur_us, &b.name));
        }
    }

    #[test]
    fn spans_nest_and_end_after_start() {
        let _g = locked();
        enable();
        drain();
        {
            let _outer = span("stage", "outer");
            std::thread::sleep(std::time::Duration::from_millis(3));
            {
                let _inner = span("stage", "inner");
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        disable();
        let spans = drain();
        let outer = spans.iter().find(|s| s.name == "outer").unwrap();
        let inner = spans.iter().find(|s| s.name == "inner").unwrap();
        // inner opened after outer, and closed before outer closed:
        // proper nesting, no end-before-start
        assert!(inner.ts_us >= outer.ts_us);
        assert!(
            inner.ts_us + inner.dur_us <= outer.ts_us + outer.dur_us,
            "inner span must end within its enclosing span"
        );
        assert!(outer.dur_us >= inner.dur_us);
    }

    #[test]
    fn record_all_respects_the_switch_and_merges() {
        let _g = locked();
        disable();
        drain();
        let foreign = vec![Span {
            name: "load".into(),
            cat: "stage".into(),
            ts_us: 10,
            dur_us: 5,
            pid: 4242,
            tid: 1,
            args: vec![("worker".into(), "4242".into())],
        }];
        record_all(foreign.clone());
        assert!(drain().is_empty(), "disabled tracer must drop merges");
        enable();
        record_all(foreign);
        disable();
        let spans = drain();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].pid, 4242, "worker spans keep the worker pid");
    }

    #[test]
    fn aggregate_groups_by_stage_and_pid() {
        let mk = |name: &str, pid: u32, dur: u64| Span {
            name: name.into(),
            cat: "stage".into(),
            ts_us: 0,
            dur_us: dur,
            pid,
            tid: 1,
            args: Vec::new(),
        };
        let rows = aggregate(&[
            mk("build", 1, 10),
            mk("build", 1, 30),
            mk("build", 2, 7),
            mk("load", 1, 5),
        ]);
        assert_eq!(rows.len(), 3);
        assert_eq!(
            (rows[0].name.as_str(), rows[0].pid, rows[0].count),
            ("build", 1, 2)
        );
        assert_eq!(rows[0].total_us, 40);
        assert_eq!(rows[0].max_us, 30);
        assert_eq!(rows[0].hist.count, 2);
        assert_eq!(rows[0].p99_us(), 30, "p99 clamps to the exact max");
        assert!(rows[0].p50_us() >= 10 && rows[0].p50_us() <= 30);
        assert_eq!(rows[2].p50_us(), 5, "single span is exact");
        assert_eq!(rows[2].p95_us(), 5);
        assert_eq!((rows[1].name.as_str(), rows[1].pid), ("build", 2));
        assert_eq!((rows[2].name.as_str(), rows[2].pid), ("load", 1));
    }

    #[test]
    fn span_files_roundtrip_and_collect() {
        let dir = std::env::temp_dir().join("mlonmcu_trace_collect_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let mk = |pid: u32| Span {
            name: "build".into(),
            cat: "stage".into(),
            ts_us: 100,
            dur_us: 1,
            pid,
            tid: 1,
            args: Vec::new(),
        };
        write_spans(&dir.join("trace-11.json"), vec![mk(11)]).unwrap();
        write_spans(&dir.join("trace-22.json"), vec![mk(22)]).unwrap();
        std::fs::write(dir.join("trace-bad.json"), b"{half a doc").unwrap();
        std::fs::write(dir.join("task-0.json"), b"{}").unwrap();
        let spans = collect_dir(&dir);
        assert_eq!(spans.len(), 2, "two good span files, bad one skipped");
        let pids: std::collections::BTreeSet<u32> =
            spans.iter().map(|s| s.pid).collect();
        assert_eq!(pids.into_iter().collect::<Vec<_>>(), vec![11, 22]);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
