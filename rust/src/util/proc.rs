//! Process liveness probing for crash detection.
//!
//! Both the environment store's lock file (`session/store.rs`) and the
//! dispatch work queue's lease files (`session/dispatch.rs`) record the
//! owning process id so that a file left behind by a crashed or killed
//! process can be reclaimed immediately instead of waiting out a
//! conservative mtime timeout.

/// Is a process with this pid currently running (and not a zombie)?
///
/// On Linux this reads `/proc/<pid>/stat`; a missing entry or a
/// zombie/dead state means the owner can no longer touch its files, so
/// breaking its lock/lease is safe. Zombies count as dead because a
/// zombie has already exited — only its exit status lingers.
#[cfg(target_os = "linux")]
pub fn pid_alive(pid: u32) -> bool {
    match std::fs::read_to_string(format!("/proc/{pid}/stat")) {
        Ok(stat) => {
            // field 3 (state) follows the parenthesised comm, which may
            // itself contain spaces or parens — split on the LAST ')'
            let state = stat
                .rfind(')')
                .and_then(|i| stat[i + 1..].trim_start().chars().next());
            !matches!(state, Some('Z' | 'X') | None)
        }
        Err(_) => false,
    }
}

/// Portable fallback: without /proc there is no dependency-free way to
/// probe liveness, so report "alive" and let callers fall back to
/// mtime-based staleness.
#[cfg(not(target_os = "linux"))]
pub fn pid_alive(_pid: u32) -> bool {
    true
}

/// Is the owner-marker file at `path` (store lock, dispatch lease)
/// stale? Stale means (a) its mtime exceeds `timeout` — the portable
/// fallback — or (b) the `<pid>-<nonce>` token it records names a
/// process that no longer runs: a dead owner has no writes in flight,
/// so breaking immediately is safe. A vanished file, or a half-written
/// or unparsable token (the owner may be mid-write), reads as live.
pub fn stale_owner_file(path: &std::path::Path, timeout: std::time::Duration) -> bool {
    let Some(age) = std::fs::metadata(path)
        .and_then(|m| m.modified())
        .ok()
        .and_then(|t| t.elapsed().ok())
    else {
        return false; // vanished: the owner released it
    };
    if age > timeout {
        return true;
    }
    let pid = std::fs::read_to_string(path)
        .ok()
        .and_then(|s| s.trim().split('-').next()?.parse::<u32>().ok());
    match pid {
        Some(pid) if pid != std::process::id() => !pid_alive(pid),
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn own_pid_is_alive() {
        assert!(pid_alive(std::process::id()));
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn reaped_child_is_dead() {
        let mut child = std::process::Command::new("true")
            .spawn()
            .expect("spawning /bin/true");
        let pid = child.id();
        child.wait().unwrap();
        assert!(!pid_alive(pid), "reaped pid {pid} must read as dead");
    }
}
