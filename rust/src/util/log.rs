//! Minimal leveled logger. Level from `MLONMCU_LOG`
//! (error|warn|info|debug|trace), default `info`. Thread-safe via a
//! global atomic; output to stderr so reports on stdout stay clean.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized

/// Map an `MLONMCU_LOG` value to a level. Unset means the `info`
/// default silently; an *unrecognized* value also falls back to
/// `info` but returns a warning so the user learns their setting was
/// ignored (previously `MLONMCU_LOG=inof` was indistinguishable from
/// unset).
fn parse_level(var: Option<&str>) -> (Level, Option<String>) {
    match var {
        None => (Level::Info, None),
        Some("error") => (Level::Error, None),
        Some("warn") => (Level::Warn, None),
        Some("info") => (Level::Info, None),
        Some("debug") => (Level::Debug, None),
        Some("trace") => (Level::Trace, None),
        Some(other) => (
            Level::Info,
            Some(format!(
                "unrecognized MLONMCU_LOG value {other:?} (expected \
                 error|warn|info|debug|trace); using info"
            )),
        ),
    }
}

fn init_level() -> u8 {
    let var = std::env::var("MLONMCU_LOG").ok();
    let (level, warning) = parse_level(var.as_deref());
    let lvl = level as u8;
    // compare_exchange so exactly one thread initializes — and warns
    // about a bad value exactly once per process
    match LEVEL.compare_exchange(255, lvl, Ordering::Relaxed, Ordering::Relaxed)
    {
        Ok(_) => {
            if let Some(msg) = warning {
                log(Level::Warn, format_args!("{msg}"));
            }
            lvl
        }
        Err(current) => current,
    }
}

pub fn enabled(level: Level) -> bool {
    let mut cur = LEVEL.load(Ordering::Relaxed);
    if cur == 255 {
        cur = init_level();
    }
    (level as u8) <= cur
}

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn log(level: Level, args: std::fmt::Arguments) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[mlonmcu {tag}] {args}");
    }
}

#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_trace { ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Trace, format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }

    #[test]
    fn parse_level_distinguishes_unset_info_and_garbage() {
        assert_eq!(parse_level(None), (Level::Info, None));
        assert_eq!(parse_level(Some("info")), (Level::Info, None));
        assert_eq!(parse_level(Some("error")), (Level::Error, None));
        assert_eq!(parse_level(Some("warn")), (Level::Warn, None));
        assert_eq!(parse_level(Some("debug")), (Level::Debug, None));
        assert_eq!(parse_level(Some("trace")), (Level::Trace, None));
        let (lvl, warning) = parse_level(Some("inof"));
        assert_eq!(lvl, Level::Info, "bad values still default to info");
        let msg = warning.expect("bad values must produce a warning");
        assert!(msg.contains("inof"), "warning names the bad value: {msg}");
        assert!(msg.contains("error|warn|info|debug|trace"));
    }

    #[test]
    fn log_trace_macro_compiles_and_gates_on_level() {
        set_level(Level::Info);
        assert!(!enabled(Level::Trace));
        crate::log_trace!("invisible at info: {}", 42);
        set_level(Level::Trace);
        assert!(enabled(Level::Trace));
        crate::log_trace!("visible at trace");
        set_level(Level::Info); // restore default for other tests
    }
}
