//! Minimal leveled logger. Level from `MLONMCU_LOG`
//! (error|warn|info|debug|trace), default `info`. Thread-safe via a
//! global atomic; output to stderr so reports on stdout stay clean.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized

fn init_level() -> u8 {
    let lvl = match std::env::var("MLONMCU_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    } as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

pub fn enabled(level: Level) -> bool {
    let mut cur = LEVEL.load(Ordering::Relaxed);
    if cur == 255 {
        cur = init_level();
    }
    (level as u8) <= cur
}

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn log(level: Level, args: std::fmt::Arguments) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[mlonmcu {tag}] {args}");
    }
}

#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }
}
