//! Stable content hashing (FNV-1a, 64-bit) for the artifact cache.
//!
//! `std::hash::Hasher` implementations are allowed to vary between
//! releases and processes; cache keys are persisted to disk and must
//! be reproducible byte-for-byte across runs, so we fix the function
//! here. FNV-1a is tiny, dependency-free and good enough for
//! content-addressing a few thousand artifacts.

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Incremental FNV-1a hasher with typed, length-prefixed writes so
/// that field boundaries cannot alias (`"ab","c"` != `"a","bc"`).
#[derive(Debug, Clone, Copy)]
pub struct StableHasher {
    state: u64,
}

impl Default for StableHasher {
    fn default() -> Self {
        StableHasher::new()
    }
}

impl StableHasher {
    pub fn new() -> StableHasher {
        StableHasher { state: FNV_OFFSET }
    }

    pub fn write_bytes(&mut self, bytes: &[u8]) -> &mut Self {
        self.write_raw(&(bytes.len() as u64).to_le_bytes());
        self.write_raw(bytes);
        self
    }

    pub fn write_str(&mut self, s: &str) -> &mut Self {
        self.write_bytes(s.as_bytes())
    }

    pub fn write_u64(&mut self, x: u64) -> &mut Self {
        self.write_raw(&x.to_le_bytes());
        self
    }

    pub fn write_i64(&mut self, x: i64) -> &mut Self {
        self.write_raw(&x.to_le_bytes());
        self
    }

    pub fn write_u8(&mut self, x: u8) -> &mut Self {
        self.write_raw(&[x]);
        self
    }

    pub fn write_bool(&mut self, x: bool) -> &mut Self {
        self.write_u8(x as u8)
    }

    /// f32 by bit pattern (scales/quant params are exact artifacts of
    /// the python build, never NaN-compared).
    pub fn write_f32(&mut self, x: f32) -> &mut Self {
        self.write_raw(&x.to_bits().to_le_bytes());
        self
    }

    fn write_raw(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state ^= b as u64;
            self.state = self.state.wrapping_mul(FNV_PRIME);
        }
    }

    pub fn finish(&self) -> u64 {
        self.state
    }
}

/// One-shot hash of a byte slice (model file contents).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut state = FNV_OFFSET;
    for &b in bytes {
        state ^= b as u64;
        state = state.wrapping_mul(FNV_PRIME);
    }
    state
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vector() {
        // FNV-1a("") is the offset basis; "a" is a published vector.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = StableHasher::new();
        a.write_str("model").write_u64(42).write_bool(true);
        let mut b = StableHasher::new();
        b.write_str("model").write_u64(42).write_bool(true);
        assert_eq!(a.finish(), b.finish());
    }

    #[test]
    fn field_boundaries_do_not_alias() {
        let mut a = StableHasher::new();
        a.write_str("ab").write_str("c");
        let mut b = StableHasher::new();
        b.write_str("a").write_str("bc");
        assert_ne!(a.finish(), b.finish());
    }

    #[test]
    fn any_field_change_changes_hash() {
        let base = {
            let mut h = StableHasher::new();
            h.write_str("aww").write_str("tvmaot").write_bool(false);
            h.finish()
        };
        let tuned = {
            let mut h = StableHasher::new();
            h.write_str("aww").write_str("tvmaot").write_bool(true);
            h.finish()
        };
        assert_ne!(base, tuned);
    }
}
