//! Deterministic, seeded fault injection for the I/O and execution
//! layers — the systematic replacement for one-off test hooks like
//! the old `dispatch.fault_marker`.
//!
//! A **fault plan** is a comma-separated spec string:
//!
//! ```text
//! seed=42,hang_ms=2000,store.save:error:0.2,stage.build:exit:1:3
//! ```
//!
//! Each rule is `site:kind:prob[:after_n]` — at the named injection
//! site, after the first `after_n` checks, fire `kind` with
//! probability `prob` per check. `seed=`/`hang_ms=`/`delay_ms=`
//! entries parameterize the whole plan. Every rule draws from its own
//! [`XorShift64`](crate::util::rng::XorShift64) stream derived from
//! the plan seed and the rule text, so a plan replays the *same*
//! fault sequence on every run (serial runs are fully deterministic;
//! multi-process runs are deterministic per worker process).
//!
//! Injection sites (checked via [`fire`]) and the kinds they honor:
//!
//! | site | kinds | effect |
//! |---|---|---|
//! | `store.save` | `error`, `short` | save fails / writes a truncated entry |
//! | `store.load` | `error`, `bitflip` | read error (miss) / payload bit flip (verify fail) |
//! | `transport.send` | `drop`, `truncate`, `delay` | request I/O fails / is delayed |
//! | `transport.recv` | `drop`, `truncate`, `delay` | response I/O fails / is delayed |
//! | `queue.lease.heartbeat` | `stall` | heartbeat pauses for `hang_ms` |
//! | `stage.load` / `stage.tune` / `stage.build` | `error`, `panic`, `hang`, `exit` | stage fails / panics / wedges for `hang_ms` / worker exits(9) |
//! | `cache.promote` | `error` | remote-hit promotion into the local store is skipped |
//!
//! The registry is process-global, exactly like the tracer
//! (`util/trace.rs`): with no plan installed, [`fire`] is a single
//! relaxed atomic load. Plans install from config
//! (`[faults] seed/plan/hang_ms`), the `--faults` CLI flag, the
//! `MLONMCU_FAULTS` environment variable, forwarded `-c` overrides
//! (local dispatch workers) or the served queue's claim payload
//! (remote workers). `exit` rules are inert outside worker processes
//! ([`set_worker_role`]) so a dying fleet can never take the
//! supervising parent — and its in-process drain fallback — with it.
//!
//! Every triggered fault increments [`injected_count`] and records a
//! `fault` trace span, so chaos runs are auditable in the timeline.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::util::rng::XorShift64;

/// Fast-path switch: true iff a plan with at least one rule is
/// installed. The only cost of disabled fault checks.
static ARMED: AtomicBool = AtomicBool::new(false);

/// Faults actually triggered by this process since startup. Sessions
/// snapshot deltas; workers report per-task deltas in done records.
static INJECTED: AtomicU64 = AtomicU64::new(0);

/// `exit` rules only fire in processes that declared themselves
/// dispatch workers — never in the supervising parent or a serial run.
static WORKER_ROLE: AtomicBool = AtomicBool::new(false);

static PLAN: Mutex<Option<Plan>> = Mutex::new(None);

/// Every site name [`install`] accepts — a typo in a plan must be a
/// loud config error, not a silently inert rule.
pub const SITES: [&str; 9] = [
    "store.save",
    "store.load",
    "transport.send",
    "transport.recv",
    "queue.lease.heartbeat",
    "stage.load",
    "stage.tune",
    "stage.build",
    "cache.promote",
];

/// What a firing rule does to its site.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The operation returns an injected error (ENOSPC-style).
    Error,
    /// A write persists only a truncated payload.
    Short,
    /// A read sees one flipped payload byte.
    BitFlip,
    /// The connection drops before/after the frame.
    Drop,
    /// The frame arrives truncated.
    Truncate,
    /// The operation completes after sleeping `delay_ms`.
    Delay,
    /// The heartbeat pauses for `hang_ms` (lease goes stale).
    Stall,
    /// The stage panics.
    Panic,
    /// The stage wedges for `hang_ms` before continuing (heartbeat
    /// stays alive — only a deadline watchdog catches this).
    Hang,
    /// The worker process exits(9) mid-task, lease held.
    Exit,
}

impl FaultKind {
    pub fn name(self) -> &'static str {
        match self {
            FaultKind::Error => "error",
            FaultKind::Short => "short",
            FaultKind::BitFlip => "bitflip",
            FaultKind::Drop => "drop",
            FaultKind::Truncate => "truncate",
            FaultKind::Delay => "delay",
            FaultKind::Stall => "stall",
            FaultKind::Panic => "panic",
            FaultKind::Hang => "hang",
            FaultKind::Exit => "exit",
        }
    }

    pub fn from_name(name: &str) -> Option<FaultKind> {
        [
            FaultKind::Error,
            FaultKind::Short,
            FaultKind::BitFlip,
            FaultKind::Drop,
            FaultKind::Truncate,
            FaultKind::Delay,
            FaultKind::Stall,
            FaultKind::Panic,
            FaultKind::Hang,
            FaultKind::Exit,
        ]
        .into_iter()
        .find(|k| k.name() == name)
    }
}

struct Rule {
    site: String,
    kind: FaultKind,
    prob: f64,
    /// Checks of this site to let pass before the rule may fire.
    after: u64,
    checks: u64,
    rng: XorShift64,
    /// Original `site:kind:prob[:after]` text, for spec round-trips.
    raw: String,
}

struct Plan {
    seed: u64,
    hang_ms: u64,
    delay_ms: u64,
    rules: Vec<Rule>,
}

fn lock_plan() -> std::sync::MutexGuard<'static, Option<Plan>> {
    PLAN.lock().unwrap_or_else(|e| e.into_inner())
}

/// Is a fault plan installed? One relaxed atomic load.
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Install a plan from its spec string, replacing any previous plan.
/// An empty spec (or one with zero rules) clears instead.
pub fn install(spec: &str) -> Result<()> {
    let mut seed = 1u64;
    let mut hang_ms = 3000u64;
    let mut delay_ms = 100u64;
    let mut raw_rules: Vec<String> = Vec::new();
    for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
        if let Some(v) = entry.strip_prefix("seed=") {
            seed = v.parse().with_context(|| format!("fault seed '{v}'"))?;
        } else if let Some(v) = entry.strip_prefix("hang_ms=") {
            hang_ms = v.parse().with_context(|| format!("hang_ms '{v}'"))?;
        } else if let Some(v) = entry.strip_prefix("delay_ms=") {
            delay_ms = v.parse().with_context(|| format!("delay_ms '{v}'"))?;
        } else {
            raw_rules.push(entry.to_string());
        }
    }
    let mut rules = Vec::with_capacity(raw_rules.len());
    for (i, raw) in raw_rules.iter().enumerate() {
        let parts: Vec<&str> = raw.split(':').collect();
        if parts.len() < 3 || parts.len() > 4 {
            bail!("fault rule '{raw}' is not site:kind:prob[:after_n]");
        }
        let site = parts[0].to_string();
        if !SITES.contains(&site.as_str()) {
            bail!("unknown fault site '{site}' (valid: {})", SITES.join(", "));
        }
        let kind = FaultKind::from_name(parts[1])
            .with_context(|| format!("unknown fault kind '{}'", parts[1]))?;
        let prob: f64 = parts[2]
            .parse()
            .with_context(|| format!("fault probability '{}'", parts[2]))?;
        if !(0.0..=1.0).contains(&prob) {
            bail!("fault probability {prob} outside [0, 1] in '{raw}'");
        }
        let after: u64 = match parts.get(3) {
            Some(v) => v.parse().with_context(|| format!("after_n '{v}'"))?,
            None => 0,
        };
        // every rule gets its own deterministic stream, derived from
        // the plan seed and the rule's identity (text + position, so
        // duplicate rules still diverge)
        let tag = format!("{raw}#{i}");
        rules.push(Rule {
            site,
            kind,
            prob,
            after,
            checks: 0,
            rng: XorShift64::stream(seed, &tag),
            raw: raw.clone(),
        });
    }
    let mut plan = lock_plan();
    if rules.is_empty() {
        *plan = None;
        ARMED.store(false, Ordering::Relaxed);
        return Ok(());
    }
    *plan = Some(Plan { seed, hang_ms, delay_ms, rules });
    ARMED.store(true, Ordering::Relaxed);
    Ok(())
}

/// Remove the installed plan (end of a session / test teardown).
pub fn clear() {
    *lock_plan() = None;
    ARMED.store(false, Ordering::Relaxed);
}

/// Canonical spec string of the installed plan, for propagation to
/// remote workers through the served queue's claim payload.
pub fn spec_string() -> Option<String> {
    let plan = lock_plan();
    let p = plan.as_ref()?;
    let rules: Vec<&str> = p.rules.iter().map(|r| r.raw.as_str()).collect();
    Some(format!(
        "seed={},hang_ms={},delay_ms={},{}",
        p.seed,
        p.hang_ms,
        p.delay_ms,
        rules.join(",")
    ))
}

/// Declare this process a dispatch worker: `exit` rules arm. Parents
/// and serial runs never call this, so a plan that kills every worker
/// still leaves someone alive to drain the queue.
pub fn set_worker_role() {
    WORKER_ROLE.store(true, Ordering::Relaxed);
}

/// True in processes that declared themselves dispatch workers.
pub fn worker_role() -> bool {
    WORKER_ROLE.load(Ordering::Relaxed)
}

/// Faults triggered by this process so far.
pub fn injected_count() -> u64 {
    INJECTED.load(Ordering::Relaxed)
}

/// Check the injection site: does a rule fire here, now? Returns the
/// firing kind; `Delay`/`Hang`/`Stall` have already slept and `Exit`
/// never returns (worker processes only — inert elsewhere). With no
/// plan installed this is one relaxed atomic load.
pub fn fire(site: &str) -> Option<FaultKind> {
    if !ARMED.load(Ordering::Relaxed) {
        return None;
    }
    fire_slow(site)
}

#[cold]
fn fire_slow(site: &str) -> Option<FaultKind> {
    let worker = WORKER_ROLE.load(Ordering::Relaxed);
    let (kind, sleep_ms) = {
        let mut plan = lock_plan();
        let p = plan.as_mut()?;
        let mut fired = None;
        for r in p.rules.iter_mut().filter(|r| r.site == site) {
            if r.kind == FaultKind::Exit && !worker {
                continue;
            }
            r.checks += 1;
            if r.checks <= r.after {
                continue;
            }
            if r.prob < 1.0 && r.rng.f64() >= r.prob {
                continue;
            }
            fired = Some(r.kind);
            break;
        }
        let kind = fired?;
        let sleep_ms = match kind {
            FaultKind::Delay => p.delay_ms,
            FaultKind::Hang | FaultKind::Stall => p.hang_ms,
            _ => 0,
        };
        (kind, sleep_ms)
    };
    INJECTED.fetch_add(1, Ordering::Relaxed);
    crate::log_debug!("fault injected: {site}:{}", kind.name());
    {
        let _span = crate::util::trace::span("fault", site.to_string())
            .arg("kind", kind.name());
    }
    if kind == FaultKind::Exit {
        crate::log_warn!("fault {site}:exit — worker exiting(9) with lease held");
        std::process::exit(9);
    }
    if sleep_ms > 0 {
        std::thread::sleep(std::time::Duration::from_millis(sleep_ms));
    }
    Some(kind)
}

/// Flip one payload byte in place (the `bitflip` read fault). The
/// middle byte keeps headers intact often enough that the *hash*
/// verification path is what catches it.
pub fn flip_byte(bytes: &mut [u8]) {
    if !bytes.is_empty() {
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
    }
}

/// Truncate a payload to half its length (the `short` write fault).
pub fn truncate_half(bytes: &mut Vec<u8>) {
    bytes.truncate(bytes.len() / 2);
}

/// The registry is process-global and cargo runs unit tests on
/// parallel threads: every test that installs a plan — here or in any
/// other module — must hold this gate for its whole install/fire/clear
/// window.
#[cfg(test)]
pub(crate) fn test_gate() -> std::sync::MutexGuard<'static, ()> {
    static GATE: Mutex<()> = Mutex::new(());
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn locked() -> std::sync::MutexGuard<'static, ()> {
        test_gate()
    }

    #[test]
    fn disarmed_registry_never_fires() {
        let _g = locked();
        clear();
        assert!(!armed());
        assert_eq!(fire("store.save"), None);
    }

    #[test]
    fn install_validates_sites_kinds_and_probs() {
        let _g = locked();
        clear();
        assert!(install("nope.site:error:1").is_err());
        assert!(install("store.save:frobnicate:1").is_err());
        assert!(install("store.save:error:2.0").is_err());
        assert!(install("store.save:error").is_err());
        assert!(install("store.save:error:0.5:x").is_err());
        assert!(!armed(), "failed installs must not arm");
        clear();
    }

    #[test]
    fn empty_plan_clears_instead_of_arming() {
        let _g = locked();
        install("seed=9,hang_ms=10").unwrap();
        assert!(!armed());
        clear();
    }

    #[test]
    fn prob_one_fires_every_time_and_counts() {
        let _g = locked();
        install("seed=1,store.save:error:1").unwrap();
        let before = injected_count();
        assert_eq!(fire("store.save"), Some(FaultKind::Error));
        assert_eq!(fire("store.save"), Some(FaultKind::Error));
        assert_eq!(fire("store.load"), None, "other sites untouched");
        assert_eq!(injected_count() - before, 2);
        clear();
    }

    #[test]
    fn after_n_skips_the_first_checks() {
        let _g = locked();
        install("stage.build:error:1:2").unwrap();
        assert_eq!(fire("stage.build"), None);
        assert_eq!(fire("stage.build"), None);
        assert_eq!(fire("stage.build"), Some(FaultKind::Error));
        clear();
    }

    #[test]
    fn seeded_plans_replay_identically() {
        let _g = locked();
        let run = |spec: &str| -> Vec<bool> {
            install(spec).unwrap();
            let out =
                (0..64).map(|_| fire("store.load").is_some()).collect();
            clear();
            out
        };
        let a = run("seed=42,store.load:bitflip:0.3");
        let b = run("seed=42,store.load:bitflip:0.3");
        let c = run("seed=43,store.load:bitflip:0.3");
        assert_eq!(a, b, "same seed, same firing sequence");
        assert_ne!(a, c, "different seed diverges");
        assert!(a.iter().any(|&f| f) && !a.iter().all(|&f| f));
    }

    #[test]
    fn exit_rules_are_inert_outside_worker_processes() {
        let _g = locked();
        // WORKER_ROLE is false in the test harness: if the rule fired
        // the process would be gone, so reaching the asserts proves it
        install("stage.build:exit:1").unwrap();
        assert_eq!(fire("stage.build"), None);
        clear();
    }

    #[test]
    fn spec_string_round_trips() {
        let _g = locked();
        install("seed=7,hang_ms=250,store.save:error:0.5,stage.tune:panic:1:3")
            .unwrap();
        let spec = spec_string().unwrap();
        assert_eq!(
            spec,
            "seed=7,hang_ms=250,delay_ms=100,store.save:error:0.5,stage.tune:panic:1:3"
        );
        install(&spec).unwrap();
        assert_eq!(spec_string().unwrap(), spec);
        clear();
        assert_eq!(spec_string(), None);
    }

    #[test]
    fn payload_mutators() {
        let mut v = vec![0u8; 8];
        flip_byte(&mut v);
        assert_eq!(v.iter().filter(|&&b| b != 0).count(), 1);
        truncate_half(&mut v);
        assert_eq!(v.len(), 4);
        let mut empty: Vec<u8> = Vec::new();
        flip_byte(&mut empty);
        truncate_half(&mut empty);
        assert!(empty.is_empty());
    }
}
