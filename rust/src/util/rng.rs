//! Deterministic xorshift64* RNG — the only randomness source in the
//! coordinator (tuner search, property tests, workload generators).
//! Seeded everywhere so sessions are reproducible (paper §II
//! "Reproducibility").

/// xorshift64* — tiny, fast, good-enough statistical quality for
/// tuner sampling and test-case generation.
#[derive(Debug, Clone)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    pub fn new(seed: u64) -> Self {
        // avoid the all-zero fixpoint
        XorShift64 { state: seed.wrapping_mul(0x9E3779B97F4A7C15) | 1 }
    }

    /// Derive an independent deterministic stream from a base seed and
    /// a string tag — used by the fault-injection registry so every
    /// `site:kind` rule replays its own firing sequence regardless of
    /// how other rules consume randomness.
    pub fn stream(seed: u64, tag: &str) -> Self {
        XorShift64::new(seed ^ crate::util::hash::fnv1a64(tag.as_bytes()))
    }

    /// Non-deterministic seed for the few places where determinism is
    /// the *wrong* property — retry-backoff jitter must differ across
    /// processes or a fleet of workers retries in lockstep. Mixes wall
    /// clock, pid, and a process-local counter so two clients created
    /// in the same nanosecond still diverge.
    pub fn from_entropy() -> Self {
        use std::sync::atomic::{AtomicU64, Ordering};
        static COUNTER: AtomicU64 = AtomicU64::new(0);
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0x5EED);
        let seed = nanos
            ^ (std::process::id() as u64).rotate_left(32)
            ^ COUNTER.fetch_add(1, Ordering::Relaxed).wrapping_mul(0x9E37);
        XorShift64::new(seed)
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // multiply-shift; bias is negligible for our n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = XorShift64::new(42);
        let mut b = XorShift64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = XorShift64::new(1);
        let mut b = XorShift64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_is_in_range() {
        let mut r = XorShift64::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn f64_in_unit_interval_and_spread() {
        let mut r = XorShift64::new(9);
        let mut lo = false;
        let mut hi = false;
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            lo |= x < 0.3;
            hi |= x > 0.7;
        }
        assert!(lo && hi, "samples should cover the interval");
    }

    #[test]
    fn streams_are_deterministic_and_independent() {
        let mut a = XorShift64::stream(42, "store.save:error:0.5#0");
        let mut b = XorShift64::stream(42, "store.save:error:0.5#0");
        let mut c = XorShift64::stream(42, "store.load:error:0.5#1");
        let mut d = XorShift64::stream(43, "store.save:error:0.5#0");
        assert_eq!(a.next_u64(), b.next_u64());
        assert_ne!(a.next_u64(), c.next_u64());
        assert_ne!(b.next_u64(), d.next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = XorShift64::new(3);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
