//! Process-global metrics registry: counters, gauges and log2-bucket
//! latency/size histograms behind one relaxed atomic load.
//!
//! Every instrumented layer — stage execution
//! (`scheduler.rs`/`dispatch.rs`/`run.rs`), the tiered artifact cache
//! (`cache.rs`), environment-store I/O (`store.rs`), wire requests on
//! both transport sides (`transport.rs`) and queue leases — records
//! into this registry when metrics are enabled (`[metrics] enabled`,
//! the default for sessions and the serve daemon). Disabled, every
//! recording call is a single relaxed atomic load and **performs no
//! allocation** (asserted by a counting-allocator unit test), the same
//! contract as [`super::trace`] and [`super::faults`].
//!
//! [`Histogram`] keeps 64 fixed log2 buckets (bucket *i* counts values
//! in `[2^i, 2^(i+1))`; bucket 0 also holds zero) plus **exact**
//! count/sum/min/max, so percentile estimates interpolate inside one
//! power-of-two bucket and clamp to the exact observed range.
//! `trace summary` shares this percentile code: [`super::trace::aggregate`]
//! feeds span durations through the same type.
//!
//! Fleet merging mirrors span merging: local worker processes write
//! `metrics-<pid>.json` snapshot files into their queue dir
//! ([`worker_file_name`], collected by [`collect_dir`]), remote
//! workers ship drained snapshots over the transport
//! (`OP_METRICS_PUT`), and the serve daemon samples its registry every
//! `[metrics] interval_ms` into a bounded [`SnapshotRing`] of
//! timestamped deltas served to `mlonmcu top` via `OP_METRICS`.
//!
//! Metrics never touch report bytes: sessions write `metrics.json`
//! *next to* `report.md`/`report.csv`, whose serial-vs-sharded
//! byte-identity holds with metrics on
//! (`tests/dispatch_equivalence.rs`).

use std::collections::{BTreeMap, VecDeque};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::data::Json;

/// Number of log2 buckets; covers the whole `u64` range.
pub const BUCKETS: usize = 64;

/// Process-global on/off switch. Off by default; the only cost of a
/// disabled registry is the relaxed load in [`enabled`].
static ENABLED: AtomicBool = AtomicBool::new(false);

static REGISTRY: Mutex<Registry> = Mutex::new(Registry::new());

struct Registry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, i64>,
    hists: BTreeMap<String, Histogram>,
}

impl Registry {
    const fn new() -> Registry {
        Registry {
            counters: BTreeMap::new(),
            gauges: BTreeMap::new(),
            hists: BTreeMap::new(),
        }
    }
}

/// Poison-tolerant registry lock: a panicking recorder thread must
/// degrade to possibly-incomplete numbers, never wedge the process.
fn lock() -> std::sync::MutexGuard<'static, Registry> {
    REGISTRY.lock().unwrap_or_else(|e| e.into_inner())
}

pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

pub fn enable() {
    ENABLED.store(true, Ordering::Relaxed);
}

pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
}

/// Add `delta` to the named counter. No-op (one relaxed load, no
/// allocation) while disabled.
pub fn counter(name: &str, delta: u64) {
    if !enabled() {
        return;
    }
    let mut r = lock();
    match r.counters.get_mut(name) {
        Some(v) => *v = v.saturating_add(delta),
        None => {
            r.counters.insert(name.to_string(), delta);
        }
    }
}

/// Set the named gauge to `value` (last write wins).
pub fn gauge(name: &str, value: i64) {
    if !enabled() {
        return;
    }
    let mut r = lock();
    match r.gauges.get_mut(name) {
        Some(v) => *v = value,
        None => {
            r.gauges.insert(name.to_string(), value);
        }
    }
}

/// Record one observation into the named histogram.
pub fn observe(name: &str, value: u64) {
    if !enabled() {
        return;
    }
    let mut r = lock();
    match r.hists.get_mut(name) {
        Some(h) => h.observe(value),
        None => {
            let mut h = Histogram::default();
            h.observe(value);
            r.hists.insert(name.to_string(), h);
        }
    }
}

/// Record one observation under a lazily built name: the closure only
/// runs when metrics are enabled, so disabled call sites never pay
/// for `format!`.
pub fn observe_with(name: impl FnOnce() -> String, value: u64) {
    if !enabled() {
        return;
    }
    observe(&name(), value);
}

/// A started clock, or nothing when metrics are disabled at start
/// ([`clock`]); the disabled variant never reads the system clock.
pub struct Clock(Option<Instant>);

pub fn clock() -> Clock {
    Clock(enabled().then(Instant::now))
}

impl Clock {
    pub fn elapsed_us(&self) -> Option<u64> {
        self.0.map(|t| t.elapsed().as_micros() as u64)
    }

    /// Record the elapsed µs into `name` (outcome-dependent names are
    /// only known at the end of the measured section).
    pub fn observe(&self, name: &str) {
        if let Some(us) = self.elapsed_us() {
            observe(name, us);
        }
    }

    pub fn observe_fn(&self, name: impl FnOnce() -> String) {
        if let Some(us) = self.elapsed_us() {
            observe(&name(), us);
        }
    }
}

/// RAII µs timer: records into the named histogram on drop. Disabled,
/// construction is one relaxed load and drop does nothing.
pub struct TimerGuard {
    name: &'static str,
    clock: Clock,
}

pub fn timer(name: &'static str) -> TimerGuard {
    TimerGuard { name, clock: clock() }
}

impl Drop for TimerGuard {
    fn drop(&mut self) {
        self.clock.observe(self.name);
    }
}

/// The per-stage histogram name for a stage span name, without
/// allocating (the scheduler and dispatch record on every task).
pub fn stage_metric(stage: &str) -> &'static str {
    match stage {
        "load" => "stage.load.us",
        "tune" => "stage.tune.us",
        "build" => "stage.build.us",
        "compile" => "stage.compile.us",
        "run" => "stage.run.us",
        _ => "stage.other.us",
    }
}

// ---------------------------------------------------------- histogram --

/// Fixed-bucket log2 histogram with exact count/sum/min/max.
///
/// Bucket `i` counts values in `[2^i, 2^(i+1))`; bucket 0 also counts
/// zero. Percentiles interpolate linearly inside the selected bucket
/// and clamp to the exact `[min, max]` range, so single-observation
/// and extreme quantiles are exact.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Histogram {
    pub buckets: [u64; BUCKETS],
    pub count: u64,
    pub sum: u64,
    pub min: u64,
    pub max: u64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram { buckets: [0; BUCKETS], count: 0, sum: 0, min: 0, max: 0 }
    }
}

impl Histogram {
    /// The bucket index of one value.
    pub fn bucket_of(value: u64) -> usize {
        if value <= 1 {
            0
        } else {
            63 - value.leading_zeros() as usize
        }
    }

    /// Inclusive upper bound of bucket `i`.
    pub fn bucket_bound(i: usize) -> u64 {
        if i >= BUCKETS - 1 {
            u64::MAX
        } else {
            (1u64 << (i + 1)) - 1
        }
    }

    pub fn observe(&mut self, value: u64) {
        self.buckets[Self::bucket_of(value)] += 1;
        self.min = if self.count == 0 { value } else { self.min.min(value) };
        self.max = self.max.max(value);
        self.count += 1;
        self.sum = self.sum.saturating_add(value);
    }

    /// Build a histogram from raw values (`trace summary` feeds span
    /// durations through this to share the percentile estimator).
    pub fn from_values(values: impl IntoIterator<Item = u64>) -> Histogram {
        let mut h = Histogram::default();
        for v in values {
            h.observe(v);
        }
        h
    }

    /// Merge another histogram into this one (fleet aggregation).
    pub fn merge(&mut self, other: &Histogram) {
        if other.count == 0 {
            return;
        }
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.min =
            if self.count == 0 { other.min } else { self.min.min(other.min) };
        self.max = self.max.max(other.max);
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
    }

    /// Quantile estimate for `q` in `[0, 1]` (0.5 = p50). Nearest-rank
    /// bucket walk, linear interpolation inside the bucket, clamped to
    /// the exact observed `[min, max]`.
    pub fn percentile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let q = q.clamp(0.0, 1.0);
        let rank = ((q * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            seen += c;
            if seen >= rank {
                let lo = if i == 0 { 0u64 } else { 1u64 << i };
                let hi = Self::bucket_bound(i);
                let into = rank - (seen - c); // 1..=c within this bucket
                let frac = into as f64 / c as f64;
                let est = lo as f64 + frac * (hi.saturating_sub(lo)) as f64;
                return (est as u64).clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// This histogram minus an earlier sample of the same series
    /// (snapshot-ring deltas). Buckets/count/sum subtract
    /// (saturating — a drained registry restarts from zero); min/max
    /// cannot be windowed and carry the cumulative values.
    pub fn delta_since(&self, prev: &Histogram) -> Histogram {
        let mut d = self.clone();
        for (a, b) in d.buckets.iter_mut().zip(prev.buckets.iter()) {
            *a = a.saturating_sub(*b);
        }
        d.count = self.count.saturating_sub(prev.count);
        d.sum = self.sum.saturating_sub(prev.sum);
        d
    }

    fn to_json(&self) -> Json {
        let buckets = self
            .buckets
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| {
                Json::Arr(vec![Json::Num(i as f64), Json::Num(c as f64)])
            })
            .collect();
        Json::obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("sum", Json::Num(self.sum as f64)),
            ("min", Json::Num(self.min as f64)),
            ("max", Json::Num(self.max as f64)),
            ("buckets", Json::Arr(buckets)),
        ])
    }

    fn from_json(j: &Json) -> Result<Histogram> {
        let num = |k: &str| -> Result<u64> {
            Ok(j.get(k)
                .and_then(Json::as_i64)
                .ok_or_else(|| anyhow!("histogram lacks numeric '{k}'"))?
                .max(0) as u64)
        };
        let mut h = Histogram {
            buckets: [0; BUCKETS],
            count: num("count")?,
            sum: num("sum")?,
            min: num("min")?,
            max: num("max")?,
        };
        for pair in j.get("buckets").and_then(Json::as_arr).unwrap_or(&[]) {
            let cells = pair
                .as_arr()
                .ok_or_else(|| anyhow!("histogram bucket is not a pair"))?;
            let idx = cells
                .first()
                .and_then(Json::as_i64)
                .ok_or_else(|| anyhow!("histogram bucket lacks an index"))?;
            let n = cells
                .get(1)
                .and_then(Json::as_i64)
                .ok_or_else(|| anyhow!("histogram bucket lacks a count"))?;
            let idx = idx.max(0) as usize;
            anyhow::ensure!(idx < BUCKETS, "histogram bucket index {idx}");
            h.buckets[idx] = n.max(0) as u64;
        }
        Ok(h)
    }
}

// ----------------------------------------------------------- snapshot --

/// A point-in-time copy of the registry — the unit of merging,
/// shipping and exporting.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Snapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, i64>,
    pub hists: BTreeMap<String, Histogram>,
}

/// Copy the registry (deterministic: BTreeMap order).
pub fn snapshot() -> Snapshot {
    let r = lock();
    Snapshot {
        counters: r.counters.clone(),
        gauges: r.gauges.clone(),
        hists: r.hists.clone(),
    }
}

/// Take the registry contents, leaving it empty (end of a session or
/// of one remote task — the shipped snapshot is a delta by
/// construction).
pub fn drain() -> Snapshot {
    let mut r = lock();
    Snapshot {
        counters: std::mem::take(&mut r.counters),
        gauges: std::mem::take(&mut r.gauges),
        hists: std::mem::take(&mut r.hists),
    }
}

/// Merge an externally produced snapshot (worker files, wire-shipped
/// deltas) into the live registry. No-op while disabled, so stray
/// late arrivals cannot leak into a metrics-off run.
pub fn record_all(snap: &Snapshot) {
    if !enabled() || snap.is_empty() {
        return;
    }
    let mut r = lock();
    for (k, v) in &snap.counters {
        match r.counters.get_mut(k) {
            Some(c) => *c = c.saturating_add(*v),
            None => {
                r.counters.insert(k.clone(), *v);
            }
        }
    }
    for (k, v) in &snap.gauges {
        r.gauges.insert(k.clone(), *v);
    }
    for (k, h) in &snap.hists {
        match r.hists.get_mut(k) {
            Some(mine) => mine.merge(h),
            None => {
                r.hists.insert(k.clone(), h.clone());
            }
        }
    }
}

impl Snapshot {
    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.hists.is_empty()
    }

    /// Merge another snapshot into this one (counters add, gauges take
    /// the other's value, histograms merge).
    pub fn merge(&mut self, other: &Snapshot) {
        for (k, v) in &other.counters {
            let c = self.counters.entry(k.clone()).or_insert(0);
            *c = c.saturating_add(*v);
        }
        for (k, v) in &other.gauges {
            self.gauges.insert(k.clone(), *v);
        }
        for (k, h) in &other.hists {
            match self.hists.get_mut(k) {
                Some(mine) => mine.merge(h),
                None => {
                    self.hists.insert(k.clone(), h.clone());
                }
            }
        }
    }

    /// This snapshot minus an earlier one of the same registry
    /// (snapshot-ring deltas).
    pub fn delta_since(&self, prev: &Snapshot) -> Snapshot {
        let counters = self
            .counters
            .iter()
            .map(|(k, v)| {
                let before = prev.counters.get(k).copied().unwrap_or(0);
                (k.clone(), v.saturating_sub(before))
            })
            .collect();
        let hists = self
            .hists
            .iter()
            .map(|(k, h)| {
                let d = match prev.hists.get(k) {
                    Some(p) => h.delta_since(p),
                    None => h.clone(),
                };
                (k.clone(), d)
            })
            .collect();
        Snapshot { counters, gauges: self.gauges.clone(), hists }
    }

    pub fn to_json(&self) -> Json {
        let counters = self
            .counters
            .iter()
            .map(|(k, v)| (k.clone(), Json::Num(*v as f64)))
            .collect();
        let gauges = self
            .gauges
            .iter()
            .map(|(k, v)| (k.clone(), Json::Num(*v as f64)))
            .collect();
        let hists = self
            .hists
            .iter()
            .map(|(k, h)| (k.clone(), h.to_json()))
            .collect();
        Json::obj(vec![
            ("counters", Json::Obj(counters)),
            ("gauges", Json::Obj(gauges)),
            ("hists", Json::Obj(hists)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Snapshot> {
        let mut snap = Snapshot::default();
        if let Some(Json::Obj(m)) = j.get("counters") {
            for (k, v) in m {
                let v = v
                    .as_i64()
                    .ok_or_else(|| anyhow!("counter '{k}' is not numeric"))?;
                snap.counters.insert(k.clone(), v.max(0) as u64);
            }
        }
        if let Some(Json::Obj(m)) = j.get("gauges") {
            for (k, v) in m {
                let v = v
                    .as_i64()
                    .ok_or_else(|| anyhow!("gauge '{k}' is not numeric"))?;
                snap.gauges.insert(k.clone(), v);
            }
        }
        if let Some(Json::Obj(m)) = j.get("hists") {
            for (k, v) in m {
                let h = Histogram::from_json(v)
                    .with_context(|| format!("histogram '{k}'"))?;
                snap.hists.insert(k.clone(), h);
            }
        }
        Ok(snap)
    }

    /// Prometheus text exposition (version 0.0.4). Names are
    /// `mlonmcu_<name>` with non-alphanumerics folded to `_`;
    /// histograms emit cumulative `_bucket{le="2^(i+1)-1"}` rows plus
    /// `_sum`/`_count`, and the exact extremes as `_min`/`_max`
    /// gauges.
    pub fn to_prometheus(&self) -> String {
        let mut s = String::new();
        for (k, v) in &self.counters {
            let n = prom_name(k);
            s.push_str(&format!("# TYPE {n} counter\n{n} {v}\n"));
        }
        for (k, v) in &self.gauges {
            let n = prom_name(k);
            s.push_str(&format!("# TYPE {n} gauge\n{n} {v}\n"));
        }
        for (k, h) in &self.hists {
            let n = prom_name(k);
            s.push_str(&format!("# TYPE {n} histogram\n"));
            let mut cum = 0u64;
            let top = h
                .buckets
                .iter()
                .rposition(|&c| c > 0)
                .unwrap_or(0)
                .min(BUCKETS - 2);
            for (i, &c) in h.buckets.iter().enumerate().take(top + 1) {
                cum += c;
                s.push_str(&format!(
                    "{n}_bucket{{le=\"{}\"}} {cum}\n",
                    Histogram::bucket_bound(i)
                ));
            }
            s.push_str(&format!("{n}_bucket{{le=\"+Inf\"}} {}\n", h.count));
            s.push_str(&format!("{n}_sum {}\n", h.sum));
            s.push_str(&format!("{n}_count {}\n", h.count));
            s.push_str(&format!("# TYPE {n}_min gauge\n{n}_min {}\n", h.min));
            s.push_str(&format!("# TYPE {n}_max gauge\n{n}_max {}\n", h.max));
        }
        s
    }
}

fn prom_name(name: &str) -> String {
    let folded: String = name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect();
    format!("mlonmcu_{folded}")
}

// ------------------------------------------------------ snapshot ring --

/// One ring sample: the registry delta accumulated since the previous
/// sample, stamped with the sampling wall clock.
#[derive(Debug, Clone)]
pub struct RingEntry {
    pub ts_ms: u64,
    pub delta: Snapshot,
}

/// Bounded ring of timestamped registry deltas — the serve daemon
/// samples its registry every `[metrics] interval_ms` so `mlonmcu
/// top` can show recent rates, not just process-lifetime totals.
#[derive(Debug)]
pub struct SnapshotRing {
    cap: usize,
    last: Snapshot,
    entries: VecDeque<RingEntry>,
}

impl SnapshotRing {
    pub fn new(cap: usize) -> SnapshotRing {
        SnapshotRing {
            cap: cap.max(1),
            last: Snapshot::default(),
            entries: VecDeque::new(),
        }
    }

    /// Record the delta between `current` and the previous sample;
    /// the oldest entry falls off once the ring is full.
    pub fn sample(&mut self, ts_ms: u64, current: Snapshot) {
        let delta = current.delta_since(&self.last);
        self.last = current;
        self.entries.push_back(RingEntry { ts_ms, delta });
        while self.entries.len() > self.cap {
            self.entries.pop_front();
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn entries(&self) -> impl Iterator<Item = &RingEntry> {
        self.entries.iter()
    }

    pub fn to_json(&self) -> Json {
        let samples = self
            .entries
            .iter()
            .map(|e| {
                Json::obj(vec![
                    ("ts_ms", Json::Num(e.ts_ms as f64)),
                    ("delta", e.delta.to_json()),
                ])
            })
            .collect();
        Json::obj(vec![
            ("cap", Json::Num(self.cap as f64)),
            ("samples", Json::Arr(samples)),
        ])
    }
}

// -------------------------------------------------------- fleet files --

/// The snapshot-file name a worker process writes into its queue dir
/// (the metrics analogue of `trace-<pid>.json`).
pub fn worker_file_name() -> String {
    format!("metrics-{}.json", std::process::id())
}

/// Write a snapshot file ([`worker_file_name`] / session
/// `metrics.json`), creating parent dirs.
pub fn write_snapshot(path: &Path, snap: &Snapshot) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {}", dir.display()))?;
        }
    }
    std::fs::write(path, snap.to_json().to_string())
        .with_context(|| format!("writing metrics to {}", path.display()))
}

pub fn read_snapshot(path: &Path) -> Result<Snapshot> {
    let doc = Json::parse_file(path)
        .with_context(|| format!("reading metrics {}", path.display()))?;
    Snapshot::from_json(&doc)
        .with_context(|| format!("decoding metrics {}", path.display()))
}

/// Merge every `metrics-*.json` snapshot file directly under `dir` (a
/// session queue dir). A malformed file — a worker killed mid-write —
/// is skipped with a warning naming the file, never silently.
pub fn collect_dir(dir: &Path) -> Snapshot {
    let mut merged = Snapshot::default();
    let Ok(entries) = std::fs::read_dir(dir) else {
        return merged;
    };
    let mut files: Vec<_> = entries
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| {
            p.file_name().and_then(|n| n.to_str()).is_some_and(|n| {
                n.starts_with("metrics-") && n.ends_with(".json")
            })
        })
        .collect();
    files.sort();
    for f in files {
        match read_snapshot(&f) {
            Ok(snap) => merged.merge(&snap),
            Err(e) => {
                crate::log_warn!(
                    "metrics: skipping malformed snapshot file {} ({e:#})",
                    f.display()
                );
            }
        }
    }
    merged
}

/// Delete worker snapshot files under `dir` after collection.
pub fn remove_snapshot_files(dir: &Path) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    for p in entries.filter_map(|e| e.ok().map(|e| e.path())) {
        let named = p.file_name().and_then(|n| n.to_str()).is_some_and(|n| {
            n.starts_with("metrics-") && n.ends_with(".json")
        });
        if named {
            let _ = std::fs::remove_file(&p);
        }
    }
}

/// Serialize unit tests that toggle the process-global switch or
/// registry — shared with the transport tests, exactly like
/// `faults::test_gate`.
#[cfg(test)]
pub(crate) fn test_gate() -> std::sync::MutexGuard<'static, ()> {
    static GATE: Mutex<()> = Mutex::new(());
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Counting allocator: delegates to the system allocator and
    /// counts allocations per thread, so the zero-allocation claim of
    /// the disabled path is asserted, not assumed. Thread-local
    /// (const-init `Cell`, no destructor, no lazy allocation) so
    /// parallel test threads don't pollute each other's counts.
    struct CountingAlloc;

    thread_local! {
        static ALLOCS: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
    }

    unsafe impl std::alloc::GlobalAlloc for CountingAlloc {
        unsafe fn alloc(&self, layout: std::alloc::Layout) -> *mut u8 {
            let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
            std::alloc::System.alloc(layout)
        }

        unsafe fn dealloc(&self, ptr: *mut u8, layout: std::alloc::Layout) {
            std::alloc::System.dealloc(ptr, layout)
        }
    }

    #[global_allocator]
    static COUNTING: CountingAlloc = CountingAlloc;

    fn thread_allocs() -> u64 {
        ALLOCS.with(|c| c.get())
    }

    #[test]
    fn disabled_path_performs_no_allocation() {
        let _g = test_gate();
        disable();
        drain();
        let before = thread_allocs();
        for i in 0..10_000u64 {
            counter("cache.hit", 1);
            gauge("tasks.open", 3);
            observe("stage.build.us", i);
            observe_with(|| format!("wire.client.{}.us", "get"), i);
            let c = clock();
            c.observe("stage.load.us");
            let _t = timer("stage.run.us");
        }
        assert_eq!(
            thread_allocs() - before,
            0,
            "disabled metrics must not allocate"
        );
        assert!(snapshot().is_empty(), "disabled metrics must record nothing");
    }

    #[test]
    fn histogram_percentiles_are_exact_at_extremes() {
        let h = Histogram::from_values([7u64]);
        assert_eq!((h.count, h.min, h.max, h.sum), (1, 7, 7, 7));
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(h.percentile(q), 7, "single value is exact at q={q}");
        }

        let h = Histogram::from_values([0, 1, 2, 3, 1000]);
        assert_eq!(h.min, 0);
        assert_eq!(h.max, 1000);
        assert_eq!(h.percentile(1.0), 1000, "p100 clamps to the exact max");
        assert_eq!(h.percentile(0.0), 0, "p0 clamps to the exact min");
        assert!(h.percentile(0.5) <= 3, "p50 stays in the low buckets");

        assert_eq!(Histogram::default().percentile(0.5), 0);
    }

    #[test]
    fn histogram_buckets_and_merge() {
        assert_eq!(Histogram::bucket_of(0), 0);
        assert_eq!(Histogram::bucket_of(1), 0);
        assert_eq!(Histogram::bucket_of(2), 1);
        assert_eq!(Histogram::bucket_of(3), 1);
        assert_eq!(Histogram::bucket_of(4), 2);
        assert_eq!(Histogram::bucket_of(u64::MAX), 63);
        assert_eq!(Histogram::bucket_bound(0), 1);
        assert_eq!(Histogram::bucket_bound(1), 3);
        assert_eq!(Histogram::bucket_bound(63), u64::MAX);

        let mut a = Histogram::from_values([1, 10, 100]);
        let b = Histogram::from_values([0, 1000]);
        a.merge(&b);
        assert_eq!((a.count, a.min, a.max), (5, 0, 1000));
        assert_eq!(a.sum, 1111);
        let empty = Histogram::default();
        a.merge(&empty);
        assert_eq!(a.count, 5, "merging an empty histogram changes nothing");
    }

    #[test]
    fn snapshots_are_deterministic_under_concurrent_recorders() {
        let _g = test_gate();
        enable();
        drain();
        std::thread::scope(|scope| {
            for t in 0..8u64 {
                scope.spawn(move || {
                    for i in 1..=100u64 {
                        observe("stage.build.us", i);
                        counter("cache.hit", 1);
                        gauge("tasks.open", 3);
                        observe_with(|| format!("wire.client.t{}.us", t % 2), i);
                    }
                });
            }
        });
        let a = snapshot();
        let b = snapshot();
        disable();
        assert_eq!(a, b, "snapshot must be a stable copy");
        assert_eq!(a.counters["cache.hit"], 800);
        assert_eq!(a.gauges["tasks.open"], 3);
        let h = &a.hists["stage.build.us"];
        assert_eq!((h.count, h.min, h.max), (800, 1, 100));
        assert_eq!(h.sum, 8 * 5050);
        assert_eq!(a.hists["wire.client.t0.us"].count, 400);
        assert_eq!(a.hists["wire.client.t1.us"].count, 400);
        // the interleaving cannot change the final state: rebuild the
        // same observations serially and compare
        drain();
        enable();
        for _ in 0..8u64 {
            for i in 1..=100u64 {
                observe("stage.build.us", i);
                counter("cache.hit", 1);
            }
        }
        let serial = snapshot();
        disable();
        drain();
        assert_eq!(serial.hists["stage.build.us"], a.hists["stage.build.us"]);
        assert_eq!(serial.counters["cache.hit"], a.counters["cache.hit"]);
    }

    #[test]
    fn snapshot_json_roundtrip_and_merge() {
        let _g = test_gate();
        enable();
        drain();
        counter("ops", 41);
        gauge("open", -2);
        observe("stage.load.us", 12);
        observe("stage.load.us", 900);
        let snap = drain();
        disable();

        let back = Snapshot::from_json(
            &Json::parse(&snap.to_json().to_string()).unwrap(),
        )
        .unwrap();
        assert_eq!(back, snap);

        let mut merged = back.clone();
        merged.merge(&snap);
        assert_eq!(merged.counters["ops"], 82);
        assert_eq!(merged.hists["stage.load.us"].count, 4);
        assert_eq!(merged.gauges["open"], -2);

        assert!(Snapshot::from_json(&Json::parse("{}").unwrap())
            .unwrap()
            .is_empty());
        assert!(
            Snapshot::from_json(
                &Json::parse(r#"{"counters": {"x": "nan"}}"#).unwrap()
            )
            .is_err(),
            "malformed snapshots reject with context"
        );
    }

    #[test]
    fn prometheus_exposition_shape() {
        let mut snap = Snapshot::default();
        snap.counters.insert("wire.server.ops".into(), 7);
        snap.gauges.insert("tasks.open".into(), 3);
        snap.hists
            .insert("stage.build.us".into(), Histogram::from_values([2, 5, 80]));
        let text = snap.to_prometheus();
        assert!(text.contains("# TYPE mlonmcu_wire_server_ops counter"));
        assert!(text.contains("mlonmcu_wire_server_ops 7"));
        assert!(text.contains("# TYPE mlonmcu_tasks_open gauge"));
        assert!(text.contains("# TYPE mlonmcu_stage_build_us histogram"));
        assert!(text.contains("mlonmcu_stage_build_us_bucket{le=\"+Inf\"} 3"));
        assert!(text.contains("mlonmcu_stage_build_us_sum 87"));
        assert!(text.contains("mlonmcu_stage_build_us_count 3"));
        assert!(text.contains("mlonmcu_stage_build_us_min 2"));
        assert!(text.contains("mlonmcu_stage_build_us_max 80"));
        // cumulative bucket rows are monotone
        let mut last = 0u64;
        for line in text.lines().filter(|l| l.contains("_bucket{le=")) {
            let n: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(n >= last, "non-monotone bucket row: {line}");
            last = n;
        }
    }

    #[test]
    fn snapshot_ring_keeps_bounded_deltas() {
        let mut ring = SnapshotRing::new(3);
        let mut cum = Snapshot::default();
        for i in 1..=5u64 {
            cum.counters.insert("ops".into(), i * 10);
            let mut h = Histogram::default();
            for v in 0..i {
                h.observe(v);
            }
            cum.hists.insert("stage.run.us".into(), h);
            ring.sample(1000 * i, cum.clone());
        }
        assert_eq!(ring.len(), 3, "ring is bounded");
        let entries: Vec<&RingEntry> = ring.entries().collect();
        assert_eq!(entries[0].ts_ms, 3000, "oldest entries fell off");
        for e in &entries {
            assert_eq!(
                e.delta.counters["ops"], 10,
                "each sample carries the delta, not the total"
            );
            assert_eq!(e.delta.hists["stage.run.us"].count, 1);
        }
        let doc = Json::parse(&ring.to_json().to_string()).unwrap();
        let samples = doc.get("samples").and_then(Json::as_arr).unwrap();
        assert_eq!(samples.len(), 3);
        assert!(samples[0].get("ts_ms").is_some());
    }

    #[test]
    fn worker_snapshot_files_collect_and_warn_on_garbage() {
        let dir = std::env::temp_dir().join("mlonmcu_metrics_collect_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let mut a = Snapshot::default();
        a.counters.insert("cache.hit".into(), 2);
        a.hists
            .insert("stage.build.us".into(), Histogram::from_values([5, 9]));
        let mut b = Snapshot::default();
        b.counters.insert("cache.hit".into(), 3);
        write_snapshot(&dir.join("metrics-11.json"), &a).unwrap();
        write_snapshot(&dir.join("metrics-22.json"), &b).unwrap();
        std::fs::write(dir.join("metrics-bad.json"), b"{torn").unwrap();
        std::fs::write(dir.join("task-0.json"), b"{}").unwrap();
        let merged = collect_dir(&dir);
        assert_eq!(merged.counters["cache.hit"], 5);
        assert_eq!(merged.hists["stage.build.us"].count, 2);
        remove_snapshot_files(&dir);
        assert!(collect_dir(&dir).is_empty(), "files removed after collect");
        assert!(
            dir.join("task-0.json").exists(),
            "queue task files must survive the sweep"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn record_all_respects_the_switch() {
        let _g = test_gate();
        disable();
        drain();
        let mut snap = Snapshot::default();
        snap.counters.insert("cache.hit".into(), 9);
        record_all(&snap);
        enable();
        assert!(snapshot().is_empty(), "disabled registry must drop merges");
        record_all(&snap);
        let got = drain();
        disable();
        assert_eq!(got.counters["cache.hit"], 9);
    }

    #[test]
    fn stage_metric_names_are_static() {
        assert_eq!(stage_metric("load"), "stage.load.us");
        assert_eq!(stage_metric("run"), "stage.run.us");
        assert_eq!(stage_metric("weird"), "stage.other.us");
    }
}
