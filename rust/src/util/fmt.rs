//! Human-readable formatting used by reports and the CLI.

/// Bytes -> "37.2 kB" / "1.5 MB" (decimal, like the paper's tables).
pub fn human_bytes(n: u64) -> String {
    if n >= 1_000_000 {
        format!("{:.1} MB", n as f64 / 1e6)
    } else if n >= 1_000 {
        format!("{:.1} kB", n as f64 / 1e3)
    } else {
        format!("{n} B")
    }
}

/// Instruction counts -> "153.144 M" style (Table IV uses ×10^6).
pub fn human_count(n: u64) -> String {
    if n >= 1_000_000 {
        format!("{:.3} M", n as f64 / 1e6)
    } else if n >= 1_000 {
        format!("{:.1} k", n as f64 / 1e3)
    } else {
        format!("{n}")
    }
}

/// Seconds -> "0.113 s" / "43.2 min" (Table III/V style).
pub fn human_secs(s: f64) -> String {
    if s >= 120.0 {
        format!("{:.1} min", s / 60.0)
    } else if s >= 0.001 {
        format!("{s:.3} s")
    } else {
        format!("{:.1} us", s * 1e6)
    }
}

/// Fixed-width right-aligned cell for plain-text tables.
pub fn cell(s: &str, w: usize) -> String {
    format!("{s:>w$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(37_200), "37.2 kB");
        assert_eq!(human_bytes(1_500_000), "1.5 MB");
    }

    #[test]
    fn counts() {
        assert_eq!(human_count(153_144_000), "153.144 M");
        assert_eq!(human_count(2_500), "2.5 k");
        assert_eq!(human_count(42), "42");
    }

    #[test]
    fn secs() {
        assert_eq!(human_secs(0.113), "0.113 s");
        assert_eq!(human_secs(2580.0), "43.0 min");
        assert_eq!(human_secs(0.0000005), "0.5 us");
    }
}
