//! Host-side tensor helpers: shapes, strides, and layout transforms
//! used by the code generators (weight packing) and the golden runtime.
//! The virtual MCU itself works on raw simulated memory (see `mcu/`).

use anyhow::{bail, Result};

/// Element type of a model tensor. Mirrors python/compile/tmodel.py.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    I8,
    I16,
    I32,
    F32,
}

impl DType {
    pub fn from_u8(x: u8) -> Result<DType> {
        Ok(match x {
            0 => DType::I8,
            1 => DType::I16,
            2 => DType::I32,
            3 => DType::F32,
            _ => bail!("unknown dtype tag {x}"),
        })
    }

    /// Inverse of `from_u8` (the on-disk .tmodel tag).
    pub fn to_u8(self) -> u8 {
        match self {
            DType::I8 => 0,
            DType::I16 => 1,
            DType::I32 => 2,
            DType::F32 => 3,
        }
    }

    pub fn size(self) -> usize {
        match self {
            DType::I8 => 1,
            DType::I16 => 2,
            DType::I32 | DType::F32 => 4,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::I8 => "i8",
            DType::I16 => "i16",
            DType::I32 => "i32",
            DType::F32 => "f32",
        }
    }
}

/// Number of elements of a shape.
pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

/// Row-major (C-order) strides in elements.
pub fn strides(shape: &[usize]) -> Vec<usize> {
    let mut s = vec![1; shape.len()];
    for i in (0..shape.len().saturating_sub(1)).rev() {
        s[i] = s[i + 1] * shape[i + 1];
    }
    s
}

/// Flat index of a coordinate under row-major layout.
pub fn flat_index(shape: &[usize], coord: &[usize]) -> usize {
    debug_assert_eq!(shape.len(), coord.len());
    let st = strides(shape);
    coord.iter().zip(&st).map(|(c, s)| c * s).sum()
}

/// TF/TFLite SAME padding for one spatial dim: (before, after).
/// Matches python/compile/kernels/ref.py::same_pads exactly.
pub fn same_pads(size: usize, k: usize, s: usize) -> (usize, usize) {
    let out = size.div_ceil(s);
    let total = ((out - 1) * s + k).saturating_sub(size);
    (total / 2, total - total / 2)
}

/// Output spatial size of a conv/pool: SAME (pad=0) or VALID (pad=1).
pub fn conv_out(size: usize, k: usize, s: usize, padding: u8) -> usize {
    if padding == 0 {
        size.div_ceil(s)
    } else {
        (size - k) / s + 1
    }
}

/// Transpose OHWI conv weights into the (i, j, c)-major GEMM matrix
/// rows used by NHWC im2col (mirrors kernels/conv2d.py).
pub fn pack_ohwi_to_hwio_matrix(w: &[i8], o: usize, h: usize, ws: usize, i: usize) -> Vec<i8> {
    let mut out = vec![0i8; w.len()];
    // src index [oc, kh, kw, ic]; dst row = ((kh*ws)+kw)*i + ic, col = oc
    for oc in 0..o {
        for kh in 0..h {
            for kw in 0..ws {
                for ic in 0..i {
                    let src = ((oc * h + kh) * ws + kw) * i + ic;
                    let row = (kh * ws + kw) * i + ic;
                    out[row * o + oc] = w[src];
                }
            }
        }
    }
    out
}

/// Pack OHWI weights channel-major (c, kh, kw) — the NCHW/OIHW-io
/// ordering used by the TVM-default schedules.
pub fn pack_ohwi_to_oihw_matrix(w: &[i8], o: usize, h: usize, ws: usize, i: usize) -> Vec<i8> {
    let mut out = vec![0i8; w.len()];
    for oc in 0..o {
        for kh in 0..h {
            for kw in 0..ws {
                for ic in 0..i {
                    let src = ((oc * h + kh) * ws + kw) * i + ic;
                    let row = (ic * h + kh) * ws + kw;
                    out[row * o + oc] = w[src];
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_row_major() {
        assert_eq!(strides(&[2, 3, 4]), vec![12, 4, 1]);
        assert_eq!(strides(&[5]), vec![1]);
        assert!(strides(&[]).is_empty());
    }

    #[test]
    fn flat_index_matches_manual() {
        assert_eq!(flat_index(&[2, 3, 4], &[1, 2, 3]), 12 + 8 + 3);
    }

    #[test]
    fn same_pads_matches_python() {
        // from python tests: (10,3,1)->(1,1), (10,4,2)->(1,1),
        // (49,10,2)->(4,5), (5,1,1)->(0,0)
        assert_eq!(same_pads(10, 3, 1), (1, 1));
        assert_eq!(same_pads(10, 4, 2), (1, 1));
        assert_eq!(same_pads(49, 10, 2), (4, 5));
        assert_eq!(same_pads(5, 1, 1), (0, 0));
    }

    #[test]
    fn conv_out_same_and_valid() {
        assert_eq!(conv_out(49, 10, 2, 0), 25);
        assert_eq!(conv_out(10, 3, 1, 1), 8);
        assert_eq!(conv_out(32, 3, 2, 0), 16);
    }

    #[test]
    fn weight_packing_shapes() {
        // 2 output channels, 1x1 kernel, 3 input channels
        let w: Vec<i8> = vec![1, 2, 3, 4, 5, 6]; // [oc=2][1][1][ic=3]
        let m = pack_ohwi_to_hwio_matrix(&w, 2, 1, 1, 3);
        // rows = ic (3), cols = oc (2): [[1,4],[2,5],[3,6]]
        assert_eq!(m, vec![1, 4, 2, 5, 3, 6]);
        let m2 = pack_ohwi_to_oihw_matrix(&w, 2, 1, 1, 3);
        assert_eq!(m2, vec![1, 4, 2, 5, 3, 6]); // 1x1: same ordering
    }

    #[test]
    fn weight_packing_transposes_kernel_dims() {
        // oc=1, kh=2, kw=1, ic=2: OHWI = [k0c0, k0c1, k1c0, k1c1]
        let w: Vec<i8> = vec![10, 11, 20, 21];
        // hwio rows (i,j,c): (0,0,0),(0,0,1),(1,0,0),(1,0,1)
        assert_eq!(pack_ohwi_to_hwio_matrix(&w, 1, 2, 1, 2), vec![10, 11, 20, 21]);
        // oihw rows (c,i,j): (0,0,0),(0,1,0),(1,0,0),(1,1,0)
        assert_eq!(pack_ohwi_to_oihw_matrix(&w, 1, 2, 1, 2), vec![10, 20, 11, 21]);
    }
}
