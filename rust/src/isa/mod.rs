//! Per-ISA cost models for the targets of Table II.
//!
//! The cost model maps a TinyIR `InstrMix` (expressed on the reference
//! scalar RV32GC ISA, the one ETISS simulates) to instruction and
//! cycle counts on each micro-architecture:
//!
//!   instructions = ref_instructions × instr_factor(class mix)
//!   cycles       = instructions × CPI / dual_issue + memory stalls
//!
//! `instr_factor` captures compiler/ISA density differences the paper
//! observes ("the used ARM compiler seems to be more sophisticated"):
//! Thumb-2 with DSP MAC instructions needs fewer instructions per MAC
//! than RV32GC; Xtensa LX6 sits in between.

use crate::tinyir::InstrMix;

/// One micro-architecture's cost parameters.
#[derive(Debug, Clone, Copy)]
pub struct IsaModel {
    pub name: &'static str,
    /// Instruction-count factor vs the RV32GC reference, per class.
    pub alu_factor: f64,
    pub mul_factor: f64,
    pub mem_factor: f64,
    pub branch_factor: f64,
    /// Cycles per (issued) instruction, before stalls.
    pub cpi: f64,
    /// Sustained issue width (Cortex-M7 is dual-issue: Table II).
    pub issue_width: f64,
}

impl IsaModel {
    /// Scale a reference-ISA instruction count by the class mix.
    pub fn instructions(&self, per_unit: &InstrMix, units: f64) -> f64 {
        units
            * (per_unit.alu * self.alu_factor
                + per_unit.mul * self.mul_factor
                + (per_unit.load + per_unit.store) * self.mem_factor
                + per_unit.branch * self.branch_factor)
    }

    /// Core cycles for an instruction count (no memory stalls).
    pub fn core_cycles(&self, instructions: f64) -> f64 {
        instructions * self.cpi / self.issue_width
    }
}

/// RV32GC (ETISS reference) — by definition all factors are 1.
pub const RV32GC: IsaModel = IsaModel {
    name: "rv32gc",
    alu_factor: 1.0,
    mul_factor: 1.0,
    mem_factor: 1.0,
    branch_factor: 1.0,
    cpi: 1.0,
    issue_width: 1.0,
};

/// ESP32-C3: RV32IMC single-issue in-order @ 160 MHz. Same ISA family
/// as the reference minus compressed-code effects (slightly denser).
pub const RV32IMC_ESP32C3: IsaModel = IsaModel {
    name: "rv32imc",
    alu_factor: 1.0,
    mul_factor: 1.0,
    mem_factor: 1.0,
    branch_factor: 1.05, // no compressed branch fusion
    cpi: 1.0,
    issue_width: 1.0,
};

/// STM32F4: Cortex-M4 @ 100 MHz. Thumb-2 + DSP (SMLABB etc.): MACs
/// fold mul+add, LDRD pairs loads — ~0.72× the RV32 instruction count
/// for kernel loops (fits Table V: aww NCHW 0.220 s @100 MHz vs
/// esp32c3 0.113 s @160 MHz).
pub const CORTEX_M4: IsaModel = IsaModel {
    name: "cortex-m4",
    alu_factor: 0.70,
    mul_factor: 0.55, // MLA/SMLA fold multiply-accumulate
    mem_factor: 0.80,
    branch_factor: 0.85,
    cpi: 1.08, // occasional pipeline bubbles
    issue_width: 1.0,
};

/// STM32F7: Cortex-M7 @ 216 MHz, dual-issue in-order (Table II notes
/// "dual issue"): best latency row of Table V throughout.
pub const CORTEX_M7: IsaModel = IsaModel {
    name: "cortex-m7",
    alu_factor: 0.70,
    mul_factor: 0.55,
    mem_factor: 0.80,
    branch_factor: 0.85,
    cpi: 1.0,
    issue_width: 1.55, // sustained dual-issue on kernel loops
};

/// ESP32: Xtensa LX6 @ 240 MHz. Dense 16/24-bit encodings, MUL16;
/// clocked 50 % above the esp32c3 — "similar or better performance in
/// most of the rows" (paper §III-C) comes from the clock.
pub const XTENSA_LX6: IsaModel = IsaModel {
    name: "xtensa-lx6",
    alu_factor: 0.95,
    mul_factor: 0.85,
    mem_factor: 1.0,
    branch_factor: 1.0,
    cpi: 1.05,
    issue_width: 1.0,
};

pub fn by_name(name: &str) -> Option<&'static IsaModel> {
    match name {
        "rv32gc" => Some(&RV32GC),
        "rv32imc" => Some(&RV32IMC_ESP32C3),
        "cortex-m4" => Some(&CORTEX_M4),
        "cortex-m7" => Some(&CORTEX_M7),
        "xtensa-lx6" => Some(&XTENSA_LX6),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MIX: InstrMix = InstrMix { alu: 4.0, mul: 1.0, load: 3.0, store: 0.5, branch: 1.0 };

    #[test]
    fn reference_is_identity() {
        let i = RV32GC.instructions(&MIX, 1000.0);
        assert!((i - 1000.0 * MIX.total()).abs() < 1e-9);
        assert_eq!(RV32GC.core_cycles(100.0), 100.0);
    }

    #[test]
    fn arm_denser_than_riscv() {
        let rv = RV32GC.instructions(&MIX, 1e6);
        let m4 = CORTEX_M4.instructions(&MIX, 1e6);
        assert!(m4 < 0.85 * rv, "m4 {m4} vs rv {rv}");
    }

    #[test]
    fn m7_faster_than_m4_per_instruction() {
        let i = 1e6;
        assert!(CORTEX_M7.core_cycles(i) < 0.75 * CORTEX_M4.core_cycles(i));
    }

    #[test]
    fn table5_aww_nchw_cross_target_shape() {
        // aww NCHW untuned: c3 0.113s@160MHz, f4 0.220s@100MHz,
        // f7 0.043s@216MHz — check relative ordering with a
        // representative conv mix (~9.2 ref instr/MAC, 2.66M MACs)
        let macs = 2.66e6;
        let mix = crate::calib::TVM_CONV_NCHW_PER_MAC;
        let time = |isa: &IsaModel, mhz: f64| {
            isa.core_cycles(isa.instructions(&mix, macs)) / (mhz * 1e6)
        };
        let c3 = time(&RV32IMC_ESP32C3, 160.0);
        let f4 = time(&CORTEX_M4, 100.0);
        let f7 = time(&CORTEX_M7, 216.0);
        let lx6 = time(&XTENSA_LX6, 240.0);
        // paper ordering: f7 << c3 < lx6? (0.125) < f4 hmm: c3 0.113,
        // lx6 0.125, f4 0.220 — check the ordering we can claim:
        assert!(f7 < c3 && f7 < f4 && f7 < lx6, "f7 fastest");
        assert!(f4 > c3, "f4 slower than c3 (100 vs 160 MHz)");
        // ratios within 2x of the paper's
        assert!((0.3..1.2).contains(&(c3 / f4)), "c3/f4 {}", c3 / f4);
        assert!((0.15..0.45).contains(&(f7 / f4)), "f7/f4 {}", f7 / f4);
    }

    #[test]
    fn registry() {
        for n in ["rv32gc", "rv32imc", "cortex-m4", "cortex-m7", "xtensa-lx6"] {
            assert_eq!(by_name(n).unwrap().name, n);
        }
        assert!(by_name("z80").is_none());
    }
}
