//! Environments (paper §II-A1 "Configuration"): a directory with an
//! `environment.toml` describing paths, enabled components and default
//! config. Multiple environments can coexist ("isolated dependencies
//! and reproducibility"); `Environment::discover` resolves the active
//! one from `MLONMCU_HOME`, the working directory, or defaults.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::data::toml::{TomlDoc, TomlValue};

/// A resolved environment.
#[derive(Debug, Clone)]
pub struct Environment {
    pub root: PathBuf,
    pub doc: TomlDoc,
    /// `-c key=value` CLI overrides (highest precedence).
    pub overrides: BTreeMap<String, String>,
}

pub const DEFAULT_TEMPLATE: &str = r#"# MLonMCU environment
name = "default"

[paths]
artifacts = "artifacts"
models = "artifacts/models"
sessions = "artifacts/sessions"
cache = "cache"

[cache]
capacity = 256
budget_mb = 512

[run]
parallel = 2
validate_atol = 1
seed = 7

[dispatch]
# > 0: shard Load/Tune/Build across this many `mlonmcu worker`
# child processes (artifacts exchanged through the env store)
workers = 0
# lease heartbeat timeout before a worker's claim is reclaimed
lease_ms = 5000

[remote]
# artifact server (`mlonmcu serve`) to consult after the local env
# store misses; empty = local-only. Also enables `--connect` fleets.
connect = ""
# per-request timeout and bounded retry/backoff of the remote client
timeout_ms = 2000
retries = 3
backoff_ms = 100
# queue-stall age after which a dispatching parent drains one task
# itself instead of waiting for remote workers
grace_ms = 500

[serve]
# serve-daemon resource knobs (`mlonmcu serve`): byte budget of the
# in-memory hot-entry cache fronting the store (0 = off), cap on
# simultaneous connections, and idle-connection timeout (0 = never)
mem_mb = 64
max_conns = 256
idle_ms = 300000

[trace]
# span-tracer output (`--trace FILE`): Chrome trace_event JSON with
# per-stage spans from every local/remote worker; empty = tracing off
file = ""

[metrics]
# process-global metrics registry (counters/gauges/log2 histograms):
# sessions write metrics.json next to the report (display-only —
# report bytes are untouched), workers merge their numbers back, and
# the serve daemon samples the registry every interval_ms into a
# bounded ring of `ring` timestamped deltas for `mlonmcu top`
enabled = true
interval_ms = 1000
ring = 128

[store]
# stale-lock mtime fallback of the env-store lock file: a lock whose
# owner cannot be probed is broken after this age (dead-pid locks
# always break immediately)
lock_stale_ms = 30000

[faults]
# deterministic fault-injection plan (`--faults` / MLONMCU_FAULTS):
# comma-separated "site:kind:prob[:after_n]" rules plus optional
# seed=N / hang_ms=N / delay_ms=N; empty = injection off
plan = ""

[retry]
# per-stage execution attempts (1 = no retry) with linear backoff;
# a task exhausting its attempts becomes a failed report row
# annotated "[attempts=N]"
attempts = 1
backoff_ms = 100
# stage deadline for the dispatch watchdog: a claimed task whose
# lease token is unchanged past this age is reclaimed even if its
# heartbeat is alive (a wedged-but-beating worker); 0 = off
deadline_ms = 0

[tune]
trials = 600

[frameworks]
enabled = ["tflm", "tvm"]

[targets]
enabled = ["etiss", "esp32c3", "stm32f4", "stm32f7", "esp32"]
"#;

impl Environment {
    /// Initialize a new environment directory (CLI `init`).
    pub fn init(dir: &Path) -> Result<Environment> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        let file = dir.join("environment.toml");
        if !file.exists() {
            std::fs::write(&file, DEFAULT_TEMPLATE)?;
        }
        Environment::load(dir)
    }

    pub fn load(dir: &Path) -> Result<Environment> {
        let doc = TomlDoc::parse_file(&dir.join("environment.toml"))?;
        Ok(Environment {
            root: dir.to_path_buf(),
            doc,
            overrides: BTreeMap::new(),
        })
    }

    /// Load `dir`'s environment, or — when it has no
    /// `environment.toml` (e.g. the implicit default environment
    /// `discover` synthesizes) — the built-in template rooted there.
    /// Dispatch worker processes resolve their `--home` this way so a
    /// parent running in an implicit environment can still shard.
    pub fn load_or_template(dir: &Path) -> Result<Environment> {
        if dir.join("environment.toml").is_file() {
            return Environment::load(dir);
        }
        Ok(Environment {
            root: dir.to_path_buf(),
            doc: TomlDoc::parse(DEFAULT_TEMPLATE).expect("builtin template"),
            overrides: BTreeMap::new(),
        })
    }

    /// Resolve the active environment: $MLONMCU_HOME, else ./, else an
    /// implicit default rooted in the working directory.
    pub fn discover() -> Result<Environment> {
        if let Ok(home) = std::env::var("MLONMCU_HOME") {
            return Environment::load(Path::new(&home));
        }
        let cwd = std::env::current_dir()?;
        if cwd.join("environment.toml").is_file() {
            return Environment::load(&cwd);
        }
        // implicit default: built-in template, rooted at cwd
        Ok(Environment {
            root: cwd,
            doc: TomlDoc::parse(DEFAULT_TEMPLATE).expect("builtin template"),
            overrides: BTreeMap::new(),
        })
    }

    /// Apply `-c table.key=value` overrides.
    pub fn with_overrides(mut self, kvs: &[String]) -> Result<Environment> {
        for kv in kvs {
            let (k, v) = kv
                .split_once('=')
                .with_context(|| format!("override '{kv}' is not key=value"))?;
            self.overrides.insert(k.trim().to_string(), v.trim().to_string());
        }
        Ok(self)
    }

    /// Look up `table.key` with override precedence.
    fn raw(&self, table: &str, key: &str) -> Option<TomlValue> {
        let dotted = if table.is_empty() {
            key.to_string()
        } else {
            format!("{table}.{key}")
        };
        if let Some(v) = self.overrides.get(&dotted) {
            return Some(TomlValue::Str(v.clone()));
        }
        self.doc.get(table, key).cloned()
    }

    pub fn get_str(&self, table: &str, key: &str, default: &str) -> String {
        match self.raw(table, key) {
            Some(TomlValue::Str(s)) => s,
            Some(v) => v.as_str().map(str::to_string).unwrap_or_else(|| default.into()),
            None => default.into(),
        }
    }

    pub fn get_i64(&self, table: &str, key: &str, default: i64) -> i64 {
        match self.raw(table, key) {
            Some(TomlValue::Str(s)) => s.parse().unwrap_or(default),
            Some(v) => v.as_i64().unwrap_or(default),
            None => default,
        }
    }

    /// Artifacts root (HLO files, models, sessions).
    pub fn artifacts_dir(&self) -> PathBuf {
        self.root.join(self.get_str("paths", "artifacts", "artifacts"))
    }

    pub fn model_dirs(&self) -> Vec<PathBuf> {
        vec![self.root.join(self.get_str("paths", "models", "artifacts/models"))]
    }

    pub fn sessions_dir(&self) -> PathBuf {
        self.root
            .join(self.get_str("paths", "sessions", "artifacts/sessions"))
    }

    /// Environment-level artifact store directory (`paths.cache`, or
    /// the `--cache-dir` CLI flag via an override). Relative paths are
    /// rooted at the environment; absolute paths win the join.
    pub fn cache_dir(&self) -> PathBuf {
        self.root.join(self.get_str("paths", "cache", "cache"))
    }

    /// Whether sessions open the persistent environment store at all
    /// (`cache.persist`, default true). Benchmarks measuring cold
    /// stage execution turn this off so repeated sessions stay cold.
    pub fn cache_persist(&self) -> bool {
        match self.raw("cache", "persist") {
            Some(TomlValue::Bool(b)) => b,
            Some(TomlValue::Str(s)) => !matches!(s.as_str(), "false" | "0" | "no"),
            Some(_) | None => true,
        }
    }

    /// Default worker-process count of the sharded dispatcher
    /// (`dispatch.workers`, or the `--workers` CLI flag). 0 keeps
    /// matrix execution in-process.
    pub fn dispatch_workers(&self) -> usize {
        self.get_i64("dispatch", "workers", 0).max(0) as usize
    }

    /// Lease heartbeat timeout of the dispatch work queue in
    /// milliseconds (`dispatch.lease_ms`): a claimed task whose lease
    /// goes this long without a heartbeat is reclaimed by another
    /// worker. Clamped to a sane range.
    pub fn dispatch_lease_ms(&self) -> u64 {
        self.get_i64("dispatch", "lease_ms", 5000).clamp(50, 600_000) as u64
    }

    /// Override the binary spawned as `mlonmcu worker`
    /// (`dispatch.worker_bin`). Defaults to the current executable;
    /// tests point it at the real CLI binary because their own
    /// executable is the test harness.
    pub fn dispatch_worker_bin(&self) -> Option<PathBuf> {
        let s = self.get_str("dispatch", "worker_bin", "");
        (!s.is_empty()).then(|| PathBuf::from(s))
    }

    /// Remote artifact server address (`remote.connect`, or the
    /// `--connect` CLI flag via an override). `None` when unset: the
    /// cache chain stays local-only.
    pub fn remote_connect(&self) -> Option<String> {
        let s = self.get_str("remote", "connect", "");
        (!s.is_empty()).then_some(s)
    }

    /// Per-request timeout of the remote client in milliseconds.
    pub fn remote_timeout_ms(&self) -> u64 {
        self.get_i64("remote", "timeout_ms", 2000).clamp(50, 60_000) as u64
    }

    /// Bounded retry count of the remote client (attempts = retries+1).
    pub fn remote_retries(&self) -> u32 {
        self.get_i64("remote", "retries", 3).clamp(0, 10) as u32
    }

    /// Base backoff between remote retries in milliseconds (doubles
    /// each attempt, plus jitter).
    pub fn remote_backoff_ms(&self) -> u64 {
        self.get_i64("remote", "backoff_ms", 100).clamp(1, 10_000) as u64
    }

    /// Queue-stall age in milliseconds after which a dispatching
    /// parent drains one served task itself instead of waiting for
    /// remote workers (`remote.grace_ms`).
    pub fn remote_grace_ms(&self) -> u64 {
        self.get_i64("remote", "grace_ms", 500).clamp(20, 60_000) as u64
    }

    /// Byte budget of the serve daemon's in-memory hot-entry cache
    /// (`serve.mem_mb`; 0 disables the cache entirely).
    pub fn serve_mem_bytes(&self) -> u64 {
        (self.get_i64("serve", "mem_mb", 64).clamp(0, 16_384) as u64) << 20
    }

    /// Cap on simultaneous serve-daemon connections
    /// (`serve.max_conns`); accepts beyond it are dropped.
    pub fn serve_max_conns(&self) -> usize {
        self.get_i64("serve", "max_conns", 256).clamp(1, 65_536) as usize
    }

    /// Idle-connection timeout of the serve daemon in milliseconds
    /// (`serve.idle_ms`; 0 = connections never time out).
    pub fn serve_idle_ms(&self) -> u64 {
        self.get_i64("serve", "idle_ms", 300_000).clamp(0, 86_400_000) as u64
    }

    /// Span-tracer output file (`trace.file`, or the `--trace` CLI
    /// flag via an override). `None` (the default) keeps the tracer
    /// disabled. Relative paths are rooted at the environment;
    /// absolute paths win the join.
    pub fn trace_file(&self) -> Option<PathBuf> {
        let s = self.get_str("trace", "file", "");
        (!s.is_empty()).then(|| self.root.join(s))
    }

    /// Whether the process-global metrics registry records at all
    /// (`metrics.enabled`, default true; disabled, every recording
    /// call is one relaxed atomic load).
    pub fn metrics_enabled(&self) -> bool {
        match self.raw("metrics", "enabled") {
            Some(TomlValue::Bool(b)) => b,
            Some(TomlValue::Str(s)) => {
                !matches!(s.as_str(), "false" | "0" | "no")
            }
            Some(_) | None => true,
        }
    }

    /// Snapshot-ring sampling period of the serve daemon in
    /// milliseconds (`metrics.interval_ms`).
    pub fn metrics_interval_ms(&self) -> u64 {
        self.get_i64("metrics", "interval_ms", 1000).clamp(50, 3_600_000)
            as u64
    }

    /// Bounded sample count of the serve daemon's snapshot ring
    /// (`metrics.ring`).
    pub fn metrics_ring(&self) -> usize {
        self.get_i64("metrics", "ring", 128).clamp(1, 100_000) as usize
    }

    /// Fault-injection plan spec (`faults.plan`, or `--faults` /
    /// `MLONMCU_FAULTS` via an override). `None` (the default) keeps
    /// the registry disarmed — every fault check is then one relaxed
    /// atomic load.
    pub fn fault_spec(&self) -> Option<String> {
        let s = self.get_str("faults", "plan", "");
        (!s.is_empty()).then_some(s)
    }

    /// Per-stage execution attempts (`retry.attempts`, default 1 =
    /// today's fail-fast behavior).
    pub fn retry_attempts(&self) -> u32 {
        self.get_i64("retry", "attempts", 1).clamp(1, 100) as u32
    }

    /// Linear backoff between stage retries in milliseconds
    /// (`retry.backoff_ms`; attempt N sleeps N × this).
    pub fn retry_backoff_ms(&self) -> u64 {
        self.get_i64("retry", "backoff_ms", 100).clamp(0, 60_000) as u64
    }

    /// Stage deadline of the dispatch watchdog in milliseconds
    /// (`retry.deadline_ms`): a claimed task whose lease token is
    /// unchanged past this age is reclaimed even with a live
    /// heartbeat. 0 (the default) disables the watchdog.
    pub fn retry_deadline_ms(&self) -> u64 {
        self.get_i64("retry", "deadline_ms", 0).clamp(0, 3_600_000) as u64
    }

    /// Stale-lock mtime fallback of the env store in milliseconds
    /// (`store.lock_stale_ms`).
    pub fn store_lock_stale_ms(&self) -> u64 {
        self.get_i64(
            "store",
            "lock_stale_ms",
            crate::session::store::DEFAULT_LOCK_STALE_MS as i64,
        )
        .clamp(100, 3_600_000) as u64
    }

    /// Size budget of the environment store in bytes
    /// (`cache.budget_mb`, or `--cache-budget` via an override).
    pub fn cache_budget_bytes(&self) -> u64 {
        let mb = self
            .get_i64("cache", "budget_mb", crate::session::store::DEFAULT_BUDGET_MB as i64)
            .max(1) as u64;
        mb * 1024 * 1024
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_writes_template_and_loads() {
        let dir = std::env::temp_dir().join("mlonmcu_env_test_init");
        let _ = std::fs::remove_dir_all(&dir);
        let env = Environment::init(&dir).unwrap();
        assert_eq!(env.get_str("", "name", "?"), "default");
        assert_eq!(env.get_i64("run", "parallel", 0), 2);
        assert!(env.artifacts_dir().ends_with("artifacts"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn overrides_win() {
        let env = Environment {
            root: PathBuf::from("/tmp"),
            doc: TomlDoc::parse(DEFAULT_TEMPLATE).unwrap(),
            overrides: BTreeMap::new(),
        }
        .with_overrides(&["run.parallel=8".into()])
        .unwrap();
        assert_eq!(env.get_i64("run", "parallel", 0), 8);
    }

    #[test]
    fn bad_override_rejected() {
        let env = Environment {
            root: PathBuf::from("/tmp"),
            doc: TomlDoc::parse(DEFAULT_TEMPLATE).unwrap(),
            overrides: BTreeMap::new(),
        };
        assert!(env.with_overrides(&["no-equals".into()]).is_err());
    }

    #[test]
    fn cache_dir_and_budget_resolve_with_overrides() {
        let env = Environment {
            root: PathBuf::from("/x"),
            doc: TomlDoc::parse(DEFAULT_TEMPLATE).unwrap(),
            overrides: BTreeMap::new(),
        };
        assert_eq!(env.cache_dir(), PathBuf::from("/x/cache"));
        assert_eq!(env.cache_budget_bytes(), 512 * 1024 * 1024);
        let env = env
            .with_overrides(&[
                "paths.cache=/abs/store".into(),
                "cache.budget_mb=2".into(),
            ])
            .unwrap();
        // an absolute --cache-dir wins the join; budget is in MB
        assert_eq!(env.cache_dir(), PathBuf::from("/abs/store"));
        assert_eq!(env.cache_budget_bytes(), 2 * 1024 * 1024);
    }

    #[test]
    fn remote_section_defaults_and_overrides() {
        let env = Environment {
            root: PathBuf::from("/x"),
            doc: TomlDoc::parse(DEFAULT_TEMPLATE).unwrap(),
            overrides: BTreeMap::new(),
        };
        // template ships with the tier disabled
        assert_eq!(env.remote_connect(), None);
        assert_eq!(env.remote_timeout_ms(), 2000);
        assert_eq!(env.remote_retries(), 3);
        assert_eq!(env.remote_backoff_ms(), 100);
        assert_eq!(env.remote_grace_ms(), 500);
        let env = env
            .with_overrides(&[
                "remote.connect=127.0.0.1:4917".into(),
                "remote.retries=99".into(),
            ])
            .unwrap();
        assert_eq!(env.remote_connect().as_deref(), Some("127.0.0.1:4917"));
        assert_eq!(env.remote_retries(), 10, "retries clamp to a sane bound");
    }

    #[test]
    fn serve_section_defaults_and_overrides() {
        let env = Environment {
            root: PathBuf::from("/x"),
            doc: TomlDoc::parse(DEFAULT_TEMPLATE).unwrap(),
            overrides: BTreeMap::new(),
        };
        assert_eq!(env.serve_mem_bytes(), 64 << 20);
        assert_eq!(env.serve_max_conns(), 256);
        assert_eq!(env.serve_idle_ms(), 300_000);
        let env = env
            .with_overrides(&[
                "serve.mem_mb=0".into(),
                "serve.max_conns=0".into(),
                "serve.idle_ms=-5".into(),
            ])
            .unwrap();
        // mem_mb=0 is a legal "cache off"; the others clamp to sane floors
        assert_eq!(env.serve_mem_bytes(), 0);
        assert_eq!(env.serve_max_conns(), 1);
        assert_eq!(env.serve_idle_ms(), 0);
    }

    #[test]
    fn trace_file_defaults_off_and_roots_relative_paths() {
        let env = Environment {
            root: PathBuf::from("/x"),
            doc: TomlDoc::parse(DEFAULT_TEMPLATE).unwrap(),
            overrides: BTreeMap::new(),
        };
        // template ships with tracing disabled
        assert_eq!(env.trace_file(), None);
        let env = env
            .with_overrides(&["trace.file=out/trace.json".into()])
            .unwrap();
        assert_eq!(env.trace_file(), Some(PathBuf::from("/x/out/trace.json")));
        let env = env
            .with_overrides(&["trace.file=/abs/trace.json".into()])
            .unwrap();
        assert_eq!(env.trace_file(), Some(PathBuf::from("/abs/trace.json")));
    }

    #[test]
    fn faults_retry_and_lock_staleness_defaults_and_overrides() {
        let env = Environment {
            root: PathBuf::from("/x"),
            doc: TomlDoc::parse(DEFAULT_TEMPLATE).unwrap(),
            overrides: BTreeMap::new(),
        };
        // template ships with injection off, fail-fast, no watchdog
        assert_eq!(env.fault_spec(), None);
        assert_eq!(env.retry_attempts(), 1);
        assert_eq!(env.retry_backoff_ms(), 100);
        assert_eq!(env.retry_deadline_ms(), 0);
        assert_eq!(env.store_lock_stale_ms(), 30_000);
        let env = env
            .with_overrides(&[
                "faults.plan=seed=3,store.save:error:0.5".into(),
                "retry.attempts=0".into(),
                "retry.deadline_ms=1500".into(),
                "store.lock_stale_ms=500".into(),
            ])
            .unwrap();
        assert_eq!(
            env.fault_spec().as_deref(),
            Some("seed=3,store.save:error:0.5")
        );
        assert_eq!(env.retry_attempts(), 1, "attempts clamp to >= 1");
        assert_eq!(env.retry_deadline_ms(), 1500);
        assert_eq!(env.store_lock_stale_ms(), 500);
    }

    #[test]
    fn metrics_knobs_default_on_and_clamp() {
        let env = Environment {
            root: PathBuf::from("/x"),
            doc: TomlDoc::parse(DEFAULT_TEMPLATE).unwrap(),
            overrides: BTreeMap::new(),
        };
        // template ships with metrics on, 1s sampling, 128-deep ring
        assert!(env.metrics_enabled());
        assert_eq!(env.metrics_interval_ms(), 1000);
        assert_eq!(env.metrics_ring(), 128);
        let env = env
            .with_overrides(&[
                "metrics.enabled=false".into(),
                "metrics.interval_ms=1".into(),
                "metrics.ring=0".into(),
            ])
            .unwrap();
        assert!(!env.metrics_enabled());
        assert_eq!(env.metrics_interval_ms(), 50, "interval clamps up");
        assert_eq!(env.metrics_ring(), 1, "ring clamps to >= 1");
    }

    #[test]
    fn defaults_for_missing_keys() {
        let env = Environment {
            root: PathBuf::from("/x"),
            doc: TomlDoc::parse("").unwrap(),
            overrides: BTreeMap::new(),
        };
        assert_eq!(env.get_i64("run", "parallel", 3), 3);
        assert_eq!(env.get_str("paths", "artifacts", "artifacts"), "artifacts");
    }
}
