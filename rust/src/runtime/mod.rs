//! PJRT golden runtime: loads the AOT artifacts the python build path
//! produced (`artifacts/<model>.hlo.txt` — the JAX/Pallas golden
//! inference lowered to HLO text) and executes them on the XLA CPU
//! client. Used by the `validate` feature to check the virtual MCU's
//! int8 outputs against the L1/L2 golden path, cross-language.
//!
//! HLO *text* is the interchange format (not serialized protos): jax
//! ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see aot.py).
//!
//! The XLA bindings are only reachable in environments with a vendored
//! `xla` crate, so the live-execution half is gated behind the `pjrt`
//! cargo feature. Without it, `GoldenRuntime::new` reports the runtime
//! as unavailable and the `validate` feature degrades to `Skipped`
//! (the session already handles that path); the dumped-golden-JSON
//! comparisons keep working either way.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

#[cfg(feature = "pjrt")]
mod pjrt {
    use std::collections::BTreeMap;
    use std::path::{Path, PathBuf};
    use std::sync::Mutex;

    use anyhow::{anyhow, Context, Result};

    /// Lazily-initialized PJRT CPU client + per-model executable
    /// cache. Compilation is expensive (~seconds for vww), so
    /// executables are compiled once per session and reused across
    /// runs/threads.
    pub struct Engine {
        client: xla::PjRtClient,
        artifacts_dir: PathBuf,
        cache: Mutex<BTreeMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    }

    // xla handles are opaque C pointers; the PJRT CPU client is
    // thread-safe for compile/execute, and our cache is mutex-guarded.
    unsafe impl Send for Engine {}
    unsafe impl Sync for Engine {}

    impl Engine {
        pub fn new(artifacts_dir: &Path) -> Result<Engine> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
            Ok(Engine {
                client,
                artifacts_dir: artifacts_dir.to_path_buf(),
                cache: Mutex::new(BTreeMap::new()),
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        fn executable(
            &self,
            model: &str,
        ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
            if let Some(e) = self.cache.lock().unwrap().get(model) {
                return Ok(e.clone());
            }
            let path = self.artifacts_dir.join(format!("{model}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .map_err(|e| {
                anyhow!(
                    "loading {} failed ({e}) — run `make artifacts` first",
                    path.display()
                )
            })?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("XLA compile of {model}: {e}"))?;
            let exe = std::sync::Arc::new(exe);
            self.cache
                .lock()
                .unwrap()
                .insert(model.to_string(), exe.clone());
            Ok(exe)
        }

        pub fn run_golden(
            &self,
            model: &str,
            input: &[i8],
            input_shape: &[usize],
        ) -> Result<Vec<i8>> {
            let exe = self.executable(model)?;
            let bytes: Vec<u8> = input.iter().map(|&x| x as u8).collect();
            let lit = xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::S8,
                input_shape,
                &bytes,
            )
            .map_err(|e| anyhow!("input literal: {e}"))?;
            let result = exe
                .execute::<xla::Literal>(&[lit])
                .map_err(|e| anyhow!("execute {model}: {e}"))?;
            let out = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch result: {e}"))?;
            // aot.py lowers with return_tuple=True: unwrap the 1-tuple
            let out = out.to_tuple1().map_err(|e| anyhow!("untuple: {e}"))?;
            out.to_vec::<i8>().map_err(|e| anyhow!("to_vec<i8>: {e}"))
        }
    }
}

/// Golden reference runtime. With the `pjrt` feature this wraps a live
/// XLA CPU client; without it, construction fails gracefully and the
/// validate feature is skipped.
pub struct GoldenRuntime {
    artifacts_dir: PathBuf,
    #[cfg(feature = "pjrt")]
    engine: pjrt::Engine,
}

impl GoldenRuntime {
    /// Create a golden runtime rooted at an artifacts dir.
    #[cfg(feature = "pjrt")]
    pub fn new(artifacts_dir: &Path) -> Result<GoldenRuntime> {
        Ok(GoldenRuntime {
            artifacts_dir: artifacts_dir.to_path_buf(),
            engine: pjrt::Engine::new(artifacts_dir)?,
        })
    }

    /// Without the `pjrt` feature there is nothing to execute HLO on.
    #[cfg(not(feature = "pjrt"))]
    pub fn new(artifacts_dir: &Path) -> Result<GoldenRuntime> {
        let _ = artifacts_dir;
        anyhow::bail!(
            "PJRT golden runtime unavailable: built without the `pjrt` \
             feature (requires a vendored xla crate)"
        )
    }

    pub fn platform(&self) -> String {
        #[cfg(feature = "pjrt")]
        {
            self.engine.platform()
        }
        #[cfg(not(feature = "pjrt"))]
        {
            "unavailable".to_string()
        }
    }

    /// Run the golden model: int8 input tensor -> int8 output vector.
    pub fn run_golden(
        &self,
        model: &str,
        input: &[i8],
        input_shape: &[usize],
    ) -> Result<Vec<i8>> {
        #[cfg(feature = "pjrt")]
        {
            self.engine.run_golden(model, input, input_shape)
        }
        #[cfg(not(feature = "pjrt"))]
        {
            let _ = (model, input, input_shape);
            anyhow::bail!("PJRT golden runtime unavailable (pjrt feature off)")
        }
    }

    /// Load the golden I/O vectors dumped by aot.py (pytest-independent
    /// cross-check of run_golden).
    pub fn load_golden_json(&self, model: &str) -> Result<(Vec<i8>, Vec<i8>, Vec<usize>)> {
        let path = self
            .artifacts_dir
            .join("golden")
            .join(format!("{model}.json"));
        let j = crate::data::Json::parse_file(&path)?;
        let to_i8 = |key: &str| -> Result<Vec<i8>> {
            Ok(j.get(key)
                .and_then(|v| v.as_i64_vec())
                .context(key.to_string())?
                .into_iter()
                .map(|x| x as i8)
                .collect())
        };
        let shape: Vec<usize> = j
            .get("input_shape")
            .and_then(|v| v.as_i64_vec())
            .context("input_shape")?
            .into_iter()
            .map(|x| x as usize)
            .collect();
        Ok((to_i8("input")?, to_i8("output")?, shape))
    }
}

#[cfg(test)]
mod tests {
    // PJRT-backed tests live in tests/golden_e2e.rs (integration),
    // since they need `make artifacts` outputs. Here: path handling.
    use super::*;

    #[test]
    fn missing_artifact_error_mentions_make() {
        let rt = GoldenRuntime::new(Path::new("/nonexistent-dir"));
        // client creation itself should succeed where PJRT is present
        let rt = match rt {
            Ok(rt) => rt,
            Err(_) => return, // no PJRT in this build: skip
        };
        let err = rt.run_golden("nosuch", &[0], &[1]).unwrap_err();
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_reports_unavailable() {
        let err = GoldenRuntime::new(Path::new("/tmp")).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
