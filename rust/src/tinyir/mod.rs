//! TinyIR — the portable inference-program format emitted by the
//! backends (Build stage) and executed by the virtual MCU (Run stage).
//!
//! This is the substrate standing in for the C code TFLM/TVM generate:
//! a list of kernel calls over arena buffers and flash constants, each
//! carrying both *semantics* (shapes, quantization — really executed,
//! numerically) and a *cost descriptor* (loop structure, instruction
//! mix, weight-streaming pattern — accounted by the ISA/memory models).
//! Keeping both on the same object guarantees the numbers the paper
//! reports (instructions, cycles, ROM, RAM) and the computed tensors
//! come from the same program.

pub mod listing;

use crate::tensor::DType;

/// Index into `Program::buffers`.
pub type BufId = usize;
/// Index into `Program::consts`.
pub type ConstId = usize;

/// Activation buffer in the RAM arena. `offset` is assigned by the
/// backend's memory planner; lifetimes are in call indices.
#[derive(Debug, Clone)]
pub struct BufferDecl {
    pub name: String,
    pub size: usize,
    pub dtype: DType,
    /// Arena offset (bytes); None until planned.
    pub offset: Option<usize>,
    /// First/last kernel-call index touching this buffer.
    pub first_use: usize,
    pub last_use: usize,
}

/// Constant placed in flash (weights, biases, packed matrices).
#[derive(Debug, Clone)]
pub struct ConstDecl {
    pub name: String,
    pub data: Vec<u8>,
    pub dtype: DType,
}

/// Requantization parameters (float64 multiplier + round-half-even,
/// identical to python/compile/quant.py).
#[derive(Debug, Clone, Copy)]
pub struct Requant {
    pub multiplier: f64,
    pub zp_in: i32,
    pub zp_out: i32,
    /// 0 = none, 1 = fused ReLU (clamp at zp_out).
    pub act: i64,
}

/// Per-unit instruction mix for the cost model (counts per MAC or per
/// element, depending on context). Fractions allowed — e.g. a loop
/// branch amortized over an unrolled-by-4 body is 0.25 per element.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct InstrMix {
    pub alu: f64,
    pub mul: f64,
    pub load: f64,
    pub store: f64,
    pub branch: f64,
}

impl InstrMix {
    pub fn total(&self) -> f64 {
        self.alu + self.mul + self.load + self.store + self.branch
    }

    pub fn scale(&self, k: f64) -> InstrMix {
        InstrMix {
            alu: self.alu * k,
            mul: self.mul * k,
            load: self.load * k,
            store: self.store * k,
            branch: self.branch * k,
        }
    }

    pub fn add(&self, o: &InstrMix) -> InstrMix {
        InstrMix {
            alu: self.alu + o.alu,
            mul: self.mul + o.mul,
            load: self.load + o.load,
            store: self.store + o.store,
            branch: self.branch + o.branch,
        }
    }
}

/// How a kernel streams its weights from flash — drives the
/// memory-system stall model that reproduces Table V's NHWC blowups
/// on SPI-flash targets (see DESIGN.md §1 and mcu/memsys.rs).
#[derive(Debug, Clone, Copy)]
pub struct WeightStream {
    /// Total weight bytes touched per inference (with re-reads):
    /// bytes_per_pass * passes.
    pub bytes_streamed: u64,
    /// Working set that must stay resident for reuse (bytes). If it
    /// exceeds the target's flash-cache/fast-memory window, every pass
    /// re-fetches from backing store.
    pub reuse_window: u64,
    /// Contiguous (packed NCHWc blocks) vs strided (NHWC walk) access.
    pub contiguous: bool,
}

impl WeightStream {
    pub fn none() -> Self {
        WeightStream { bytes_streamed: 0, reuse_window: 0, contiguous: true }
    }
}

/// Cost descriptor of one kernel call: everything the ISA + memory
/// models need, derived from the schedule's loop structure.
#[derive(Debug, Clone)]
pub struct LoopCost {
    /// Multiply-accumulates (0 for data-movement ops).
    pub macs: u64,
    /// Elements produced (requantize/store cost driver).
    pub out_elems: u64,
    /// Instruction mix per MAC (inner loop body).
    pub per_mac: InstrMix,
    /// Instruction mix per output element (requant + store + loop tails).
    pub per_out: InstrMix,
    /// Fixed per-call instructions (prologue, address setup).
    pub fixed: f64,
    /// Weight-streaming pattern.
    pub weights: WeightStream,
    /// Estimated code footprint of this kernel's generated body.
    pub code_bytes: u64,
    /// Scratch RAM the kernel needs while running (im2col rows, ...).
    pub workspace: usize,
}

impl LoopCost {
    /// Total instruction count on the *reference* scalar ISA
    /// (RV32GC): the number ETISS reports in Table IV.
    pub fn ref_instructions(&self) -> u64 {
        (self.macs as f64 * self.per_mac.total()
            + self.out_elems as f64 * self.per_out.total()
            + self.fixed) as u64
    }

    /// Aggregate load count (memory-stall driver).
    pub fn loads(&self) -> u64 {
        (self.macs as f64 * self.per_mac.load
            + self.out_elems as f64 * self.per_out.load) as u64
    }
}

/// Operand of a kernel call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Operand {
    Buf(BufId),
    Const(ConstId),
}

/// Semantic kernel kinds the virtual MCU can execute. Shapes are NHWC.
#[derive(Debug, Clone)]
pub enum KernelKind {
    /// GEMM-ified convolution: input [1,H,W,C] × packed weight matrix.
    Conv2D {
        ih: usize, iw: usize, ic: usize,
        oh: usize, ow: usize, oc: usize,
        kh: usize, kw: usize,
        stride: (usize, usize),
        /// SAME = 0 / VALID = 1.
        padding: u8,
        /// Weight matrix rows ordered (i,j,c) for NHWC or (c,i,j) for
        /// NCHW packing; cols = oc. See tensor::pack_* helpers.
        channels_first: bool,
        requant: Requant,
    },
    DwConv2D {
        ih: usize, iw: usize, c: usize,
        oh: usize, ow: usize,
        kh: usize, kw: usize,
        stride: (usize, usize),
        padding: u8,
        requant: Requant,
    },
    Dense {
        batch: usize, in_n: usize, out_n: usize,
        requant: Requant,
    },
    AvgPool2D {
        ih: usize, iw: usize, c: usize,
        oh: usize, ow: usize,
        fh: usize, fw: usize,
        stride: (usize, usize),
    },
    MaxPool2D {
        ih: usize, iw: usize, c: usize,
        oh: usize, ow: usize,
        fh: usize, fw: usize,
        stride: (usize, usize),
    },
    Add {
        elems: usize,
        s_a: f64, zp_a: i32,
        s_b: f64, zp_b: i32,
        s_o: f64, zp_o: i32,
        act: i64,
    },
    /// Byte copy / reinterpret (reshape, identity).
    Copy { elems: usize },
    Softmax { elems: usize, s_in: f64, zp_in: i32 },
    /// Layout/dtype transform inserted by TVM-style backends
    /// (NHWC i8 <-> NCHWc i16 copies). Numerically value-preserving.
    Transform { elems: usize, widen: bool },
}

impl KernelKind {
    pub fn name(&self) -> &'static str {
        match self {
            KernelKind::Conv2D { .. } => "conv2d",
            KernelKind::DwConv2D { .. } => "dwconv2d",
            KernelKind::Dense { .. } => "dense",
            KernelKind::AvgPool2D { .. } => "avg_pool2d",
            KernelKind::MaxPool2D { .. } => "max_pool2d",
            KernelKind::Add { .. } => "add",
            KernelKind::Copy { .. } => "copy",
            KernelKind::Softmax { .. } => "softmax",
            KernelKind::Transform { .. } => "transform",
        }
    }
}

/// One kernel invocation.
#[derive(Debug, Clone)]
pub struct KernelCall {
    pub kind: KernelKind,
    /// Activation inputs (order is kind-specific; conv: [input]).
    pub inputs: Vec<Operand>,
    /// Constant operands (conv: [packed weights, bias, colsums]).
    pub consts: Vec<ConstId>,
    pub output: BufId,
    pub cost: LoopCost,
    /// Human-readable origin (graph op name) for listings/debug.
    pub origin: String,
}

/// A complete generated inference program.
#[derive(Debug, Clone)]
pub struct Program {
    pub name: String,
    pub buffers: Vec<BufferDecl>,
    pub consts: Vec<ConstDecl>,
    pub calls: Vec<KernelCall>,
    pub input: BufId,
    pub output: BufId,
    /// Total planned arena size (bytes); set by the memory planner.
    pub arena_size: usize,
    /// Peak workspace requirement on top of the arena.
    pub workspace_size: usize,
}

impl Program {
    /// Total flash bytes of constants.
    pub fn const_bytes(&self) -> usize {
        self.consts.iter().map(|c| c.data.len()).sum()
    }

    /// Total generated-code footprint estimate.
    pub fn code_bytes(&self) -> u64 {
        self.calls.iter().map(|c| c.cost.code_bytes).sum()
    }

    /// Reference-ISA invoke instruction count (Table IV "Invoke").
    pub fn ref_invoke_instructions(&self) -> u64 {
        self.calls.iter().map(|c| c.cost.ref_instructions()).sum()
    }

    /// Re-derive the cost descriptors of every tunable kernel under
    /// `schedule`, in place, without re-lowering the graph. Knob
    /// candidates change only `LoopCost`/workspace descriptors —
    /// never shapes, weights, buffers or numerics — so the tuner's
    /// measure loop can re-cost one cached build per trial instead of
    /// paying a full `backend.build`. Produces exactly the costs a
    /// fresh TVM lowering under `schedule` would (asserted by
    /// tuner tests).
    pub fn recost(&mut self, schedule: crate::schedules::Schedule) {
        use crate::kernels::{self, KernelLib};
        let lib = KernelLib::Tvm(schedule);
        for call in &mut self.calls {
            match &call.kind {
                KernelKind::Conv2D { ih, iw, ic, oh, ow, oc, kh, kw, .. } => {
                    call.cost = kernels::conv2d_cost(
                        lib, *ih, *iw, *oh, *ow, *oc, *kh, *kw, *ic,
                    );
                }
                KernelKind::DwConv2D { c, oh, ow, kh, kw, .. } => {
                    call.cost =
                        kernels::dwconv2d_cost(lib, *oh, *ow, *c, *kh, *kw);
                }
                KernelKind::Dense { batch, in_n, out_n, .. } => {
                    call.cost =
                        kernels::dense_cost(lib, *batch, *in_n, *out_n);
                }
                // data-movement kernels have schedule-independent costs
                _ => {}
            }
        }
        self.workspace_size =
            self.calls.iter().map(|c| c.cost.workspace).max().unwrap_or(0);
    }

    /// Recompute buffer lifetimes from the call list. Planner input.
    pub fn recompute_lifetimes(&mut self) {
        for b in &mut self.buffers {
            b.first_use = usize::MAX;
            b.last_use = 0;
        }
        // graph input must be live from the very start; output to end
        let n = self.calls.len();
        for (i, call) in self.calls.iter().enumerate() {
            let mut touch = |id: BufId, bufs: &mut Vec<BufferDecl>| {
                bufs[id].first_use = bufs[id].first_use.min(i);
                bufs[id].last_use = bufs[id].last_use.max(i);
            };
            for op in &call.inputs {
                if let Operand::Buf(id) = op {
                    touch(*id, &mut self.buffers);
                }
            }
            touch(call.output, &mut self.buffers);
        }
        self.buffers[self.input].first_use = 0;
        self.buffers[self.output].last_use = n.saturating_sub(1);
    }

    /// Sanity-check planned offsets: no live-range overlap in the
    /// arena. Returns Err with the colliding pair (used by tests and
    /// the debug-arena feature).
    pub fn check_plan(&self) -> anyhow::Result<()> {
        for (i, a) in self.buffers.iter().enumerate() {
            let ao = a.offset.ok_or_else(|| {
                anyhow::anyhow!("buffer {} unplanned", a.name)
            })?;
            anyhow::ensure!(
                ao + a.size <= self.arena_size,
                "buffer {} [{}..{}] exceeds arena {}",
                a.name, ao, ao + a.size, self.arena_size
            );
            for b in self.buffers.iter().skip(i + 1) {
                let bo = b.offset.unwrap_or(usize::MAX);
                let lifetimes_overlap =
                    a.first_use <= b.last_use && b.first_use <= a.last_use;
                let space_overlap = ao < bo + b.size && bo < ao + a.size;
                anyhow::ensure!(
                    !(lifetimes_overlap && space_overlap),
                    "arena collision: {} [{}..{}] live {}..{} vs {} [{}..{}] live {}..{}",
                    a.name, ao, ao + a.size, a.first_use, a.last_use,
                    b.name, bo, bo + b.size, b.first_use, b.last_use
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn buf(name: &str, size: usize) -> BufferDecl {
        BufferDecl {
            name: name.into(),
            size,
            dtype: DType::I8,
            offset: None,
            first_use: 0,
            last_use: 0,
        }
    }

    fn copy_call(src: BufId, dst: BufId, elems: usize) -> KernelCall {
        KernelCall {
            kind: KernelKind::Copy { elems },
            inputs: vec![Operand::Buf(src)],
            consts: vec![],
            output: dst,
            cost: LoopCost {
                macs: 0,
                out_elems: elems as u64,
                per_mac: InstrMix::default(),
                per_out: InstrMix { load: 1.0, store: 1.0, ..Default::default() },
                fixed: 10.0,
                weights: WeightStream::none(),
                code_bytes: 32,
                workspace: 0,
            },
            origin: "copy".into(),
        }
    }

    fn chain3() -> Program {
        let mut p = Program {
            name: "t".into(),
            buffers: vec![buf("a", 16), buf("b", 16), buf("c", 16)],
            consts: vec![],
            calls: vec![copy_call(0, 1, 16), copy_call(1, 2, 16)],
            input: 0,
            output: 2,
            arena_size: 0,
            workspace_size: 0,
        };
        p.recompute_lifetimes();
        p
    }

    #[test]
    fn lifetimes_from_calls() {
        let p = chain3();
        assert_eq!((p.buffers[0].first_use, p.buffers[0].last_use), (0, 0));
        assert_eq!((p.buffers[1].first_use, p.buffers[1].last_use), (0, 1));
        assert_eq!((p.buffers[2].first_use, p.buffers[2].last_use), (1, 1));
    }

    #[test]
    fn plan_check_catches_overlap() {
        let mut p = chain3();
        // a and b are simultaneously live at call 0 — same offset must fail
        p.buffers[0].offset = Some(0);
        p.buffers[1].offset = Some(0);
        p.buffers[2].offset = Some(16);
        p.arena_size = 32;
        assert!(p.check_plan().is_err());
        // disjoint offsets pass; a and c may alias (disjoint lifetimes)
        p.buffers[1].offset = Some(16);
        p.buffers[2].offset = Some(0);
        p.check_plan().unwrap();
    }

    #[test]
    fn plan_check_catches_arena_overflow() {
        let mut p = chain3();
        p.buffers[0].offset = Some(0);
        p.buffers[1].offset = Some(16);
        p.buffers[2].offset = Some(0);
        p.arena_size = 20; // b sticks out
        assert!(p.check_plan().is_err());
    }

    #[test]
    fn instruction_accounting() {
        let p = chain3();
        // per copy: 16 elems * (1 load + 1 store) + 10 fixed = 42
        assert_eq!(p.ref_invoke_instructions(), 84);
        assert_eq!(p.code_bytes(), 64);
    }

    #[test]
    fn instr_mix_algebra() {
        let a = InstrMix { alu: 1.0, mul: 2.0, load: 3.0, store: 0.0, branch: 0.5 };
        assert_eq!(a.total(), 6.5);
        assert_eq!(a.scale(2.0).mul, 4.0);
        assert_eq!(a.add(&a).load, 6.0);
    }
}
