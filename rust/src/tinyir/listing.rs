//! Human-readable program listing — written into every run's artifact
//! directory (paper §II "Reproducibility": all intermediates inspectable).

use super::{KernelKind, Program};
use crate::util::fmt::human_bytes;

/// Render a TinyIR program as an assembly-like listing.
pub fn render(p: &Program) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "; TinyIR program '{}'\n; arena {} + workspace {}, consts {}\n",
        p.name,
        human_bytes(p.arena_size as u64),
        human_bytes(p.workspace_size as u64),
        human_bytes(p.const_bytes() as u64),
    ));
    out.push_str(";\n; buffers:\n");
    for (i, b) in p.buffers.iter().enumerate() {
        out.push_str(&format!(
            ";   %{i:<3} {:<24} {:>8} B  @{:<8} live [{}, {}]\n",
            b.name,
            b.size,
            b.offset.map_or("?".to_string(), |o| format!("0x{o:x}")),
            b.first_use,
            b.last_use
        ));
    }
    out.push_str(";\n");
    for (i, c) in p.calls.iter().enumerate() {
        let dims = match &c.kind {
            KernelKind::Conv2D { oh, ow, oc, kh, kw, ic, channels_first, .. } => {
                format!(
                    "{}x{}x{} k{}x{}x{} {}",
                    oh, ow, oc, kh, kw, ic,
                    if *channels_first { "nchw" } else { "nhwc" }
                )
            }
            KernelKind::DwConv2D { oh, ow, c, kh, kw, .. } => {
                format!("{oh}x{ow}x{c} k{kh}x{kw} dw")
            }
            KernelKind::Dense { in_n, out_n, .. } => format!("{in_n}->{out_n}"),
            KernelKind::AvgPool2D { oh, ow, c, .. } => format!("{oh}x{ow}x{c}"),
            KernelKind::MaxPool2D { oh, ow, c, .. } => format!("{oh}x{ow}x{c}"),
            KernelKind::Add { elems, .. }
            | KernelKind::Copy { elems }
            | KernelKind::Softmax { elems, .. }
            | KernelKind::Transform { elems, .. } => format!("{elems} elems"),
        };
        out.push_str(&format!(
            "{i:>4}: {:<10} {:<28} -> %{:<3} ; {} macs, ~{} instr ({})\n",
            c.kind.name(),
            dims,
            c.output,
            c.cost.macs,
            c.cost.ref_instructions(),
            c.origin,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::DType;
    use crate::tinyir::*;

    #[test]
    fn listing_contains_calls_and_buffers() {
        let p = Program {
            name: "demo".into(),
            buffers: vec![BufferDecl {
                name: "x".into(),
                size: 64,
                dtype: DType::I8,
                offset: Some(0),
                first_use: 0,
                last_use: 0,
            }],
            consts: vec![],
            calls: vec![KernelCall {
                kind: KernelKind::Softmax { elems: 16, s_in: 0.1, zp_in: 0 },
                inputs: vec![Operand::Buf(0)],
                consts: vec![],
                output: 0,
                cost: LoopCost {
                    macs: 0,
                    out_elems: 16,
                    per_mac: InstrMix::default(),
                    per_out: InstrMix { alu: 30.0, ..Default::default() },
                    fixed: 50.0,
                    weights: WeightStream::none(),
                    code_bytes: 400,
                    workspace: 0,
                },
                origin: "softmax0".into(),
            }],
            input: 0,
            output: 0,
            arena_size: 64,
            workspace_size: 0,
        };
        let text = render(&p);
        assert!(text.contains("softmax"));
        assert!(text.contains("%0"));
        assert!(text.contains("demo"));
    }
}
