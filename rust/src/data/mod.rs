//! Self-contained data formats (serde is not available offline):
//! a JSON value + parser/writer, a TOML subset for environment files,
//! and CSV emission for report artifacts.

pub mod json;
pub mod toml;
pub mod csv;

pub use json::Json;
