//! TOML subset parser for environment/config files
//! (`environment.toml`). Supports: `[table]` and `[table.sub]`
//! headers, `key = value` with string / integer / float / bool /
//! homogeneous-array values, comments, and blank lines. That covers
//! everything MLonMCU environment templates need; exotic TOML
//! (multi-line strings, dates, inline tables) is intentionally out of
//! scope and rejected loudly.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(x) => Some(*x),
            TomlValue::Int(x) => Some(*x as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_str_arr(&self) -> Option<Vec<String>> {
        match self {
            TomlValue::Arr(v) => v
                .iter()
                .map(|x| x.as_str().map(|s| s.to_string()))
                .collect(),
            _ => None,
        }
    }
}

/// A parsed document: dotted table path -> key -> value.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TomlDoc {
    pub tables: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    pub fn get(&self, table: &str, key: &str) -> Option<&TomlValue> {
        self.tables.get(table).and_then(|t| t.get(key))
    }

    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut current = String::new(); // "" = root table
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let ctx = || format!("line {}: {raw:?}", lineno + 1);
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .with_context(|| format!("unclosed table header, {}", ctx()))?
                    .trim();
                if name.is_empty() {
                    bail!("empty table name, {}", ctx());
                }
                current = name.to_string();
                doc.tables.entry(current.clone()).or_default();
            } else if let Some(eq) = find_eq(line) {
                let key = line[..eq].trim().trim_matches('"').to_string();
                if key.is_empty() {
                    bail!("empty key, {}", ctx());
                }
                let val = parse_value(line[eq + 1..].trim())
                    .with_context(ctx)?;
                doc.tables
                    .entry(current.clone())
                    .or_default()
                    .insert(key, val);
            } else {
                bail!("unparseable line, {}", ctx());
            }
        }
        Ok(doc)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<TomlDoc> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        TomlDoc::parse(&text)
    }

    /// Render back to TOML text (environment init writes templates).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        for (table, kv) in &self.tables {
            if !table.is_empty() {
                out.push_str(&format!("[{table}]\n"));
            }
            for (k, v) in kv {
                out.push_str(&format!("{k} = {}\n", render(v)));
            }
            out.push('\n');
        }
        out
    }
}

fn render(v: &TomlValue) -> String {
    match v {
        TomlValue::Str(s) => format!("{s:?}"),
        TomlValue::Int(x) => x.to_string(),
        TomlValue::Float(x) => {
            if x.fract() == 0.0 {
                format!("{x:.1}")
            } else {
                x.to_string()
            }
        }
        TomlValue::Bool(b) => b.to_string(),
        TomlValue::Arr(xs) => format!(
            "[{}]",
            xs.iter().map(render).collect::<Vec<_>>().join(", ")
        ),
    }
}

/// Find the `=` separating key and value (not inside quotes).
fn find_eq(line: &str) -> Option<usize> {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '=' if !in_str => return Some(i),
            _ => {}
        }
    }
    None
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    if s.is_empty() {
        bail!("missing value");
    }
    if let Some(body) = s.strip_prefix('"') {
        let body = body.strip_suffix('"').context("unterminated string")?;
        // minimal escapes
        let un = body.replace("\\\"", "\"").replace("\\\\", "\\");
        return Ok(TomlValue::Str(un));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body.strip_suffix(']').context("unterminated array")?;
        let mut items = Vec::new();
        for part in split_top(body) {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part)?);
            }
        }
        return Ok(TomlValue::Arr(items));
    }
    if let Ok(x) = s.parse::<i64>() {
        return Ok(TomlValue::Int(x));
    }
    if let Ok(x) = s.parse::<f64>() {
        return Ok(TomlValue::Float(x));
    }
    bail!("unsupported TOML value: {s:?}")
}

/// Split on commas not inside quotes or nested brackets.
fn split_top(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0;
    let mut in_str = false;
    let mut start = 0;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth -= 1,
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_environment_shape() {
        let doc = TomlDoc::parse(
            r#"
# MLonMCU environment
name = "default"

[paths]
artifacts = "artifacts"   # inline comment

[targets.etiss]
enabled = true
clock_mhz = 100

[run]
models = ["aww", "vww"]
parallel = 4
validate_atol = 1
"#,
        )
        .unwrap();
        assert_eq!(doc.get("", "name").unwrap().as_str(), Some("default"));
        assert_eq!(
            doc.get("targets.etiss", "clock_mhz").unwrap().as_i64(),
            Some(100)
        );
        assert_eq!(
            doc.get("run", "models").unwrap().as_str_arr().unwrap(),
            vec!["aww", "vww"]
        );
        assert_eq!(doc.get("run", "parallel").unwrap().as_i64(), Some(4));
    }

    #[test]
    fn roundtrip() {
        let src = "a = 1\n\n[t]\nb = \"x\"\nc = [1, 2]\nd = true\ne = 2.5\n";
        let doc = TomlDoc::parse(src).unwrap();
        let doc2 = TomlDoc::parse(&doc.to_string()).unwrap();
        assert_eq!(doc, doc2);
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(TomlDoc::parse("[unclosed").is_err());
        assert!(TomlDoc::parse("novalue =").is_err());
        assert!(TomlDoc::parse("just words").is_err());
        assert!(TomlDoc::parse("k = 1990-01-01").is_err()); // dates: out of scope
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = TomlDoc::parse("k = \"a#b\"").unwrap();
        assert_eq!(doc.get("", "k").unwrap().as_str(), Some("a#b"));
    }
}
