//! CSV writer for report artifacts (RFC-4180 quoting).

/// Serialize rows into CSV text. Every row must have `headers.len()`
/// cells; this is asserted because ragged report artifacts are always
/// a bug upstream.
pub fn to_csv(headers: &[String], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    out.push_str(&join(headers));
    out.push('\n');
    for row in rows {
        assert_eq!(
            row.len(),
            headers.len(),
            "ragged CSV row: {row:?} vs headers {headers:?}"
        );
        out.push_str(&join(row));
        out.push('\n');
    }
    out
}

fn join(cells: &[String]) -> String {
    cells
        .iter()
        .map(|c| quote(c))
        .collect::<Vec<_>>()
        .join(",")
}

fn quote(cell: &str) -> String {
    if cell.contains([',', '"', '\n']) {
        format!("\"{}\"", cell.replace('"', "\"\""))
    } else {
        cell.to_string()
    }
}

/// Minimal CSV reader (used by tests and the compare postprocess).
pub fn parse_csv(text: &str) -> Vec<Vec<String>> {
    let mut rows = Vec::new();
    let mut row = Vec::new();
    let mut cell = String::new();
    let mut in_quotes = false;
    let mut chars = text.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' if in_quotes => {
                if chars.peek() == Some(&'"') {
                    cell.push('"');
                    chars.next();
                } else {
                    in_quotes = false;
                }
            }
            '"' if cell.is_empty() => in_quotes = true,
            ',' if !in_quotes => {
                row.push(std::mem::take(&mut cell));
            }
            '\n' if !in_quotes => {
                row.push(std::mem::take(&mut cell));
                rows.push(std::mem::take(&mut row));
            }
            '\r' if !in_quotes => {}
            c => cell.push(c),
        }
    }
    if !cell.is_empty() || !row.is_empty() {
        row.push(cell);
        rows.push(row);
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(x: &str) -> String {
        x.to_string()
    }

    #[test]
    fn roundtrip_with_quoting() {
        let headers = vec![s("a"), s("b")];
        let rows = vec![
            vec![s("1"), s("x,y")],
            vec![s("he said \"hi\""), s("line\nbreak")],
        ];
        let text = to_csv(&headers, &rows);
        let parsed = parse_csv(&text);
        assert_eq!(parsed[0], headers);
        assert_eq!(parsed[1], rows[0]);
        assert_eq!(parsed[2], rows[1]);
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_rows_panic() {
        to_csv(&[s("a"), s("b")], &[vec![s("only-one")]]);
    }
}
