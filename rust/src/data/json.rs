//! JSON value type, recursive-descent parser and writer.
//!
//! Used for: golden I/O vectors from the python build path, the
//! tvmrt graph.json artifact, session metadata and report export.
//! Covers the full JSON grammar except `\u` surrogate pairs beyond the
//! BMP (not needed for our artifacts).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON document. Object keys are sorted (BTreeMap) so output is
/// deterministic — artifacts must be reproducible byte-for-byte.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------------------------------------------------------- access --
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Flattened i64 array (golden vectors).
    pub fn as_i64_vec(&self) -> Option<Vec<i64>> {
        self.as_arr()
            .map(|v| v.iter().filter_map(|x| x.as_i64()).collect())
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
        )
    }

    pub fn from_i64s(xs: &[i64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ----------------------------------------------------------- write --
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ----------------------------------------------------------- parse --
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        Json::parse(&text)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!(
                "expected '{}' at byte {} (found {:?})",
                c as char,
                self.i,
                self.peek().map(|x| x as char)
            )
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other, self.i),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )?;
                            let code = u32::from_str_radix(hex, 16)?;
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad \\u"))?,
                            );
                            self.i += 4;
                        }
                        other => bail!("bad escape {:?}", other),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one utf-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..])?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => bail!("expected ',' or ']', got {:?}", other),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => bail!("expected ',' or '}}', got {:?}", other),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": -2.5}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_i64(), Some(1));
        assert_eq!(v.get("c").unwrap().as_f64(), Some(-2.5));
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn parses_golden_shape() {
        let src = r#"{"model":"toycar","input":[-1,2,3],"output":[0,-128]}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(
            v.get("input").unwrap().as_i64_vec().unwrap(),
            vec![-1, 2, 3]
        );
        assert_eq!(v.get("model").unwrap().as_str(), Some("toycar"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn escapes_control_chars() {
        let v = Json::Str("a\"\\\n\u{1}".into());
        assert_eq!(v.to_string(), "\"a\\\"\\\\\\n\\u0001\"");
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn deterministic_key_order() {
        let a = Json::obj(vec![("z", Json::Num(1.0)), ("a", Json::Num(2.0))]);
        assert_eq!(a.to_string(), r#"{"a":2,"z":1}"#);
    }

    #[test]
    fn big_int_precision() {
        // instruction counts fit f64 exactly up to 2^53
        let v = Json::parse("153144000").unwrap();
        assert_eq!(v.as_i64(), Some(153_144_000));
        assert_eq!(v.to_string(), "153144000");
    }
}
