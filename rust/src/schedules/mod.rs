//! TVM-style schedule library (paper §III-C).
//!
//! A `Schedule` describes how a conv/dense kernel is lowered: loop
//! order (layout), tiling, unrolling — exactly the axes Table V sweeps:
//!
//!   Default (NHWC) — TVM's x86 schedules on the TFLite-native layout;
//!       int8→int16 QNN legalization, direct conv with an im2col/packed
//!       workspace, weights walked **strided across the whole layer**
//!       (the flash-cache thrash driver on SPI-flash targets).
//!   Default (NCHW) — TVM's default relayout: NCHWc/OIHWio packing,
//!       int16 legalization, weights **block-contiguous** with a small
//!       reuse window. Fastest CNN schedules, bigger RAM.
//!   ARM (NHWC/NCHW) — aarch64 schedules: no int16 legalization (i8
//!       activations), different instruction mixes; dense is ~2×
//!       better than default, convs similar-or-worse (Table V).
//!
//! Tunable knobs mirror AutoTVM template parameters; `knob_space`
//! enumerates the candidate configurations the tuner measures on the
//! target device.

use crate::calib;
use crate::tinyir::InstrMix;

/// Schedule family — the two rows groups of Table V.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Family {
    /// TVM default schedules (written for x86).
    DefaultX86,
    /// Schedules intended for larger ARM (aarch64) targets.
    Arm,
}

/// Activation/weight layout.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Layout {
    Nhwc,
    Nchw,
}

/// AutoTVM-style knob configuration for conv templates.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Knobs {
    /// Output-channel tile (0 = whole layer at once).
    pub tile_oc: usize,
    /// Spatial tile in output rows (0 = whole output).
    pub tile_oh: usize,
    /// Inner unroll factor (1, 2, 4, 8).
    pub unroll: usize,
}

impl Knobs {
    /// TVM "fallback config" used when no tuning log exists.
    pub fn fallback(family: Family, layout: Layout) -> Knobs {
        match (family, layout) {
            // NCHWc default: modest channel blocking (io-block 8)
            (Family::DefaultX86, Layout::Nchw) => {
                Knobs { tile_oc: 8, tile_oh: 4, unroll: 2 }
            }
            // x86 NHWC: no MCU-suitable blocking — whole layer
            (Family::DefaultX86, Layout::Nhwc) => {
                Knobs { tile_oc: 0, tile_oh: 0, unroll: 4 }
            }
            (Family::Arm, Layout::Nchw) => {
                Knobs { tile_oc: 8, tile_oh: 2, unroll: 2 }
            }
            (Family::Arm, Layout::Nhwc) => {
                Knobs { tile_oc: 0, tile_oh: 0, unroll: 2 }
            }
        }
    }
}

/// A fully specified schedule (family × layout × knobs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Schedule {
    pub family: Family,
    pub layout: Layout,
    pub knobs: Knobs,
}

impl Schedule {
    pub fn new(family: Family, layout: Layout) -> Schedule {
        Schedule { family, layout, knobs: Knobs::fallback(family, layout) }
    }

    /// Parse Table V row labels: "default-nhwc", "arm-nchw", ...
    pub fn parse(s: &str) -> Option<Schedule> {
        let (fam, lay) = s.split_once('-')?;
        let family = match fam {
            "default" | "x86" => Family::DefaultX86,
            "arm" => Family::Arm,
            _ => return None,
        };
        let layout = match lay {
            "nhwc" => Layout::Nhwc,
            "nchw" => Layout::Nchw,
            _ => return None,
        };
        Some(Schedule::new(family, layout))
    }

    pub fn label(&self) -> String {
        format!(
            "{} ({})",
            match self.family {
                Family::DefaultX86 => "Default",
                Family::Arm => "ARM",
            },
            match self.layout {
                Layout::Nhwc => "NHWC",
                Layout::Nchw => "NCHW",
            }
        )
    }

    /// Does the int8→int16 QNN legalization apply? (x86 schedules
    /// upcast; the paper's §III-B memory-factor-2 observation.)
    pub fn legalizes_to_i16(&self) -> bool {
        self.family == Family::DefaultX86
    }

    // ------------------------------------------------------------ cost --
    /// Per-MAC instruction mix for a regular conv under this schedule,
    /// including knob effects (unroll amortizes branches; spatial
    /// tiling adds modest re-load overhead when tiles are tiny).
    pub fn conv_per_mac(&self) -> InstrMix {
        let base = match (self.family, self.layout) {
            (Family::DefaultX86, Layout::Nchw) => calib::TVM_CONV_NCHW_PER_MAC,
            (Family::DefaultX86, Layout::Nhwc) => calib::TVM_CONV_NHWC_PER_MAC,
            (Family::Arm, Layout::Nchw) => calib::TVM_CONV_ARM_NCHW_PER_MAC,
            (Family::Arm, Layout::Nhwc) => calib::TVM_CONV_ARM_NHWC_PER_MAC,
        };
        self.apply_knobs(base)
    }

    /// Depthwise conv mix: same family characteristics, ~15 % more
    /// bookkeeping per MAC (per-channel accumulators).
    pub fn dwconv_per_mac(&self) -> InstrMix {
        let m = self.conv_per_mac();
        m.scale(1.15)
    }

    /// Dense mix. The ARM dense schedule has **no tuning template**
    /// (Table V: zero improvement from AutoTVM on ARM dense), so knobs
    /// are not applied there.
    pub fn dense_per_mac(&self) -> InstrMix {
        match self.family {
            Family::DefaultX86 => self.apply_knobs(calib::TVM_DENSE_PER_MAC),
            Family::Arm => calib::TVM_DENSE_ARM_PER_MAC,
        }
    }

    fn apply_knobs(&self, base: InstrMix) -> InstrMix {
        let k = self.knobs;
        // unroll amortizes loop branches (fallback unroll is the
        // baseline the calib constants were fitted at)
        let fallback = Knobs::fallback(self.family, self.layout);
        let branch_scale = fallback.unroll as f64 / k.unroll as f64;
        // register-tiled oc blocks keep accumulators resident: fewer
        // result re-loads once tile_oc is a sane small block
        let load_scale = match k.tile_oc {
            0 => 1.0,            // whole layer: accumulator spills
            1..=4 => 0.92,
            5..=16 => 0.85,
            _ => 0.95,
        } / match fallback.tile_oc {
            0 => 1.0,
            1..=4 => 0.92,
            5..=16 => 0.85,
            _ => 0.95,
        };
        InstrMix {
            branch: base.branch * branch_scale,
            load: base.load * load_scale,
            ..base
        }
    }

    // ------------------------------------------------- weight streaming --
    /// Weight-reuse window in bytes for a conv with `kh*kw*ic*oc`-byte
    /// weights: the working set that must stay cache-resident between
    /// successive uses. NCHW packs weights into OIHWio blocks reused
    /// per tile; NHWC walks the full layer per output pixel.
    pub fn conv_reuse_window(&self, kh: usize, kw: usize, ic: usize, oc: usize) -> u64 {
        let tile_oc = if self.knobs.tile_oc == 0 { oc } else { self.knobs.tile_oc.min(oc) };
        match self.layout {
            Layout::Nchw => (kh * kw * ic * tile_oc) as u64,
            Layout::Nhwc => (kh * kw * ic * tile_oc) as u64,
        }
    }

    /// Are weight accesses contiguous (packed blocks) or strided?
    pub fn weights_contiguous(&self) -> bool {
        self.layout == Layout::Nchw
    }

    // ---------------------------------------------------------- tuning --
    /// Does an AutoTVM template exist for convs under this schedule?
    /// x86 NHWC convs are untunable (Table V: "only fully-connected
    /// layers are tunable" for x86 NHWC).
    pub fn conv_tunable(&self) -> bool {
        !(self.family == Family::DefaultX86 && self.layout == Layout::Nhwc)
    }

    /// Dense template: exists for x86, missing for ARM (Table V last
    /// row: "no tuning-templates for fully-connected on ARM").
    pub fn dense_tunable(&self) -> bool {
        self.family == Family::DefaultX86
    }

    /// Enumerate the knob space for the tuner (conv templates).
    pub fn conv_knob_space(&self, oc: usize) -> Vec<Knobs> {
        if !self.conv_tunable() {
            return vec![self.knobs];
        }
        let mut space = Vec::new();
        for &tile_oc in &[1usize, 2, 4, 8, 16, 32, 0] {
            if tile_oc > oc {
                continue;
            }
            for &tile_oh in &[1usize, 2, 4, 8, 0] {
                for &unroll in &[1usize, 2, 4, 8] {
                    space.push(Knobs { tile_oc, tile_oh, unroll });
                }
            }
        }
        space
    }

    /// Knob space for dense templates (unroll only).
    pub fn dense_knob_space(&self) -> Vec<Knobs> {
        if !self.dense_tunable() {
            return vec![self.knobs];
        }
        [1usize, 2, 4, 8]
            .iter()
            .map(|&unroll| Knobs { tile_oc: self.knobs.tile_oc, tile_oh: 0, unroll })
            .collect()
    }

    pub fn with_knobs(&self, knobs: Knobs) -> Schedule {
        Schedule { knobs, ..*self }
    }
}

/// The four Table V schedule rows.
pub fn table5_schedules() -> Vec<Schedule> {
    vec![
        Schedule::new(Family::DefaultX86, Layout::Nhwc),
        Schedule::new(Family::DefaultX86, Layout::Nchw),
        Schedule::new(Family::Arm, Layout::Nhwc),
        Schedule::new(Family::Arm, Layout::Nchw),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_label_roundtrip() {
        for s in table5_schedules() {
            let txt = match (s.family, s.layout) {
                (Family::DefaultX86, Layout::Nhwc) => "default-nhwc",
                (Family::DefaultX86, Layout::Nchw) => "default-nchw",
                (Family::Arm, Layout::Nhwc) => "arm-nhwc",
                (Family::Arm, Layout::Nchw) => "arm-nchw",
            };
            assert_eq!(Schedule::parse(txt).unwrap(), s);
        }
        assert!(Schedule::parse("bogus").is_none());
    }

    #[test]
    fn nchw_beats_nhwc_in_instructions() {
        let nchw = Schedule::new(Family::DefaultX86, Layout::Nchw);
        let nhwc = Schedule::new(Family::DefaultX86, Layout::Nhwc);
        assert!(nhwc.conv_per_mac().total() > 1.4 * nchw.conv_per_mac().total());
    }

    #[test]
    fn arm_dense_twice_as_fast_and_untunable() {
        let x86 = Schedule::new(Family::DefaultX86, Layout::Nhwc);
        let arm = Schedule::new(Family::Arm, Layout::Nhwc);
        let ratio = x86.dense_per_mac().total() / arm.dense_per_mac().total();
        assert!((1.7..2.4).contains(&ratio), "{ratio}");
        assert!(!arm.dense_tunable());
        assert!(x86.dense_tunable());
        assert_eq!(arm.dense_knob_space().len(), 1);
    }

    #[test]
    fn x86_nhwc_convs_untunable() {
        let s = Schedule::new(Family::DefaultX86, Layout::Nhwc);
        assert!(!s.conv_tunable());
        assert_eq!(s.conv_knob_space(64).len(), 1);
        let nchw = Schedule::new(Family::DefaultX86, Layout::Nchw);
        assert!(nchw.conv_tunable());
        assert!(nchw.conv_knob_space(64).len() > 20);
    }

    #[test]
    fn legalization_only_for_x86() {
        assert!(Schedule::new(Family::DefaultX86, Layout::Nhwc).legalizes_to_i16());
        assert!(Schedule::new(Family::DefaultX86, Layout::Nchw).legalizes_to_i16());
        assert!(!Schedule::new(Family::Arm, Layout::Nhwc).legalizes_to_i16());
    }

    #[test]
    fn reuse_window_shrinks_with_tiling() {
        let untiled = Schedule::new(Family::Arm, Layout::Nhwc); // tile_oc=0
        let full = untiled.conv_reuse_window(3, 3, 64, 64);
        assert_eq!(full, 3 * 3 * 64 * 64);
        let tiled = untiled.with_knobs(Knobs { tile_oc: 4, tile_oh: 2, unroll: 2 });
        assert_eq!(tiled.conv_reuse_window(3, 3, 64, 64), 3 * 3 * 64 * 4);
    }

    #[test]
    fn unroll_reduces_branch_cost() {
        let s = Schedule::new(Family::DefaultX86, Layout::Nchw);
        let fast = s.with_knobs(Knobs { unroll: 8, ..s.knobs });
        assert!(fast.conv_per_mac().branch < s.conv_per_mac().branch);
        assert!(fast.conv_per_mac().total() < s.conv_per_mac().total());
    }
}
