//! Cost-model calibration constants, with their derivations.
//!
//! The virtual targets must reproduce the *shape* of the paper's
//! Tables III–V, not the authors' absolute testbed numbers. Every
//! constant here is derived from a paper datapoint (cited inline) or
//! from the structure of the kernel code it models. The instruction
//! accounting itself (trip counts × instruction mixes) lives in
//! `kernels/`; this module only pins the per-implementation mixes and
//! runtime overheads.
//!
//! Reference-ISA ground truth from Table IV (RV32GC, instructions/MAC):
//!
//!   backend   model    invoke instr / MACs       => instr per MAC
//!   tflmi     aww      153.1 M / 2.66 M          => ~57
//!   tflmi     vww      432.0 M / ~10 M           => ~43
//!   tflmi     resnet   687.5 M / 12.5 M          => ~55
//!   tflmi     toycar     3.0 M / 0.264 M         => ~11 (dense)
//!   tvmaot    aww       29.8 M / 2.66 M          => ~11
//!   tvmaot    resnet   114.8 M / 12.5 M          => ~9.2
//!   tvmaot    toycar     2.44 M / 0.264 M        => ~9.2 (dense)
//!
//! TFLM's reference conv kernels recompute offsets per element and
//! take no advantage of layout, hence ~6× the GEMM-ified TVM cost;
//! its dense kernel is a plain dot-product loop, hence near-TVM.

use crate::tinyir::InstrMix;

// ---------------------------------------------------------------------------
// per-MAC instruction mixes of the kernel implementations
// ---------------------------------------------------------------------------

/// TFLM reference conv2d (tflite-micro `reference_ops::Conv`):
/// per MAC: 2 loads (input, filter — both via Offset() index math:
/// ~4 address ALU each), 1 mul, 1 add, amortized branch.
/// Σ ≈ 55 instructions/MAC — matches tflmi aww/resnet rows above.
pub const TFLM_CONV_PER_MAC: InstrMix = InstrMix {
    alu: 42.0, // offset arithmetic dominates (4 nested index computations)
    mul: 1.0,
    load: 8.0, // input+filter plus re-loaded loop bounds/pointers
    store: 0.0,
    branch: 4.0,
};

/// TFLM reference depthwise conv — same structure, slightly worse
/// per-MAC bookkeeping (per-channel multiplier lookup).
pub const TFLM_DWCONV_PER_MAC: InstrMix = InstrMix {
    alu: 46.0,
    mul: 1.0,
    load: 9.0,
    store: 0.0,
    branch: 4.0,
};

/// TFLM fully_connected: tight dot-product loop, no index math.
/// ≈ 11 instr/MAC (toycar tflmi row).
pub const TFLM_DENSE_PER_MAC: InstrMix = InstrMix {
    alu: 4.0,
    mul: 1.0,
    load: 4.0,
    store: 0.0,
    branch: 2.0,
};

/// TVM GEMM-ified conv (default NCHW/NCHWc schedule): blocked loops,
/// hoisted addresses, unrolled-by-4 inner body.
/// ≈ 9.2 instr/MAC (tvmaot resnet/toycar rows).
pub const TVM_CONV_NCHW_PER_MAC: InstrMix = InstrMix {
    alu: 3.7,
    mul: 1.0,
    load: 3.0,
    store: 0.5,
    branch: 1.0,
};

/// TVM default NHWC conv schedule (written for x86 SIMD): on a scalar
/// MCU the vector body scalarizes with register spills — ~1.8× the
/// NCHW cost in pure instructions (Table V: "difference ×1.5–2 for
/// the rest"). The catastrophic NHWC rows on SPI-flash targets come
/// from the weight-streaming model, not from this mix.
pub const TVM_CONV_NHWC_PER_MAC: InstrMix = InstrMix {
    alu: 7.4,
    mul: 1.0,
    load: 6.0,
    store: 1.0,
    branch: 1.6,
};

/// TVM ARM (aarch64) NHWC conv schedule on a 32-bit MCU: tiled for
/// big cores; mediocre here (Table V: "similar or worse").
pub const TVM_CONV_ARM_NHWC_PER_MAC: InstrMix = InstrMix {
    alu: 8.9,
    mul: 1.0,
    load: 6.5,
    store: 1.0,
    branch: 1.8,
};

/// TVM ARM NCHW conv schedule: ~1.4× default NCHW (Table V).
pub const TVM_CONV_ARM_NCHW_PER_MAC: InstrMix = InstrMix {
    alu: 5.6,
    mul: 1.0,
    load: 4.2,
    store: 0.7,
    branch: 1.4,
};

/// TVM default dense schedule ≈ 9.2 instr/MAC (toycar tvmaot row).
pub const TVM_DENSE_PER_MAC: InstrMix = InstrMix {
    alu: 3.2,
    mul: 1.0,
    load: 3.5,
    store: 0.3,
    branch: 1.2,
};

/// TVM ARM dense schedule: ~2× better (Table V toycar: ARM 0.040 s vs
/// default 0.075 s on esp32c3) — unrolled, dual-accumulator.
pub const TVM_DENSE_ARM_PER_MAC: InstrMix = InstrMix {
    alu: 1.3,
    mul: 1.0,
    load: 1.6,
    store: 0.15,
    branch: 0.55,
};

/// Per-output-element requantization (f64-multiplier model of the
/// fixed-point SRDHM sequence) + store + loop tail. Shared by all
/// conv-like kernels.
pub const REQUANT_PER_OUT: InstrMix = InstrMix {
    alu: 12.0,
    mul: 2.0,
    load: 1.0,
    store: 1.0,
    branch: 2.0,
};

/// Simple elementwise ops (add: two rescales + clamp).
pub const ADD_PER_ELEM: InstrMix = InstrMix {
    alu: 14.0,
    mul: 2.0,
    load: 2.0,
    store: 1.0,
    branch: 1.0,
};

/// Pooling per input-window element.
pub const POOL_PER_ELEM: InstrMix = InstrMix {
    alu: 2.0,
    mul: 0.0,
    load: 1.0,
    store: 0.1,
    branch: 0.5,
};

/// Softmax per element (LUT exp + fixed-point normalize).
pub const SOFTMAX_PER_ELEM: InstrMix = InstrMix {
    alu: 40.0,
    mul: 4.0,
    load: 6.0,
    store: 1.0,
    branch: 4.0,
};

/// memcpy-style per element.
pub const COPY_PER_ELEM: InstrMix = InstrMix {
    alu: 0.5,
    mul: 0.0,
    load: 1.0,
    store: 1.0,
    branch: 0.25,
};

/// Layout/dtype transform per element (strided gather + widen).
pub const TRANSFORM_PER_ELEM: InstrMix = InstrMix {
    alu: 4.0,
    mul: 0.0,
    load: 1.0,
    store: 1.0,
    branch: 0.5,
};

/// Fixed prologue per kernel call (argument setup, bounds checks).
pub const CALL_FIXED: f64 = 150.0;

// ---------------------------------------------------------------------------
// setup-phase models (Table IV "Setup" column)
// ---------------------------------------------------------------------------

/// tflmi: FlatBuffer verification + interpreter graph walk + per-op
/// Prepare() + arena planning touch-per-byte.
/// Table IV: aww 264k, vww 1025k, resnet 217k, toycar 71k.
pub struct SetupModel {
    pub per_op: f64,
    pub per_conv_channel: f64,
    pub per_arena_byte: f64,
    pub per_weight_byte: f64,
    pub fixed: f64,
}

pub const TFLMI_SETUP: SetupModel = SetupModel {
    per_op: 4_000.0,
    per_conv_channel: 250.0, // per-channel quant-param expansion
    per_arena_byte: 1.0,     // greedy planner touches lifetimes per byte
    per_weight_byte: 0.55,   // flatbuffer vector verification
    fixed: 25_000.0,
};

/// tflmc: codegen removes parse + planning; only per-op Init/Prepare
/// remain. Table IV: −73 % … −92 % vs tflmi.
pub const TFLMC_SETUP: SetupModel = SetupModel {
    per_op: 1_200.0,
    per_conv_channel: 60.0,
    per_arena_byte: 0.0,
    per_weight_byte: 0.0,
    fixed: 3_500.0,
};

/// tvmaot: fully static — "≈ 0" in Table IV. A handful of pointer
/// assignments remain.
pub const TVMAOT_SETUP: SetupModel = SetupModel {
    per_op: 12.0,
    per_conv_channel: 0.0,
    per_arena_byte: 0.0,
    per_weight_byte: 0.0,
    fixed: 300.0,
};

/// tvmrt: JSON graph parse + param-blob load + dynamic allocation.
/// Table IV: aww 2 988k, vww 10 688k, resnet 3 970k, toycar 5 014k —
/// correlates with weight bytes (param memcpy + alloc) plus a large
/// fixed runtime bring-up.
pub const TVMRT_SETUP: SetupModel = SetupModel {
    per_op: 60_000.0,
    per_conv_channel: 0.0,
    per_arena_byte: 0.6,
    per_weight_byte: 14.0,
    fixed: 1_200_000.0,
};

// ---------------------------------------------------------------------------
// ROM models (Table IV "ROM")
// ---------------------------------------------------------------------------

/// Code+rodata overhead per backend runtime, bytes.
/// tflmi aww ROM 143 kB ≈ 58 kB model flatbuffer + ~45 kB interpreter
/// + ~35 kB kernel library + MLIF; tvmrt adds the JSON graph string
/// and the graph-executor runtime.
pub const TFLMI_RUNTIME_ROM: u64 = 46_000;
pub const TFLMC_RUNTIME_ROM: u64 = 9_000;
pub const TVMAOT_RUNTIME_ROM: u64 = 11_000;
pub const TVMRT_RUNTIME_ROM: u64 = 52_000;
/// Per-op kernel code: TFLM links one reference kernel per op *type*;
/// TVM emits specialized code per op *instance*.
pub const TFLM_KERNEL_CODE_PER_TYPE: u64 = 6_500;
pub const TVM_KERNEL_CODE_PER_INSTANCE: u64 = 2_200;
/// FlatBuffer metadata on top of raw weights (tflmi/tflmc embed the
/// model container; tflmc strips it to raw arrays).
pub const FLATBUFFER_OVERHEAD_PER_TENSOR: u64 = 220;
pub const TVMRT_JSON_PER_OP: u64 = 1_100;
/// MLIF target-software wrapper (shared by all backends).
pub const MLIF_ROM: u64 = 14_000;

// ---------------------------------------------------------------------------
// RAM models (Table IV "RAM")
// ---------------------------------------------------------------------------

/// Interpreter state: tflmi keeps per-tensor runtime structs + the
/// interpreter object; tflmc only a static context; tvmrt keeps the
/// JSON DOM + per-node storage entries.
pub const TFLMI_RUNTIME_RAM_FIXED: u64 = 10_000;
pub const TFLMI_RUNTIME_RAM_PER_TENSOR: u64 = 64;
pub const TFLMC_RUNTIME_RAM_FIXED: u64 = 1_200;
pub const TVMAOT_RUNTIME_RAM_FIXED: u64 = 1_500;
pub const TVMRT_RUNTIME_RAM_FIXED: u64 = 24_000;
pub const TVMRT_RUNTIME_RAM_PER_TENSOR: u64 = 160;
/// tvmrt's page-based dynamic allocator reserves a fixed pool
/// (Table IV: toycar tvmrt RAM ≈ 1 MB despite ~10 kB of tensors).
pub const TVMRT_HEAP_POOL: u64 = 1_000_000;
/// MLIF static buffers (UART, timers, stacks).
pub const MLIF_RAM: u64 = 2_600;

// ---------------------------------------------------------------------------
// tuning (Table V AutoTVM columns)
// ---------------------------------------------------------------------------

/// Tuning iterations the paper used ("at least 600 per combination").
pub const PAPER_TUNING_ITERATIONS: usize = 600;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_mac_totals_match_table4_ratios() {
        // tflm conv ~55/MAC, tvm nchw ~9.2/MAC => ratio ~6
        let tflm = TFLM_CONV_PER_MAC.total();
        let tvm = TVM_CONV_NCHW_PER_MAC.total();
        assert!((50.0..60.0).contains(&tflm), "{tflm}");
        assert!((8.0..10.5).contains(&tvm), "{tvm}");
        assert!(tflm / tvm > 4.0 && tflm / tvm < 8.0);
        // dense: tflm ~11, tvm ~9.2, arm ~4.6 (2x better than tvm)
        let td = TFLM_DENSE_PER_MAC.total();
        let vd = TVM_DENSE_PER_MAC.total();
        let ad = TVM_DENSE_ARM_PER_MAC.total();
        assert!((10.0..12.5).contains(&td), "{td}");
        assert!((8.0..10.5).contains(&vd), "{vd}");
        assert!(vd / ad > 1.7 && vd / ad < 2.4, "{}", vd / ad);
    }

    #[test]
    fn nhwc_penalty_is_moderate_in_pure_instructions() {
        // the ×1.5–2 "rest" gap of Table V; flash thrash adds the rest
        let r = TVM_CONV_NHWC_PER_MAC.total() / TVM_CONV_NCHW_PER_MAC.total();
        assert!((1.5..2.2).contains(&r), "{r}");
    }

    #[test]
    fn setup_models_reproduce_table4_order() {
        // tvmaot << tflmc < tflmi << tvmrt for a mid-size CNN
        let ops = 16.0;
        let conv_ch = 8.0 * 40.0;
        let arena = 70_000.0;
        let weights = 80_000.0;
        let eval = |m: &SetupModel| {
            m.fixed
                + m.per_op * ops
                + m.per_conv_channel * conv_ch
                + m.per_arena_byte * arena
                + m.per_weight_byte * weights
        };
        let i = eval(&TFLMI_SETUP);
        let c = eval(&TFLMC_SETUP);
        let a = eval(&TVMAOT_SETUP);
        let r = eval(&TVMRT_SETUP);
        assert!(a < 10_000.0);
        assert!(c < 0.27 * i, "tflmc {c} vs tflmi {i}"); // −73 %+
        assert!(r > 5.0 * i, "tvmrt {r} vs tflmi {i}");
    }
}
