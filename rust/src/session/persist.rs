//! Versioned on-disk serialization of cache artifacts.
//!
//! The environment-level artifact store (`store.rs`) persists stage
//! outputs across CLI invocations, so the bytes must be (a) versioned
//! — a future format change must read as a miss, never a panic — and
//! (b) verifiable — a corrupted or truncated file must be detected
//! before its artifact is trusted. Every entry therefore carries a
//! fixed header:
//!
//! ```text
//! "MLCA" | version u32 | stage u8 | key u64 | len u64 | fnv u64 | payload
//! ```
//!
//! `key` is the producing `StageKey` (re-checked against the key the
//! loader asked for) and `fnv` is the FNV-1a hash of the payload
//! bytes (re-checked before decoding). Payloads:
//!
//! * **Graph** — the `.tmodel` wire format (`frontends::tmodel`),
//!   reused verbatim: it already round-trips every field a backend
//!   can observe, byte-compatibly with the python writer.
//! * **TuneOutcome** — schedule family/layout/knobs + improvement.
//! * **BuildResult** — a full TinyIR `Program` (buffers, consts,
//!   kernel calls with cost descriptors) plus `BuildMetrics`.
//!
//! All integers little-endian; floats by IEEE bit pattern; `usize`
//! widened to u64 on disk.

use std::sync::Arc;

use anyhow::{bail, ensure, Result};

use crate::backends::{BuildMetrics, BuildResult};
use crate::frontends::tmodel;
use crate::schedules::{Family, Knobs, Layout, Schedule};
use crate::session::cache::{Artifact, CachedStage, StageKey, TuneOutcome};
use crate::tensor::DType;
use crate::tinyir::{
    BufferDecl, ConstDecl, InstrMix, KernelCall, KernelKind, LoopCost,
    Operand, Program, Requant, WeightStream,
};
use crate::util::fnv1a64;

const MAGIC: &[u8; 4] = b"MLCA";
/// Bump on ANY payload layout change: old entries then decode as
/// misses and are recomputed (never migrated in place).
/// v2: `BuildResult` gained an optional lowering `Schedule`.
///
/// The dispatch work queue (`dispatch.rs`) stamps this version into
/// its task records too: a worker built from a different format
/// refuses the queue outright instead of exchanging artifacts it
/// would decode as misses (or worse, misread).
pub const FORMAT_VERSION: u32 = 2;

const HEADER_LEN: usize = 4 + 4 + 1 + 8 + 8 + 8;

fn stage_tag(stage: CachedStage) -> u8 {
    match stage {
        CachedStage::Load => 0,
        CachedStage::Tune => 1,
        CachedStage::Build => 2,
    }
}

fn stage_from_tag(tag: u8) -> Result<CachedStage> {
    Ok(match tag {
        0 => CachedStage::Load,
        1 => CachedStage::Tune,
        2 => CachedStage::Build,
        _ => bail!("unknown stage tag {tag}"),
    })
}

/// Serialize one artifact under its content key.
pub fn encode(key: StageKey, artifact: &Artifact) -> Vec<u8> {
    let payload = match artifact {
        Artifact::Graph(g) => tmodel::write(g),
        Artifact::Tune(t) => {
            let mut e = Enc::new();
            put_schedule(&mut e, &t.schedule);
            e.f64(t.improvement);
            e.0
        }
        Artifact::Build(b) => {
            let mut e = Enc::new();
            put_metrics(&mut e, &b.metrics);
            match &b.schedule {
                Some(s) => {
                    e.u8(1);
                    put_schedule(&mut e, s);
                }
                None => e.u8(0),
            }
            put_program(&mut e, &b.program);
            e.0
        }
    };
    let mut v = Vec::with_capacity(HEADER_LEN + payload.len());
    v.extend(MAGIC);
    v.extend(FORMAT_VERSION.to_le_bytes());
    v.push(stage_tag(artifact.stage()));
    v.extend(key.0.to_le_bytes());
    v.extend((payload.len() as u64).to_le_bytes());
    v.extend(fnv1a64(&payload).to_le_bytes());
    v.extend(payload);
    v
}

/// Read the format version stamped into an encoded entry without
/// decoding it. `None` when the bytes are too short or not an "MLCA"
/// entry at all — used by the remote tier to tell "peer runs another
/// format" apart from "peer sent garbage" when logging a miss.
pub fn peek_version(bytes: &[u8]) -> Option<u32> {
    if bytes.len() < 8 || &bytes[..4] != MAGIC {
        return None;
    }
    Some(u32::from_le_bytes(bytes[4..8].try_into().unwrap()))
}

/// Decode an entry, verifying magic, version, key and payload hash.
/// Any mismatch is an error — callers treat it as a cache miss.
pub fn decode(bytes: &[u8], expect: StageKey) -> Result<Artifact> {
    ensure!(bytes.len() >= HEADER_LEN, "entry shorter than header");
    ensure!(&bytes[..4] == MAGIC, "bad magic: not a cache artifact");
    let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    ensure!(
        version == FORMAT_VERSION,
        "format version {version} != {FORMAT_VERSION}"
    );
    let stage = stage_from_tag(bytes[8])?;
    let key = u64::from_le_bytes(bytes[9..17].try_into().unwrap());
    ensure!(
        key == expect.0,
        "stored key {key:016x} != expected {:016x}",
        expect.0
    );
    let len = u64::from_le_bytes(bytes[17..25].try_into().unwrap()) as usize;
    let fnv = u64::from_le_bytes(bytes[25..33].try_into().unwrap());
    let payload = &bytes[HEADER_LEN..];
    ensure!(payload.len() == len, "payload length mismatch");
    ensure!(fnv1a64(payload) == fnv, "payload hash mismatch (corrupt entry)");
    match stage {
        CachedStage::Load => {
            Ok(Artifact::Graph(Arc::new(tmodel::parse(payload)?)))
        }
        CachedStage::Tune => {
            let mut d = Dec { b: payload, i: 0 };
            let schedule = get_schedule(&mut d)?;
            let improvement = d.f64()?;
            d.done()?;
            Ok(Artifact::Tune(TuneOutcome { schedule, improvement }))
        }
        CachedStage::Build => {
            let mut d = Dec { b: payload, i: 0 };
            let metrics = get_metrics(&mut d)?;
            let schedule = match d.u8()? {
                0 => None,
                1 => Some(get_schedule(&mut d)?),
                x => bail!("bad schedule flag {x}"),
            };
            let program = get_program(&mut d)?;
            d.done()?;
            Ok(Artifact::Build(Arc::new(BuildResult {
                program,
                metrics,
                schedule,
            })))
        }
    }
}

// ------------------------------------------------------------- schedule --

fn put_schedule(e: &mut Enc, s: &Schedule) {
    e.u8(match s.family {
        Family::DefaultX86 => 0,
        Family::Arm => 1,
    });
    e.u8(match s.layout {
        Layout::Nhwc => 0,
        Layout::Nchw => 1,
    });
    e.u64(s.knobs.tile_oc as u64);
    e.u64(s.knobs.tile_oh as u64);
    e.u64(s.knobs.unroll as u64);
}

fn get_schedule(d: &mut Dec) -> Result<Schedule> {
    let family = match d.u8()? {
        0 => Family::DefaultX86,
        1 => Family::Arm,
        x => bail!("unknown schedule family tag {x}"),
    };
    let layout = match d.u8()? {
        0 => Layout::Nhwc,
        1 => Layout::Nchw,
        x => bail!("unknown layout tag {x}"),
    };
    let knobs = Knobs {
        tile_oc: d.usize()?,
        tile_oh: d.usize()?,
        unroll: d.usize()?,
    };
    Ok(Schedule { family, layout, knobs })
}

// -------------------------------------------------------------- metrics --

fn put_metrics(e: &mut Enc, m: &BuildMetrics) {
    e.u64(m.setup_instructions);
    e.u64(m.rom_code);
    e.u64(m.rom_weights);
    e.u64(m.rom_misc);
    e.u64(m.ram_arena);
    e.u64(m.ram_workspace);
    e.u64(m.ram_runtime);
}

fn get_metrics(d: &mut Dec) -> Result<BuildMetrics> {
    Ok(BuildMetrics {
        setup_instructions: d.u64()?,
        rom_code: d.u64()?,
        rom_weights: d.u64()?,
        rom_misc: d.u64()?,
        ram_arena: d.u64()?,
        ram_workspace: d.u64()?,
        ram_runtime: d.u64()?,
    })
}

// -------------------------------------------------------------- program --

fn put_program(e: &mut Enc, p: &Program) {
    e.str(&p.name);
    e.u64(p.input as u64);
    e.u64(p.output as u64);
    e.u64(p.arena_size as u64);
    e.u64(p.workspace_size as u64);
    e.u32(p.buffers.len() as u32);
    for b in &p.buffers {
        e.str(&b.name);
        e.u64(b.size as u64);
        e.u8(b.dtype.to_u8());
        match b.offset {
            Some(o) => {
                e.u8(1);
                e.u64(o as u64);
            }
            None => {
                e.u8(0);
                e.u64(0);
            }
        }
        e.u64(b.first_use as u64);
        e.u64(b.last_use as u64);
    }
    e.u32(p.consts.len() as u32);
    for c in &p.consts {
        e.str(&c.name);
        e.u8(c.dtype.to_u8());
        e.bytes(&c.data);
    }
    e.u32(p.calls.len() as u32);
    for call in &p.calls {
        put_kind(e, &call.kind);
        e.u32(call.inputs.len() as u32);
        for op in &call.inputs {
            match op {
                Operand::Buf(id) => {
                    e.u8(0);
                    e.u64(*id as u64);
                }
                Operand::Const(id) => {
                    e.u8(1);
                    e.u64(*id as u64);
                }
            }
        }
        e.u32(call.consts.len() as u32);
        for &c in &call.consts {
            e.u64(c as u64);
        }
        e.u64(call.output as u64);
        put_cost(e, &call.cost);
        e.str(&call.origin);
    }
}

fn get_program(d: &mut Dec) -> Result<Program> {
    let name = d.str()?;
    let input = d.usize()?;
    let output = d.usize()?;
    let arena_size = d.usize()?;
    let workspace_size = d.usize()?;
    let n_buffers = d.count()?;
    let mut buffers = Vec::with_capacity(n_buffers);
    for _ in 0..n_buffers {
        let name = d.str()?;
        let size = d.usize()?;
        let dtype = DType::from_u8(d.u8()?)?;
        let has_offset = d.u8()?;
        let off = d.usize()?;
        let offset = (has_offset == 1).then_some(off);
        buffers.push(BufferDecl {
            name,
            size,
            dtype,
            offset,
            first_use: d.usize()?,
            last_use: d.usize()?,
        });
    }
    let n_consts = d.count()?;
    let mut consts = Vec::with_capacity(n_consts);
    for _ in 0..n_consts {
        consts.push(ConstDecl {
            name: d.str()?,
            dtype: DType::from_u8(d.u8()?)?,
            data: d.bytes()?,
        });
    }
    let n_calls = d.count()?;
    let mut calls = Vec::with_capacity(n_calls);
    for _ in 0..n_calls {
        let kind = get_kind(d)?;
        let n_in = d.count()?;
        let mut inputs = Vec::with_capacity(n_in);
        for _ in 0..n_in {
            let tag = d.u8()?;
            let id = d.usize()?;
            inputs.push(match tag {
                0 => Operand::Buf(id),
                1 => Operand::Const(id),
                x => bail!("unknown operand tag {x}"),
            });
        }
        let n_c = d.count()?;
        let mut call_consts = Vec::with_capacity(n_c);
        for _ in 0..n_c {
            call_consts.push(d.usize()?);
        }
        let out = d.usize()?;
        let cost = get_cost(d)?;
        let origin = d.str()?;
        calls.push(KernelCall {
            kind,
            inputs,
            consts: call_consts,
            output: out,
            cost,
            origin,
        });
    }
    Ok(Program {
        name,
        buffers,
        consts,
        calls,
        input,
        output,
        arena_size,
        workspace_size,
    })
}

fn put_cost(e: &mut Enc, c: &LoopCost) {
    e.u64(c.macs);
    e.u64(c.out_elems);
    put_mix(e, &c.per_mac);
    put_mix(e, &c.per_out);
    e.f64(c.fixed);
    e.u64(c.weights.bytes_streamed);
    e.u64(c.weights.reuse_window);
    e.u8(c.weights.contiguous as u8);
    e.u64(c.code_bytes);
    e.u64(c.workspace as u64);
}

fn get_cost(d: &mut Dec) -> Result<LoopCost> {
    Ok(LoopCost {
        macs: d.u64()?,
        out_elems: d.u64()?,
        per_mac: get_mix(d)?,
        per_out: get_mix(d)?,
        fixed: d.f64()?,
        weights: WeightStream {
            bytes_streamed: d.u64()?,
            reuse_window: d.u64()?,
            contiguous: d.u8()? == 1,
        },
        code_bytes: d.u64()?,
        workspace: d.usize()?,
    })
}

fn put_mix(e: &mut Enc, m: &InstrMix) {
    e.f64(m.alu);
    e.f64(m.mul);
    e.f64(m.load);
    e.f64(m.store);
    e.f64(m.branch);
}

fn get_mix(d: &mut Dec) -> Result<InstrMix> {
    Ok(InstrMix {
        alu: d.f64()?,
        mul: d.f64()?,
        load: d.f64()?,
        store: d.f64()?,
        branch: d.f64()?,
    })
}

fn put_requant(e: &mut Enc, r: &Requant) {
    e.f64(r.multiplier);
    e.i64(r.zp_in as i64);
    e.i64(r.zp_out as i64);
    e.i64(r.act);
}

fn get_requant(d: &mut Dec) -> Result<Requant> {
    Ok(Requant {
        multiplier: d.f64()?,
        zp_in: d.i64()? as i32,
        zp_out: d.i64()? as i32,
        act: d.i64()?,
    })
}

fn put_kind(e: &mut Enc, k: &KernelKind) {
    match k {
        KernelKind::Conv2D {
            ih, iw, ic, oh, ow, oc, kh, kw, stride, padding,
            channels_first, requant,
        } => {
            e.u8(0);
            for &x in [ih, iw, ic, oh, ow, oc, kh, kw, &stride.0, &stride.1] {
                e.u64(x as u64);
            }
            e.u8(*padding);
            e.u8(*channels_first as u8);
            put_requant(e, requant);
        }
        KernelKind::DwConv2D {
            ih, iw, c, oh, ow, kh, kw, stride, padding, requant,
        } => {
            e.u8(1);
            for &x in [ih, iw, c, oh, ow, kh, kw, &stride.0, &stride.1] {
                e.u64(x as u64);
            }
            e.u8(*padding);
            put_requant(e, requant);
        }
        KernelKind::Dense { batch, in_n, out_n, requant } => {
            e.u8(2);
            e.u64(*batch as u64);
            e.u64(*in_n as u64);
            e.u64(*out_n as u64);
            put_requant(e, requant);
        }
        KernelKind::AvgPool2D { ih, iw, c, oh, ow, fh, fw, stride } => {
            e.u8(3);
            for &x in [ih, iw, c, oh, ow, fh, fw, &stride.0, &stride.1] {
                e.u64(x as u64);
            }
        }
        KernelKind::MaxPool2D { ih, iw, c, oh, ow, fh, fw, stride } => {
            e.u8(4);
            for &x in [ih, iw, c, oh, ow, fh, fw, &stride.0, &stride.1] {
                e.u64(x as u64);
            }
        }
        KernelKind::Add { elems, s_a, zp_a, s_b, zp_b, s_o, zp_o, act } => {
            e.u8(5);
            e.u64(*elems as u64);
            e.f64(*s_a);
            e.i64(*zp_a as i64);
            e.f64(*s_b);
            e.i64(*zp_b as i64);
            e.f64(*s_o);
            e.i64(*zp_o as i64);
            e.i64(*act);
        }
        KernelKind::Copy { elems } => {
            e.u8(6);
            e.u64(*elems as u64);
        }
        KernelKind::Softmax { elems, s_in, zp_in } => {
            e.u8(7);
            e.u64(*elems as u64);
            e.f64(*s_in);
            e.i64(*zp_in as i64);
        }
        KernelKind::Transform { elems, widen } => {
            e.u8(8);
            e.u64(*elems as u64);
            e.u8(*widen as u8);
        }
    }
}

fn get_kind(d: &mut Dec) -> Result<KernelKind> {
    Ok(match d.u8()? {
        0 => KernelKind::Conv2D {
            ih: d.usize()?,
            iw: d.usize()?,
            ic: d.usize()?,
            oh: d.usize()?,
            ow: d.usize()?,
            oc: d.usize()?,
            kh: d.usize()?,
            kw: d.usize()?,
            stride: (d.usize()?, d.usize()?),
            padding: d.u8()?,
            channels_first: d.u8()? == 1,
            requant: get_requant(d)?,
        },
        1 => KernelKind::DwConv2D {
            ih: d.usize()?,
            iw: d.usize()?,
            c: d.usize()?,
            oh: d.usize()?,
            ow: d.usize()?,
            kh: d.usize()?,
            kw: d.usize()?,
            stride: (d.usize()?, d.usize()?),
            padding: d.u8()?,
            requant: get_requant(d)?,
        },
        2 => KernelKind::Dense {
            batch: d.usize()?,
            in_n: d.usize()?,
            out_n: d.usize()?,
            requant: get_requant(d)?,
        },
        3 => KernelKind::AvgPool2D {
            ih: d.usize()?,
            iw: d.usize()?,
            c: d.usize()?,
            oh: d.usize()?,
            ow: d.usize()?,
            fh: d.usize()?,
            fw: d.usize()?,
            stride: (d.usize()?, d.usize()?),
        },
        4 => KernelKind::MaxPool2D {
            ih: d.usize()?,
            iw: d.usize()?,
            c: d.usize()?,
            oh: d.usize()?,
            ow: d.usize()?,
            fh: d.usize()?,
            fw: d.usize()?,
            stride: (d.usize()?, d.usize()?),
        },
        5 => KernelKind::Add {
            elems: d.usize()?,
            s_a: d.f64()?,
            zp_a: d.i64()? as i32,
            s_b: d.f64()?,
            zp_b: d.i64()? as i32,
            s_o: d.f64()?,
            zp_o: d.i64()? as i32,
            act: d.i64()?,
        },
        6 => KernelKind::Copy { elems: d.usize()? },
        7 => KernelKind::Softmax {
            elems: d.usize()?,
            s_in: d.f64()?,
            zp_in: d.i64()? as i32,
        },
        8 => KernelKind::Transform {
            elems: d.usize()?,
            widen: d.u8()? == 1,
        },
        x => bail!("unknown kernel tag {x}"),
    })
}

// ------------------------------------------------------- byte plumbing --

struct Enc(Vec<u8>);

impl Enc {
    fn new() -> Enc {
        Enc(Vec::new())
    }
    fn u8(&mut self, x: u8) {
        self.0.push(x);
    }
    fn u32(&mut self, x: u32) {
        self.0.extend(x.to_le_bytes());
    }
    fn u64(&mut self, x: u64) {
        self.0.extend(x.to_le_bytes());
    }
    fn i64(&mut self, x: i64) {
        self.0.extend(x.to_le_bytes());
    }
    fn f64(&mut self, x: f64) {
        self.0.extend(x.to_bits().to_le_bytes());
    }
    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.0.extend(s.as_bytes());
    }
    fn bytes(&mut self, b: &[u8]) {
        self.u64(b.len() as u64);
        self.0.extend(b);
    }
}

struct Dec<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.i + n <= self.b.len(), "truncated at byte {}", self.i);
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }
    fn usize(&mut self) -> Result<usize> {
        Ok(self.u64()? as usize)
    }
    /// A u32 element count, sanity-bounded so a corrupt count cannot
    /// drive a giant allocation before the read fails.
    fn count(&mut self) -> Result<usize> {
        let n = self.u32()? as usize;
        ensure!(n <= 1 << 20, "implausible element count {n}");
        Ok(n)
    }
    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        ensure!(n <= 1 << 20, "implausible string length {n}");
        Ok(String::from_utf8(self.take(n)?.to_vec())?)
    }
    fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u64()? as usize;
        ensure!(n <= 1 << 32, "implausible byte length {n}");
        Ok(self.take(n)?.to_vec())
    }
    fn done(&self) -> Result<()> {
        ensure!(self.i == self.b.len(), "trailing bytes in payload");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backends::{self, BackendConfig};
    use crate::graph::model::testutil::tiny_conv;
    use crate::session::cache::load_key;

    fn build_artifact() -> (StageKey, Artifact) {
        let g = tiny_conv();
        let backend = backends::by_name("tvmaot").unwrap();
        let b = backend.build(&g, &BackendConfig::default()).unwrap();
        (StageKey(0xB0), Artifact::Build(Arc::new(b)))
    }

    #[test]
    fn graph_roundtrip_preserves_content_hash() {
        let g = Arc::new(tiny_conv());
        let key = load_key(7);
        let bytes = encode(key, &Artifact::Graph(g.clone()));
        match decode(&bytes, key).unwrap() {
            Artifact::Graph(back) => {
                assert_eq!(back.content_hash(), g.content_hash());
                back.validate().unwrap();
            }
            _ => panic!("wrong artifact kind"),
        }
    }

    #[test]
    fn tune_roundtrip() {
        let key = StageKey(0x71);
        let schedule = Schedule::new(Family::Arm, Layout::Nchw)
            .with_knobs(Knobs { tile_oc: 16, tile_oh: 4, unroll: 8 });
        let t = TuneOutcome { schedule, improvement: 1.37 };
        let bytes = encode(key, &Artifact::Tune(t));
        match decode(&bytes, key).unwrap() {
            Artifact::Tune(back) => {
                assert_eq!(back.schedule, schedule);
                assert_eq!(back.improvement, 1.37);
            }
            _ => panic!("wrong artifact kind"),
        }
    }

    #[test]
    fn build_roundtrip_preserves_program_and_metrics() {
        let (key, artifact) = build_artifact();
        let Artifact::Build(orig) = &artifact else { unreachable!() };
        let bytes = encode(key, &artifact);
        match decode(&bytes, key).unwrap() {
            Artifact::Build(back) => {
                // the listing renders every call, buffer and const —
                // byte-equal listings mean a faithful roundtrip
                assert_eq!(
                    crate::tinyir::listing::render(&back.program),
                    crate::tinyir::listing::render(&orig.program)
                );
                assert_eq!(
                    back.program.ref_invoke_instructions(),
                    orig.program.ref_invoke_instructions()
                );
                assert_eq!(back.program.arena_size, orig.program.arena_size);
                assert_eq!(back.schedule, orig.schedule);
                assert!(back.schedule.is_some(), "tvm build carries its schedule");
                assert_eq!(back.metrics.rom_total(), orig.metrics.rom_total());
                assert_eq!(back.metrics.ram_total(), orig.metrics.ram_total());
                assert_eq!(
                    back.metrics.setup_instructions,
                    orig.metrics.setup_instructions
                );
                back.program.check_plan().unwrap();
            }
            _ => panic!("wrong artifact kind"),
        }
    }

    #[test]
    fn any_flipped_payload_byte_is_detected() {
        let (key, artifact) = build_artifact();
        let bytes = encode(key, &artifact);
        // flip a byte in the payload: the fnv check must catch it
        let mut bad = bytes.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x01;
        assert!(decode(&bad, key).is_err());
        // and a mid-payload flip too
        let mut bad = bytes.clone();
        let mid = HEADER_LEN + (bytes.len() - HEADER_LEN) / 2;
        bad[mid] ^= 0x80;
        assert!(decode(&bad, key).is_err());
    }

    #[test]
    fn wrong_key_version_magic_truncation_rejected() {
        let g = Arc::new(tiny_conv());
        let key = load_key(1);
        let bytes = encode(key, &Artifact::Graph(g));
        assert!(decode(&bytes, load_key(2)).is_err(), "wrong key");
        let mut v = bytes.clone();
        v[0] = b'X';
        assert!(decode(&v, key).is_err(), "bad magic");
        let mut v = bytes.clone();
        v[4] = 0xFF;
        assert!(decode(&v, key).is_err(), "future version");
        for cut in [0, 10, HEADER_LEN, bytes.len() - 1] {
            assert!(decode(&bytes[..cut], key).is_err(), "truncated at {cut}");
        }
    }
}
