//! Sessions and the run flow (paper §II-A2, Fig. 1): the heart of
//! MLonMCU. A `Session` expands a `RunMatrix` (models × backends ×
//! targets × schedules × features) into `Run`s, drives each run
//! through the stages
//!
//! ```text
//! Load → [Tune] → Build → Compile → Run → Postprocess
//! ```
//!
//! It executes independent
//! runs on a fixed thread pool (paper §II "Parallelism"), writes
//! every intermediate artifact into an isolated
//! per-session directory ("Isolation", "Reproducibility"), and
//! produces the report.

pub mod matrix;
pub mod run;

pub use matrix::RunMatrix;
pub use run::{RunRecord, RunSpec, RunStatus, StageTimes};

use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::config::Environment;
use crate::report::Report;
use crate::runtime::GoldenRuntime;
use crate::util::Stopwatch;

/// A benchmarking session.
pub struct Session {
    pub id: usize,
    pub dir: PathBuf,
    env: Environment,
    golden: Mutex<Option<Arc<GoldenRuntime>>>,
    /// Total wall-clock of the last run_matrix call, split by stage
    /// boundary (Table III's Load–Compile vs Load–Run distinction).
    pub last_timing: Mutex<SessionTiming>,
}

/// Aggregated session timing (Table III).
#[derive(Debug, Clone, Copy, Default)]
pub struct SessionTiming {
    pub runs: usize,
    pub wall_s: f64,
    /// Σ host stage seconds up to Compile (Load–Compile column).
    pub load_compile_s: f64,
    /// Σ host stage seconds including Run (Load–Run column).
    pub load_run_s: f64,
    /// Σ simulated device seconds (build+flash+run latency models).
    pub sim_s: f64,
}

impl Session {
    /// Create the next session directory under the environment.
    pub fn new(env: &Environment) -> Result<Session> {
        let sessions = env.sessions_dir();
        std::fs::create_dir_all(&sessions)?;
        // next free index — sessions are append-only
        let mut id = 0usize;
        while sessions.join(format!("{id}")).exists() {
            id += 1;
        }
        let dir = sessions.join(format!("{id}"));
        std::fs::create_dir_all(&dir)?;
        Ok(Session {
            id,
            dir,
            env: env.clone(),
            golden: Mutex::new(None),
            last_timing: Mutex::new(SessionTiming::default()),
        })
    }

    pub fn env(&self) -> &Environment {
        &self.env
    }

    /// Lazily create the PJRT golden runtime (only when a run actually
    /// uses the validate feature — PJRT startup is not free).
    pub fn golden(&self) -> Result<Arc<GoldenRuntime>> {
        let mut slot = self.golden.lock().unwrap();
        if let Some(g) = slot.as_ref() {
            return Ok(g.clone());
        }
        let rt = Arc::new(
            GoldenRuntime::new(&self.env.artifacts_dir())
                .context("creating PJRT golden runtime")?,
        );
        *slot = Some(rt.clone());
        Ok(rt)
    }

    /// Execute all runs of the matrix with `parallel` workers and
    /// return the report. Failed runs produce rows with Missing cells
    /// (Table V "—"), not errors.
    pub fn run_matrix(&self, matrix: &RunMatrix, parallel: usize) -> Result<Report> {
        let specs = matrix.expand()?;
        let total = specs.len();
        crate::log_info!(
            "session {}: {} runs, {} worker(s)",
            self.id,
            total,
            parallel.max(1)
        );
        let watch = Stopwatch::start();
        let queue: Mutex<std::collections::VecDeque<(usize, RunSpec)>> =
            Mutex::new(specs.into_iter().enumerate().collect());
        let records: Mutex<Vec<(usize, RunRecord)>> = Mutex::new(Vec::new());

        let workers = parallel.max(1).min(total.max(1));
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let job = queue.lock().unwrap().pop_front();
                    let Some((idx, spec)) = job else { break };
                    let rec = run::execute_run(self, idx, &spec);
                    records.lock().unwrap().push((idx, rec));
                });
            }
        });

        let mut records = records.into_inner().unwrap();
        records.sort_by_key(|(i, _)| *i);
        let records: Vec<RunRecord> =
            records.into_iter().map(|(_, r)| r).collect();

        // session timing aggregate (Table III)
        let mut timing = SessionTiming {
            runs: total,
            wall_s: watch.elapsed_s(),
            ..Default::default()
        };
        for r in &records {
            timing.load_compile_s +=
                r.stages.load_s + r.stages.tune_s + r.stages.build_s + r.stages.compile_s;
            timing.load_run_s += r.stages.total_host();
            timing.sim_s += r.sim_total_s();
        }
        *self.last_timing.lock().unwrap() = timing;

        // build the report + write session artifacts
        let mut report = Report::default();
        for r in &records {
            report.push(r.to_row());
        }
        std::fs::write(self.dir.join("report.csv"), report.to_csv())?;
        std::fs::write(self.dir.join("report.md"), report.to_markdown())?;
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Environment;

    fn test_env(tag: &str) -> (Environment, PathBuf) {
        let dir = std::env::temp_dir().join(format!("mlonmcu_sess_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        let env = Environment::init(&dir).unwrap();
        (env, dir)
    }

    #[test]
    fn session_dirs_increment() {
        let (env, dir) = test_env("incr");
        let a = Session::new(&env).unwrap();
        let b = Session::new(&env).unwrap();
        assert_eq!(b.id, a.id + 1);
        std::fs::remove_dir_all(dir).unwrap();
    }

    // full matrix execution is covered by tests/session_e2e.rs with
    // generated models; here we exercise the empty-matrix edge
    #[test]
    fn empty_matrix_is_error() {
        let (env, dir) = test_env("empty");
        let s = Session::new(&env).unwrap();
        let err = s.run_matrix(&RunMatrix::new(), 2).unwrap_err();
        assert!(err.to_string().contains("empty"), "{err}");
        std::fs::remove_dir_all(dir).unwrap();
    }
}
