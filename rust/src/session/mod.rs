//! Sessions and the run flow (paper §II-A2, Fig. 1): the heart of
//! MLonMCU. A `Session` expands a `RunMatrix` (models × backends ×
//! targets × schedules × features) into `Run`s, drives each run
//! through the stages
//!
//! ```text
//! Load → [Tune] → Build → Compile → Run → Postprocess
//! ```
//!
//! Runs are decomposed into stage tasks executed by a shared worker
//! pool (paper §II "Parallelism"); stage outputs are content-addressed
//! in the session's artifact cache so identical (model, backend,
//! schedule) prefixes across the matrix — and across repeated
//! `run_matrix` calls — execute exactly once ("fast retargeting").
//! Every intermediate artifact lands in an isolated per-session
//! directory ("Isolation", "Reproducibility"), and the session
//! produces the report.

pub mod cache;
pub mod dispatch;
pub mod matrix;
pub mod persist;
pub mod run;
pub mod scheduler;
pub mod store;
pub mod transport;

pub use cache::{ArtifactCache, CacheStats};
pub use dispatch::DispatchCounters;
pub use matrix::RunMatrix;
pub use run::{RunRecord, RunSpec, RunStatus, StageTimes};
pub use scheduler::{RunOptions, StageExecCounts};
pub use store::{EnvStore, StoreStats};

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::config::Environment;
use crate::report::Report;
use crate::runtime::GoldenRuntime;
use crate::util::Stopwatch;

/// A benchmarking session.
pub struct Session {
    pub id: usize,
    pub dir: PathBuf,
    env: Environment,
    golden: Mutex<Option<Arc<GoldenRuntime>>>,
    /// Content-addressed stage-artifact cache, shared by every
    /// `run_matrix` call on this session.
    cache: ArtifactCache,
    /// Parsed golden input vectors, keyed by model name. `None` caches
    /// a negative lookup so a matrix of N runs parses (or misses)
    /// `golden/<model>.json` once, not N times.
    golden_inputs: Mutex<HashMap<String, Option<Arc<Vec<i8>>>>>,
    /// Total wall-clock of the last run_matrix call, split by stage
    /// boundary (Table III's Load–Compile vs Load–Run distinction).
    pub last_timing: Mutex<SessionTiming>,
}

/// Aggregated session timing (Table III), including the cache and
/// scheduler counters of the last `run_matrix` call.
#[derive(Debug, Clone, Copy, Default)]
pub struct SessionTiming {
    pub runs: usize,
    pub wall_s: f64,
    /// Σ host stage seconds up to Compile (Load–Compile column).
    pub load_compile_s: f64,
    /// Σ host stage seconds including Run (Load–Run column).
    pub load_run_s: f64,
    /// Σ simulated device seconds (build+flash+run latency models).
    pub sim_s: f64,
    /// Artifact-cache hits during this call (stage outputs served
    /// from cache or shared across runs instead of re-executing).
    pub cache_hits: usize,
    /// Artifact-cache misses during this call.
    pub cache_misses: usize,
    /// Memory-tier evictions during this call.
    pub cache_evictions: usize,
    /// Subset of `cache_hits` served by the environment store (i.e.
    /// computed by an earlier session or CLI invocation).
    pub disk_hits: usize,
    /// Environment-store consultations that found nothing.
    pub disk_misses: usize,
    /// Environment-store entries that failed verification and were
    /// recomputed.
    pub verify_fails: usize,
    /// Load/Tune/Build stage executions that actually ran.
    pub stage_execs: StageExecCounts,
    /// Subset of `cache_hits` served by the remote store tier (a serve
    /// daemon on another machine held the artifact).
    pub remote_hits: usize,
    /// Remote-tier consultations that found nothing.
    pub remote_misses: usize,
    /// Remote transport failures (the tier degrades to local-only
    /// after the first, so at most one per session).
    pub remote_errors: usize,
    /// Worker child processes the sharded dispatcher actually spawned
    /// (0 = the matrix ran in-process, including `--workers` fallbacks
    /// when the environment store is unavailable). On the remote-fleet
    /// path this is the peak number of connected remote workers.
    pub worker_procs: usize,
    /// Trace spans exported to `trace.file` by this call (0 with
    /// tracing off). Display-only: tracing never adds a byte to the
    /// report files, so traced and untraced runs stay byte-identical.
    pub trace_spans: usize,
    /// Faults injected during this call (`faults.plan`), summed across
    /// this process and every worker's done records. Display-only,
    /// like `trace_spans`: never a byte in the report files.
    pub faults_injected: u64,
}

/// Per-invocation counters, normalized across the two execution
/// paths: the in-process scheduler reports live `ArtifactCache`
/// deltas, the sharded dispatcher reconstructs the serial-equivalent
/// numbers from its worker outcomes (so serial and sharded reports
/// carry identical notes).
#[derive(Debug, Clone, Copy, Default)]
struct MatrixCounters {
    hits: usize,
    misses: usize,
    evictions: usize,
    disk_hits: usize,
    disk_misses: usize,
    verify_fails: usize,
    execs: StageExecCounts,
    /// Faults reported by worker processes (dispatch paths only).
    faults: u64,
}

impl Session {
    /// Create the next session directory under the environment.
    pub fn new(env: &Environment) -> Result<Session> {
        let sessions = env.sessions_dir();
        std::fs::create_dir_all(&sessions)?;
        // next free index — sessions are append-only
        let mut id = 0usize;
        while sessions.join(format!("{id}")).exists() {
            id += 1;
        }
        let dir = sessions.join(format!("{id}"));
        std::fs::create_dir_all(&dir)?;
        // clamp before the cast: a negative value must not wrap into
        // a huge capacity that silently disables eviction
        let capacity = env
            .get_i64("cache", "capacity", cache::DEFAULT_CAPACITY as i64)
            .max(1) as usize;
        // the shared environment store makes a second CLI invocation
        // as cheap as a second run_matrix call; failing to open it
        // degrades to session-local caching, never to an error
        let store = if env.cache_persist() {
            match EnvStore::open_with(
                &env.cache_dir(),
                env.cache_budget_bytes(),
                env.store_lock_stale_ms(),
            ) {
                Ok(s) => Some(Arc::new(s)),
                Err(e) => {
                    crate::log_warn!(
                        "env cache at {} unavailable ({e}); continuing without it",
                        env.cache_dir().display()
                    );
                    None
                }
            }
        } else {
            None
        };
        let cache = ArtifactCache::new(capacity, Some(dir.join("cache")));
        let cache = cache
            .with_store(store)
            // remote tier ([remote] connect / --connect): consulted
            // after the local store misses; unreachable servers degrade
            // to local-only, never to an error
            .with_remote(transport::RemoteStore::from_env(env));
        Ok(Session {
            id,
            dir,
            env: env.clone(),
            golden: Mutex::new(None),
            cache,
            golden_inputs: Mutex::new(HashMap::new()),
            last_timing: Mutex::new(SessionTiming::default()),
        })
    }

    /// The golden input vector dumped by the python build path for
    /// `model`, if one exists — parsed once per session and cached.
    pub fn golden_input(&self, model: &str) -> Option<Arc<Vec<i8>>> {
        let mut cache = self.golden_inputs.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(hit) = cache.get(model) {
            return hit.clone();
        }
        let path = self
            .env
            .artifacts_dir()
            .join("golden")
            .join(format!("{model}.json"));
        let parsed = crate::data::Json::parse_file(&path)
            .ok()
            .and_then(|j| j.get("input").and_then(|v| v.as_i64_vec()))
            .map(|v| {
                Arc::new(v.into_iter().map(|x| x as i8).collect::<Vec<i8>>())
            });
        cache.insert(model.to_string(), parsed.clone());
        parsed
    }

    pub fn env(&self) -> &Environment {
        &self.env
    }

    /// Cumulative artifact-cache statistics of this session.
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// The shared environment-level artifact store, when it opened.
    pub fn env_store(&self) -> Option<&Arc<EnvStore>> {
        self.cache.env_store()
    }

    /// Lazily create the PJRT golden runtime (only when a run actually
    /// uses the validate feature — PJRT startup is not free).
    pub fn golden(&self) -> Result<Arc<GoldenRuntime>> {
        let mut slot = self.golden.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(g) = slot.as_ref() {
            return Ok(g.clone());
        }
        let rt = Arc::new(
            GoldenRuntime::new(&self.env.artifacts_dir())
                .context("creating PJRT golden runtime")?,
        );
        *slot = Some(rt.clone());
        Ok(rt)
    }

    /// Execute all runs of the matrix with `parallel` workers and
    /// return the report. Failed runs produce rows with Missing cells
    /// (Table V "—"), not errors.
    pub fn run_matrix(&self, matrix: &RunMatrix, parallel: usize) -> Result<Report> {
        self.run_matrix_opts(matrix, RunOptions::with_parallel(parallel))
    }

    /// `run_matrix` with explicit options (`--no-cache`, `workers`,
    /// ...). With `opts.workers > 0` (and the environment store open)
    /// the Load/Tune/Build stages execute in `mlonmcu worker` child
    /// processes (`dispatch`), exchanging artifacts through the store;
    /// with a remote tier attached (`--connect`) they are dispatched
    /// through the serve daemon's task queue to `worker --connect`
    /// fleets instead. Either way the resulting report is
    /// byte-identical to a serial run.
    pub fn run_matrix_opts(
        &self,
        matrix: &RunMatrix,
        opts: RunOptions,
    ) -> Result<Report> {
        let specs = matrix.expand()?;
        let total = specs.len();
        crate::log_info!(
            "session {}: {} runs, {} thread(s), cache {}",
            self.id,
            total,
            opts.parallel.max(1),
            if opts.use_cache { "on" } else { "off" }
        );
        // fleet-wide tracing: `trace.file` turns the tracer on for the
        // whole call. Local workers inherit the setting through the
        // forwarded `-c` overrides, remote workers through the served
        // queue's trace flag; their spans merge back here at export.
        let trace_file = self.env.trace_file();
        if trace_file.is_some() {
            crate::util::trace::enable();
        }
        // metrics share that lifecycle: on for the whole call, worker
        // registries merged back at the end, exported as metrics.json
        // next to the report. Display-only — the report files never
        // gain or lose a byte from metering.
        let metered = self.env.metrics_enabled();
        if metered {
            crate::util::metrics::enable();
        }
        // fault plans work the same way: installed for the whole call,
        // forwarded to local workers via `-c` overrides and to remote
        // workers through the served queue's claim payload
        let fault_plan = self.env.fault_spec();
        if let Some(spec) = &fault_plan {
            crate::util::faults::install(spec)
                .with_context(|| format!("installing fault plan {spec:?}"))?;
        }
        let faults_before = crate::util::faults::injected_count();
        let watch = Stopwatch::start();
        let stats_before = self.cache.stats();
        // --no-cache: a throwaway disabled cache keeps the session
        // tier untouched and all counters at zero
        let bypass = ArtifactCache::disabled();
        let cache = if opts.use_cache { &self.cache } else { &bypass };

        // sharded dispatch needs the store as the artifact-exchange
        // substrate; without it (or under --no-cache) fall back to the
        // in-process scheduler rather than failing the run
        let sharded = opts.workers > 0
            && opts.use_cache
            && self.cache.env_store().is_some();
        if opts.workers > 0 && !sharded {
            crate::log_warn!(
                "sharded dispatch ({} workers) needs the environment store \
                 and the cache enabled; running in-process instead",
                opts.workers
            );
        }
        // with a remote tier attached, --workers dispatches through
        // the serve daemon's task queue instead of spawning local
        // children; a server that cannot be used returns None and the
        // matrix runs in-process (remote trouble is never fatal)
        let remote_store =
            if opts.use_cache { self.cache.remote_store().cloned() } else { None };
        let dispatched = if sharded {
            match &remote_store {
                Some(r) => dispatch::execute_remote(self, &specs, cache, opts, r)?,
                None => {
                    Some(dispatch::execute_sharded(self, &specs, cache, opts)?)
                }
            }
        } else {
            None
        };
        let via_dispatch = dispatched.is_some();
        let mut worker_procs = 0usize;
        let (records, c) = if let Some((records, d)) = dispatched {
            worker_procs = d.workers_spawned;
            let counters = MatrixCounters {
                hits: d.hits,
                misses: d.misses,
                // memory-tier evictions happen in the tail pass (store
                // promotions), not in the workers: the live delta is
                // the truthful number
                evictions: self.cache.stats().since(&stats_before).evictions,
                disk_hits: d.disk_hits,
                disk_misses: d.disk_misses,
                verify_fails: d.verify_fails,
                execs: d.execs,
                faults: d.faults,
            };
            (records, counters)
        } else {
            let (records, execs) =
                scheduler::execute_matrix(self, &specs, cache, opts)?;
            let s = self.cache.stats().since(&stats_before);
            let counters = MatrixCounters {
                hits: s.hits,
                misses: s.misses,
                evictions: s.evictions,
                disk_hits: s.disk_hits,
                disk_misses: s.disk_misses,
                verify_fails: s.verify_fails,
                execs,
                faults: 0,
            };
            (records, counters)
        };
        let execs = c.execs;
        // remote-tier counters are always the live delta: on dispatch
        // paths the parent's tail pass does the remote fetches
        let live = self.cache.stats().since(&stats_before);

        // session timing aggregate (Table III + cache counters)
        let mut timing = SessionTiming {
            runs: total,
            wall_s: watch.elapsed_s(),
            cache_hits: c.hits,
            cache_misses: c.misses,
            cache_evictions: c.evictions,
            disk_hits: c.disk_hits,
            disk_misses: c.disk_misses,
            verify_fails: c.verify_fails,
            remote_hits: live.remote_hits,
            remote_misses: live.remote_misses,
            remote_errors: live.remote_errors,
            stage_execs: execs,
            worker_procs,
            // this process's own injections plus what workers reported
            faults_injected: crate::util::faults::injected_count()
                .saturating_sub(faults_before)
                + c.faults,
            ..Default::default()
        };
        for r in &records {
            timing.load_compile_s +=
                r.stages.load_s + r.stages.tune_s + r.stages.build_s + r.stages.compile_s;
            timing.load_run_s += r.stages.total_host();
            timing.sim_s += r.sim_total_s();
        }
        if let Some(path) = &trace_file {
            let mut spans = crate::util::trace::drain();
            // local worker processes leave their spans behind as
            // queue/<n>/trace-<pid>.json files; fold every queue of
            // this session in, then consume the files so a later
            // run_matrix call does not re-export them
            if let Ok(queues) = std::fs::read_dir(self.dir.join("queue")) {
                for sub in queues.flatten() {
                    let qdir = sub.path();
                    spans.extend(crate::util::trace::collect_dir(&qdir));
                    remove_span_files(&qdir);
                }
            }
            timing.trace_spans = spans.len();
            match crate::util::trace::write_spans(path, spans) {
                Ok(()) => crate::log_info!(
                    "session {}: exported {} trace span(s) to {}",
                    self.id,
                    timing.trace_spans,
                    path.display()
                ),
                Err(e) => crate::log_warn!(
                    "trace not written to {} ({e:#})",
                    path.display()
                ),
            }
            crate::util::trace::disable();
        }
        if fault_plan.is_some() {
            crate::util::faults::clear();
        }
        *self.last_timing.lock().unwrap_or_else(|e| e.into_inner()) = timing;
        crate::log_info!(
            "session {}: cache {} hit(s) ({} from env store) / {} miss(es), \
             {} verify failure(s); executed {} load, {} tune, {} build \
             stage(s) for {} run(s)",
            self.id,
            c.hits,
            c.disk_hits,
            c.misses,
            c.verify_fails,
            execs.loads,
            execs.tunes,
            execs.builds,
            total
        );
        if remote_store.is_some() {
            crate::log_info!(
                "session {}: remote store: {} hit(s), {} miss(es), {} error(s)",
                self.id,
                live.remote_hits,
                live.remote_misses,
                live.remote_errors
            );
        }

        // build the report + write session artifacts
        let mut report = Report::default();
        for r in &records {
            report.push(r.to_row());
        }
        if opts.use_cache {
            report.notes.push(format!(
                "artifact cache: {} hit(s) ({} from env store), {} miss(es), \
                 {} verify failure(s); executed {} load / {} tune / {} build \
                 stage(s) for {} run(s)",
                c.hits,
                c.disk_hits,
                c.misses,
                c.verify_fails,
                execs.loads,
                execs.tunes,
                execs.builds,
                total
            ));
            // only the in-process path notes the remote tier: the
            // dispatch paths reconstruct serial-equivalent notes, so a
            // remote-fleet report stays byte-identical to a plain
            // serial run of the same matrix
            if !via_dispatch && remote_store.is_some() {
                report.notes.push(format!(
                    "remote store: {} hit(s), {} miss(es), {} error(s)",
                    live.remote_hits, live.remote_misses, live.remote_errors
                ));
            }
        }
        std::fs::write(self.dir.join("report.csv"), report.to_csv())?;
        std::fs::write(self.dir.join("report.md"), report.to_markdown())?;
        // disk tier is best-effort everywhere: the memory tier is
        // authoritative and the runs already succeeded
        if let Err(e) = self.cache.write_index() {
            crate::log_warn!("cache index not written: {e}");
        }
        if metered {
            // local worker processes leave their registries behind as
            // queue/<n>/metrics-<pid>.json snapshots (remote workers'
            // snapshots already merged through the poll loop); fold
            // them in and consume the files, then export
            let mut snap = crate::util::metrics::drain();
            if let Ok(queues) = std::fs::read_dir(self.dir.join("queue")) {
                for sub in queues.flatten() {
                    let qdir = sub.path();
                    snap.merge(&crate::util::metrics::collect_dir(&qdir));
                    crate::util::metrics::remove_snapshot_files(&qdir);
                }
            }
            let path = self.dir.join("metrics.json");
            match crate::util::metrics::write_snapshot(&path, &snap) {
                Ok(()) => crate::log_info!(
                    "session {}: exported {} metric series to {}",
                    self.id,
                    snap.counters.len() + snap.gauges.len() + snap.hists.len(),
                    path.display()
                ),
                Err(e) => crate::log_warn!(
                    "metrics not written to {} ({e:#})",
                    path.display()
                ),
            }
            crate::util::metrics::disable();
        }
        Ok(report)
    }
}

/// Delete collected `trace-<pid>.json` worker span files.
fn remove_span_files(dir: &std::path::Path) {
    let Ok(files) = std::fs::read_dir(dir) else {
        return;
    };
    for f in files.flatten() {
        let name = f.file_name();
        let n = name.to_string_lossy();
        if n.starts_with("trace-") && n.ends_with(".json") {
            let _ = std::fs::remove_file(f.path());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Environment;

    fn test_env(tag: &str) -> (Environment, PathBuf) {
        let dir = std::env::temp_dir().join(format!("mlonmcu_sess_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        let env = Environment::init(&dir).unwrap();
        (env, dir)
    }

    #[test]
    fn session_dirs_increment() {
        let (env, dir) = test_env("incr");
        let a = Session::new(&env).unwrap();
        let b = Session::new(&env).unwrap();
        assert_eq!(b.id, a.id + 1);
        std::fs::remove_dir_all(dir).unwrap();
    }

    // full matrix execution is covered by tests/session_e2e.rs and
    // tests/cache_dedup.rs with generated models; here we exercise the
    // empty-matrix edge
    #[test]
    fn empty_matrix_is_error() {
        let (env, dir) = test_env("empty");
        let s = Session::new(&env).unwrap();
        let err = s.run_matrix(&RunMatrix::new(), 2).unwrap_err();
        assert!(err.to_string().contains("empty"), "{err}");
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn golden_input_parsed_once_and_cached() {
        let (env, dir) = test_env("golden");
        let gdir = env.artifacts_dir().join("golden");
        std::fs::create_dir_all(&gdir).unwrap();
        std::fs::write(gdir.join("m.json"), r#"{"input": [1, -2, 3]}"#).unwrap();
        let s = Session::new(&env).unwrap();
        let a = s.golden_input("m").unwrap();
        assert_eq!(*a, vec![1i8, -2, 3]);
        // delete the file: the cached parse must still serve it
        std::fs::remove_file(gdir.join("m.json")).unwrap();
        assert!(s.golden_input("m").is_some());
        // negative lookups are cached too
        assert!(s.golden_input("missing").is_none());
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn fresh_session_has_empty_cache_stats() {
        let (env, dir) = test_env("stats");
        let s = Session::new(&env).unwrap();
        assert_eq!(s.cache_stats(), CacheStats::default());
        std::fs::remove_dir_all(dir).unwrap();
    }
}
