//! Stage-level scheduler: decomposes every run of the matrix into
//! stage tasks (Load → [Tune] → Build → per-run tail), deduplicates
//! tasks whose content key matches across the matrix, and executes the
//! resulting DAG on a shared ready-queue worker pool.
//!
//! This replaces the seed's whole-run thread pool: with 1 model ×
//! 2 backends × 5 targets the seed executed 10 Loads and 10 Builds;
//! the scheduler executes 1 Load and 2 Builds and shares the
//! artifacts through the session's content-addressed cache
//! (`cache.rs`). Workers pull ready tasks from a shared deque and
//! push tasks whose dependencies just resolved — idle workers thereby
//! "steal" whatever becomes runnable, so one slow Tune cannot stall
//! unrelated pipelines.
//!
//! The task/key decomposition is exposed as [`plan`]/[`TaskGraph`] so
//! the multi-process sharded executor (`dispatch.rs`) can publish the
//! same DAG to worker processes, and so property tests can check the
//! graph invariants directly. When a dispatch pass already executed
//! the Load/Tune/Build tasks out of process, `execute_matrix_with`
//! takes an *overlay* of those worker outcomes: the stage artifacts
//! are then served from the environment store while timing, execution
//! attribution and failure propagation replay exactly as if the
//! stages had run here — which is what makes serial and sharded
//! reports byte-identical.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::Result;

use crate::session::cache::{
    self, Artifact, ArtifactCache, CachedStage, StageKey, TuneParams,
};
use crate::session::run::{self, RunRecord, RunSpec};
use crate::session::Session;
use crate::util::Stopwatch;

/// Options of one `run_matrix` invocation.
#[derive(Debug, Clone, Copy)]
pub struct RunOptions {
    /// Worker count of the in-process stage scheduler (threads).
    pub parallel: usize,
    /// `false` = `--no-cache`: no artifact reuse, no dedup — every run
    /// executes every stage itself (the seed behaviour).
    pub use_cache: bool,
    /// `> 0`: shard Load/Tune/Build execution across this many
    /// `mlonmcu worker` child processes (`dispatch.rs`). Requires the
    /// environment store; `0` keeps everything in-process.
    pub workers: usize,
}

impl RunOptions {
    pub fn with_parallel(parallel: usize) -> RunOptions {
        RunOptions { parallel, use_cache: true, workers: 0 }
    }
}

impl Default for RunOptions {
    fn default() -> RunOptions {
        RunOptions::with_parallel(1)
    }
}

/// How many stage executions actually ran (vs. being served from the
/// cache or shared across runs). Surfaced in `SessionTiming`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StageExecCounts {
    pub loads: usize,
    pub tunes: usize,
    pub builds: usize,
}

/// Kind of one planned task. `Tail` (Compile → Run → Postprocess) is
/// always per-run and never cached or dispatched to worker processes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageKind {
    Load,
    Tune,
    Build,
    Tail,
}

impl StageKind {
    pub fn stage_name(self) -> &'static str {
        match self {
            StageKind::Load => "load",
            StageKind::Tune => "tune",
            StageKind::Build => "build",
            StageKind::Tail => "tail",
        }
    }

    pub fn cached_stage(self) -> CachedStage {
        match self {
            StageKind::Load => CachedStage::Load,
            StageKind::Tune => CachedStage::Tune,
            StageKind::Build => CachedStage::Build,
            StageKind::Tail => unreachable!("tail stages are never cached"),
        }
    }
}

/// One node of the planned stage DAG.
#[derive(Debug, Clone)]
pub struct PlannedTask {
    pub kind: StageKind,
    /// Representative run whose spec parameterizes this stage (for
    /// shared tasks, the lowest consuming run index).
    pub spec_idx: usize,
    /// Cache key; `None` under `--no-cache` and for tails.
    pub key: Option<StageKey>,
    /// Dependency task ids (sorted, deduplicated, always `< self`).
    pub deps: Vec<usize>,
    pub dependents: Vec<usize>,
    /// Consuming run indices (tails: exactly their own run).
    pub consumers: Vec<usize>,
}

/// The deduplicated stage DAG of one matrix invocation. Task ids are
/// indices into `tasks`; dependencies always point at lower ids
/// (topological by construction).
#[derive(Debug, Clone, Default)]
pub struct TaskGraph {
    pub tasks: Vec<PlannedTask>,
}

impl TaskGraph {
    /// Number of Load/Tune/Build tasks (excludes per-run tails).
    pub fn stage_task_count(&self) -> usize {
        self.tasks.iter().filter(|t| t.kind != StageKind::Tail).count()
    }

    /// Unique stage tasks per kind — what a fully cold serial run
    /// would execute.
    pub fn unique_stage_counts(&self) -> StageExecCounts {
        let mut c = StageExecCounts::default();
        for t in &self.tasks {
            match t.kind {
                StageKind::Load => c.loads += 1,
                StageKind::Tune => c.tunes += 1,
                StageKind::Build => c.builds += 1,
                StageKind::Tail => {}
            }
        }
        c
    }
}

/// Outcome of one stage task executed out-of-process by a dispatch
/// worker, keyed by stage key (`Overlay`). `executed` and `secs`
/// replay the worker's execution attribution into the records;
/// `failed` short-circuits the task exactly like a local failure.
#[derive(Debug, Clone)]
pub struct WorkerOutcome {
    pub executed: bool,
    pub secs: f64,
    pub failed: Option<(&'static str, String)>,
}

/// Stage key (`StageKey.0`) → worker outcome, from a dispatch pass.
pub type Overlay = HashMap<u64, WorkerOutcome>;

/// Build the deduplicated stage DAG for `specs`. With `use_cache`
/// false every run gets private tasks (no keys, no sharing) — the
/// seed behaviour. `model_fp` must cover every model the specs name
/// (see [`model_fingerprints`]): a missing fingerprint would silently
/// collide distinct models' Load keys, so it panics instead.
pub fn plan(
    specs: &[RunSpec],
    tune: TuneParams,
    model_fp: &HashMap<String, u64>,
    use_cache: bool,
) -> TaskGraph {
    let mut tasks: Vec<PlannedTask> = Vec::new();
    // (kind, key) -> task id, for prefix dedup
    let mut dedup: HashMap<(StageKind, u64), usize> = HashMap::new();
    let mut shared_or_new = |tasks: &mut Vec<PlannedTask>,
                             dedup: &mut HashMap<(StageKind, u64), usize>,
                             kind: StageKind,
                             key: StageKey,
                             run_idx: usize,
                             deps: Vec<usize>|
     -> usize {
        if use_cache {
            if let Some(&id) = dedup.get(&(kind, key.0)) {
                tasks[id].consumers.push(run_idx);
                return id;
            }
        }
        let id = tasks.len();
        tasks.push(PlannedTask {
            kind,
            spec_idx: run_idx,
            key: use_cache.then_some(key),
            deps,
            dependents: Vec::new(),
            consumers: vec![run_idx],
        });
        if use_cache {
            dedup.insert((kind, key.0), id);
        }
        id
    };

    for (i, spec) in specs.iter().enumerate() {
        let fp = *model_fp
            .get(&spec.model)
            .expect("a fingerprint for every model in the matrix");
        let load_id = shared_or_new(
            &mut tasks,
            &mut dedup,
            StageKind::Load,
            cache::load_key(fp),
            i,
            Vec::new(),
        );
        let tune_id = spec.needs_tune().then(|| {
            shared_or_new(
                &mut tasks,
                &mut dedup,
                StageKind::Tune,
                cache::tune_key(fp, spec, tune),
                i,
                vec![load_id],
            )
        });
        let mut build_deps = vec![load_id];
        build_deps.extend(tune_id);
        let build_id = shared_or_new(
            &mut tasks,
            &mut dedup,
            StageKind::Build,
            cache::build_key(fp, spec, tune),
            i,
            build_deps,
        );
        let mut tail_deps = vec![load_id, build_id];
        tail_deps.extend(tune_id);
        tasks.push(PlannedTask {
            kind: StageKind::Tail,
            spec_idx: i,
            key: None,
            deps: tail_deps,
            dependents: Vec::new(),
            consumers: vec![i],
        });
    }
    // wire dependents (deps are deduplicated per task so a shared dep
    // is only counted once)
    for id in 0..tasks.len() {
        let mut deps = std::mem::take(&mut tasks[id].deps);
        deps.sort_unstable();
        deps.dedup();
        for &d in &deps {
            tasks[d].dependents.push(id);
        }
        tasks[id].deps = deps;
    }
    TaskGraph { tasks }
}

/// Content fingerprints (and raw bytes, for single-read Load stages)
/// of every distinct model named by `specs`.
pub fn model_fingerprints(
    session: &Session,
    specs: &[RunSpec],
) -> (HashMap<String, u64>, HashMap<String, Arc<Vec<u8>>>) {
    let mut model_fp: HashMap<String, u64> = HashMap::new();
    let mut model_bytes: HashMap<String, Arc<Vec<u8>>> = HashMap::new();
    for s in specs {
        if !model_fp.contains_key(&s.model) {
            let (fp, bytes) = model_fingerprint(session, &s.model);
            model_fp.insert(s.model.clone(), fp);
            if let Some(b) = bytes {
                model_bytes.insert(s.model.clone(), b);
            }
        }
    }
    (model_fp, model_bytes)
}

/// Tuning inputs of this session's environment (shared by the serial
/// scheduler and the dispatch workers — keys must agree).
pub fn tune_params(env: &crate::config::Environment) -> TuneParams {
    TuneParams {
        trials: env.get_i64("tune", "trials", 600) as usize,
        seed: env.get_i64("run", "seed", 7) as u64,
    }
}

/// Result slot of a finished task.
enum Output {
    /// Artifact + host seconds spent (0.0 when served from cache) +
    /// whether this task actually executed the stage.
    Done(Artifact, f64, bool),
    /// Stage name + error message; propagated to dependents.
    Failed(&'static str, String),
    /// Tails write their record elsewhere.
    Tail,
    /// Artifact released after the last dependent consumed it, so
    /// peak memory stays O(live tasks), not O(matrix size).
    Consumed,
}

struct SchedState {
    ready: VecDeque<usize>,
    pending: Vec<usize>,
    /// Dependents yet to consume each task's output; at 0 the slot is
    /// replaced with `Consumed` to drop the artifact.
    remaining: Vec<usize>,
    outputs: Vec<Option<Output>>,
    completed: usize,
}

/// Lock that shrugs off poisoning: a panicked worker must not wedge
/// the whole scheduler (the panic itself is surfaced as a failed
/// stage by the catch_unwind in the worker loop).
fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Execute all `specs` and return the records (in spec order) plus
/// the stage-execution counters for this invocation.
pub fn execute_matrix(
    session: &Session,
    specs: &[RunSpec],
    cache: &ArtifactCache,
    opts: RunOptions,
) -> Result<(Vec<RunRecord>, StageExecCounts)> {
    execute_matrix_with(session, specs, cache, opts, None)
}

/// `execute_matrix` with an optional dispatch overlay: stage tasks a
/// worker process already completed are served from the cache tiers
/// with the worker's timing/attribution (or fail with the worker's
/// error) instead of executing here. Tasks the store lost fall back
/// to local execution.
pub fn execute_matrix_with(
    session: &Session,
    specs: &[RunSpec],
    cache: &ArtifactCache,
    opts: RunOptions,
    overlay: Option<&Overlay>,
) -> Result<(Vec<RunRecord>, StageExecCounts)> {
    let tune = tune_params(session.env());
    let (model_fp, model_bytes) = model_fingerprints(session, specs);
    let graph = plan(specs, tune, &model_fp, opts.use_cache);
    execute_planned(session, specs, cache, opts, &graph, &model_bytes, tune, overlay)
}

/// Execute an already-planned graph. The dispatcher reuses its own
/// plan (and fingerprints) here, so models are read and hashed once
/// per sharded invocation and the tail pass replays the *identical*
/// DAG the workers executed.
#[allow(clippy::too_many_arguments)]
pub(crate) fn execute_planned(
    session: &Session,
    specs: &[RunSpec],
    cache: &ArtifactCache,
    opts: RunOptions,
    graph: &TaskGraph,
    model_bytes: &HashMap<String, Arc<Vec<u8>>>,
    tune: TuneParams,
    overlay: Option<&Overlay>,
) -> Result<(Vec<RunRecord>, StageExecCounts)> {
    let tasks = &graph.tasks;
    let n_tasks = tasks.len();

    // --------------------------------------------------- execution --
    let pending: Vec<usize> = tasks.iter().map(|t| t.deps.len()).collect();
    let ready: VecDeque<usize> = (0..n_tasks).filter(|&i| pending[i] == 0).collect();
    let remaining: Vec<usize> = tasks.iter().map(|t| t.dependents.len()).collect();
    let state = Mutex::new(SchedState {
        ready,
        pending,
        remaining,
        outputs: (0..n_tasks).map(|_| None).collect(),
        completed: 0,
    });
    let cond = Condvar::new();
    let records: Mutex<Vec<Option<RunRecord>>> =
        Mutex::new((0..specs.len()).map(|_| None).collect());
    let execs = Mutex::new(StageExecCounts::default());

    let workers = opts.parallel.max(1).min(n_tasks.max(1));
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let task_id = {
                    let mut st = lock(&state);
                    loop {
                        if let Some(id) = st.ready.pop_front() {
                            break id;
                        }
                        if st.completed == n_tasks {
                            return;
                        }
                        st = cond.wait(st).unwrap_or_else(|e| e.into_inner());
                    }
                };
                // a panicking stage (backend bug, poisoned lock) must
                // become a failed run, not a wedged scheduler
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                    || {
                        run_task(
                            session, specs, tasks, task_id, cache, tune,
                            model_bytes, overlay, &state, &records, &execs,
                        )
                    },
                ))
                .unwrap_or_else(|p| {
                    let msg = format!("stage panicked: {}", panic_msg(&p));
                    let task = &tasks[task_id];
                    if task.kind == StageKind::Tail {
                        let mut recs = lock(&records);
                        if recs[task.spec_idx].is_none() {
                            let mut rec = run::blank_record(&specs[task.spec_idx]);
                            rec.status = run::RunStatus::Failed("run", msg);
                            recs[task.spec_idx] = Some(rec);
                        }
                        Output::Tail
                    } else {
                        Output::Failed(task.kind.stage_name(), msg)
                    }
                });
                let mut st = lock(&state);
                st.outputs[task_id] = Some(out);
                st.completed += 1;
                // release dep artifacts this task was the last to use
                for &d in &tasks[task_id].deps {
                    st.remaining[d] -= 1;
                    if st.remaining[d] == 0 {
                        st.outputs[d] = Some(Output::Consumed);
                    }
                }
                for &dep in &tasks[task_id].dependents {
                    st.pending[dep] -= 1;
                    if st.pending[dep] == 0 {
                        st.ready.push_back(dep);
                    }
                }
                cond.notify_all();
            });
        }
    });

    let records = records
        .into_inner()
        .unwrap_or_else(|e| e.into_inner())
        .into_iter()
        .map(|r| r.expect("every run produced a record"))
        .collect();
    Ok((records, execs.into_inner().unwrap_or_else(|e| e.into_inner())))
}

/// Execute one stage attempt-by-attempt: up to `attempts` tries with
/// linear backoff, each attempt catching panics so an injected (or
/// stray) panic retries exactly like an error. At exhaustion the
/// error carries the `[attempts=N]` quarantine marker — but only when
/// more than one attempt was configured, so default sessions keep
/// byte-identical reports. Shared by the in-process scheduler and the
/// dispatch worker stage loop.
pub(crate) fn with_retry<T>(
    attempts: u32,
    backoff_ms: u64,
    stage: &'static str,
    f: impl Fn() -> Result<T>,
) -> Result<T> {
    let attempts = attempts.max(1);
    let mut last: Option<anyhow::Error> = None;
    for attempt in 1..=attempts {
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f())) {
            Ok(Ok(v)) => return Ok(v),
            Ok(Err(e)) => last = Some(e),
            Err(p) => {
                last = Some(anyhow::anyhow!("stage panicked: {}", panic_msg(&p)))
            }
        }
        if attempt < attempts {
            crate::log_debug!(
                "stage {stage} attempt {attempt}/{attempts} failed: {}; retrying",
                last.as_ref().map(|e| e.to_string()).unwrap_or_default()
            );
            std::thread::sleep(std::time::Duration::from_millis(
                backoff_ms.saturating_mul(attempt as u64),
            ));
        }
    }
    let e = last.expect("at least one attempt ran");
    if attempts > 1 {
        Err(anyhow::anyhow!("{}", run::annotate_attempts(&e.to_string(), attempts)))
    } else {
        Err(e)
    }
}

pub(crate) fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic".to_string()
    }
}

/// Clone the finished outputs of `ids` out of the state (cheap: Arcs).
fn dep_outputs(
    state: &Mutex<SchedState>,
    ids: &[usize],
) -> Vec<Result<(Artifact, f64, bool), (&'static str, String)>> {
    let st = lock(state);
    ids.iter()
        .map(|&d| match st.outputs[d].as_ref().expect("dep finished") {
            Output::Done(a, secs, executed) => Ok((a.clone(), *secs, *executed)),
            Output::Failed(stage, e) => Err((*stage, e.clone())),
            Output::Tail | Output::Consumed => {
                unreachable!("dep output consumed before its dependents ran")
            }
        })
        .collect()
}

#[allow(clippy::too_many_arguments)]
fn run_task(
    session: &Session,
    specs: &[RunSpec],
    tasks: &[PlannedTask],
    task_id: usize,
    cache: &ArtifactCache,
    tune: TuneParams,
    model_bytes: &HashMap<String, Arc<Vec<u8>>>,
    overlay: Option<&Overlay>,
    state: &Mutex<SchedState>,
    records: &Mutex<Vec<Option<RunRecord>>>,
    execs: &Mutex<StageExecCounts>,
) -> Output {
    let task = &tasks[task_id];
    let spec = &specs[task.spec_idx];
    let deps = dep_outputs(state, &task.deps);

    if task.kind == StageKind::Tail {
        return run_tail(session, specs, tasks, task_id, &deps, records);
    }

    // failed upstream stage: propagate without executing
    if let Some(Err((stage, e))) = deps.iter().find(|d| d.is_err()).cloned() {
        return Output::Failed(stage, e);
    }

    // a dispatch worker already settled this task: replay its failure,
    // or serve its artifact from the cache tiers with its timing
    let worker = overlay
        .zip(task.key)
        .and_then(|(ov, key)| ov.get(&key.0));
    if let Some(w) = worker {
        if let Some((stage, e)) = w.failed.clone() {
            return Output::Failed(stage, e);
        }
    }

    // cache tiers (memory, then env store): shared consumers beyond
    // the first each count a hit
    if let Some(key) = task.key {
        if let Some(artifact) = cache.lookup(key, task.kind.cached_stage()) {
            cache.note_shared_hits(task.consumers.len() - 1);
            // with an overlay, the worker's host seconds + execution
            // flag are charged as if the stage had run here
            let (secs, executed) =
                worker.map(|w| (w.secs, w.executed)).unwrap_or((0.0, false));
            return Output::Done(artifact, secs, executed);
        }
        // an overlay task missing from the store (evicted or corrupted
        // between the worker's write and now) falls through and
        // recomputes locally — degraded, never wrong
    }

    let graph = deps.iter().find_map(|d| match d {
        Ok((Artifact::Graph(g), _, _)) => Some(g.clone()),
        _ => None,
    });
    let tuned = deps.iter().find_map(|d| match d {
        Ok((Artifact::Tune(t), _, _)) => Some(*t),
        _ => None,
    });

    let watch = Stopwatch::start();
    let mut span = crate::util::trace::span("stage", task.kind.stage_name())
        .arg_with("run", || task.spec_idx.to_string())
        .arg_with("backend", || spec.backend.clone())
        .arg_with("schedule", || {
            spec.schedule.clone().unwrap_or_else(|| "default".into())
        });
    let attempts = session.env().retry_attempts();
    let backoff_ms = session.env().retry_backoff_ms();
    let result: Result<Artifact> =
        with_retry(attempts, backoff_ms, task.kind.stage_name(), || {
            match task.kind {
                StageKind::Load => match model_bytes.get(&spec.model) {
                    Some(bytes) => {
                        crate::frontends::load_model_from_bytes(bytes, &spec.model)
                    }
                    None => run::stage_load(session.env(), spec),
                }
                .map(|g| Artifact::Graph(Arc::new(g))),
                StageKind::Tune => {
                    run::stage_tune(spec, graph.as_ref().expect("load is a dep"), tune)
                        .map(Artifact::Tune)
                }
                StageKind::Build => run::stage_build(
                    spec,
                    graph.as_ref().expect("load is a dep"),
                    tuned.map(|t| t.schedule),
                )
                .map(|b| Artifact::Build(Arc::new(b))),
                StageKind::Tail => unreachable!(),
            }
        });
    span.note("outcome", if result.is_ok() { "ok" } else { "failed" });
    drop(span);
    let secs = watch.elapsed_s();
    crate::util::metrics::observe(
        crate::util::metrics::stage_metric(task.kind.stage_name()),
        (secs * 1e6) as u64,
    );
    match result {
        Ok(artifact) => {
            {
                let mut e = lock(execs);
                match task.kind {
                    StageKind::Load => e.loads += 1,
                    StageKind::Tune => e.tunes += 1,
                    StageKind::Build => e.builds += 1,
                    StageKind::Tail => {}
                }
            }
            if let Some(key) = task.key {
                cache.insert(key, artifact.clone(), &spec.label());
                // runs sharing this execution avoided their own one
                cache.note_shared_hits(task.consumers.len() - 1);
            }
            Output::Done(artifact, secs, true)
        }
        Err(e) => Output::Failed(task.kind.stage_name(), e.to_string()),
    }
}

/// Per-run tail: assemble the record from the shared stage artifacts,
/// charge stage times to the lowest consumer, then Compile/Run/Post.
fn run_tail(
    session: &Session,
    specs: &[RunSpec],
    tasks: &[PlannedTask],
    task_id: usize,
    deps: &[Result<(Artifact, f64, bool), (&'static str, String)>],
    records: &Mutex<Vec<Option<RunRecord>>>,
) -> Output {
    let task = &tasks[task_id];
    let run_idx = task.spec_idx;
    let spec = &specs[run_idx];
    let mut rec = run::blank_record(spec);

    let mut graph = None;
    let mut build = None;
    let mut failure: Option<(&'static str, String)> = None;
    for (pos, dep) in deps.iter().enumerate() {
        let dep_task = &tasks[task.deps[pos]];
        // charge the stage's host seconds to its lowest consumer run;
        // everyone else reused the shared artifact
        let charged = dep_task.consumers.iter().copied().min() == Some(run_idx);
        match dep {
            Ok((artifact, secs, executed)) => {
                let secs = if charged && *executed { *secs } else { 0.0 };
                if !(charged && *executed) && dep_task.kind != StageKind::Tail {
                    rec.reused.push(dep_task.kind.stage_name());
                }
                match artifact {
                    Artifact::Graph(g) => {
                        rec.stages.load_s = secs;
                        graph = Some(g.clone());
                    }
                    Artifact::Tune(t) => {
                        rec.stages.tune_s = secs;
                        rec.tune_improvement = Some(t.improvement);
                    }
                    Artifact::Build(b) => {
                        rec.stages.build_s = secs;
                        build = Some(b.clone());
                    }
                }
            }
            Err((stage, e)) => {
                // keep the earliest stage's failure (load before tune
                // before build)
                let rank = |s: &str| match s {
                    "load" => 0,
                    "tune" => 1,
                    _ => 2,
                };
                if failure
                    .as_ref()
                    .map(|(s, _)| rank(stage) < rank(s))
                    .unwrap_or(true)
                {
                    failure = Some((*stage, e.clone()));
                }
            }
        }
    }

    if let Some((stage, e)) = failure {
        run::fail_record(session, run_idx, &mut rec, stage, &e);
    } else {
        let graph = graph.expect("load artifact present");
        let build = build.expect("build artifact present");
        run::stage_tail(session, run_idx, &mut rec, &graph, &build);
    }
    lock(records)[run_idx] = Some(rec);
    Output::Tail
}

/// Content fingerprint of a model reference: the file bytes when
/// resolvable (content-addressing — renaming a file or regenerating
/// identical bytes keys the same), else a hash of the name alone and
/// no bytes (the Load stage then resolves itself and fails with the
/// real error).
fn model_fingerprint(session: &Session, model: &str) -> (u64, Option<Arc<Vec<u8>>>) {
    let dirs = session.env().model_dirs();
    match crate::frontends::resolve(model, &dirs)
        .and_then(|p| Ok(std::fs::read(p)?))
    {
        Ok(bytes) => (crate::util::fnv1a64(&bytes), Some(Arc::new(bytes))),
        Err(_) => {
            let mut h = crate::util::StableHasher::new();
            h.write_str("unresolved").write_str(model);
            (h.finish(), None)
        }
    }
}
