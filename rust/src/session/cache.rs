//! Content-addressed artifact cache for the stage scheduler.
//!
//! Every cacheable stage output (Load → `Graph`, Tune → best
//! `Schedule`, Build → `BuildResult`) is keyed by a stable FNV-1a hash
//! of the *upstream spec slice* that fully determines it — model file
//! contents, backend, schedule, tuning inputs, feature set. Runs whose
//! prefixes agree share one execution: the paper's "large number of
//! configurations in a low amount of time" claim hinges on exactly
//! this reuse (MLonMCU §II "Parallelism"/"Reproducibility").
//!
//! Three tiers:
//! * **memory** — `Arc`-shared live artifacts with LRU eviction;
//!   this is what the scheduler deduplicates against, within and
//!   across `run_matrix` calls on the same session.
//! * **environment store** — the persistent `$ENV/cache/` tier shared
//!   by every session of an environment (`store.rs`): serialized
//!   artifacts (`persist.rs`) verified by key + payload hash on load,
//!   with a size budget and LRU GC. This is what makes a *second CLI
//!   invocation* as fast as a second `run_matrix` call. Lookups fall
//!   through memory → store → execute; corrupt entries count as
//!   `verify_fails` and are recomputed, never fatal.
//! * **session disk** — a per-session `cache/` directory holding an
//!   `index.json` (keys, stages, labels, hit/miss/eviction counters)
//!   plus small human-readable per-entry artifacts (program listing,
//!   tuned schedule). This records *what* was reused for
//!   reproducibility; a pre-existing index is loaded and validated at
//!   construction so re-opening a directory never silently truncates
//!   its history.
//!
//! `--no-cache` disables all tiers: every run then executes every
//! stage itself and all counters stay zero.

use std::collections::{HashMap, VecDeque};
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::backends::BuildResult;
use crate::data::Json;
use crate::graph::Graph;
use crate::schedules::Schedule;
use crate::session::run::RunSpec;
use crate::session::store::{EnvStore, StoreLookup};
use crate::session::transport::{RemoteLookup, RemoteStore};
use crate::util::StableHasher;

/// A stable 64-bit content key for one stage output.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct StageKey(pub u64);

impl StageKey {
    /// Fixed-width hex form used for directory names and the index.
    pub fn hex(&self) -> String {
        format!("{:016x}", self.0)
    }
}

/// Cacheable stages of the run pipeline. Compile/Run/Postprocess stay
/// per-run: their identity includes the full spec, so two distinct
/// runs can never share them.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CachedStage {
    Load,
    Tune,
    Build,
}

impl CachedStage {
    pub fn name(self) -> &'static str {
        match self {
            CachedStage::Load => "load",
            CachedStage::Tune => "tune",
            CachedStage::Build => "build",
        }
    }

    /// Inverse of `name` (parsing persisted indexes).
    pub fn from_name(name: &str) -> Option<CachedStage> {
        [CachedStage::Load, CachedStage::Tune, CachedStage::Build]
            .into_iter()
            .find(|s| s.name() == name)
    }
}

/// Tune-stage output: the winning schedule plus the improvement ratio
/// reported in Table V.
#[derive(Debug, Clone, Copy)]
pub struct TuneOutcome {
    pub schedule: Schedule,
    pub improvement: f64,
}

/// A shared stage artifact held by the memory tier.
#[derive(Debug, Clone)]
pub enum Artifact {
    Graph(Arc<Graph>),
    Tune(TuneOutcome),
    Build(Arc<BuildResult>),
}

impl Artifact {
    /// The pipeline stage that produces this artifact kind.
    pub fn stage(&self) -> CachedStage {
        match self {
            Artifact::Graph(_) => CachedStage::Load,
            Artifact::Tune(_) => CachedStage::Tune,
            Artifact::Build(_) => CachedStage::Build,
        }
    }
}

/// Tuning inputs that flow into Tune/Build keys (from the
/// environment, not the spec).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TuneParams {
    pub trials: usize,
    pub seed: u64,
}

/// Key of the Load stage: the model file contents alone.
pub fn load_key(model_fingerprint: u64) -> StageKey {
    let mut h = StableHasher::new();
    h.write_str("load").write_u64(model_fingerprint);
    StageKey(h.finish())
}

/// Key of the Tune stage: model content + backend + base schedule +
/// target (measurements run on the target) + tuning budget/seed.
pub fn tune_key(model_fingerprint: u64, spec: &RunSpec, tune: TuneParams) -> StageKey {
    let mut h = StableHasher::new();
    h.write_str("tune")
        .write_u64(model_fingerprint)
        .write_str(&spec.backend)
        .write_str(spec.schedule.as_deref().unwrap_or(""))
        .write_str(&spec.target)
        .write_u64(tune.trials as u64)
        .write_u64(tune.seed);
    StageKey(h.finish())
}

/// Key of the Build stage: model content + backend + schedule + tuned
/// flag + feature set. Untuned builds are target-independent — that is
/// the dedup the paper's matrix sweeps exploit (1 model × 2 backends ×
/// 5 targets ⇒ 2 builds). Tuned builds consume a target-measured
/// schedule, so the tune key (which includes the target) folds in.
pub fn build_key(model_fingerprint: u64, spec: &RunSpec, tune: TuneParams) -> StageKey {
    let mut h = StableHasher::new();
    h.write_str("build")
        .write_u64(model_fingerprint)
        .write_str(&spec.backend)
        .write_str(spec.schedule.as_deref().unwrap_or(""))
        .write_bool(spec.tuned);
    for f in spec.features.names() {
        h.write_str(&f);
    }
    if spec.needs_tune() {
        h.write_u64(tune_key(model_fingerprint, spec, tune).0);
    }
    StageKey(h.finish())
}

/// Counters surfaced in `SessionTiming`, the report and `cache.json`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Artifacts served without execution (memory tier, env store, or
    /// shared across runs by the scheduler).
    pub hits: usize,
    pub misses: usize,
    pub inserts: usize,
    pub evictions: usize,
    /// Live entries in the memory tier.
    pub entries: usize,
    /// Subset of `hits` served by the environment store (a different
    /// process or session computed the artifact).
    pub disk_hits: usize,
    /// Env-store consultations that found nothing.
    pub disk_misses: usize,
    /// Env-store entries that failed key/hash verification and were
    /// recomputed (corruption or a stale format — a miss, not an
    /// error).
    pub verify_fails: usize,
    /// Subset of `hits` served by the remote store tier (another
    /// machine's serve daemon held the artifact).
    pub remote_hits: usize,
    /// Remote consultations that found nothing (including entries that
    /// failed client-side verification — skew is a miss).
    pub remote_misses: usize,
    /// Remote transport failures; the tier degrades to local-only
    /// after the first one, so this counts at most one per session.
    pub remote_errors: usize,
}

impl CacheStats {
    /// Counter delta since `earlier` (entries is a level, not a
    /// counter, so it is reported as-is).
    pub fn since(&self, earlier: &CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits - earlier.hits,
            misses: self.misses - earlier.misses,
            inserts: self.inserts - earlier.inserts,
            evictions: self.evictions - earlier.evictions,
            entries: self.entries,
            disk_hits: self.disk_hits - earlier.disk_hits,
            disk_misses: self.disk_misses - earlier.disk_misses,
            verify_fails: self.verify_fails - earlier.verify_fails,
            remote_hits: self.remote_hits - earlier.remote_hits,
            remote_misses: self.remote_misses - earlier.remote_misses,
            remote_errors: self.remote_errors - earlier.remote_errors,
        }
    }
}

struct Inner {
    map: HashMap<u64, Artifact>,
    /// LRU order, least-recent first. Touched on hit and insert.
    lru: VecDeque<u64>,
    stats: CacheStats,
    /// Entries a previous process recorded in this directory's
    /// `index.json`, validated at construction. `write_index` keeps
    /// them, so re-opening a session dir never silently drops history.
    persisted: Vec<(u64, CachedStage)>,
}

/// The tiered artifact cache owned by a `Session`.
pub struct ArtifactCache {
    enabled: bool,
    capacity: usize,
    disk_dir: Option<PathBuf>,
    store: Option<Arc<EnvStore>>,
    remote: Option<Arc<RemoteStore>>,
    inner: Mutex<Inner>,
}

pub const DEFAULT_CAPACITY: usize = 256;

impl ArtifactCache {
    pub fn new(capacity: usize, disk_dir: Option<PathBuf>) -> ArtifactCache {
        let persisted = disk_dir.as_deref().map(load_session_index).unwrap_or_default();
        ArtifactCache {
            enabled: true,
            capacity: capacity.max(1),
            disk_dir,
            store: None,
            remote: None,
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                lru: VecDeque::new(),
                stats: CacheStats::default(),
                persisted,
            }),
        }
    }

    /// Attach the environment-level store tier.
    pub fn with_store(mut self, store: Option<Arc<EnvStore>>) -> ArtifactCache {
        self.store = store;
        self
    }

    /// Attach the remote store tier (consulted after the local store
    /// misses; `transport::RemoteStore`).
    pub fn with_remote(mut self, remote: Option<Arc<RemoteStore>>) -> ArtifactCache {
        self.remote = remote;
        self
    }

    /// A cache that never stores or counts anything (`--no-cache`).
    pub fn disabled() -> ArtifactCache {
        ArtifactCache {
            enabled: false,
            capacity: 1,
            disk_dir: None,
            store: None,
            remote: None,
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                lru: VecDeque::new(),
                stats: CacheStats::default(),
                persisted: Vec::new(),
            }),
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    pub fn env_store(&self) -> Option<&Arc<EnvStore>> {
        self.store.as_ref()
    }

    pub fn remote_store(&self) -> Option<&Arc<RemoteStore>> {
        self.remote.as_ref()
    }

    /// Look up a stage artifact: memory tier, then the environment
    /// store, then the remote store. Counts a hit (plus `disk_hits` /
    /// `remote_hits` for the serving tier), a miss, a `verify_fails`
    /// for a corrupt store entry, or a `remote_errors` for the
    /// (single, degrading) remote transport failure.
    pub fn lookup(&self, key: StageKey, stage: CachedStage) -> Option<Artifact> {
        if !self.enabled {
            return None;
        }
        let clock = crate::util::metrics::clock();
        let mut span = crate::util::trace::span("cache", "lookup")
            .arg("stage", stage.name())
            .arg_with("key", || key.hex());
        {
            let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
            if let Some(a) = inner.map.get(&key.0).cloned() {
                inner.stats.hits += 1;
                touch(&mut inner.lru, key.0);
                span.note("outcome", "mem-hit");
                clock.observe("cache.mem_hit.us");
                return Some(a);
            }
        }
        // fall through to the env store (if attached), promoting hits
        // into the memory tier — the file is decoded at most once per
        // process
        let looked_up = self.store.as_ref().map(|s| s.load(key, stage));
        let mut store_corrupt = false;
        let mut store_missed = false;
        match looked_up {
            Some(StoreLookup::Hit(artifact)) => {
                let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
                inner.stats.hits += 1;
                inner.stats.disk_hits += 1;
                insert_mem(&mut inner, self.capacity, key, artifact.clone());
                span.note("outcome", "store-hit");
                clock.observe("cache.store_hit.us");
                return Some(artifact);
            }
            Some(StoreLookup::Corrupt) => store_corrupt = true,
            Some(StoreLookup::Miss) => store_missed = true,
            None => {}
        }
        // last tier: the remote store (if attached) — network faults
        // degrade it, they never fail the lookup
        let remote = self.remote.as_ref().map(|r| r.load(key, stage));
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if store_corrupt {
            inner.stats.verify_fails += 1;
        }
        if store_missed {
            inner.stats.disk_misses += 1;
        }
        match remote {
            Some(RemoteLookup::Hit(artifact)) => {
                inner.stats.hits += 1;
                inner.stats.remote_hits += 1;
                insert_mem(&mut inner, self.capacity, key, artifact.clone());
                drop(inner);
                // promote into the local store: the next process on
                // this machine must not cross the network again. An
                // injected promotion fault skips the save — the
                // artifact is still served, only locality is lost
                let promote_fault =
                    crate::util::faults::fire("cache.promote").is_some();
                if let Some(store) = self.store.as_ref().filter(|_| !promote_fault) {
                    if let Err(e) = store.save(key, &artifact) {
                        crate::log_warn!(
                            "env cache: remote entry {} not saved locally: {e}",
                            key.hex()
                        );
                    }
                }
                span.note("outcome", "remote-hit");
                clock.observe("cache.remote_hit.us");
                return Some(artifact);
            }
            Some(RemoteLookup::Miss) => inner.stats.remote_misses += 1,
            Some(RemoteLookup::Error) => inner.stats.remote_errors += 1,
            Some(RemoteLookup::Off) | None => {}
        }
        inner.stats.misses += 1;
        span.note("outcome", "miss");
        clock.observe("cache.miss.us");
        None
    }

    /// Insert a freshly computed artifact, evicting the least-recently
    /// used memory entry when over capacity and persisting to the env
    /// store. `label` names the producing run in the on-disk index.
    pub fn insert(&self, key: StageKey, artifact: Artifact, label: &str) {
        if !self.enabled {
            return;
        }
        self.persist_meta(key, &artifact, label);
        if let Some(store) = &self.store {
            // best-effort: the memory tier is authoritative
            if let Err(e) = store.save(key, &artifact) {
                crate::log_warn!("env cache: entry {} not saved: {e}", key.hex());
            }
        }
        if let Some(remote) = &self.remote {
            // best-effort too: degradation is handled inside the tier
            remote.save(key, &artifact);
        }
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if !inner.map.contains_key(&key.0) {
            insert_mem(&mut inner, self.capacity, key, artifact);
            inner.stats.inserts += 1;
        }
        inner.stats.entries = inner.map.len();
    }

    /// Does the memory tier hold `key`? Pure probe — no hit/miss
    /// counting, no LRU touch. The sharded dispatcher uses it to tell
    /// memory-tier hits from env-store hits when reconstructing the
    /// serial-equivalent counters (a warm same-session rerun is served
    /// from memory in a serial pass, so it must not count disk hits).
    pub fn contains_mem(&self, key: StageKey) -> bool {
        self.enabled && self.inner.lock().unwrap_or_else(|e| e.into_inner()).map.contains_key(&key.0)
    }

    /// Count `n` extra hits for consumers that shared one deduplicated
    /// stage execution (the scheduler merges identical stage tasks, so
    /// only one of them performs the `lookup`).
    pub fn note_shared_hits(&self, n: usize) {
        if !self.enabled || n == 0 {
            return;
        }
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).stats.hits += n;
    }

    pub fn stats(&self) -> CacheStats {
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        inner.stats.entries = inner.map.len();
        inner.stats
    }

    /// Disk tier: write small reproducibility artifacts for an entry.
    /// Failures are non-fatal (the memory tier is authoritative).
    fn persist_meta(&self, key: StageKey, artifact: &Artifact, label: &str) {
        let Some(root) = &self.disk_dir else { return };
        let dir = root.join(artifact.stage().name()).join(key.hex());
        if std::fs::create_dir_all(&dir).is_err() {
            return;
        }
        let _ = std::fs::write(dir.join("producer.txt"), label);
        match artifact {
            Artifact::Graph(g) => {
                let meta = Json::obj(vec![
                    ("model", Json::Str(g.name.clone())),
                    ("params", Json::Num(g.param_count() as f64)),
                    ("weight_bytes", Json::Num(g.weight_bytes() as f64)),
                    ("macs", Json::Num(g.macs() as f64)),
                    ("content_hash", Json::Str(format!("{:016x}", g.content_hash()))),
                ]);
                let _ = std::fs::write(dir.join("graph.json"), meta.to_string());
            }
            Artifact::Tune(t) => {
                let meta = Json::obj(vec![
                    ("schedule", Json::Str(t.schedule.label())),
                    ("tile_oc", Json::Num(t.schedule.knobs.tile_oc as f64)),
                    ("tile_oh", Json::Num(t.schedule.knobs.tile_oh as f64)),
                    ("unroll", Json::Num(t.schedule.knobs.unroll as f64)),
                    ("improvement", Json::Num(t.improvement)),
                ]);
                let _ = std::fs::write(dir.join("tune.json"), meta.to_string());
            }
            Artifact::Build(b) => {
                let _ = std::fs::write(
                    dir.join("program.tir"),
                    crate::tinyir::listing::render(&b.program),
                );
                let meta = Json::obj(vec![
                    ("rom_total", Json::Num(b.metrics.rom_total() as f64)),
                    ("ram_total", Json::Num(b.metrics.ram_total() as f64)),
                    ("setup_instructions", Json::Num(b.metrics.setup_instructions as f64)),
                ]);
                let _ = std::fs::write(dir.join("metrics.json"), meta.to_string());
            }
        }
    }

    /// Write the disk index: counters plus the live key set, unioned
    /// with the validated entries of any pre-existing index. Called at
    /// the end of every `run_matrix`.
    pub fn write_index(&self) -> Result<()> {
        let Some(root) = &self.disk_dir else {
            return Ok(());
        };
        let stats = self.stats();
        let inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let mut entries: Vec<Json> = Vec::new();
        for &(k, stage) in &inner.persisted {
            if !inner.map.contains_key(&k) {
                entries.push(Json::obj(vec![
                    ("key", Json::Str(StageKey(k).hex())),
                    ("stage", Json::Str(stage.name().into())),
                ]));
            }
        }
        for (&k, a) in &inner.map {
            entries.push(Json::obj(vec![
                ("key", Json::Str(StageKey(k).hex())),
                ("stage", Json::Str(a.stage().name().into())),
            ]));
        }
        drop(inner);
        std::fs::create_dir_all(root)?;
        let doc = Json::obj(vec![
            ("hits", Json::Num(stats.hits as f64)),
            ("misses", Json::Num(stats.misses as f64)),
            ("inserts", Json::Num(stats.inserts as f64)),
            ("evictions", Json::Num(stats.evictions as f64)),
            ("entries", Json::Num(entries.len() as f64)),
            ("disk_hits", Json::Num(stats.disk_hits as f64)),
            ("disk_misses", Json::Num(stats.disk_misses as f64)),
            ("verify_fails", Json::Num(stats.verify_fails as f64)),
            ("remote_hits", Json::Num(stats.remote_hits as f64)),
            ("remote_misses", Json::Num(stats.remote_misses as f64)),
            ("remote_errors", Json::Num(stats.remote_errors as f64)),
            ("artifacts", Json::Arr(entries)),
        ]);
        std::fs::write(root.join("index.json"), doc.to_string())?;
        Ok(())
    }
}

/// Memory-tier insert with LRU eviction; shared by fresh inserts and
/// store-hit promotion (which must not count as an `insert`).
fn insert_mem(inner: &mut Inner, capacity: usize, key: StageKey, artifact: Artifact) {
    if inner.map.insert(key.0, artifact).is_none() {
        touch(&mut inner.lru, key.0);
        while inner.map.len() > capacity {
            if let Some(old) = inner.lru.pop_front() {
                inner.map.remove(&old);
                inner.stats.evictions += 1;
            } else {
                break;
            }
        }
    }
    inner.stats.entries = inner.map.len();
}

/// Load + validate a previously written session `index.json`: keep
/// entries whose stage is known, whose key parses, and whose artifact
/// directory still exists; drop the rest. A missing or malformed
/// index is an empty history, never an error.
fn load_session_index(root: &std::path::Path) -> Vec<(u64, CachedStage)> {
    let Ok(doc) = Json::parse_file(&root.join("index.json")) else {
        return Vec::new();
    };
    let mut out = Vec::new();
    let artifacts = doc.get("artifacts").and_then(Json::as_arr);
    for e in artifacts.unwrap_or(&[]) {
        let key = e.get("key").and_then(Json::as_str);
        let Some(key) = key.and_then(|k| u64::from_str_radix(k, 16).ok()) else {
            continue;
        };
        let stage = e.get("stage").and_then(Json::as_str);
        let Some(stage) = stage.and_then(CachedStage::from_name) else {
            continue;
        };
        if root.join(stage.name()).join(StageKey(key).hex()).is_dir() {
            out.push((key, stage));
        }
    }
    out
}

fn touch(lru: &mut VecDeque<u64>, key: u64) {
    if let Some(pos) = lru.iter().position(|&k| k == key) {
        lru.remove(pos);
    }
    lru.push_back(key);
}

// ============================================================ hot cache --

/// Counters of a [`HotCache`], all monotonic except `entries`/`bytes`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HotCacheStats {
    pub entries: usize,
    pub bytes: u64,
    pub budget: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

struct HotEntry {
    bytes: Arc<Vec<u8>>,
    /// Stamp of this entry's newest position in `order`; older deque
    /// positions for the same key are skipped at eviction time.
    stamp: u64,
}

/// Bounded in-memory cache of raw artifact entries: a bytes budget,
/// LRU eviction and hit/miss counters, keyed by `(stage, key)`. The
/// serve daemon mounts one in front of its `EnvStore` so repeated
/// `OP_GET`s of hot artifacts are answered from memory without
/// touching disk — entries are content-addressed, so a cached value
/// can go stale only in the sense of "also evicted from disk", never
/// in the sense of "wrong bytes".
///
/// Recency is tracked with a stamp deque instead of a re-ordered list:
/// every touch pushes `(key, stamp)` and bumps the entry's stamp;
/// eviction pops from the front and skips records whose stamp no
/// longer matches (a later touch superseded them). Touches are O(1),
/// eviction is amortized O(1).
pub struct HotCache {
    budget: u64,
    used: u64,
    map: HashMap<(CachedStage, StageKey), HotEntry>,
    order: VecDeque<((CachedStage, StageKey), u64)>,
    tick: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl HotCache {
    pub fn new(budget_bytes: u64) -> HotCache {
        HotCache {
            budget: budget_bytes,
            used: 0,
            map: HashMap::new(),
            order: VecDeque::new(),
            tick: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    /// Look up an entry, counting a hit or miss and refreshing recency.
    pub fn get(&mut self, stage: CachedStage, key: StageKey) -> Option<Arc<Vec<u8>>> {
        let id = (stage, key);
        match self.map.get_mut(&id) {
            Some(e) => {
                self.tick += 1;
                e.stamp = self.tick;
                self.order.push_back((id, self.tick));
                self.hits += 1;
                Some(Arc::clone(&e.bytes))
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert (or refresh) an entry, then evict least-recently-used
    /// entries until the budget holds. Entries larger than the whole
    /// budget are not cached at all.
    pub fn put(&mut self, stage: CachedStage, key: StageKey, bytes: Arc<Vec<u8>>) {
        let len = bytes.len() as u64;
        if len > self.budget {
            return;
        }
        let id = (stage, key);
        self.tick += 1;
        if let Some(old) = self.map.insert(id, HotEntry { bytes, stamp: self.tick })
        {
            self.used -= old.bytes.len() as u64;
        }
        self.used += len;
        self.order.push_back((id, self.tick));
        while self.used > self.budget {
            let Some((victim, stamp)) = self.order.pop_front() else {
                break;
            };
            if self.map.get(&victim).is_some_and(|e| e.stamp == stamp) {
                let e = self.map.remove(&victim).expect("checked just above");
                self.used -= e.bytes.len() as u64;
                self.evictions += 1;
            }
        }
    }

    pub fn stats(&self) -> HotCacheStats {
        HotCacheStats {
            entries: self.map.len(),
            bytes: self.used,
            budget: self.budget,
            hits: self.hits,
            misses: self.misses,
            evictions: self.evictions,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::Features;
    use crate::graph::model::testutil::tiny_conv;

    fn spec() -> RunSpec {
        RunSpec {
            model: "aww".into(),
            backend: "tvmaot".into(),
            target: "etiss".into(),
            schedule: Some("default-nchw".into()),
            tuned: false,
            features: Features::default(),
        }
    }

    const TP: TuneParams = TuneParams { trials: 600, seed: 7 };

    #[test]
    fn same_spec_same_key() {
        assert_eq!(build_key(1, &spec(), TP), build_key(1, &spec(), TP));
        assert_eq!(tune_key(1, &spec(), TP), tune_key(1, &spec(), TP));
        assert_eq!(load_key(1), load_key(1));
    }

    #[test]
    fn any_field_change_changes_build_key() {
        let base = build_key(1, &spec(), TP);
        assert_ne!(build_key(2, &spec(), TP), base, "model content");
        let mut s = spec();
        s.backend = "tflmi".into();
        assert_ne!(build_key(1, &s, TP), base, "backend");
        let mut s = spec();
        s.schedule = Some("arm-nhwc".into());
        assert_ne!(build_key(1, &s, TP), base, "schedule");
        let mut s = spec();
        s.schedule = None;
        assert_ne!(build_key(1, &s, TP), base, "schedule presence");
        let mut s = spec();
        s.tuned = true;
        assert_ne!(build_key(1, &s, TP), base, "tuned flag");
        let mut s = spec();
        s.features = Features::parse(&["validate".into()]).unwrap();
        assert_ne!(build_key(1, &s, TP), base, "features");
    }

    #[test]
    fn untuned_build_key_ignores_target_tuned_does_not() {
        let mut a = spec();
        let mut b = spec();
        a.target = "esp32c3".into();
        b.target = "stm32f7".into();
        assert_eq!(build_key(1, &a, TP), build_key(1, &b, TP));
        a.tuned = true;
        b.tuned = true;
        assert_ne!(build_key(1, &a, TP), build_key(1, &b, TP));
    }

    #[test]
    fn tune_budget_changes_tune_and_tuned_build_keys() {
        let mut s = spec();
        s.tuned = true;
        let more = TuneParams { trials: 1200, seed: 7 };
        assert_ne!(tune_key(1, &s, TP), tune_key(1, &s, more));
        assert_ne!(build_key(1, &s, TP), build_key(1, &s, more));
        // untuned builds never see the budget
        let u = spec();
        assert_eq!(build_key(1, &u, TP), build_key(1, &u, more));
    }

    #[test]
    fn hit_miss_accounting() {
        let cache = ArtifactCache::new(8, None);
        let key = load_key(42);
        assert!(cache.lookup(key, CachedStage::Load).is_none());
        cache.insert(key, Artifact::Graph(Arc::new(tiny_conv())), "t");
        assert!(cache.lookup(key, CachedStage::Load).is_some());
        assert!(cache.lookup(load_key(43), CachedStage::Load).is_none());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.inserts, s.entries), (1, 2, 1, 1));
        // no store attached: the disk counters stay zero
        assert_eq!((s.disk_hits, s.disk_misses, s.verify_fails), (0, 0, 0));
    }

    #[test]
    fn lru_eviction_over_capacity() {
        let cache = ArtifactCache::new(2, None);
        let g = Arc::new(tiny_conv());
        for fp in 0..3u64 {
            cache.insert(load_key(fp), Artifact::Graph(g.clone()), "t");
        }
        let s = cache.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.entries, 2);
        // key 0 was least recently used => evicted
        assert!(cache.lookup(load_key(0), CachedStage::Load).is_none());
        assert!(cache.lookup(load_key(2), CachedStage::Load).is_some());
    }

    #[test]
    fn disabled_cache_stores_and_counts_nothing() {
        let cache = ArtifactCache::disabled();
        let key = load_key(1);
        assert!(cache.lookup(key, CachedStage::Load).is_none());
        cache.insert(key, Artifact::Graph(Arc::new(tiny_conv())), "t");
        assert!(cache.lookup(key, CachedStage::Load).is_none());
        assert_eq!(cache.stats(), CacheStats::default());
    }

    #[test]
    fn disk_tier_persists_index_and_artifacts() {
        let dir = std::env::temp_dir().join("mlonmcu_cache_disk_test");
        let _ = std::fs::remove_dir_all(&dir);
        let cache = ArtifactCache::new(8, Some(dir.clone()));
        let key = load_key(7);
        cache.insert(key, Artifact::Graph(Arc::new(tiny_conv())), "aww/tvmaot");
        cache.write_index().unwrap();
        assert!(dir.join("load").join(key.hex()).join("graph.json").is_file());
        let idx = Json::parse_file(&dir.join("index.json")).unwrap();
        assert_eq!(idx.get("inserts").unwrap().as_i64(), Some(1));
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn preexisting_index_is_loaded_and_preserved() {
        let dir = std::env::temp_dir().join("mlonmcu_cache_index_reload");
        let _ = std::fs::remove_dir_all(&dir);
        let a = load_key(7);
        {
            let cache = ArtifactCache::new(8, Some(dir.clone()));
            cache.insert(a, Artifact::Graph(Arc::new(tiny_conv())), "first");
            cache.write_index().unwrap();
        }
        // a fresh cache on the same directory must read the index back
        // (the old behaviour silently started empty and truncated it)
        let cache = ArtifactCache::new(8, Some(dir.clone()));
        let b = load_key(8);
        cache.insert(b, Artifact::Graph(Arc::new(tiny_conv())), "second");
        cache.write_index().unwrap();
        let idx = Json::parse_file(&dir.join("index.json")).unwrap();
        let keys: Vec<String> = idx
            .get("artifacts")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|e| e.get("key").unwrap().as_str().unwrap().to_string())
            .collect();
        assert!(keys.contains(&a.hex()), "prior entry kept: {keys:?}");
        assert!(keys.contains(&b.hex()), "new entry present: {keys:?}");
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn malformed_or_stale_index_entries_are_dropped() {
        let dir = std::env::temp_dir().join("mlonmcu_cache_index_bad");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        // entry with no artifact dir + garbage rows: all dropped
        std::fs::write(
            dir.join("index.json"),
            r#"{"artifacts":[{"key":"00000000000000ff","stage":"load"},
                {"key":"zzz","stage":"load"},{"key":"01","stage":"wat"}]}"#,
        )
        .unwrap();
        let cache = ArtifactCache::new(8, Some(dir.clone()));
        cache.write_index().unwrap();
        let idx = Json::parse_file(&dir.join("index.json")).unwrap();
        assert_eq!(idx.get("artifacts").unwrap().as_arr().unwrap().len(), 0);
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn remote_tier_fallthrough_counts_remote_hits_and_promotes() {
        use crate::session::transport::{RemoteConfig, RemoteStore, Server};
        let dir = std::env::temp_dir().join("mlonmcu_cache_remote_tier");
        let _ = std::fs::remove_dir_all(&dir);
        let served =
            Arc::new(EnvStore::open(&dir.join("served"), u64::MAX).unwrap());
        let server = Server::spawn(Arc::clone(&served), "127.0.0.1:0").unwrap();
        let remote = Arc::new(RemoteStore::new(RemoteConfig {
            addr: server.addr.to_string(),
            timeout_ms: 2000,
            retries: 1,
            backoff_ms: 10,
            grace_ms: 100,
        }));
        let local =
            Arc::new(EnvStore::open(&dir.join("local"), u64::MAX).unwrap());
        let key = load_key(21);
        served
            .save(key, &Artifact::Graph(Arc::new(tiny_conv())))
            .unwrap();

        // mem miss -> local store miss -> remote hit, promoted locally
        let cache = ArtifactCache::new(8, None)
            .with_store(Some(Arc::clone(&local)))
            .with_remote(Some(Arc::clone(&remote)));
        assert!(cache.lookup(key, CachedStage::Load).is_some());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 0));
        assert_eq!((s.remote_hits, s.remote_misses), (1, 0));
        assert_eq!((s.disk_hits, s.disk_misses), (0, 1));
        assert_eq!(local.stats().entries, 1, "remote hit promoted to local");

        // unknown key: counted as both a disk and a remote miss
        assert!(cache.lookup(load_key(22), CachedStage::Load).is_none());
        let s = cache.stats();
        assert_eq!((s.remote_misses, s.misses), (1, 1));

        // inserts replicate to the served store
        cache.insert(
            load_key(23),
            Artifact::Graph(Arc::new(tiny_conv())),
            "t",
        );
        assert_eq!(served.stats().entries, 2);

        // server death: one counted error, then the tier is off
        server.shutdown();
        assert!(cache.lookup(load_key(24), CachedStage::Load).is_none());
        assert!(cache.lookup(load_key(25), CachedStage::Load).is_none());
        let s = cache.stats();
        assert_eq!(s.remote_errors, 1, "degrades after the first failure");
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn store_tier_fallthrough_counts_disk_hits() {
        let dir = std::env::temp_dir().join("mlonmcu_cache_store_tier");
        let _ = std::fs::remove_dir_all(&dir);
        let store =
            Arc::new(EnvStore::open(&dir.join("cache"), u64::MAX).unwrap());
        let key = load_key(11);
        // first cache computes + persists
        let a = ArtifactCache::new(8, None).with_store(Some(store.clone()));
        assert!(a.lookup(key, CachedStage::Load).is_none());
        a.insert(key, Artifact::Graph(Arc::new(tiny_conv())), "t");
        assert_eq!(a.stats().disk_misses, 1);
        // second cache (fresh memory tier) is served by the store
        let b = ArtifactCache::new(8, None).with_store(Some(store));
        assert!(b.lookup(key, CachedStage::Load).is_some());
        let s = b.stats();
        assert_eq!((s.hits, s.disk_hits, s.misses), (1, 1, 0));
        // promoted into memory: second lookup does not touch the disk
        assert!(b.lookup(key, CachedStage::Load).is_some());
        assert_eq!(b.stats().disk_hits, 1);
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn hot_cache_counts_hits_and_misses() {
        let mut hot = HotCache::new(1024);
        let (k1, k2) = (StageKey(1), StageKey(2));
        assert!(hot.get(CachedStage::Load, k1).is_none());
        hot.put(CachedStage::Load, k1, Arc::new(vec![7u8; 100]));
        let got = hot.get(CachedStage::Load, k1).unwrap();
        assert_eq!(got.len(), 100);
        // same key under a different stage is a distinct entry
        assert!(hot.get(CachedStage::Build, k1).is_none());
        assert!(hot.get(CachedStage::Load, k2).is_none());
        let s = hot.stats();
        assert_eq!((s.hits, s.misses), (1, 3));
        assert_eq!((s.entries, s.bytes), (1, 100));
    }

    #[test]
    fn hot_cache_evicts_least_recently_used_within_budget() {
        let mut hot = HotCache::new(250);
        for i in 0..3u64 {
            hot.put(CachedStage::Load, StageKey(i), Arc::new(vec![0u8; 100]));
        }
        // 300 bytes > 250 budget: key 0 (oldest) is gone, 1 and 2 remain
        let s = hot.stats();
        assert_eq!((s.entries, s.bytes, s.evictions), (2, 200, 1));
        assert!(hot.get(CachedStage::Load, StageKey(0)).is_none());
        assert!(hot.get(CachedStage::Load, StageKey(1)).is_some());
        // touch 1 so 2 becomes the LRU victim of the next insert
        hot.put(CachedStage::Load, StageKey(3), Arc::new(vec![0u8; 100]));
        assert!(hot.get(CachedStage::Load, StageKey(2)).is_none());
        assert!(hot.get(CachedStage::Load, StageKey(1)).is_some());
        assert!(hot.get(CachedStage::Load, StageKey(3)).is_some());
    }

    #[test]
    fn hot_cache_refuses_oversized_and_replaces_in_place() {
        let mut hot = HotCache::new(100);
        hot.put(CachedStage::Tune, StageKey(9), Arc::new(vec![0u8; 101]));
        assert_eq!(hot.stats().entries, 0, "over-budget entry not cached");
        hot.put(CachedStage::Tune, StageKey(9), Arc::new(vec![0u8; 40]));
        hot.put(CachedStage::Tune, StageKey(9), Arc::new(vec![0u8; 60]));
        let s = hot.stats();
        assert_eq!((s.entries, s.bytes, s.evictions), (1, 60, 0));
        assert_eq!(hot.get(CachedStage::Tune, StageKey(9)).unwrap().len(), 60);
    }
}
