//! Environment-level artifact store: the persistent disk tier shared
//! by every session (and every CLI invocation) of one environment.
//!
//! Layout under `$ENV/cache/` (configurable via `paths.cache` /
//! `--cache-dir`):
//!
//! ```text
//! cache/
//!   index.json          keys, stages, sizes, LRU sequence numbers
//!   .lock               transient advisory lock (held during writes)
//!   load/<key>.bin      serialized artifacts (persist.rs format)
//!   tune/<key>.bin
//!   build/<key>.bin
//! ```
//!
//! Properties:
//! * **Verified loads** — every entry is decoded through
//!   `persist::decode`, which re-checks the stored key and the payload
//!   hash; corrupt or stale-format entries are deleted and reported as
//!   misses, never errors.
//! * **Budgeted** — `cache.budget_mb` (or `--cache-budget`) bounds the
//!   total entry bytes; inserts evict least-recently-used entries
//!   until the store fits.
//! * **Concurrent-safe** — index read-modify-write cycles run under a
//!   lock file (atomic `create_new`), and both entries and the index
//!   are written tmp-then-rename, so two CLI processes sharing one
//!   environment cannot corrupt each other. Entry files are
//!   content-addressed: racing writers of the same key write identical
//!   bytes.

use std::collections::HashMap;
use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::data::Json;
use crate::session::cache::{Artifact, CachedStage, StageKey};
use crate::session::persist;

/// Default size budget when neither config nor CLI specify one.
pub const DEFAULT_BUDGET_MB: u64 = 512;

const INDEX_VERSION: i64 = 1;
const ALL_STAGES: [CachedStage; 3] =
    [CachedStage::Load, CachedStage::Tune, CachedStage::Build];

/// Outcome of a store lookup. `Corrupt` means an entry existed but
/// failed key/hash verification and was deleted — callers recompute.
pub enum StoreLookup {
    Hit(Artifact),
    Miss,
    Corrupt,
}

/// Store-level counters and levels (`cache stats`, tests).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StoreStats {
    pub entries: usize,
    pub total_bytes: u64,
    /// Entries evicted by the size budget (this process).
    pub evictions: usize,
    pub loads: usize,
    pub tunes: usize,
    pub builds: usize,
}

#[derive(Debug, Clone, Copy)]
struct Entry {
    stage: CachedStage,
    bytes: u64,
    /// LRU clock: higher = more recently used.
    seq: u64,
}

struct Index {
    seq: u64,
    entries: HashMap<u64, Entry>,
    evictions: usize,
}

/// The shared environment-level artifact store.
pub struct EnvStore {
    root: PathBuf,
    budget_bytes: u64,
    /// Age after which a lock whose owner cannot be probed is broken
    /// (`store.lock_stale_ms`; dead-pid locks always break instantly).
    lock_stale: Duration,
    inner: Mutex<Index>,
    /// Read operations (`load` + `load_raw`) served by this handle —
    /// the serve daemon's proof that its hot-path cache kept a warm
    /// workload off the disk tier.
    reads: AtomicU64,
}

/// Default mtime fallback for breaking locks with unprobeable owners.
pub const DEFAULT_LOCK_STALE_MS: u64 = 30_000;

/// Result of a full store verification pass ([`EnvStore::verify`]).
#[derive(Debug, Default)]
pub struct VerifyReport {
    /// Entries that decoded cleanly (key + payload hash re-checked).
    pub ok: usize,
    /// Index rows whose file is gone (self-heal as misses — not
    /// corruption).
    pub missing: usize,
    /// Entries that failed verification: `"<key> (<stage>): <error>"`.
    pub corrupt: Vec<String>,
}

impl VerifyReport {
    pub fn clean(&self) -> bool {
        self.corrupt.is_empty()
    }
}

impl EnvStore {
    /// Open (creating if needed) the store at `root`. The persisted
    /// index is loaded and validated: entries whose files are missing
    /// or mis-sized are dropped, and files on disk that the index lost
    /// (e.g. a crashed writer) are adopted as oldest.
    pub fn open(root: &Path, budget_bytes: u64) -> Result<EnvStore> {
        EnvStore::open_with(root, budget_bytes, DEFAULT_LOCK_STALE_MS)
    }

    /// `open` with an explicit stale-lock mtime fallback
    /// (`store.lock_stale_ms`) — tests use a few hundred ms so the
    /// unprobeable-owner path runs without a 30 s sleep.
    pub fn open_with(
        root: &Path,
        budget_bytes: u64,
        lock_stale_ms: u64,
    ) -> Result<EnvStore> {
        fs::create_dir_all(root)
            .with_context(|| format!("creating cache dir {}", root.display()))?;
        let lock_stale = Duration::from_millis(lock_stale_ms.max(1));
        let _lock = FileLock::acquire(root, lock_stale)?;
        let index = read_index(root, true);
        Ok(EnvStore {
            root: root.to_path_buf(),
            budget_bytes: budget_bytes.max(1),
            lock_stale,
            inner: Mutex::new(index),
            reads: AtomicU64::new(0),
        })
    }

    /// Poison-tolerant index lock: a thread that panicked mid-update
    /// (injected fault, backend bug) must degrade to possibly-stale
    /// bookkeeping, never wedge every later store call.
    fn lock_index(&self) -> std::sync::MutexGuard<'_, Index> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn budget_bytes(&self) -> u64 {
        self.budget_bytes
    }

    fn entry_path(&self, stage: CachedStage, key: StageKey) -> PathBuf {
        entry_path(&self.root, stage, key)
    }

    /// Look up `key`, expecting a `stage` artifact. Decoding verifies
    /// the stored key and payload hash; any failure deletes the entry
    /// and returns `Corrupt` so the caller recomputes.
    pub fn load(&self, key: StageKey, stage: CachedStage) -> StoreLookup {
        use crate::util::faults::{self, FaultKind};
        self.reads.fetch_add(1, Ordering::Relaxed);
        let clock = crate::util::metrics::clock();
        let mut span = crate::util::trace::span("store", "load")
            .arg("stage", stage.name())
            .arg_with("key", || key.hex());
        let fault = faults::fire("store.load");
        if fault == Some(FaultKind::Error) {
            // injected read error: degrade to a plain miss, recompute
            span.note("outcome", "miss");
            return StoreLookup::Miss;
        }
        let path = self.entry_path(stage, key);
        let mut bytes = match fs::read(&path) {
            Ok(b) => b,
            Err(_) => {
                span.note("outcome", "miss");
                return StoreLookup::Miss;
            }
        };
        if fault == Some(FaultKind::BitFlip) {
            faults::flip_byte(&mut bytes);
        }
        match persist::decode(&bytes, key) {
            Ok(artifact) => {
                let mut ix = self.lock_index();
                ix.seq += 1;
                let seq = ix.seq;
                ix.entries
                    .entry(key.0)
                    .or_insert(Entry { stage, bytes: bytes.len() as u64, seq })
                    .seq = seq;
                span.note("outcome", "hit");
                clock.observe("store.load.us");
                crate::util::metrics::observe(
                    "store.load.bytes",
                    bytes.len() as u64,
                );
                StoreLookup::Hit(artifact)
            }
            Err(e) => {
                crate::log_warn!(
                    "env cache: entry {} failed verification ({e}); removing",
                    key.hex()
                );
                // drop the file and the memory entry only: the stale
                // index row self-heals (open-time validation drops
                // rows whose files are gone, and a trusted row reads
                // as a plain miss) without taking the file lock here,
                // which would invert the save() lock order
                let _ = fs::remove_file(&path);
                self.lock_index().entries.remove(&key.0);
                span.note("outcome", "corrupt");
                StoreLookup::Corrupt
            }
        }
    }

    /// Persist an artifact. Best-effort: errors are returned for
    /// logging but the memory tier stays authoritative.
    pub fn save(&self, key: StageKey, artifact: &Artifact) -> Result<()> {
        let stage = artifact.stage();
        let bytes = persist::encode(key, artifact);
        self.save_bytes(key, stage, &bytes)
    }

    /// Persist an already-encoded entry received from a remote peer.
    /// The bytes are decoded first — re-checking magic, version, key
    /// and payload hash — so a malicious or mismatched peer can never
    /// poison the local store with bytes `load` would later reject.
    pub fn save_raw(
        &self,
        key: StageKey,
        stage: CachedStage,
        bytes: &[u8],
    ) -> Result<()> {
        let artifact = persist::decode(bytes, key)?;
        anyhow::ensure!(
            artifact.stage() == stage,
            "entry {} decodes as {} but was sent as {}",
            key.hex(),
            artifact.stage().name(),
            stage.name()
        );
        self.save_bytes(key, stage, bytes)
    }

    /// Read an entry's raw encoded bytes without decoding, for serving
    /// over the wire (the remote *client* verifies via
    /// `persist::decode`; the server stays a dumb byte pipe). Bumps
    /// the LRU clock like `load`. Reads the file directly, not the
    /// index, so entries written by other processes are served too.
    pub fn load_raw(&self, key: StageKey, stage: CachedStage) -> Option<Vec<u8>> {
        self.reads.fetch_add(1, Ordering::Relaxed);
        let bytes = fs::read(self.entry_path(stage, key)).ok()?;
        let mut ix = self.lock_index();
        ix.seq += 1;
        let seq = ix.seq;
        ix.entries
            .entry(key.0)
            .or_insert(Entry { stage, bytes: bytes.len() as u64, seq })
            .seq = seq;
        Some(bytes)
    }

    fn save_bytes(
        &self,
        key: StageKey,
        stage: CachedStage,
        bytes: &[u8],
    ) -> Result<()> {
        use crate::util::faults::{self, FaultKind};
        let clock = crate::util::metrics::clock();
        let _span = crate::util::trace::span("store", "save")
            .arg("stage", stage.name())
            .arg_with("key", || key.hex());
        let fault = faults::fire("store.save");
        if fault == Some(FaultKind::Error) {
            // ENOSPC-style: callers already treat save errors as
            // warnings — the memory tier stays authoritative
            anyhow::bail!("injected fault at store.save for {}", key.hex());
        }
        let mut short;
        let bytes = if fault == Some(FaultKind::Short) {
            // torn write: the truncated entry fails hash verification
            // on its next load and is deleted + recomputed
            short = bytes.to_vec();
            faults::truncate_half(&mut short);
            &short[..]
        } else {
            bytes
        };
        let path = self.entry_path(stage, key);
        let dir = path.parent().context("entry path has no parent")?;
        fs::create_dir_all(dir)?;
        let _lock = FileLock::acquire(&self.root, self.lock_stale)?;
        write_atomic(&path, bytes)?;
        let mut ix = self.lock_index();
        // merge entries another process added since we last looked
        merge_disk_index(&self.root, &mut ix);
        ix.seq += 1;
        let seq = ix.seq;
        let entry = Entry { stage, bytes: bytes.len() as u64, seq };
        ix.entries.insert(key.0, entry);
        self.evict_until_within_budget(&mut ix, Some(key.0));
        let result = self.write_index_locked(&mut ix);
        if result.is_ok() {
            clock.observe("store.save.us");
            crate::util::metrics::observe(
                "store.save.bytes",
                bytes.len() as u64,
            );
        }
        result
    }

    /// Evict least-recently-used entries until the budget fits,
    /// never touching `keep` (a just-inserted artifact larger than
    /// the whole budget would otherwise thrash forever). Returns
    /// (entries evicted, bytes freed).
    fn evict_until_within_budget(
        &self,
        ix: &mut Index,
        keep: Option<u64>,
    ) -> (usize, u64) {
        let mut evicted = 0usize;
        let mut freed = 0u64;
        loop {
            let total: u64 = ix.entries.values().map(|e| e.bytes).sum();
            if total <= self.budget_bytes {
                break;
            }
            let victim = ix
                .entries
                .iter()
                .filter(|(&k, _)| Some(k) != keep)
                .min_by_key(|(_, e)| e.seq)
                .map(|(&k, e)| (k, *e));
            let Some((k, e)) = victim else { break };
            let _ = fs::remove_file(self.entry_path(e.stage, StageKey(k)));
            ix.entries.remove(&k);
            ix.evictions += 1;
            evicted += 1;
            freed += e.bytes;
        }
        (evicted, freed)
    }

    /// Run the size budget now (CLI `cache gc`). Returns (entries
    /// evicted, bytes freed).
    pub fn gc(&self) -> Result<(usize, u64)> {
        let _lock = FileLock::acquire(&self.root, self.lock_stale)?;
        let mut ix = self.lock_index();
        merge_disk_index(&self.root, &mut ix);
        // no key to protect: GC may empty the store entirely
        let (evicted, freed) = self.evict_until_within_budget(&mut ix, None);
        self.write_index_locked(&mut ix)?;
        Ok((evicted, freed))
    }

    /// Delete every entry and the index (CLI `cache clear`).
    pub fn clear(&self) -> Result<()> {
        let _lock = FileLock::acquire(&self.root, self.lock_stale)?;
        let mut ix = self.lock_index();
        for stage in ALL_STAGES {
            let _ = fs::remove_dir_all(self.root.join(stage.name()));
        }
        let _ = fs::remove_file(self.root.join("index.json"));
        ix.entries.clear();
        ix.seq = 0;
        Ok(())
    }

    /// Decode every indexed entry (key + payload hash re-checked) and
    /// report the damage. Read-only: corrupt entries are listed, not
    /// deleted — the next `load` of that key deletes + recomputes.
    /// Used by `cache verify` and the chaos-soak harness, which
    /// asserts `clean()` after every faulted session.
    pub fn verify(&self) -> VerifyReport {
        let entries: Vec<(u64, CachedStage)> = self
            .lock_index()
            .entries
            .iter()
            .map(|(&k, e)| (k, e.stage))
            .collect();
        let mut rep = VerifyReport::default();
        for (k, stage) in entries {
            let key = StageKey(k);
            match fs::read(self.entry_path(stage, key)) {
                Err(_) => rep.missing += 1,
                Ok(bytes) => match persist::decode(&bytes, key) {
                    Ok(_) => rep.ok += 1,
                    Err(e) => rep.corrupt.push(format!(
                        "{} ({}): {e}",
                        key.hex(),
                        stage.name()
                    )),
                },
            }
        }
        rep
    }

    /// Total `load`/`load_raw` calls served by this handle (process
    /// lifetime, not persisted). The serve saturation bench asserts
    /// this stays flat across a warm phase — hot entries must be
    /// answered from the in-memory cache, not the disk tier.
    pub fn read_ops(&self) -> u64 {
        self.reads.load(Ordering::Relaxed)
    }

    pub fn stats(&self) -> StoreStats {
        let ix = self.lock_index();
        let mut s = StoreStats {
            entries: ix.entries.len(),
            total_bytes: ix.entries.values().map(|e| e.bytes).sum(),
            evictions: ix.evictions,
            ..Default::default()
        };
        for e in ix.entries.values() {
            match e.stage {
                CachedStage::Load => s.loads += 1,
                CachedStage::Tune => s.tunes += 1,
                CachedStage::Build => s.builds += 1,
            }
        }
        s
    }

    fn write_index_locked(&self, ix: &mut Index) -> Result<()> {
        let mut entries: Vec<(&u64, &Entry)> = ix.entries.iter().collect();
        entries.sort_by_key(|(_, e)| e.seq);
        let arr = entries
            .into_iter()
            .map(|(&k, e)| {
                Json::obj(vec![
                    ("key", Json::Str(StageKey(k).hex())),
                    ("stage", Json::Str(e.stage.name().into())),
                    ("bytes", Json::Num(e.bytes as f64)),
                    ("seq", Json::Num(e.seq as f64)),
                ])
            })
            .collect();
        let doc = Json::obj(vec![
            ("version", Json::Num(INDEX_VERSION as f64)),
            ("seq", Json::Num(ix.seq as f64)),
            ("entries", Json::Arr(arr)),
        ]);
        write_atomic(&self.root.join("index.json"), doc.to_string().as_bytes())
    }
}

fn entry_path(root: &Path, stage: CachedStage, key: StageKey) -> PathBuf {
    root.join(stage.name()).join(format!("{}.bin", key.hex()))
}

/// Write via tmp + rename so readers never observe partial files.
/// Shared with the dispatch work queue (`dispatch.rs`), whose task
/// and outcome records need the same no-partial-reads guarantee.
pub(crate) fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let tmp = path.with_extension(format!("tmp{}", std::process::id()));
    fs::write(&tmp, bytes)
        .with_context(|| format!("writing {}", tmp.display()))?;
    fs::rename(&tmp, path)
        .with_context(|| format!("renaming into {}", path.display()))
}

/// Load the persisted index. With `validate` (store open), every
/// entry's file is checked to exist with the recorded size — invalid
/// rows are dropped — and entry files the index does not know about
/// (a crashed writer) are adopted with seq 0 ⇒ first eviction
/// candidates. Without it (per-save merges), index rows are trusted:
/// a row whose file has vanished self-heals as a plain load miss.
fn read_index(root: &Path, validate: bool) -> Index {
    let mut ix = Index { seq: 0, entries: HashMap::new(), evictions: 0 };
    if let Ok(doc) = Json::parse_file(&root.join("index.json")) {
        if doc.get("version").and_then(Json::as_i64) == Some(INDEX_VERSION) {
            let seq = doc.get("seq").and_then(Json::as_i64).unwrap_or(0);
            ix.seq = seq.max(0) as u64;
            let entries = doc.get("entries").and_then(Json::as_arr);
            for e in entries.unwrap_or(&[]) {
                let Some((key, entry)) = parse_entry(e) else {
                    continue;
                };
                if validate && !entry_file_matches(root, key, entry) {
                    continue;
                }
                ix.entries.insert(key, entry);
            }
        }
    }
    if !validate {
        return ix;
    }
    // adopt orphans a crashed writer left behind
    for stage in ALL_STAGES {
        let Ok(dir) = fs::read_dir(root.join(stage.name())) else { continue };
        for f in dir.flatten() {
            let name = f.file_name();
            let Some(hex) = name.to_str().and_then(|n| n.strip_suffix(".bin"))
            else {
                continue;
            };
            let Ok(key) = u64::from_str_radix(hex, 16) else { continue };
            let Ok(md) = f.metadata() else { continue };
            ix.entries
                .entry(key)
                .or_insert(Entry { stage, bytes: md.len(), seq: 0 });
        }
    }
    ix
}

/// One index entry → (key, Entry): stage known, key hex, counters
/// non-negative. No filesystem access.
fn parse_entry(e: &Json) -> Option<(u64, Entry)> {
    let key = u64::from_str_radix(e.get("key")?.as_str()?, 16).ok()?;
    let stage = CachedStage::from_name(e.get("stage")?.as_str()?)?;
    let bytes = e.get("bytes")?.as_i64()?.max(0) as u64;
    let seq = e.get("seq")?.as_i64()?.max(0) as u64;
    Some((key, Entry { stage, bytes, seq }))
}

/// Does the entry's artifact file exist with the recorded size?
fn entry_file_matches(root: &Path, key: u64, entry: Entry) -> bool {
    let md = fs::metadata(entry_path(root, entry.stage, StageKey(key)));
    md.is_ok_and(|m| m.len() == entry.bytes)
}

/// Re-read the disk index (trusting its rows — no per-entry stat; the
/// caller holds the file lock, so the rows are the latest writer's)
/// and merge entries we don't know about; for shared keys keep the
/// higher seq.
fn merge_disk_index(root: &Path, ix: &mut Index) {
    let disk = read_index(root, false);
    ix.seq = ix.seq.max(disk.seq);
    for (k, e) in disk.entries {
        match ix.entries.get_mut(&k) {
            Some(ours) => ours.seq = ours.seq.max(e.seq),
            None => {
                ix.entries.insert(k, e);
            }
        }
    }
}

/// Advisory cross-process lock via atomic lock-file creation. Held
/// briefly, for the duration of an index read-modify-write. Stale
/// locks are broken (a) immediately when the owning pid recorded in
/// the lock no longer runs — a lock left by a killed or crashed
/// process used to block every other process for the full mtime
/// timeout — or (b) after `store.lock_stale_ms` (default 30 s)
/// without the owner touching the file, the portable fallback.
/// Breaking renames the lock to a
/// breaker-unique name first, so exactly one of several concurrent
/// breakers wins (the losers' renames fail) and nobody can unlink a
/// lock another process just created. The lock file records the
/// owning token (`<pid>-<nonce>`) and release only unlinks a
/// still-owned lock.
struct FileLock {
    path: PathBuf,
    token: String,
}

/// Is the lock at `path` left over from a process that no longer
/// exists, or simply ancient? Shared staleness rules (dead-pid =>
/// break immediately; unparsable token => only age out after `stale`)
/// live in `util::proc::stale_owner_file`, which the dispatch queue's
/// leases use too. The age fallback is `store.lock_stale_ms`.
fn lock_is_stale(path: &Path, stale: Duration) -> bool {
    crate::util::proc::stale_owner_file(path, stale)
}

impl FileLock {
    fn acquire(root: &Path, stale: Duration) -> Result<FileLock> {
        use std::io::Write as _;
        let path = root.join(".lock");
        // pid alone is not unique enough: two sessions in one process
        // may interleave acquire/release
        let token = format!("{}-{:x}", std::process::id(), next_lock_nonce());
        for _ in 0..500 {
            let mut opts = fs::OpenOptions::new();
            opts.write(true).create_new(true);
            match opts.open(&path) {
                Ok(mut f) => {
                    let _ = f.write_all(token.as_bytes());
                    return Ok(FileLock { path, token });
                }
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    if lock_is_stale(&path, stale) {
                        // rename-to-unique: only the winning breaker
                        // proceeds to delete; a fresh lock created in
                        // the meantime is never touched
                        let grave = root.join(format!(".lock.stale.{token}"));
                        if fs::rename(&path, &grave).is_ok() {
                            let _ = fs::remove_file(&grave);
                        }
                        continue;
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => {
                    return Err(e).with_context(|| {
                        format!("creating lock {}", path.display())
                    })
                }
            }
        }
        anyhow::bail!("cache lock {} held for too long", path.display())
    }
}

impl Drop for FileLock {
    fn drop(&mut self) {
        // unlink only a lock we still own: if a breaker decided we
        // were stale and replaced it, the file is no longer ours
        let ours = fs::read_to_string(&self.path)
            .is_ok_and(|s| s.trim() == self.token);
        if ours {
            let _ = fs::remove_file(&self.path);
        }
    }
}

/// Process-wide monotonic nonce for lock tokens.
fn next_lock_nonce() -> u64 {
    use std::sync::atomic::{AtomicU64, Ordering};
    static NONCE: AtomicU64 = AtomicU64::new(0);
    NONCE.fetch_add(1, Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::model::testutil::tiny_conv;
    use crate::session::cache::load_key;
    use std::sync::Arc;

    fn tmp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("mlonmcu_store_{tag}"));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn graph_artifact() -> Artifact {
        Artifact::Graph(Arc::new(tiny_conv()))
    }

    #[test]
    fn save_load_roundtrip_and_stats() {
        let dir = tmp("roundtrip");
        let store = EnvStore::open(&dir, u64::MAX).unwrap();
        let key = load_key(1);
        assert!(matches!(store.load(key, CachedStage::Load), StoreLookup::Miss));
        store.save(key, &graph_artifact()).unwrap();
        assert!(matches!(
            store.load(key, CachedStage::Load),
            StoreLookup::Hit(Artifact::Graph(_))
        ));
        let s = store.stats();
        assert_eq!((s.entries, s.loads, s.evictions), (1, 1, 0));
        assert!(s.total_bytes > 0);
        assert_eq!(store.read_ops(), 2, "one miss + one hit, both counted");
        assert!(store.load_raw(key, CachedStage::Load).is_some());
        assert_eq!(store.read_ops(), 3, "raw reads count too");
        fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn reopen_restores_index() {
        let dir = tmp("reopen");
        {
            let store = EnvStore::open(&dir, u64::MAX).unwrap();
            store.save(load_key(1), &graph_artifact()).unwrap();
            store.save(load_key(2), &graph_artifact()).unwrap();
        }
        let store = EnvStore::open(&dir, u64::MAX).unwrap();
        assert_eq!(store.stats().entries, 2);
        assert!(matches!(
            store.load(load_key(1), CachedStage::Load),
            StoreLookup::Hit(_)
        ));
        fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn corrupt_entry_is_detected_and_deleted() {
        let dir = tmp("corrupt");
        let store = EnvStore::open(&dir, u64::MAX).unwrap();
        let key = load_key(9);
        store.save(key, &graph_artifact()).unwrap();
        let path = dir.join("load").join(format!("{}.bin", key.hex()));
        let mut bytes = fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        fs::write(&path, &bytes).unwrap();
        assert!(matches!(
            store.load(key, CachedStage::Load),
            StoreLookup::Corrupt
        ));
        assert!(!path.exists(), "corrupt entry must be removed");
        assert!(matches!(store.load(key, CachedStage::Load), StoreLookup::Miss));
        fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn budget_evicts_lru_first() {
        let dir = tmp("budget");
        let one = persist::encode(load_key(0), &graph_artifact()).len() as u64;
        // room for two entries, not three
        let store = EnvStore::open(&dir, 2 * one + one / 2).unwrap();
        store.save(load_key(0), &graph_artifact()).unwrap();
        store.save(load_key(1), &graph_artifact()).unwrap();
        // touch key 0 so key 1 becomes the LRU victim
        assert!(matches!(
            store.load(load_key(0), CachedStage::Load),
            StoreLookup::Hit(_)
        ));
        store.save(load_key(2), &graph_artifact()).unwrap();
        let s = store.stats();
        assert_eq!((s.entries, s.evictions), (2, 1));
        assert!(matches!(
            store.load(load_key(1), CachedStage::Load),
            StoreLookup::Miss
        ));
        assert!(matches!(
            store.load(load_key(0), CachedStage::Load),
            StoreLookup::Hit(_)
        ));
        fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn gc_and_clear() {
        let dir = tmp("gc");
        let one = persist::encode(load_key(0), &graph_artifact()).len() as u64;
        {
            let store = EnvStore::open(&dir, u64::MAX).unwrap();
            for k in 0..4 {
                store.save(load_key(k), &graph_artifact()).unwrap();
            }
        }
        // reopen with a budget that only fits one entry: gc trims
        let store = EnvStore::open(&dir, one + one / 2).unwrap();
        let (evicted, freed) = store.gc().unwrap();
        assert_eq!(evicted, 3);
        assert_eq!(freed, 3 * one);
        assert_eq!(store.stats().entries, 1);
        store.clear().unwrap();
        assert_eq!(store.stats().entries, 0);
        assert!(!dir.join("index.json").exists());
        fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn stale_lock_of_dead_process_is_reclaimed() {
        let dir = tmp("stalelock");
        fs::create_dir_all(&dir).unwrap();
        // a lock left by a process that no longer exists (spawn + reap
        // /bin/true to get a genuinely dead pid with fresh mtime)
        let dead_pid = {
            let mut c = std::process::Command::new("true").spawn().unwrap();
            let pid = c.id();
            c.wait().unwrap();
            pid
        };
        fs::write(dir.join(".lock"), format!("{dead_pid}-deadbeef")).unwrap();
        // before the pid check this blocked until the 30 s mtime
        // timeout and then errored out of the 5 s retry loop; now the
        // dead owner's lock is broken immediately
        let watch = crate::util::Stopwatch::start();
        let store = EnvStore::open(&dir, u64::MAX).unwrap();
        assert!(
            watch.elapsed_s() < 4.0,
            "stale lock must break fast, took {:.1}s",
            watch.elapsed_s()
        );
        store.save(load_key(1), &graph_artifact()).unwrap();
        assert!(matches!(
            store.load(load_key(1), CachedStage::Load),
            StoreLookup::Hit(_)
        ));
        fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn fresh_lock_of_live_process_is_respected() {
        let dir = tmp("livelock");
        fs::create_dir_all(&dir).unwrap();
        // our own pid: alive by definition, mtime fresh => not stale
        fs::write(dir.join(".lock"), format!("{}-1", std::process::id()))
            .unwrap();
        assert!(!lock_is_stale(&dir.join(".lock"), Duration::from_secs(30)));
        fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn configured_staleness_ages_out_unprobeable_locks_fast() {
        let dir = tmp("cfgstale");
        fs::create_dir_all(&dir).unwrap();
        // unparsable token: the pid probe can't decide, so only the
        // mtime fallback applies — with the default 30 s this path was
        // untestable without sleeping
        fs::write(dir.join(".lock"), "garbage").unwrap();
        std::thread::sleep(Duration::from_millis(600));
        let watch = crate::util::Stopwatch::start();
        let store = EnvStore::open_with(&dir, u64::MAX, 500).unwrap();
        assert!(
            watch.elapsed_s() < 4.0,
            "500ms-stale lock must break fast, took {:.1}s",
            watch.elapsed_s()
        );
        store.save(load_key(3), &graph_artifact()).unwrap();
        assert!(matches!(
            store.load(load_key(3), CachedStage::Load),
            StoreLookup::Hit(_)
        ));
        fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn injected_store_faults_degrade_never_corrupt() {
        use crate::util::faults;
        let _g = faults::test_gate();
        let dir = tmp("faults");
        let store = EnvStore::open(&dir, u64::MAX).unwrap();
        let key = load_key(77);

        // save error: propagated to the caller, nothing persisted
        faults::install("store.save:error:1").unwrap();
        assert!(store.save(key, &graph_artifact()).is_err());
        faults::clear();
        assert!(matches!(store.load(key, CachedStage::Load), StoreLookup::Miss));

        // short write: truncated entry fails verification on load and
        // is deleted — recompute, never a bad artifact
        faults::install("store.save:short:1").unwrap();
        store.save(key, &graph_artifact()).unwrap();
        faults::clear();
        assert!(!store.verify().clean(), "torn write must be detectable");
        assert!(matches!(
            store.load(key, CachedStage::Load),
            StoreLookup::Corrupt
        ));
        assert!(matches!(store.load(key, CachedStage::Load), StoreLookup::Miss));

        // bit-flipped read of a good entry: corrupt once, then miss
        store.save(key, &graph_artifact()).unwrap();
        faults::install("store.load:bitflip:1").unwrap();
        assert!(matches!(
            store.load(key, CachedStage::Load),
            StoreLookup::Corrupt
        ));
        faults::clear();
        assert!(matches!(store.load(key, CachedStage::Load), StoreLookup::Miss));

        // read error: degrades to a plain miss, entry stays intact
        store.save(key, &graph_artifact()).unwrap();
        faults::install("store.load:error:1").unwrap();
        assert!(matches!(store.load(key, CachedStage::Load), StoreLookup::Miss));
        faults::clear();
        assert!(matches!(
            store.load(key, CachedStage::Load),
            StoreLookup::Hit(_)
        ));
        let rep = store.verify();
        assert!(rep.clean() && rep.ok == 1, "{rep:?}");
        fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn orphan_files_are_adopted_on_open() {
        let dir = tmp("orphan");
        {
            let store = EnvStore::open(&dir, u64::MAX).unwrap();
            store.save(load_key(5), &graph_artifact()).unwrap();
        }
        // simulate a crashed writer: entry file exists, index lost
        fs::remove_file(dir.join("index.json")).unwrap();
        let store = EnvStore::open(&dir, u64::MAX).unwrap();
        assert_eq!(store.stats().entries, 1);
        assert!(matches!(
            store.load(load_key(5), CachedStage::Load),
            StoreLookup::Hit(_)
        ));
        fs::remove_dir_all(dir).unwrap();
    }
}
