//! One run: the Load → [Tune] → Build → Compile → Run → Postprocess
//! stage pipeline, with per-stage host timing, failure capture
//! (memory-gate errors become "—" rows, exactly Table V) and artifact
//! emission.
//!
//! The stages are exposed as standalone functions so the session's
//! stage scheduler (`scheduler.rs`) can deduplicate shared prefixes
//! across the run matrix: Load depends only on the model, Tune on
//! (model, backend, schedule, target, budget), Build on everything up
//! to the schedule — Compile/Run/Postprocess are always per-run.

use std::path::Path;
use std::sync::Arc;

use anyhow::Result;

use crate::backends::{self, BackendConfig, BuildMetrics, BuildResult};
use crate::features::{compare_outputs, Features, Validation};
use crate::frontends;
use crate::graph::Graph;
use crate::report::{row, Cell, Row};
use crate::schedules::Schedule;
use crate::session::cache::{TuneOutcome, TuneParams};
use crate::session::Session;
use crate::targets::{self, RunOutcome};
use crate::tuner;
use crate::util::{Stopwatch, XorShift64};

/// Fully-resolved parameters of one run.
#[derive(Debug, Clone)]
pub struct RunSpec {
    pub model: String,
    pub backend: String,
    pub target: String,
    pub schedule: Option<String>,
    pub tuned: bool,
    pub features: Features,
}

impl RunSpec {
    pub fn label(&self) -> String {
        format!(
            "{}/{}/{}{}{}",
            self.model,
            self.backend,
            self.target,
            self.schedule
                .as_deref()
                .map(|s| format!("/{s}"))
                .unwrap_or_default(),
            if self.tuned { "/tuned" } else { "" }
        )
    }

    /// Does this run go through the Tune stage?
    pub fn needs_tune(&self) -> bool {
        self.tuned || self.features.autotvm()
    }
}

/// Host-side stage durations (Table III columns). Under the stage
/// scheduler a shared stage's cost is charged to exactly one consumer
/// run (the lowest run index), so summing over records still equals
/// the host seconds actually spent.
#[derive(Debug, Clone, Copy, Default)]
pub struct StageTimes {
    pub load_s: f64,
    pub tune_s: f64,
    pub build_s: f64,
    pub compile_s: f64,
    pub run_s: f64,
}

impl StageTimes {
    pub fn total_host(&self) -> f64 {
        self.load_s + self.tune_s + self.build_s + self.compile_s + self.run_s
    }
}

/// Run completion state.
#[derive(Debug, Clone, PartialEq)]
pub enum RunStatus {
    Ok,
    /// Stage name + error (memory overflow, unsupported tuning, ...).
    Failed(&'static str, String),
}

/// Everything recorded about one run.
#[derive(Debug, Clone)]
pub struct RunRecord {
    pub spec: RunSpec,
    pub status: RunStatus,
    pub stages: StageTimes,
    pub build: Option<BuildMetrics>,
    pub outcome: Option<RunOutcome>,
    pub validation: Validation,
    pub tune_improvement: Option<f64>,
    /// Stages this run reused from the artifact cache instead of
    /// executing ("load", "tune", "build").
    pub reused: Vec<&'static str>,
}

impl RunRecord {
    pub fn sim_total_s(&self) -> f64 {
        self.outcome
            .as_ref()
            .map(|o| o.sim_build_s + o.sim_run_s)
            .unwrap_or(0.0)
    }

    /// Flatten into a report row. Failed runs keep their identity
    /// columns and get Missing metric cells ("—").
    pub fn to_row(&self) -> Row {
        let mut r = row(vec![
            ("model", Cell::Str(self.spec.model.clone())),
            ("backend", Cell::Str(self.spec.backend.clone())),
            ("target", Cell::Str(self.spec.target.clone())),
            (
                "schedule",
                Cell::Str(
                    self.spec.schedule.clone().unwrap_or_else(|| "default".into()),
                ),
            ),
            ("tuned", Cell::Str(if self.spec.tuned { "yes" } else { "no" }.into())),
            (
                "status",
                Cell::Str(match &self.status {
                    RunStatus::Ok => "ok".to_string(),
                    RunStatus::Failed(stage, err) => match split_attempts(err) {
                        (_, Some(n)) => format!("failed:{stage} [attempts={n}]"),
                        _ => format!("failed:{stage}"),
                    },
                }),
            ),
        ]);
        match (&self.status, &self.build, &self.outcome) {
            (RunStatus::Ok, Some(b), Some(o)) => {
                r.insert("setup_instr".into(), Cell::Int(o.setup_instructions as i64));
                r.insert("invoke_instr".into(), Cell::Int(o.invoke_instructions as i64));
                r.insert("invoke_cycles".into(), Cell::Int(o.invoke_cycles as i64));
                r.insert("time_s".into(), Cell::Float(o.invoke_seconds));
                r.insert("rom_b".into(), Cell::Int(b.rom_total() as i64));
                r.insert("ram_b".into(), Cell::Int(b.ram_total() as i64));
                r.insert("sim_build_s".into(), Cell::Float(o.sim_build_s));
                r.insert("sim_run_s".into(), Cell::Float(o.sim_run_s));
            }
            _ => {
                for c in [
                    "setup_instr", "invoke_instr", "invoke_cycles", "time_s",
                    "rom_b", "ram_b", "sim_build_s", "sim_run_s",
                ] {
                    r.insert(c.into(), Cell::Missing);
                }
            }
        }
        r.insert("validate".into(), Cell::Str(self.validation.label()));
        r.insert(
            "cached_stages".into(),
            Cell::Str(if self.reused.is_empty() {
                "-".to_string()
            } else {
                self.reused.join("+")
            }),
        );
        if let Some(imp) = self.tune_improvement {
            r.insert("tune_gain".into(), Cell::Float(imp));
        }
        r
    }
}

/// Deterministic input for a run: the golden input vector when the
/// python build path dumped one, else a seeded pseudo-random tensor.
/// The golden file is parsed once per session (`Session::golden_input`
/// caches it), not once per run of the matrix.
fn run_input(session: &Session, model: &str, n: usize) -> Vec<i8> {
    if let Some(v) = session.golden_input(model) {
        if v.len() == n {
            return v.as_ref().clone();
        }
    }
    let mut rng = XorShift64::new(0x5EED ^ n as u64);
    (0..n).map(|_| (rng.next_u64() & 0xff) as i8).collect()
}

// ------------------------------------------------------------- stages --

/// Check the fault registry at a stage entry. `hang`/`exit` rules are
/// fully handled inside `fire`; `error` and `panic` surface here so
/// the stage fails through its normal error/catch_unwind path.
fn stage_fault(site: &'static str) -> Result<()> {
    use crate::util::faults::{self, FaultKind};
    match faults::fire(site) {
        Some(FaultKind::Error) => anyhow::bail!("injected fault at {site}"),
        Some(FaultKind::Panic) => panic!("injected panic at {site}"),
        _ => Ok(()),
    }
}

/// Quarantine marker appended to a stage error once retries are
/// exhausted. Callers only add it when `retry.attempts > 1`, so
/// default sessions keep byte-identical reports.
pub fn annotate_attempts(err: &str, attempts: u32) -> String {
    format!("{err} [attempts={attempts}]")
}

/// Split a quarantine marker off a stage error, if present.
pub fn split_attempts(err: &str) -> (&str, Option<u32>) {
    if let Some(rest) = err.strip_suffix(']') {
        if let Some((msg, n)) = rest.rsplit_once(" [attempts=") {
            if let Ok(a) = n.parse() {
                return (msg, Some(a));
            }
        }
    }
    (err, None)
}

/// Load stage: resolve + parse + validate the model. Takes the
/// environment (not the session) so dispatch worker processes — which
/// have no session of their own — run the identical code path.
pub fn stage_load(env: &crate::config::Environment, spec: &RunSpec) -> Result<Graph> {
    stage_fault("stage.load")?;
    frontends::load_model(&spec.model, &env.model_dirs())
}

/// Tune stage: AutoTVM-style schedule search on the target.
pub fn stage_tune(
    spec: &RunSpec,
    graph: &Graph,
    tune: TuneParams,
) -> Result<TuneOutcome> {
    stage_fault("stage.tune")?;
    let backend = backends::by_name(&spec.backend).expect("validated by matrix");
    let target = targets::by_name(&spec.target).expect("validated by matrix");
    if !target.supports_tuning() {
        // the paper's esp32 column: tuning impossible => "—"
        anyhow::bail!("target {} does not support AutoTVM", spec.target);
    }
    let base = spec
        .schedule
        .as_deref()
        .map(|s| Schedule::parse(s).expect("validated"))
        .unwrap_or_else(|| {
            Schedule::new(
                crate::schedules::Family::DefaultX86,
                crate::schedules::Layout::Nchw,
            )
        });
    let t = tuner::tune(
        &*backend,
        graph,
        &*target,
        base,
        tuner::TuneOpts { trials: tune.trials, seed: tune.seed },
    )?;
    Ok(TuneOutcome { schedule: t.best, improvement: t.improvement() })
}

/// Build stage: lower the graph through the backend, including the
/// debug-arena plan check when that feature is on.
pub fn stage_build(
    spec: &RunSpec,
    graph: &Graph,
    tuned_schedule: Option<Schedule>,
) -> Result<BuildResult> {
    stage_fault("stage.build")?;
    let backend = backends::by_name(&spec.backend).expect("validated by matrix");
    let schedule = tuned_schedule.or_else(|| {
        spec.schedule
            .as_deref()
            .map(|s| Schedule::parse(s).expect("validated"))
    });
    let cfg = BackendConfig { schedule, ..Default::default() };
    let build = backend.build(graph, &cfg)?;
    if spec.features.debug_arena() {
        build
            .program
            .check_plan()
            .map_err(|e| anyhow::anyhow!("arena check: {e}"))?;
    }
    Ok(build)
}

/// Compile + Run + Postprocess: the per-run tail of the pipeline.
/// Consumes the shared Load/Build artifacts, fills in the record and
/// writes the per-run artifacts. Never panics; failures are captured.
pub fn stage_tail(
    session: &Session,
    idx: usize,
    rec: &mut RunRecord,
    graph: &Graph,
    build: &Arc<BuildResult>,
) {
    let spec = rec.spec.clone();
    let run_dir = session.dir.join(format!("run_{idx}"));
    let _ = std::fs::create_dir_all(&run_dir);
    // reproducibility: program listing artifact
    let _ = std::fs::write(
        run_dir.join("program.tir"),
        crate::tinyir::listing::render(&build.program),
    );
    rec.build = Some(build.metrics.clone());

    let target = targets::by_name(&spec.target).expect("validated by matrix");
    let backend = backends::by_name(&spec.backend).expect("validated by matrix");

    // ------------------------------------------------------- Compile --
    let watch = Stopwatch::start();
    let mut span = crate::util::trace::span("stage", "compile")
        .arg_with("run", || idx.to_string())
        .arg_with("backend", || spec.backend.clone())
        .arg_with("target", || spec.target.clone());
    let dep = match target.deploy(build, backend.framework()) {
        Ok(d) => d,
        Err(e) => {
            // flash/RAM overflow => "—"
            span.note("outcome", "failed");
            rec.status = RunStatus::Failed("compile", e.to_string());
            crate::log_debug!("run {}: compile failed: {}", spec.label(), e);
            write_record(&run_dir, rec);
            return;
        }
    };
    drop(span);
    rec.stages.compile_s = watch.elapsed_s();
    crate::util::metrics::observe(
        "stage.compile.us",
        (rec.stages.compile_s * 1e6) as u64,
    );

    // ----------------------------------------------------------- Run --
    let watch = Stopwatch::start();
    let mut span = crate::util::trace::span("stage", "run")
        .arg_with("run", || idx.to_string())
        .arg_with("backend", || spec.backend.clone())
        .arg_with("target", || spec.target.clone())
        .arg_with("schedule", || {
            spec.schedule.clone().unwrap_or_else(|| "default".into())
        });
    let input = run_input(session, &spec.model, graph.tensor(graph.inputs[0]).numel());
    let outcome = match target.run(build, &dep, &input, true) {
        Ok(o) => o,
        Err(e) => {
            span.note("outcome", "failed");
            rec.status = RunStatus::Failed("run", e.to_string());
            crate::log_debug!("run {}: run failed: {}", spec.label(), e);
            write_record(&run_dir, rec);
            return;
        }
    };
    drop(span);
    rec.stages.run_s = watch.elapsed_s();
    crate::util::metrics::observe(
        "stage.run.us",
        (rec.stages.run_s * 1e6) as u64,
    );

    // -------------------------------------------------- Postprocess --
    if spec.features.validate() {
        let atol = session.env().get_i64("run", "validate_atol", 1) as i32;
        match session.golden().and_then(|g| {
            g.run_golden(&spec.model, &input, &graph.tensor(graph.inputs[0]).shape)
        }) {
            Ok(golden) => {
                rec.validation = compare_outputs(&outcome.output, &golden, atol);
            }
            Err(e) => {
                crate::log_warn!("validate: golden unavailable: {e}");
                rec.validation = Validation::Skipped;
            }
        }
    }
    rec.outcome = Some(outcome);
    write_record(&run_dir, rec);
}

/// A blank record for `spec`, before any stage has run.
pub fn blank_record(spec: &RunSpec) -> RunRecord {
    RunRecord {
        spec: spec.clone(),
        status: RunStatus::Ok,
        stages: StageTimes::default(),
        build: None,
        outcome: None,
        validation: Validation::Skipped,
        tune_improvement: None,
        reused: Vec::new(),
    }
}

/// Record a stage failure into `rec` and emit the per-run artifact,
/// mirroring what a successful tail would have written.
pub fn fail_record(
    session: &Session,
    idx: usize,
    rec: &mut RunRecord,
    stage: &'static str,
    err: &str,
) {
    rec.status = RunStatus::Failed(stage, err.to_string());
    crate::log_debug!("run {}: {} failed: {}", rec.spec.label(), stage, err);
    let run_dir = session.dir.join(format!("run_{idx}"));
    let _ = std::fs::create_dir_all(&run_dir);
    write_record(&run_dir, rec);
}

/// Per-run artifact: metrics.json (reproducibility).
fn write_record(dir: &Path, rec: &RunRecord) {
    use crate::data::Json;
    let mut pairs = vec![
        ("label", Json::Str(rec.spec.label())),
        (
            "status",
            Json::Str(match &rec.status {
                RunStatus::Ok => "ok".into(),
                RunStatus::Failed(stage, e) => format!("failed:{stage}: {e}"),
            }),
        ),
        ("validate", Json::Str(rec.validation.label())),
        (
            "cached_stages",
            Json::Arr(
                rec.reused
                    .iter()
                    .map(|s| Json::Str(s.to_string()))
                    .collect(),
            ),
        ),
    ];
    if let Some(o) = &rec.outcome {
        pairs.push(("invoke_instructions", Json::Num(o.invoke_instructions as f64)));
        pairs.push(("invoke_seconds", Json::Num(o.invoke_seconds)));
    }
    if let Some(b) = &rec.build {
        pairs.push(("rom_total", Json::Num(b.rom_total() as f64)));
        pairs.push(("ram_total", Json::Num(b.ram_total() as f64)));
    }
    let _ = std::fs::write(dir.join("metrics.json"), Json::obj(pairs).to_string());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_label_format() {
        let s = RunSpec {
            model: "aww".into(),
            backend: "tvmaot".into(),
            target: "esp32c3".into(),
            schedule: Some("default-nchw".into()),
            tuned: true,
            features: Features::default(),
        };
        assert_eq!(s.label(), "aww/tvmaot/esp32c3/default-nchw/tuned");
        assert!(s.needs_tune());
    }

    #[test]
    fn failed_record_renders_missing_cells() {
        let mut rec = blank_record(&RunSpec {
            model: "vww".into(),
            backend: "tvmaot".into(),
            target: "esp32".into(),
            schedule: None,
            tuned: false,
            features: Features::default(),
        });
        rec.status = RunStatus::Failed("compile", "flash overflow".into());
        let row = rec.to_row();
        assert_eq!(row["time_s"], Cell::Missing);
        assert_eq!(row["status"].render(), "failed:compile");
        assert_eq!(row["cached_stages"].render(), "-");
    }

    #[test]
    fn attempts_marker_round_trips_and_renders() {
        let annotated = annotate_attempts("flash overflow", 3);
        assert_eq!(annotated, "flash overflow [attempts=3]");
        assert_eq!(split_attempts(&annotated), ("flash overflow", Some(3)));
        assert_eq!(split_attempts("flash overflow"), ("flash overflow", None));
        assert_eq!(
            split_attempts("weird [attempts=x]"),
            ("weird [attempts=x]", None)
        );

        let mut rec = blank_record(&RunSpec {
            model: "vww".into(),
            backend: "tvmaot".into(),
            target: "esp32".into(),
            schedule: None,
            tuned: true,
            features: Features::default(),
        });
        rec.status = RunStatus::Failed("tune", annotated);
        assert_eq!(rec.to_row()["status"].render(), "failed:tune [attempts=3]");
    }

    #[test]
    fn reused_stages_render_joined() {
        let mut rec = blank_record(&RunSpec {
            model: "aww".into(),
            backend: "tflmi".into(),
            target: "etiss".into(),
            schedule: None,
            tuned: false,
            features: Features::default(),
        });
        rec.reused = vec!["load", "build"];
        assert_eq!(rec.to_row()["cached_stages"].render(), "load+build");
    }
}
