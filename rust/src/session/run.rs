//! One run: the Load → [Tune] → Build → Compile → Run → Postprocess
//! stage pipeline, with per-stage host timing, failure capture
//! (memory-gate errors become "—" rows, exactly Table V) and artifact
//! emission.

use std::path::PathBuf;

use crate::backends::{self, BackendConfig, BuildMetrics};
use crate::features::{compare_outputs, Features, Validation};
use crate::frontends;
use crate::report::{row, Cell, Row};
use crate::schedules::Schedule;
use crate::session::Session;
use crate::targets::{self, RunOutcome};
use crate::tuner;
use crate::util::{Stopwatch, XorShift64};

/// Fully-resolved parameters of one run.
#[derive(Debug, Clone)]
pub struct RunSpec {
    pub model: String,
    pub backend: String,
    pub target: String,
    pub schedule: Option<String>,
    pub tuned: bool,
    pub features: Features,
}

impl RunSpec {
    pub fn label(&self) -> String {
        format!(
            "{}/{}/{}{}{}",
            self.model,
            self.backend,
            self.target,
            self.schedule
                .as_deref()
                .map(|s| format!("/{s}"))
                .unwrap_or_default(),
            if self.tuned { "/tuned" } else { "" }
        )
    }
}

/// Host-side stage durations (Table III columns).
#[derive(Debug, Clone, Copy, Default)]
pub struct StageTimes {
    pub load_s: f64,
    pub tune_s: f64,
    pub build_s: f64,
    pub compile_s: f64,
    pub run_s: f64,
}

impl StageTimes {
    pub fn total_host(&self) -> f64 {
        self.load_s + self.tune_s + self.build_s + self.compile_s + self.run_s
    }
}

/// Run completion state.
#[derive(Debug, Clone, PartialEq)]
pub enum RunStatus {
    Ok,
    /// Stage name + error (memory overflow, unsupported tuning, ...).
    Failed(&'static str, String),
}

/// Everything recorded about one run.
#[derive(Debug, Clone)]
pub struct RunRecord {
    pub spec: RunSpec,
    pub status: RunStatus,
    pub stages: StageTimes,
    pub build: Option<BuildMetrics>,
    pub outcome: Option<RunOutcome>,
    pub validation: Validation,
    pub tune_improvement: Option<f64>,
}

impl RunRecord {
    pub fn sim_total_s(&self) -> f64 {
        self.outcome
            .as_ref()
            .map(|o| o.sim_build_s + o.sim_run_s)
            .unwrap_or(0.0)
    }

    /// Flatten into a report row. Failed runs keep their identity
    /// columns and get Missing metric cells ("—").
    pub fn to_row(&self) -> Row {
        let mut r = row(vec![
            ("model", Cell::Str(self.spec.model.clone())),
            ("backend", Cell::Str(self.spec.backend.clone())),
            ("target", Cell::Str(self.spec.target.clone())),
            (
                "schedule",
                Cell::Str(
                    self.spec.schedule.clone().unwrap_or_else(|| "default".into()),
                ),
            ),
            ("tuned", Cell::Str(if self.spec.tuned { "yes" } else { "no" }.into())),
            (
                "status",
                Cell::Str(match &self.status {
                    RunStatus::Ok => "ok".to_string(),
                    RunStatus::Failed(stage, _) => format!("failed:{stage}"),
                }),
            ),
        ]);
        match (&self.status, &self.build, &self.outcome) {
            (RunStatus::Ok, Some(b), Some(o)) => {
                r.insert("setup_instr".into(), Cell::Int(o.setup_instructions as i64));
                r.insert("invoke_instr".into(), Cell::Int(o.invoke_instructions as i64));
                r.insert("invoke_cycles".into(), Cell::Int(o.invoke_cycles as i64));
                r.insert("time_s".into(), Cell::Float(o.invoke_seconds));
                r.insert("rom_b".into(), Cell::Int(b.rom_total() as i64));
                r.insert("ram_b".into(), Cell::Int(b.ram_total() as i64));
                r.insert("sim_build_s".into(), Cell::Float(o.sim_build_s));
                r.insert("sim_run_s".into(), Cell::Float(o.sim_run_s));
            }
            _ => {
                for c in [
                    "setup_instr", "invoke_instr", "invoke_cycles", "time_s",
                    "rom_b", "ram_b", "sim_build_s", "sim_run_s",
                ] {
                    r.insert(c.into(), Cell::Missing);
                }
            }
        }
        r.insert("validate".into(), Cell::Str(self.validation.label()));
        if let Some(imp) = self.tune_improvement {
            r.insert("tune_gain".into(), Cell::Float(imp));
        }
        r
    }
}

/// Deterministic input for a run: the golden input vector when the
/// python build path dumped one, else a seeded pseudo-random tensor.
fn run_input(session: &Session, model: &str, n: usize) -> Vec<i8> {
    let path = session
        .env()
        .artifacts_dir()
        .join("golden")
        .join(format!("{model}.json"));
    if let Ok(j) = crate::data::Json::parse_file(&path) {
        if let Some(v) = j.get("input").and_then(|v| v.as_i64_vec()) {
            if v.len() == n {
                return v.into_iter().map(|x| x as i8).collect();
            }
        }
    }
    let mut rng = XorShift64::new(0x5EED ^ n as u64);
    (0..n).map(|_| (rng.next_u64() & 0xff) as i8).collect()
}

/// Drive one run through all stages. Never panics; failures are
/// captured in the record.
pub fn execute_run(session: &Session, idx: usize, spec: &RunSpec) -> RunRecord {
    let mut rec = RunRecord {
        spec: spec.clone(),
        status: RunStatus::Ok,
        stages: StageTimes::default(),
        build: None,
        outcome: None,
        validation: Validation::Skipped,
        tune_improvement: None,
    };
    let run_dir = session.dir.join(format!("run_{idx}"));
    let _ = std::fs::create_dir_all(&run_dir);

    macro_rules! fail {
        ($stage:expr, $err:expr) => {{
            rec.status = RunStatus::Failed($stage, $err.to_string());
            crate::log_debug!("run {}: {} failed: {}", spec.label(), $stage, $err);
            write_record(&run_dir, &rec);
            return rec;
        }};
    }

    // ---------------------------------------------------------- Load --
    let watch = Stopwatch::start();
    let graph = match frontends::load_model(&spec.model, &session.env().model_dirs()) {
        Ok(g) => g,
        Err(e) => fail!("load", e),
    };
    rec.stages.load_s = watch.elapsed_s();

    let backend = backends::by_name(&spec.backend).expect("validated by matrix");
    let target = targets::by_name(&spec.target).expect("validated by matrix");
    let mut schedule: Option<Schedule> =
        spec.schedule.as_deref().map(|s| Schedule::parse(s).expect("validated"));

    // ---------------------------------------------------------- Tune --
    if spec.tuned || spec.features.autotvm() {
        let watch = Stopwatch::start();
        if !target.supports_tuning() {
            // the paper's esp32 column: tuning impossible => "—"
            fail!("tune", format!("target {} does not support AutoTVM", spec.target));
        }
        let base = schedule.unwrap_or_else(|| {
            Schedule::new(
                crate::schedules::Family::DefaultX86,
                crate::schedules::Layout::Nchw,
            )
        });
        let trials = session.env().get_i64("tune", "trials", 600) as usize;
        match tuner::tune(
            &*backend,
            &graph,
            &*target,
            base,
            tuner::TuneOpts { trials, seed: session.env().get_i64("run", "seed", 7) as u64 },
        ) {
            Ok(t) => {
                rec.tune_improvement = Some(t.improvement());
                schedule = Some(t.best);
            }
            Err(e) => fail!("tune", e),
        }
        rec.stages.tune_s = watch.elapsed_s();
    }

    // --------------------------------------------------------- Build --
    let watch = Stopwatch::start();
    let mut cfg = BackendConfig::default();
    cfg.schedule = schedule;
    let build = match backend.build(&graph, &cfg) {
        Ok(b) => b,
        Err(e) => fail!("build", e),
    };
    rec.stages.build_s = watch.elapsed_s();
    // reproducibility: program listing artifact
    let _ = std::fs::write(
        run_dir.join("program.tir"),
        crate::tinyir::listing::render(&build.program),
    );
    if spec.features.debug_arena() {
        if let Err(e) = build.program.check_plan() {
            fail!("build", format!("arena check: {e}"));
        }
    }
    rec.build = Some(build.metrics.clone());

    // ------------------------------------------------------- Compile --
    let watch = Stopwatch::start();
    let dep = match target.deploy(&build, backend.framework()) {
        Ok(d) => d,
        Err(e) => fail!("compile", e), // flash/RAM overflow => "—"
    };
    rec.stages.compile_s = watch.elapsed_s();

    // ----------------------------------------------------------- Run --
    let watch = Stopwatch::start();
    let input = run_input(session, &spec.model, graph.tensor(graph.inputs[0]).numel());
    let outcome = match target.run(&build, &dep, &input, true) {
        Ok(o) => o,
        Err(e) => fail!("run", e),
    };
    rec.stages.run_s = watch.elapsed_s();

    // -------------------------------------------------- Postprocess --
    if spec.features.validate() {
        let atol = session.env().get_i64("run", "validate_atol", 1) as i32;
        match session.golden().and_then(|g| {
            g.run_golden(&spec.model, &input, &graph.tensor(graph.inputs[0]).shape)
        }) {
            Ok(golden) => {
                rec.validation = compare_outputs(&outcome.output, &golden, atol);
            }
            Err(e) => {
                crate::log_warn!("validate: golden unavailable: {e}");
                rec.validation = Validation::Skipped;
            }
        }
    }
    rec.outcome = Some(outcome);
    write_record(&run_dir, &rec);
    rec
}

/// Per-run artifact: metrics.json (reproducibility).
fn write_record(dir: &PathBuf, rec: &RunRecord) {
    use crate::data::Json;
    let mut pairs = vec![
        ("label", Json::Str(rec.spec.label())),
        (
            "status",
            Json::Str(match &rec.status {
                RunStatus::Ok => "ok".into(),
                RunStatus::Failed(stage, e) => format!("failed:{stage}: {e}"),
            }),
        ),
        ("validate", Json::Str(rec.validation.label())),
    ];
    if let Some(o) = &rec.outcome {
        pairs.push(("invoke_instructions", Json::Num(o.invoke_instructions as f64)));
        pairs.push(("invoke_seconds", Json::Num(o.invoke_seconds)));
    }
    if let Some(b) = &rec.build {
        pairs.push(("rom_total", Json::Num(b.rom_total() as f64)));
        pairs.push(("ram_total", Json::Num(b.ram_total() as f64)));
    }
    let _ = std::fs::write(dir.join("metrics.json"), Json::obj(pairs).to_string());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_label_format() {
        let s = RunSpec {
            model: "aww".into(),
            backend: "tvmaot".into(),
            target: "esp32c3".into(),
            schedule: Some("default-nchw".into()),
            tuned: true,
            features: Features::default(),
        };
        assert_eq!(s.label(), "aww/tvmaot/esp32c3/default-nchw/tuned");
    }

    #[test]
    fn failed_record_renders_missing_cells() {
        let rec = RunRecord {
            spec: RunSpec {
                model: "vww".into(),
                backend: "tvmaot".into(),
                target: "esp32".into(),
                schedule: None,
                tuned: false,
                features: Features::default(),
            },
            status: RunStatus::Failed("compile", "flash overflow".into()),
            stages: StageTimes::default(),
            build: None,
            outcome: None,
            validation: Validation::Skipped,
            tune_improvement: None,
        };
        let row = rec.to_row();
        assert_eq!(row["time_s"], Cell::Missing);
        assert_eq!(row["status"].render(), "failed:compile");
    }
}
