//! Run-matrix expansion: the cartesian product of models × backends ×
//! targets × schedules (× tuned on/off), with component validation up
//! front so typos fail before any work is scheduled.

use anyhow::{bail, Context, Result};

use crate::backends;
use crate::features::Features;
use crate::session::run::RunSpec;
use crate::targets;

/// Builder for a benchmark session's run set.
#[derive(Debug, Clone, Default)]
pub struct RunMatrix {
    models: Vec<String>,
    backends: Vec<String>,
    targets: Vec<String>,
    /// Schedule specs ("default-nchw", ...); empty = backend default.
    schedules: Vec<String>,
    /// Sweep AutoTVM off/on (Table V's paired columns).
    tuned: Vec<bool>,
    features: Vec<String>,
    postprocesses: Vec<String>,
}

impl RunMatrix {
    pub fn new() -> RunMatrix {
        RunMatrix { tuned: vec![false], ..Default::default() }
    }

    pub fn models<I: IntoIterator<Item = S>, S: Into<String>>(mut self, it: I) -> Self {
        self.models = it.into_iter().map(Into::into).collect();
        self
    }
    pub fn backends<I: IntoIterator<Item = S>, S: Into<String>>(mut self, it: I) -> Self {
        self.backends = it.into_iter().map(Into::into).collect();
        self
    }
    pub fn targets<I: IntoIterator<Item = S>, S: Into<String>>(mut self, it: I) -> Self {
        self.targets = it.into_iter().map(Into::into).collect();
        self
    }
    pub fn schedules<I: IntoIterator<Item = S>, S: Into<String>>(mut self, it: I) -> Self {
        self.schedules = it.into_iter().map(Into::into).collect();
        self
    }
    /// Sweep untuned and tuned variants (adds the Tune stage).
    pub fn with_tuning_sweep(mut self) -> Self {
        self.tuned = vec![false, true];
        self
    }
    pub fn features<I: IntoIterator<Item = S>, S: Into<String>>(mut self, it: I) -> Self {
        self.features = it.into_iter().map(Into::into).collect();
        self
    }
    pub fn postprocesses<I: IntoIterator<Item = S>, S: Into<String>>(mut self, it: I) -> Self {
        self.postprocesses = it.into_iter().map(Into::into).collect();
        self
    }

    pub fn postprocess_specs(&self) -> &[String] {
        &self.postprocesses
    }

    /// Validate and expand into concrete run specs.
    pub fn expand(&self) -> Result<Vec<RunSpec>> {
        if self.models.is_empty() || self.backends.is_empty() || self.targets.is_empty() {
            bail!(
                "empty run matrix: need at least one model, backend and target \
                 (got {}/{}/{})",
                self.models.len(),
                self.backends.len(),
                self.targets.len()
            );
        }
        // collect *every* invalid component so one CI pass over a bad
        // bench config reports the full fix list, not just the first
        let mut errors: Vec<String> = Vec::new();
        for b in &self.backends {
            if backends::by_name(b).is_none() {
                errors.push(format!(
                    "unknown backend '{b}' (known: {:?})",
                    backends::all_backend_names()
                ));
            }
        }
        for t in &self.targets {
            if targets::by_name(t).is_none() {
                errors.push(format!("unknown target '{t}'"));
            }
        }
        for s in &self.schedules {
            if crate::schedules::Schedule::parse(s).is_none() {
                errors.push(format!(
                    "unknown schedule '{s}' (expected family-layout, e.g. \
                     default-nchw, arm-nhwc)"
                ));
            }
        }
        for f in &self.features {
            if let Err(e) = Features::parse(std::slice::from_ref(f)) {
                errors.push(e.to_string());
            }
        }
        if !errors.is_empty() {
            bail!(
                "invalid run matrix ({} problem{}):\n  - {}",
                errors.len(),
                if errors.len() == 1 { "" } else { "s" },
                errors.join("\n  - ")
            );
        }
        let features = Features::parse(&self.features)?;
        let mut specs = Vec::new();
        let scheds: Vec<Option<String>> = if self.schedules.is_empty() {
            vec![None]
        } else {
            self.schedules.iter().cloned().map(Some).collect()
        };
        for model in &self.models {
            for backend in &self.backends {
                let supports = backends::by_name(backend)
                    .with_context(|| format!("unknown backend {backend}"))?
                    .supports_schedules();
                let backend_scheds: &[Option<String>] = if supports {
                    &scheds
                } else {
                    &[None][..] // schedule axis collapses for TFLM
                };
                for target in &self.targets {
                    for sched in backend_scheds {
                        for &tuned in &self.tuned {
                            // tuned runs only make sense for schedule-
                            // capable backends
                            if tuned && !supports {
                                continue;
                            }
                            specs.push(RunSpec {
                                model: model.clone(),
                                backend: backend.clone(),
                                target: target.clone(),
                                schedule: sched.clone(),
                                tuned,
                                features: features.clone(),
                            });
                        }
                    }
                }
            }
        }
        Ok(specs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_matrix_is_20_runs() {
        // 4 models × 5 backends × 1 target (Table III: "#Runs 20")
        let m = RunMatrix::new()
            .models(["aww", "vww", "resnet", "toycar"])
            .backends(["tflmi", "tflmc", "tvmaot", "tvmaot+", "tvmrt"])
            .targets(["etiss"]);
        assert_eq!(m.expand().unwrap().len(), 20);
    }

    #[test]
    fn table5_matrix_shape() {
        // 4 models × 4 schedules × 4 targets × {untuned, tuned} = 128
        // run *attempts* (the paper's ~98 results exclude "—" cells,
        // which our expansion keeps as failed rows)
        let m = RunMatrix::new()
            .models(["aww", "vww", "resnet", "toycar"])
            .backends(["tvmaot"])
            .targets(["esp32c3", "stm32f4", "stm32f7", "esp32"])
            .schedules(["default-nhwc", "default-nchw", "arm-nhwc", "arm-nchw"])
            .with_tuning_sweep();
        assert_eq!(m.expand().unwrap().len(), 128);
    }

    #[test]
    fn schedule_axis_collapses_for_tflm() {
        let m = RunMatrix::new()
            .models(["aww"])
            .backends(["tflmi"])
            .targets(["etiss"])
            .schedules(["default-nhwc", "default-nchw"]);
        assert_eq!(m.expand().unwrap().len(), 1);
    }

    #[test]
    fn all_invalid_components_reported_at_once() {
        let err = RunMatrix::new()
            .models(["aww"])
            .backends(["nope", "tvmaot"])
            .targets(["gba", "etiss"])
            .schedules(["sideways-chw"])
            .features(["warp-drive"])
            .expand()
            .unwrap_err()
            .to_string();
        assert!(err.contains("4 problems"), "{err}");
        assert!(err.contains("unknown backend 'nope'"), "{err}");
        assert!(err.contains("unknown target 'gba'"), "{err}");
        assert!(err.contains("unknown schedule 'sideways-chw'"), "{err}");
        assert!(err.contains("unknown feature 'warp-drive'"), "{err}");
    }

    #[test]
    fn unknown_components_rejected() {
        let base = RunMatrix::new().models(["aww"]).targets(["etiss"]);
        assert!(base.clone().backends(["nope"]).expand().is_err());
        assert!(base
            .clone()
            .backends(["tvmaot"])
            .schedules(["sideways-chw"])
            .expand()
            .is_err());
        assert!(RunMatrix::new()
            .models(["aww"])
            .backends(["tvmaot"])
            .targets(["gba"])
            .expand()
            .is_err());
    }
}
