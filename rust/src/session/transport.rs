//! Remote artifact-store transport: `mlonmcu serve` exports an
//! `EnvStore` plus the dispatch work queue over TCP, and `RemoteStore`
//! is the client-side cache tier that consults it, turning the
//! single-machine worker fleet of `session/dispatch.rs` into a
//! multi-machine one.
//!
//! ## Wire format
//!
//! Length-prefixed binary frames, one request → one response over a
//! persistent connection:
//!
//! ```text
//! "MLRQ" | version u32 | op u8     | len u32 | payload    (request)
//! "MLRS" | version u32 | status u8 | len u32 | payload    (response)
//! ```
//!
//! `version` is `persist::FORMAT_VERSION` — the same stamp the on-disk
//! entries carry. A version mismatch decodes as a **miss**, never a
//! crash: the server answers mismatched requests with `ST_MISS`
//! (except `OP_PING`, so incompatibility is diagnosable), and the
//! client maps mismatched responses to a miss locally. Artifact bytes
//! themselves travel in the `persist` encoding and are re-verified by
//! `persist::decode` on the receiving side, so the server stays a dumb
//! byte pipe and a mismatched or corrupt peer can never poison a
//! store.
//!
//! ## Fault model
//!
//! The client retries transport errors a bounded number of times with
//! exponential backoff plus jitter (entropy-seeded so a fleet doesn't
//! retry in lockstep), then reports the error. `RemoteStore` wraps
//! that in a circuit breaker: the first failure degrades the tier to
//! local-only for the rest of the session — counted and reported,
//! never fatal.
//!
//! Queue leases mirror the pid-probe path of the local queue: a claim
//! is bound to its TCP connection and released the moment the
//! connection dies (the wire analogue of "owning pid no longer runs"),
//! and a connected-but-stuck worker is reclaimed when its heartbeat
//! goes silent for `lease_ms`.

use std::collections::{HashMap, HashSet};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::config::Environment;
use crate::data::Json;
use crate::session::cache::{Artifact, CachedStage, StageKey};
use crate::session::persist;
use crate::session::store::EnvStore;
use crate::util::XorShift64;

/// Request frame magic.
pub const REQ_MAGIC: &[u8; 4] = b"MLRQ";
/// Response frame magic.
pub const RSP_MAGIC: &[u8; 4] = b"MLRS";
/// Upper bound on a frame payload — a corrupt length prefix must not
/// drive a giant allocation.
pub const MAX_FRAME: usize = 64 << 20;

// Request ops.
pub const OP_PING: u8 = 0;
pub const OP_GET: u8 = 1;
pub const OP_PUT: u8 = 2;
pub const OP_QPUSH: u8 = 3;
pub const OP_CLAIM: u8 = 4;
pub const OP_BEAT: u8 = 5;
pub const OP_DONE: u8 = 6;
pub const OP_POLL: u8 = 7;
pub const OP_BLOB_PUT: u8 = 8;
pub const OP_BLOB_GET: u8 = 9;
pub const OP_STATS: u8 = 10;
/// Ship tracer spans for a served queue (`qid u64 | Chrome trace
/// JSON`); the parent's next POLL on that queue drains them.
pub const OP_TRACE_PUT: u8 = 11;

// Response statuses.
pub const ST_OK: u8 = 0;
pub const ST_MISS: u8 = 1;
pub const ST_ERR: u8 = 2;
pub const ST_EMPTY: u8 = 3;

const HEADER_LEN: usize = 4 + 4 + 1 + 4;

fn write_frame(
    w: &mut impl Write,
    magic: &[u8; 4],
    tag: u8,
    payload: &[u8],
) -> Result<()> {
    let mut head = [0u8; HEADER_LEN];
    head[..4].copy_from_slice(magic);
    head[4..8].copy_from_slice(&persist::FORMAT_VERSION.to_le_bytes());
    head[8] = tag;
    head[9..13].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    w.write_all(&head)?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame, verifying the magic and bounding the payload
/// length. Returns `(version, tag, payload)` — the *version is not
/// checked here*: the caller decides whether a mismatch is a miss
/// (server, client) or diagnostic output (ping).
fn read_frame(r: &mut impl Read, magic: &[u8; 4]) -> Result<(u32, u8, Vec<u8>)> {
    let mut head = [0u8; HEADER_LEN];
    r.read_exact(&mut head).context("reading frame header")?;
    if &head[..4] != magic {
        bail!("bad frame magic {:02x?}", &head[..4]);
    }
    let version = u32::from_le_bytes(head[4..8].try_into().unwrap());
    let tag = head[8];
    let len = u32::from_le_bytes(head[9..13].try_into().unwrap()) as usize;
    if len > MAX_FRAME {
        bail!("frame length {len} exceeds limit");
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).context("reading frame payload")?;
    Ok((version, tag, payload))
}

fn stage_tag(stage: CachedStage) -> u8 {
    match stage {
        CachedStage::Load => 0,
        CachedStage::Tune => 1,
        CachedStage::Build => 2,
    }
}

fn stage_from_u8(tag: u8) -> Option<CachedStage> {
    Some(match tag {
        0 => CachedStage::Load,
        1 => CachedStage::Tune,
        2 => CachedStage::Build,
        _ => return None,
    })
}

/// `stage u8 | key u64` — the GET payload and the PUT payload prefix.
fn entry_ref(stage: CachedStage, key: StageKey) -> [u8; 9] {
    let mut b = [0u8; 9];
    b[0] = stage_tag(stage);
    b[1..9].copy_from_slice(&key.0.to_le_bytes());
    b
}

// ================================================================ server --

enum TaskState {
    Open,
    Claimed { conn: u64, last_beat: Instant, since: Instant },
    Done(Json),
}

struct ServedTask {
    id: u64,
    doc: Json,
    deps: Vec<u64>,
    state: TaskState,
}

struct ServedQueue {
    lease_ms: u64,
    tune: Json,
    /// Parent runs with tracing on: claimers enable their tracer and
    /// ship spans back (`OP_TRACE_PUT`).
    trace: bool,
    /// Fault plan of the dispatching parent; rides every claim so the
    /// whole fleet arms the same deterministic plan ("" = none).
    faults: String,
    /// Per-claim wall-clock deadline (0 = off): a claim held past this
    /// is reopened even while its heartbeat stays alive — the served
    /// analogue of the local parent's deadline watchdog.
    deadline_ms: u64,
    tasks: Vec<ServedTask>,
    /// Worker spans pooled until the parent's next POLL drains them.
    spans: Vec<Json>,
    /// Last claim or completion — parents use the stall age to decide
    /// when to self-drain.
    last_progress: Instant,
}

struct Shared {
    store: Arc<EnvStore>,
    queues: HashMap<u64, ServedQueue>,
    next_queue: u64,
    blobs: HashMap<u64, Arc<Vec<u8>>>,
    /// Live connections (clones held for shutdown + liveness checks).
    conns: HashMap<u64, TcpStream>,
    /// Connections that ever issued a CLAIM — the served fleet size.
    workers: HashSet<u64>,
}

/// The `mlonmcu serve` daemon: one `EnvStore` plus the in-memory work
/// queue, thread-per-connection.
pub struct Server {
    listener: TcpListener,
    shared: Arc<Mutex<Shared>>,
    stop: Arc<AtomicBool>,
}

/// Handle to a server running on its own thread (tests, embedding).
pub struct ServerHandle {
    pub addr: SocketAddr,
    shared: Arc<Mutex<Shared>>,
    stop: Arc<AtomicBool>,
    thread: std::thread::JoinHandle<()>,
}

impl Server {
    pub fn bind(store: Arc<EnvStore>, addr: &str) -> Result<Server> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding {addr}"))?;
        Ok(Server {
            listener,
            shared: Arc::new(Mutex::new(Shared {
                store,
                queues: HashMap::new(),
                next_queue: 0,
                blobs: HashMap::new(),
                conns: HashMap::new(),
                workers: HashSet::new(),
            })),
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.listener.local_addr().expect("bound listener has an address")
    }

    /// Accept loop; blocks until shut down (or an accept error).
    pub fn run(self) -> Result<()> {
        let mut next_conn = 0u64;
        for stream in self.listener.incoming() {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = stream else { continue };
            let _ = stream.set_nodelay(true);
            next_conn += 1;
            let conn_id = next_conn;
            if let Ok(clone) = stream.try_clone() {
                lock(&self.shared).conns.insert(conn_id, clone);
            }
            let shared = Arc::clone(&self.shared);
            std::thread::spawn(move || serve_conn(shared, conn_id, stream));
        }
        Ok(())
    }

    /// Bind + run on a background thread; the handle shuts it down.
    pub fn spawn(store: Arc<EnvStore>, addr: &str) -> Result<ServerHandle> {
        let server = Server::bind(store, addr)?;
        let addr = server.local_addr();
        let shared = Arc::clone(&server.shared);
        let stop = Arc::clone(&server.stop);
        let thread = std::thread::spawn(move || {
            let _ = server.run();
        });
        Ok(ServerHandle { addr, shared, stop, thread })
    }
}

impl ServerHandle {
    /// Stop accepting, sever every live connection (so clients see the
    /// death immediately — the "server killed mid-fetch" path), and
    /// join the accept thread.
    pub fn shutdown(self) {
        self.stop.store(true, Ordering::SeqCst);
        // unblock accept(); the loop re-checks the flag first
        let _ = TcpStream::connect(self.addr);
        for conn in lock(&self.shared).conns.values() {
            let _ = conn.shutdown(std::net::Shutdown::Both);
        }
        let _ = self.thread.join();
    }
}

/// A sibling thread panicking while holding the state lock must not
/// wedge the whole server — the state stays consistent (mutations are
/// single-call) so poisoning is recoverable.
fn lock(shared: &Arc<Mutex<Shared>>) -> MutexGuard<'_, Shared> {
    shared.lock().unwrap_or_else(|e| e.into_inner())
}

fn serve_conn(shared: Arc<Mutex<Shared>>, conn_id: u64, mut stream: TcpStream) {
    loop {
        let (version, op, payload) = match read_frame(&mut stream, REQ_MAGIC) {
            Ok(f) => f,
            Err(_) => break, // EOF / reset / garbage: connection is over
        };
        let (status, body) = handle_request(&shared, conn_id, version, op, &payload);
        if write_frame(&mut stream, RSP_MAGIC, status, &body).is_err() {
            break;
        }
    }
    release_conn(&shared, conn_id);
}

/// Connection death releases everything it held — the wire analogue of
/// the local queue's dead-pid lease reclamation.
fn release_conn(shared: &Arc<Mutex<Shared>>, conn_id: u64) {
    let mut s = lock(shared);
    for q in s.queues.values_mut() {
        for t in &mut q.tasks {
            if matches!(t.state, TaskState::Claimed { conn, .. } if conn == conn_id)
            {
                t.state = TaskState::Open;
            }
        }
    }
    s.workers.remove(&conn_id);
    s.conns.remove(&conn_id);
}

fn handle_request(
    shared: &Arc<Mutex<Shared>>,
    conn_id: u64,
    version: u32,
    op: u8,
    payload: &[u8],
) -> (u8, Vec<u8>) {
    // a peer built from another artifact format gets misses, never
    // errors or panics — except ping, which reports our version so
    // the mismatch is diagnosable
    if version != persist::FORMAT_VERSION && op != OP_PING {
        return (ST_MISS, Vec::new());
    }
    match op {
        OP_PING => (ST_OK, persist::FORMAT_VERSION.to_le_bytes().to_vec()),
        OP_GET => op_get(shared, payload),
        OP_PUT => op_put(shared, payload),
        OP_QPUSH => op_qpush(shared, payload),
        OP_CLAIM => op_claim(shared, conn_id, payload),
        OP_BEAT => op_beat(shared, conn_id, payload),
        OP_DONE => op_done(shared, payload),
        OP_POLL => op_poll(shared, conn_id, payload),
        OP_BLOB_PUT => op_blob_put(shared, payload),
        OP_BLOB_GET => op_blob_get(shared, payload),
        OP_STATS => op_stats(shared),
        OP_TRACE_PUT => op_trace_put(shared, payload),
        _ => (ST_ERR, Vec::new()),
    }
}

fn parse_entry_ref(payload: &[u8]) -> Option<(CachedStage, StageKey)> {
    if payload.len() < 9 {
        return None;
    }
    let stage = stage_from_u8(payload[0])?;
    let key = u64::from_le_bytes(payload[1..9].try_into().unwrap());
    Some((stage, StageKey(key)))
}

fn op_get(shared: &Arc<Mutex<Shared>>, payload: &[u8]) -> (u8, Vec<u8>) {
    let Some((stage, key)) = parse_entry_ref(payload) else {
        return (ST_ERR, Vec::new());
    };
    let store = Arc::clone(&lock(shared).store);
    match store.load_raw(key, stage) {
        Some(bytes) => (ST_OK, bytes),
        None => (ST_MISS, Vec::new()),
    }
}

fn op_put(shared: &Arc<Mutex<Shared>>, payload: &[u8]) -> (u8, Vec<u8>) {
    let Some((stage, key)) = parse_entry_ref(payload) else {
        return (ST_ERR, Vec::new());
    };
    let store = Arc::clone(&lock(shared).store);
    // save_raw re-verifies the encoding: a bad peer cannot poison us
    match store.save_raw(key, stage, &payload[9..]) {
        Ok(()) => (ST_OK, Vec::new()),
        Err(_) => (ST_ERR, Vec::new()),
    }
}

fn op_qpush(shared: &Arc<Mutex<Shared>>, payload: &[u8]) -> (u8, Vec<u8>) {
    let Ok(text) = std::str::from_utf8(payload) else {
        return (ST_ERR, Vec::new());
    };
    let Ok(doc) = Json::parse(text) else {
        return (ST_ERR, Vec::new());
    };
    let lease_ms = doc
        .get("lease_ms")
        .and_then(Json::as_i64)
        .unwrap_or(5000)
        .clamp(50, 600_000) as u64;
    let tune = doc.get("tune").cloned().unwrap_or(Json::Null);
    let trace = matches!(doc.get("trace"), Some(Json::Bool(true)));
    let faults = doc
        .get("faults")
        .and_then(Json::as_str)
        .unwrap_or("")
        .to_string();
    let deadline_ms = doc
        .get("deadline_ms")
        .and_then(Json::as_i64)
        .unwrap_or(0)
        .clamp(0, 3_600_000) as u64;
    let Some(docs) = doc.get("tasks").and_then(Json::as_arr) else {
        return (ST_ERR, Vec::new());
    };
    let mut tasks = Vec::with_capacity(docs.len());
    for d in docs {
        let Some(id) = d.get("id").and_then(Json::as_i64) else {
            return (ST_ERR, Vec::new());
        };
        // deps arrive either as bare ids or as the dispatcher's richer
        // `{id, kind, key}` records (task_doc) — accept both, readiness
        // gating only needs the id
        let deps = d
            .get("deps")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .filter_map(|el| {
                el.as_i64().or_else(|| el.get("id").and_then(Json::as_i64))
            })
            .map(|x| x.max(0) as u64)
            .collect();
        tasks.push(ServedTask {
            id: id.max(0) as u64,
            doc: d.clone(),
            deps,
            state: TaskState::Open,
        });
    }
    let mut s = lock(shared);
    s.next_queue += 1;
    let qid = s.next_queue;
    s.queues.insert(
        qid,
        ServedQueue {
            lease_ms,
            tune,
            trace,
            faults,
            deadline_ms,
            tasks,
            spans: Vec::new(),
            last_progress: Instant::now(),
        },
    );
    (ST_OK, qid.to_le_bytes().to_vec())
}

/// Reopen claims whose heartbeat went silent for a full lease (the
/// connected-but-stuck case; dead connections are reclaimed eagerly by
/// `release_conn`), plus — when the queue carries a deadline — claims
/// held past it even with a live heartbeat (hung worker: the stage is
/// wedged but its beat thread still runs).
fn reclaim_stale(q: &mut ServedQueue) {
    let lease = Duration::from_millis(q.lease_ms);
    let deadline = Duration::from_millis(q.deadline_ms);
    for t in &mut q.tasks {
        let expired = matches!(
            t.state,
            TaskState::Claimed { last_beat, since, .. }
                if last_beat.elapsed() > lease
                    || (q.deadline_ms > 0 && since.elapsed() > deadline)
        );
        if expired {
            t.state = TaskState::Open;
        }
    }
}

fn op_claim(
    shared: &Arc<Mutex<Shared>>,
    conn_id: u64,
    payload: &[u8],
) -> (u8, Vec<u8>) {
    if payload.len() < 8 {
        return (ST_ERR, Vec::new());
    }
    let want = u64::from_le_bytes(payload[..8].try_into().unwrap());
    let mut s = lock(shared);
    // even an idle claimer is part of the fleet: the parent must see
    // it in the worker count before deciding to drain the queue itself
    s.workers.insert(conn_id);
    let mut qids: Vec<u64> = s.queues.keys().copied().collect();
    qids.sort_unstable();
    for qid in qids {
        if want != 0 && qid != want {
            continue;
        }
        let q = s.queues.get_mut(&qid).expect("queue id from key scan");
        reclaim_stale(q);
        // readiness = every dep has a done record (failed deps count:
        // the claimer propagates the failure); lowest id first, the
        // same order the local queue drains in
        let ready = (0..q.tasks.len()).find(|&i| {
            matches!(q.tasks[i].state, TaskState::Open)
                && q.tasks[i].deps.iter().all(|d| {
                    q.tasks
                        .iter()
                        .any(|t| t.id == *d && matches!(t.state, TaskState::Done(_)))
                })
        });
        let Some(i) = ready else { continue };
        q.tasks[i].state = TaskState::Claimed {
            conn: conn_id,
            last_beat: Instant::now(),
            since: Instant::now(),
        };
        q.last_progress = Instant::now();
        let task = q.tasks[i].doc.clone();
        let deps_done: Vec<Json> = q.tasks[i]
            .deps
            .iter()
            .filter_map(|d| {
                q.tasks.iter().find_map(|t| match (&t.state, t.id == *d) {
                    (TaskState::Done(rec), true) => Some(rec.clone()),
                    _ => None,
                })
            })
            .collect();
        let rsp = Json::obj(vec![
            ("queue", Json::Num(qid as f64)),
            ("lease_ms", Json::Num(q.lease_ms as f64)),
            ("tune", q.tune.clone()),
            ("trace", Json::Bool(q.trace)),
            ("faults", Json::Str(q.faults.clone())),
            ("deadline_ms", Json::Num(q.deadline_ms as f64)),
            ("task", task),
            ("deps_done", Json::Arr(deps_done)),
        ]);
        return (ST_OK, rsp.to_string().into_bytes());
    }
    (ST_EMPTY, Vec::new())
}

fn parse_two_u64(payload: &[u8]) -> Option<(u64, u64)> {
    if payload.len() < 16 {
        return None;
    }
    Some((
        u64::from_le_bytes(payload[..8].try_into().unwrap()),
        u64::from_le_bytes(payload[8..16].try_into().unwrap()),
    ))
}

fn op_beat(
    shared: &Arc<Mutex<Shared>>,
    conn_id: u64,
    payload: &[u8],
) -> (u8, Vec<u8>) {
    let Some((qid, tid)) = parse_two_u64(payload) else {
        return (ST_ERR, Vec::new());
    };
    let mut s = lock(shared);
    if let Some(q) = s.queues.get_mut(&qid) {
        for t in &mut q.tasks {
            if t.id == tid {
                if let TaskState::Claimed { conn, ref mut last_beat, .. } =
                    t.state
                {
                    // only the claim owner refreshes: a reclaimed task
                    // belongs to someone else now
                    if conn == conn_id {
                        *last_beat = Instant::now();
                        return (ST_OK, Vec::new());
                    }
                }
                return (ST_MISS, Vec::new());
            }
        }
    }
    (ST_MISS, Vec::new())
}

fn op_done(shared: &Arc<Mutex<Shared>>, payload: &[u8]) -> (u8, Vec<u8>) {
    let Some((qid, tid)) = parse_two_u64(payload) else {
        return (ST_ERR, Vec::new());
    };
    let Ok(text) = std::str::from_utf8(&payload[16..]) else {
        return (ST_ERR, Vec::new());
    };
    let Ok(rec) = Json::parse(text) else {
        return (ST_ERR, Vec::new());
    };
    let mut s = lock(shared);
    let Some(q) = s.queues.get_mut(&qid) else {
        return (ST_ERR, Vec::new());
    };
    for t in &mut q.tasks {
        if t.id == tid {
            // first writer wins, exactly like the local queue's
            // hard-link done records: a reclaimed-then-finished
            // duplicate is dropped silently
            if !matches!(t.state, TaskState::Done(_)) {
                t.state = TaskState::Done(rec);
                q.last_progress = Instant::now();
            }
            return (ST_OK, Vec::new());
        }
    }
    (ST_ERR, Vec::new())
}

fn op_poll(
    shared: &Arc<Mutex<Shared>>,
    conn_id: u64,
    payload: &[u8],
) -> (u8, Vec<u8>) {
    if payload.len() < 8 {
        return (ST_ERR, Vec::new());
    }
    let qid = u64::from_le_bytes(payload[..8].try_into().unwrap());
    let mut s = lock(shared);
    // the poller is the parent: it must not count itself as a worker
    let workers = s.workers.iter().filter(|&&c| c != conn_id).count();
    let Some(q) = s.queues.get_mut(&qid) else {
        return (ST_ERR, Vec::new());
    };
    reclaim_stale(q);
    let done: Vec<Json> = q
        .tasks
        .iter()
        .filter_map(|t| match &t.state {
            TaskState::Done(rec) => Some(rec.clone()),
            _ => None,
        })
        .collect();
    // worker spans are handed to the poller exactly once
    let spans = std::mem::take(&mut q.spans);
    let rsp = Json::obj(vec![
        ("total", Json::Num(q.tasks.len() as f64)),
        ("workers", Json::Num(workers as f64)),
        ("stalled_ms", Json::Num(q.last_progress.elapsed().as_millis() as f64)),
        ("done", Json::Arr(done)),
        ("spans", Json::Arr(spans)),
    ]);
    (ST_OK, rsp.to_string().into_bytes())
}

/// Pool tracer spans shipped by a queue's workers
/// (`qid u64 | Chrome trace JSON`) until the parent polls them off.
fn op_trace_put(shared: &Arc<Mutex<Shared>>, payload: &[u8]) -> (u8, Vec<u8>) {
    if payload.len() < 8 {
        return (ST_ERR, Vec::new());
    }
    let qid = u64::from_le_bytes(payload[..8].try_into().unwrap());
    let Ok(text) = std::str::from_utf8(&payload[8..]) else {
        return (ST_ERR, Vec::new());
    };
    let Ok(doc) = Json::parse(text) else {
        return (ST_ERR, Vec::new());
    };
    let Some(events) = doc.get("traceEvents").and_then(Json::as_arr) else {
        return (ST_ERR, Vec::new());
    };
    let mut s = lock(shared);
    let Some(q) = s.queues.get_mut(&qid) else {
        return (ST_ERR, Vec::new());
    };
    q.spans.extend(events.iter().cloned());
    (ST_OK, Vec::new())
}

fn op_blob_put(shared: &Arc<Mutex<Shared>>, payload: &[u8]) -> (u8, Vec<u8>) {
    if payload.len() < 8 {
        return (ST_ERR, Vec::new());
    }
    let fp = u64::from_le_bytes(payload[..8].try_into().unwrap());
    let bytes = Arc::new(payload[8..].to_vec());
    lock(shared).blobs.insert(fp, bytes);
    (ST_OK, Vec::new())
}

fn op_blob_get(shared: &Arc<Mutex<Shared>>, payload: &[u8]) -> (u8, Vec<u8>) {
    if payload.len() < 8 {
        return (ST_ERR, Vec::new());
    }
    let fp = u64::from_le_bytes(payload[..8].try_into().unwrap());
    match lock(shared).blobs.get(&fp) {
        Some(bytes) => (ST_OK, bytes.as_ref().clone()),
        None => (ST_MISS, Vec::new()),
    }
}

fn op_stats(shared: &Arc<Mutex<Shared>>) -> (u8, Vec<u8>) {
    let (store, blobs, queues, workers) = {
        let s = lock(shared);
        (Arc::clone(&s.store), s.blobs.len(), s.queues.len(), s.workers.len())
    };
    let st = store.stats();
    let doc = Json::obj(vec![
        ("format", Json::Num(persist::FORMAT_VERSION as f64)),
        ("entries", Json::Num(st.entries as f64)),
        ("total_bytes", Json::Num(st.total_bytes as f64)),
        ("loads", Json::Num(st.loads as f64)),
        ("tunes", Json::Num(st.tunes as f64)),
        ("builds", Json::Num(st.builds as f64)),
        ("blobs", Json::Num(blobs as f64)),
        ("queues", Json::Num(queues as f64)),
        ("workers", Json::Num(workers as f64)),
    ]);
    (ST_OK, doc.to_string().into_bytes())
}

// ================================================================ client --

/// Client-side knobs, from the `[remote]` config section.
#[derive(Debug, Clone)]
pub struct RemoteConfig {
    pub addr: String,
    pub timeout_ms: u64,
    pub retries: u32,
    pub backoff_ms: u64,
    /// Queue-stall age after which a dispatching parent drains one
    /// task itself instead of waiting for workers.
    pub grace_ms: u64,
}

impl RemoteConfig {
    /// `None` when no server is configured (`remote.connect` empty).
    pub fn from_env(env: &Environment) -> Option<RemoteConfig> {
        Some(RemoteConfig {
            addr: env.remote_connect()?,
            timeout_ms: env.remote_timeout_ms(),
            retries: env.remote_retries(),
            backoff_ms: env.remote_backoff_ms(),
            grace_ms: env.remote_grace_ms(),
        })
    }
}

/// Outcome of a CLAIM: a task, an empty queue, or a server that
/// refused us outright (version-gated) — a refused worker must exit
/// rather than poll forever.
pub enum Claim {
    Task(Json),
    Empty,
    Refused,
}

struct ClientInner {
    stream: Option<TcpStream>,
    rng: XorShift64,
}

/// One logical connection to a serve daemon: lazy connect, per-request
/// timeout, bounded retry with exponential backoff + jitter. Shared
/// between a worker's main loop and its heartbeat thread — requests
/// are serialized by the inner mutex.
pub struct Client {
    cfg: RemoteConfig,
    inner: Mutex<ClientInner>,
}

impl Client {
    pub fn new(cfg: RemoteConfig) -> Client {
        Client {
            cfg,
            inner: Mutex::new(ClientInner {
                stream: None,
                rng: XorShift64::from_entropy(),
            }),
        }
    }

    pub fn addr(&self) -> &str {
        &self.cfg.addr
    }

    fn connect(cfg: &RemoteConfig) -> Result<TcpStream> {
        let timeout = Duration::from_millis(cfg.timeout_ms);
        let addrs: Vec<SocketAddr> = cfg
            .addr
            .to_socket_addrs()
            .with_context(|| format!("resolving {}", cfg.addr))?
            .collect();
        let mut last: Option<std::io::Error> = None;
        for addr in addrs {
            match TcpStream::connect_timeout(&addr, timeout) {
                Ok(s) => {
                    let _ = s.set_read_timeout(Some(timeout));
                    let _ = s.set_write_timeout(Some(timeout));
                    let _ = s.set_nodelay(true);
                    return Ok(s);
                }
                Err(e) => last = Some(e),
            }
        }
        match last {
            Some(e) => Err(e).context(format!("connecting {}", cfg.addr)),
            None => bail!("{} resolves to no address", cfg.addr),
        }
    }

    /// One request → one response, retrying transport errors up to
    /// `retries` times (backoff doubles each attempt, plus jitter so a
    /// fleet doesn't hammer in lockstep). A response stamped with a
    /// different format version maps to `ST_MISS` here — version skew
    /// is a miss, never a crash and never a retried "error".
    pub fn request(&self, op: u8, payload: &[u8]) -> Result<(u8, Vec<u8>)> {
        let _span = crate::util::trace::span("transport", op_name(op))
            .arg("addr", self.cfg.addr.as_str());
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let mut last_err = None;
        for attempt in 0..=self.cfg.retries {
            if attempt > 0 {
                let base = self.cfg.backoff_ms.max(1) << (attempt - 1).min(6);
                let jitter = inner.rng.below(base);
                std::thread::sleep(Duration::from_millis(base + jitter));
            }
            let outcome = (|| -> Result<(u8, Vec<u8>)> {
                if inner.stream.is_none() {
                    inner.stream = Some(Self::connect(&self.cfg)?);
                }
                let stream = inner.stream.as_mut().expect("stream just connected");
                // injected send faults feed the real retry/degrade
                // machinery: a dropped frame is a transport error, a
                // torn frame actually hits the wire (the server junks
                // the connection) before erroring out here
                use crate::util::faults::{self, FaultKind};
                match faults::fire("transport.send") {
                    Some(FaultKind::Drop) => {
                        bail!("injected fault at transport.send: frame dropped")
                    }
                    Some(FaultKind::Truncate) => {
                        let mut buf = Vec::new();
                        write_frame(&mut buf, REQ_MAGIC, op, payload)?;
                        buf.truncate(buf.len() / 2);
                        let _ = stream.write_all(&buf);
                        let _ = stream.flush();
                        bail!("injected fault at transport.send: frame torn")
                    }
                    _ => {} // Delay already slept inside fire
                }
                write_frame(stream, REQ_MAGIC, op, payload)?;
                match faults::fire("transport.recv") {
                    Some(FaultKind::Drop) | Some(FaultKind::Truncate) => {
                        // abandon the in-flight response; the error path
                        // resets the connection so no desynced frame is
                        // ever parsed
                        bail!("injected fault at transport.recv: response lost")
                    }
                    _ => {}
                }
                let (version, status, body) = read_frame(stream, RSP_MAGIC)?;
                if version != persist::FORMAT_VERSION {
                    return Ok((ST_MISS, Vec::new()));
                }
                Ok((status, body))
            })();
            match outcome {
                Ok(r) => return Ok(r),
                Err(e) => {
                    // a half-used connection can't be trusted for the
                    // next frame: reconnect on the retry
                    inner.stream = None;
                    last_err = Some(e);
                }
            }
        }
        Err(last_err.expect("at least one attempt ran"))
    }

    /// Reachability probe; returns the server's format version.
    pub fn ping(&self) -> Result<u32> {
        let (status, body) = self.request(OP_PING, &[])?;
        if status != ST_OK || body.len() < 4 {
            bail!("ping refused (status {status})");
        }
        Ok(u32::from_le_bytes(body[..4].try_into().unwrap()))
    }

    /// Fetch an entry's raw bytes. `Ok(None)` is a miss; the caller
    /// still has to `persist::decode` (and treat failure as a miss).
    pub fn get(&self, stage: CachedStage, key: StageKey) -> Result<Option<Vec<u8>>> {
        let (status, body) = self.request(OP_GET, &entry_ref(stage, key))?;
        match status {
            ST_OK => Ok(Some(body)),
            ST_MISS | ST_EMPTY => Ok(None),
            _ => bail!("remote get failed (status {status})"),
        }
    }

    /// Push an already-encoded entry; the server re-verifies it.
    pub fn put(&self, stage: CachedStage, key: StageKey, bytes: &[u8]) -> Result<()> {
        let mut payload = entry_ref(stage, key).to_vec();
        payload.extend_from_slice(bytes);
        let (status, _) = self.request(OP_PUT, &payload)?;
        if status != ST_OK {
            bail!("remote put refused (status {status})");
        }
        Ok(())
    }

    pub fn blob_put(&self, fp: u64, bytes: &[u8]) -> Result<()> {
        let mut payload = fp.to_le_bytes().to_vec();
        payload.extend_from_slice(bytes);
        let (status, _) = self.request(OP_BLOB_PUT, &payload)?;
        if status != ST_OK {
            bail!("blob put refused (status {status})");
        }
        Ok(())
    }

    pub fn blob_get(&self, fp: u64) -> Result<Option<Vec<u8>>> {
        let (status, body) = self.request(OP_BLOB_GET, &fp.to_le_bytes())?;
        match status {
            ST_OK => Ok(Some(body)),
            ST_MISS | ST_EMPTY => Ok(None),
            _ => bail!("blob get failed (status {status})"),
        }
    }

    /// Publish a queue document; returns the served queue id.
    pub fn qpush(&self, doc: &Json) -> Result<u64> {
        let (status, body) = self.request(OP_QPUSH, doc.to_string().as_bytes())?;
        if status != ST_OK || body.len() < 8 {
            bail!("queue push refused (status {status})");
        }
        Ok(u64::from_le_bytes(body[..8].try_into().unwrap()))
    }

    /// Claim the next ready task (`queue` 0 = any queue).
    pub fn claim(&self, queue: u64) -> Result<Claim> {
        let (status, body) = self.request(OP_CLAIM, &queue.to_le_bytes())?;
        match status {
            ST_OK => {
                let text = std::str::from_utf8(&body)?;
                Ok(Claim::Task(Json::parse(text)?))
            }
            ST_EMPTY => Ok(Claim::Empty),
            // MISS here means the server version-gated us
            _ => Ok(Claim::Refused),
        }
    }

    pub fn beat(&self, queue: u64, task: u64) -> Result<()> {
        let mut payload = queue.to_le_bytes().to_vec();
        payload.extend_from_slice(&task.to_le_bytes());
        self.request(OP_BEAT, &payload).map(|_| ())
    }

    pub fn done(&self, queue: u64, task: u64, record: &Json) -> Result<()> {
        let mut payload = queue.to_le_bytes().to_vec();
        payload.extend_from_slice(&task.to_le_bytes());
        payload.extend_from_slice(record.to_string().as_bytes());
        let (status, _) = self.request(OP_DONE, &payload)?;
        if status != ST_OK {
            bail!("done record refused (status {status})");
        }
        Ok(())
    }

    /// Queue progress: `{total, workers, stalled_ms, done: [...]}`.
    pub fn poll(&self, queue: u64) -> Result<Json> {
        let (status, body) = self.request(OP_POLL, &queue.to_le_bytes())?;
        if status != ST_OK {
            bail!("poll refused (status {status})");
        }
        Ok(Json::parse(std::str::from_utf8(&body)?)?)
    }

    /// Server-side store stats as JSON (`cache stats --connect`).
    pub fn stats(&self) -> Result<Json> {
        let (status, body) = self.request(OP_STATS, &[])?;
        if status != ST_OK {
            bail!("stats refused (status {status})");
        }
        Ok(Json::parse(std::str::from_utf8(&body)?)?)
    }

    /// Ship drained tracer spans for a served queue. Workers call this
    /// right before `done` so the poll that observes the completion
    /// also collects (or has already collected) the spans behind it.
    pub fn trace_put(
        &self,
        queue: u64,
        spans: Vec<crate::util::trace::Span>,
    ) -> Result<()> {
        let mut payload = queue.to_le_bytes().to_vec();
        payload.extend_from_slice(
            crate::util::trace::to_chrome_json(spans).as_bytes(),
        );
        let (status, _) = self.request(OP_TRACE_PUT, &payload)?;
        if status != ST_OK {
            bail!("trace put refused (status {status})");
        }
        Ok(())
    }
}

/// Human-readable op name for transport spans and diagnostics.
pub fn op_name(op: u8) -> &'static str {
    match op {
        OP_PING => "ping",
        OP_GET => "get",
        OP_PUT => "put",
        OP_QPUSH => "qpush",
        OP_CLAIM => "claim",
        OP_BEAT => "beat",
        OP_DONE => "done",
        OP_POLL => "poll",
        OP_BLOB_PUT => "blob-put",
        OP_BLOB_GET => "blob-get",
        OP_STATS => "stats",
        OP_TRACE_PUT => "trace-put",
        _ => "op?",
    }
}

// =========================================================== store tier --

/// Outcome of a remote-tier lookup, as the cache's counters see it.
pub enum RemoteLookup {
    Hit(Artifact),
    Miss,
    /// Transport failure (counted once — the tier then degrades).
    Error,
    /// Tier degraded to local-only; nothing was attempted.
    Off,
}

/// The remote cache tier: consulted after the local env store misses,
/// with a circuit breaker that degrades to local-only on the first
/// transport failure (counted and reported, never fatal).
pub struct RemoteStore {
    client: Client,
    degraded: AtomicBool,
}

impl RemoteStore {
    pub fn new(cfg: RemoteConfig) -> RemoteStore {
        RemoteStore { client: Client::new(cfg), degraded: AtomicBool::new(false) }
    }

    /// `None` unless `remote.connect` (or `--connect`) names a server.
    /// Construction never dials out — the first lookup does.
    pub fn from_env(env: &Environment) -> Option<Arc<RemoteStore>> {
        RemoteConfig::from_env(env).map(|cfg| Arc::new(RemoteStore::new(cfg)))
    }

    pub fn client(&self) -> &Client {
        &self.client
    }

    pub fn config(&self) -> &RemoteConfig {
        &self.client.cfg
    }

    pub fn addr(&self) -> &str {
        self.client.addr()
    }

    pub fn is_degraded(&self) -> bool {
        self.degraded.load(Ordering::SeqCst)
    }

    /// Trip the breaker; true if this call tripped it (first failure).
    fn mark_degraded(&self) -> bool {
        !self.degraded.swap(true, Ordering::SeqCst)
    }

    /// Fetch + verify one entry. Bytes from the wire go through
    /// `persist::decode` — a truncated frame, corrupt payload or
    /// foreign format version all decode as a plain miss.
    pub fn load(&self, key: StageKey, stage: CachedStage) -> RemoteLookup {
        if self.is_degraded() {
            return RemoteLookup::Off;
        }
        let bytes = match self.client.get(stage, key) {
            Ok(Some(b)) => b,
            Ok(None) => return RemoteLookup::Miss,
            Err(e) => {
                if self.mark_degraded() {
                    crate::log_warn!(
                        "remote store {}: {e:#}; degrading to local-only",
                        self.addr()
                    );
                    return RemoteLookup::Error;
                }
                return RemoteLookup::Off;
            }
        };
        match persist::decode(&bytes, key) {
            Ok(a) if a.stage() == stage => RemoteLookup::Hit(a),
            Ok(_) | Err(_) => {
                match persist::peek_version(&bytes) {
                    Some(v) if v != persist::FORMAT_VERSION => crate::log_warn!(
                        "remote store {}: entry {} has format v{v} (ours: v{}); \
                         treating as miss",
                        self.addr(),
                        key.hex(),
                        persist::FORMAT_VERSION
                    ),
                    _ => crate::log_warn!(
                        "remote store {}: entry {} failed verification; \
                         treating as miss",
                        self.addr(),
                        key.hex()
                    ),
                }
                RemoteLookup::Miss
            }
        }
    }

    /// Best-effort push. A degraded tier skips silently; a fresh
    /// transport failure trips the breaker like a failed load.
    pub fn save(&self, key: StageKey, artifact: &Artifact) {
        if self.is_degraded() {
            return;
        }
        let bytes = persist::encode(key, artifact);
        if let Err(e) = self.client.put(artifact.stage(), key, &bytes) {
            if self.mark_degraded() {
                crate::log_warn!(
                    "remote store {}: push failed ({e:#}); degrading to local-only",
                    self.addr()
                );
            }
        }
    }
}

/// Open the store directory a serve daemon exports — shared by `serve`
/// and tests.
pub fn open_served_store(
    cache_dir: &Path,
    budget_bytes: u64,
) -> Result<Arc<EnvStore>> {
    Ok(Arc::new(EnvStore::open(cache_dir, budget_bytes)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::model::testutil::tiny_conv;
    use crate::session::cache::load_key;
    use std::path::PathBuf;

    fn tmp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("mlonmcu_transport_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn cfg(addr: &SocketAddr) -> RemoteConfig {
        RemoteConfig {
            addr: addr.to_string(),
            timeout_ms: 2000,
            retries: 1,
            backoff_ms: 10,
            grace_ms: 100,
        }
    }

    fn spawn_server(tag: &str) -> (ServerHandle, Arc<EnvStore>, PathBuf) {
        let dir = tmp(tag);
        let store = Arc::new(EnvStore::open(&dir, u64::MAX).unwrap());
        let handle = Server::spawn(Arc::clone(&store), "127.0.0.1:0").unwrap();
        (handle, store, dir)
    }

    fn graph_artifact() -> Artifact {
        Artifact::Graph(std::sync::Arc::new(tiny_conv()))
    }

    #[test]
    fn frame_roundtrip_and_bad_magic() {
        let mut buf = Vec::new();
        write_frame(&mut buf, REQ_MAGIC, OP_GET, b"payload").unwrap();
        let (version, tag, payload) =
            read_frame(&mut &buf[..], REQ_MAGIC).unwrap();
        assert_eq!(version, persist::FORMAT_VERSION);
        assert_eq!(tag, OP_GET);
        assert_eq!(payload, b"payload");
        // wrong magic expectation rejected
        assert!(read_frame(&mut &buf[..], RSP_MAGIC).is_err());
        // truncation at every boundary is an error, not a panic
        for cut in [0, 5, HEADER_LEN, buf.len() - 1] {
            assert!(read_frame(&mut &buf[..cut], REQ_MAGIC).is_err());
        }
        // implausible length prefix rejected before allocation
        let mut huge = buf.clone();
        huge[9..13].copy_from_slice(&(u32::MAX).to_le_bytes());
        assert!(read_frame(&mut &huge[..], REQ_MAGIC).is_err());
    }

    #[test]
    fn ping_get_put_roundtrip() {
        let (server, store, dir) = spawn_server("roundtrip");
        let client = Client::new(cfg(&server.addr));
        assert_eq!(client.ping().unwrap(), persist::FORMAT_VERSION);

        let key = load_key(1);
        assert!(client.get(CachedStage::Load, key).unwrap().is_none());
        let bytes = persist::encode(key, &graph_artifact());
        client.put(CachedStage::Load, key, &bytes).unwrap();
        let back = client.get(CachedStage::Load, key).unwrap().unwrap();
        assert!(persist::decode(&back, key).is_ok());
        assert_eq!(store.stats().loads, 1);

        // corrupt push is refused server-side, store stays clean
        let mut bad = bytes.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0xFF;
        assert!(client.put(CachedStage::Load, load_key(2), &bad).is_err());
        assert_eq!(store.stats().entries, 1);

        server.shutdown();
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn remote_store_hit_miss_and_degrade() {
        let (server, _store, dir) = spawn_server("tier");
        let remote = RemoteStore::new(cfg(&server.addr));
        let key = load_key(3);
        assert!(matches!(
            remote.load(key, CachedStage::Load),
            RemoteLookup::Miss
        ));
        remote.save(key, &graph_artifact());
        assert!(matches!(
            remote.load(key, CachedStage::Load),
            RemoteLookup::Hit(Artifact::Graph(_))
        ));

        // server death: exactly one Error, then Off forever
        server.shutdown();
        assert!(matches!(
            remote.load(key, CachedStage::Load),
            RemoteLookup::Error
        ));
        assert!(remote.is_degraded());
        assert!(matches!(
            remote.load(key, CachedStage::Load),
            RemoteLookup::Off
        ));
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn blob_roundtrip() {
        let (server, _store, dir) = spawn_server("blob");
        let client = Client::new(cfg(&server.addr));
        assert!(client.blob_get(42).unwrap().is_none());
        client.blob_put(42, b"model bytes").unwrap();
        assert_eq!(client.blob_get(42).unwrap().unwrap(), b"model bytes");
        server.shutdown();
        std::fs::remove_dir_all(dir).unwrap();
    }

    fn queue_doc() -> Json {
        // 1 -> 2 dependency chain
        Json::obj(vec![
            ("lease_ms", Json::Num(400.0)),
            (
                "tune",
                Json::obj(vec![
                    ("trials", Json::Num(8.0)),
                    ("seed", Json::Num(7.0)),
                ]),
            ),
            (
                "tasks",
                Json::Arr(vec![
                    Json::obj(vec![
                        ("id", Json::Num(1.0)),
                        ("kind", Json::Str("load".into())),
                        ("deps", Json::Arr(vec![])),
                    ]),
                    Json::obj(vec![
                        ("id", Json::Num(2.0)),
                        ("kind", Json::Str("build".into())),
                        ("deps", Json::Arr(vec![Json::Num(1.0)])),
                    ]),
                ]),
            ),
        ])
    }

    #[test]
    fn queue_claim_respects_deps_and_done_flow() {
        let (server, _store, dir) = spawn_server("queue");
        let client = Client::new(cfg(&server.addr));
        let qid = client.qpush(&queue_doc()).unwrap();
        assert!(qid > 0);

        // only task 1 is ready; a second claim on the same conn while
        // it is held sees an empty queue (task 2 is dep-blocked)
        let Claim::Task(doc) = client.claim(qid).unwrap() else {
            panic!("expected a task");
        };
        assert_eq!(doc.get("task").unwrap().get("id").unwrap().as_i64(), Some(1));
        assert_eq!(doc.get("lease_ms").unwrap().as_i64(), Some(400));
        assert_eq!(
            doc.get("tune").unwrap().get("trials").unwrap().as_i64(),
            Some(8)
        );
        assert!(matches!(client.claim(qid).unwrap(), Claim::Empty));

        client.beat(qid, 1).unwrap();
        let rec = Json::obj(vec![
            ("id", Json::Num(1.0)),
            ("ok", Json::Bool(true)),
        ]);
        client.done(qid, 1, &rec).unwrap();

        // task 2 unblocks, and the claim carries dep 1's done record
        let Claim::Task(doc) = client.claim(qid).unwrap() else {
            panic!("dep-complete task must be claimable");
        };
        assert_eq!(doc.get("task").unwrap().get("id").unwrap().as_i64(), Some(2));
        let deps = doc.get("deps_done").unwrap().as_arr().unwrap();
        assert_eq!(deps.len(), 1);
        assert_eq!(deps[0].get("id").unwrap().as_i64(), Some(1));

        client
            .done(qid, 2, &Json::obj(vec![("id", Json::Num(2.0))]))
            .unwrap();
        let poll = client.poll(qid).unwrap();
        assert_eq!(poll.get("total").unwrap().as_i64(), Some(2));
        assert_eq!(poll.get("done").unwrap().as_arr().unwrap().len(), 2);
        // the polling connection does not count itself as a worker
        assert_eq!(poll.get("workers").unwrap().as_i64(), Some(0));

        server.shutdown();
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn traced_queue_flags_claims_and_pools_spans_until_polled() {
        let (server, _store, dir) = spawn_server("tracedq");
        let client = Client::new(cfg(&server.addr));
        let doc = Json::obj(vec![
            ("lease_ms", Json::Num(400.0)),
            ("trace", Json::Bool(true)),
            (
                "tasks",
                Json::Arr(vec![Json::obj(vec![
                    ("id", Json::Num(1.0)),
                    ("deps", Json::Arr(vec![])),
                ])]),
            ),
        ]);
        let qid = client.qpush(&doc).unwrap();
        let Claim::Task(c) = client.claim(qid).unwrap() else {
            panic!("expected a task");
        };
        // the claim tells the worker to record spans
        assert!(matches!(c.get("trace"), Some(Json::Bool(true))));

        let spans = vec![crate::util::trace::Span {
            name: "load".into(),
            cat: "stage".into(),
            ts_us: 10,
            dur_us: 5,
            pid: 7,
            tid: 1,
            args: vec![("outcome".into(), "ok".into())],
        }];
        client.trace_put(qid, spans).unwrap();
        client
            .done(qid, 1, &Json::obj(vec![("id", Json::Num(1.0))]))
            .unwrap();

        // the poll observing completion also drains the span pool…
        let poll = client.poll(qid).unwrap();
        let events = poll.get("spans").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].get("pid").unwrap().as_i64(), Some(7));
        assert_eq!(events[0].get("name").unwrap().as_str(), Some("load"));
        // …exactly once
        let poll = client.poll(qid).unwrap();
        assert!(poll.get("spans").unwrap().as_arr().unwrap().is_empty());

        // untraced queues advertise trace: false on every claim
        let qid2 = client.qpush(&queue_doc()).unwrap();
        let Claim::Task(c) = client.claim(qid2).unwrap() else {
            panic!("expected a task");
        };
        assert!(matches!(c.get("trace"), Some(Json::Bool(false))));
        server.shutdown();
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn object_form_deps_gate_readiness_like_bare_ids() {
        // the dispatcher's task_doc emits deps as {id, kind, key}
        // records, not bare ids — readiness gating must honour them
        let (server, _store, dir) = spawn_server("objdeps");
        let client = Client::new(cfg(&server.addr));
        let doc = Json::obj(vec![
            ("lease_ms", Json::Num(400.0)),
            (
                "tasks",
                Json::Arr(vec![
                    Json::obj(vec![
                        ("id", Json::Num(1.0)),
                        ("deps", Json::Arr(vec![])),
                    ]),
                    Json::obj(vec![
                        ("id", Json::Num(2.0)),
                        (
                            "deps",
                            Json::Arr(vec![Json::obj(vec![
                                ("id", Json::Num(1.0)),
                                ("kind", Json::Str("load".into())),
                                ("key", Json::Str("00ff".into())),
                            ])]),
                        ),
                    ]),
                ]),
            ),
        ]);
        let qid = client.qpush(&doc).unwrap();
        let Claim::Task(c) = client.claim(qid).unwrap() else {
            panic!("expected task 1");
        };
        assert_eq!(c.get("task").unwrap().get("id").unwrap().as_i64(), Some(1));
        // task 2 must be dep-blocked until 1 is done
        assert!(matches!(client.claim(qid).unwrap(), Claim::Empty));
        client
            .done(qid, 1, &Json::obj(vec![("id", Json::Num(1.0))]))
            .unwrap();
        let Claim::Task(c) = client.claim(qid).unwrap() else {
            panic!("task 2 must unblock");
        };
        assert_eq!(c.get("task").unwrap().get("id").unwrap().as_i64(), Some(2));
        let deps = c.get("deps_done").unwrap().as_arr().unwrap();
        assert_eq!(deps[0].get("id").unwrap().as_i64(), Some(1));
        server.shutdown();
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn dead_connection_releases_its_claims() {
        let (server, _store, dir) = spawn_server("deadconn");
        let parent = Client::new(cfg(&server.addr));
        let qid = parent.qpush(&queue_doc()).unwrap();

        // a worker claims task 1 and then its connection dies
        {
            let doomed = Client::new(cfg(&server.addr));
            assert!(matches!(doomed.claim(qid).unwrap(), Claim::Task(_)));
            // poll from the parent: the doomed worker is in the fleet
            let poll = parent.poll(qid).unwrap();
            assert_eq!(poll.get("workers").unwrap().as_i64(), Some(1));
        } // drop severs the TCP connection

        // the reclaim is driven by the server noticing the EOF; give
        // its connection thread a moment
        let reclaimed = (0..100).any(|_| {
            std::thread::sleep(Duration::from_millis(10));
            matches!(parent.claim(qid), Ok(Claim::Task(_)))
        });
        assert!(reclaimed, "dead connection's claim must be reclaimed");

        server.shutdown();
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn version_mismatch_is_a_miss_never_a_crash() {
        let (server, store, dir) = spawn_server("badver");
        let key = load_key(5);
        store.save(key, &graph_artifact()).unwrap();

        // a raw client stamping a foreign format version: every data
        // op answers MISS, ping still answers OK
        let mut stream = TcpStream::connect(server.addr).unwrap();
        let mut head = [0u8; HEADER_LEN];
        head[..4].copy_from_slice(REQ_MAGIC);
        head[4..8].copy_from_slice(&(persist::FORMAT_VERSION + 1).to_le_bytes());
        head[8] = OP_GET;
        head[9..13].copy_from_slice(&9u32.to_le_bytes());
        stream.write_all(&head).unwrap();
        stream.write_all(&entry_ref(CachedStage::Load, key)).unwrap();
        let (_, status, body) = read_frame(&mut stream, RSP_MAGIC).unwrap();
        assert_eq!(status, ST_MISS, "foreign version must read as a miss");
        assert!(body.is_empty());

        head[8] = OP_PING;
        head[9..13].copy_from_slice(&0u32.to_le_bytes());
        stream.write_all(&head).unwrap();
        let (_, status, body) = read_frame(&mut stream, RSP_MAGIC).unwrap();
        assert_eq!(status, ST_OK, "ping must answer so skew is diagnosable");
        assert_eq!(
            u32::from_le_bytes(body[..4].try_into().unwrap()),
            persist::FORMAT_VERSION
        );

        server.shutdown();
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn retry_is_bounded_and_backs_off() {
        // nothing listens here: request must fail after exactly
        // retries+1 attempts, spending at least the base backoff
        let cfg = RemoteConfig {
            addr: "127.0.0.1:1".to_string(), // reserved port, refused
            timeout_ms: 200,
            retries: 2,
            backoff_ms: 20,
            grace_ms: 100,
        };
        let client = Client::new(cfg);
        let watch = crate::util::Stopwatch::start();
        assert!(client.ping().is_err());
        let ms = watch.elapsed_ms();
        // attempts sleep 20..40 then 40..80 ms: bounded both ways
        assert!(ms >= 55.0, "backoff must actually wait ({ms:.0}ms)");
        assert!(ms < 5_000.0, "retry must terminate quickly ({ms:.0}ms)");
    }
}
