//! Remote artifact-store transport: `mlonmcu serve` exports an
//! `EnvStore` plus the dispatch work queue over TCP, and `RemoteStore`
//! is the client-side cache tier that consults it, turning the
//! single-machine worker fleet of `session/dispatch.rs` into a
//! multi-machine one.
//!
//! ## Wire format
//!
//! Length-prefixed binary frames, one request → one response over a
//! persistent connection:
//!
//! ```text
//! "MLRQ" | version u32 | op u8     | len u32 | payload    (request)
//! "MLRS" | version u32 | status u8 | len u32 | payload    (response)
//! ```
//!
//! `version` is `persist::FORMAT_VERSION` — the same stamp the on-disk
//! entries carry. A version mismatch decodes as a **miss**, never a
//! crash: the server answers mismatched requests with `ST_MISS`
//! (except `OP_PING`, so incompatibility is diagnosable), and the
//! client maps mismatched responses to a miss locally. Artifact bytes
//! themselves travel in the `persist` encoding and are re-verified by
//! `persist::decode` on the receiving side, so the server stays a dumb
//! byte pipe and a mismatched or corrupt peer can never poison a
//! store.
//!
//! ## Serving at fleet scale
//!
//! The daemon keeps a bounded in-memory [`HotCache`] in front of its
//! `EnvStore`: a warm `OP_GET` is answered without touching the disk
//! tier or its lock-file, so N workers hammering the same hot
//! artifacts scale with memory bandwidth, not lock contention (the
//! saturation bench `benches/serve_saturation.rs` proves the warm
//! path performs zero store reads). Batched ops collapse round
//! trips: `OP_MGET` fetches many entries in one frame and
//! `OP_CLAIM_DEPS` rides the artifacts a claimed task will ask for
//! on the claim response itself. Completed queues are retired as
//! soon as a poll has drained their results, idle connections time
//! out, and a connection cap bounds the thread-per-conn fleet.
//!
//! The client side keeps queue ops (claim/beat/poll) on one *pinned*
//! connection — the server binds claims to the connection identity,
//! its liveness *is* the lease — while stateless ops check streams
//! out of a small pool, so concurrent callers in one process don't
//! serialize behind a single stream mutex.
//!
//! ## Fault model
//!
//! The client retries transport errors a bounded number of times with
//! exponential backoff plus jitter (entropy-seeded so a fleet doesn't
//! retry in lockstep), then reports the error; the retry backoff
//! sleeps outside every lock, so one failing request never convoys
//! the process's other wire traffic. `RemoteStore` wraps
//! that in a circuit breaker: the first failure degrades the tier to
//! local-only for the rest of the session — counted and reported,
//! never fatal.
//!
//! Queue leases mirror the pid-probe path of the local queue: a claim
//! is bound to its TCP connection and released the moment the
//! connection dies (the wire analogue of "owning pid no longer runs"),
//! and a connected-but-stuck worker is reclaimed when its heartbeat
//! goes silent for `lease_ms`.

use std::collections::{HashMap, HashSet};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::config::Environment;
use crate::data::Json;
use crate::session::cache::{Artifact, CachedStage, HotCache, StageKey};
use crate::session::persist;
use crate::session::store::EnvStore;
use crate::util::metrics;
use crate::util::XorShift64;

/// Request frame magic.
pub const REQ_MAGIC: &[u8; 4] = b"MLRQ";
/// Response frame magic.
pub const RSP_MAGIC: &[u8; 4] = b"MLRS";
/// Upper bound on a frame payload — a corrupt length prefix must not
/// drive a giant allocation.
pub const MAX_FRAME: usize = 64 << 20;

// Request ops.
pub const OP_PING: u8 = 0;
pub const OP_GET: u8 = 1;
pub const OP_PUT: u8 = 2;
pub const OP_QPUSH: u8 = 3;
pub const OP_CLAIM: u8 = 4;
pub const OP_BEAT: u8 = 5;
pub const OP_DONE: u8 = 6;
pub const OP_POLL: u8 = 7;
pub const OP_BLOB_PUT: u8 = 8;
pub const OP_BLOB_GET: u8 = 9;
pub const OP_STATS: u8 = 10;
/// Ship tracer spans for a served queue (`qid u64 | Chrome trace
/// JSON`); the parent's next POLL on that queue drains them.
pub const OP_TRACE_PUT: u8 = 11;
/// Batched GET: `count u32 | count × (stage u8 | key u64)` fetches
/// many entries in one round trip; per-entry statuses in the body.
pub const OP_MGET: u8 = 12;
/// CLAIM plus dep prefetch: same request as CLAIM, but the response
/// carries the artifacts the claimed task will ask for (its own
/// stage entry and its deps'), collapsing the claim → N×GET chatter
/// of a stage execution into one frame.
pub const OP_CLAIM_DEPS: u8 = 13;
/// Fleet metrics pull (`mlonmcu top`, `metrics export --connect`):
/// one JSON doc with the OP_STATS fields plus the daemon's merged
/// metrics registry, the snapshot ring and per-worker liveness.
pub const OP_METRICS: u8 = 14;
/// Ship a worker's drained metrics snapshot for a served queue
/// (`qid u64 | snapshot JSON`): merged into the daemon's registry so
/// `top` sees the whole fleet, and pooled until the parent's next
/// POLL drains it into the session's `metrics.json`.
pub const OP_METRICS_PUT: u8 = 15;

// Response statuses.
pub const ST_OK: u8 = 0;
pub const ST_MISS: u8 = 1;
pub const ST_ERR: u8 = 2;
pub const ST_EMPTY: u8 = 3;

const HEADER_LEN: usize = 4 + 4 + 1 + 4;

fn write_frame(
    w: &mut impl Write,
    magic: &[u8; 4],
    tag: u8,
    payload: &[u8],
) -> Result<()> {
    let mut head = [0u8; HEADER_LEN];
    head[..4].copy_from_slice(magic);
    head[4..8].copy_from_slice(&persist::FORMAT_VERSION.to_le_bytes());
    head[8] = tag;
    head[9..13].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    w.write_all(&head)?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame, verifying the magic and bounding the payload
/// length. Returns `(version, tag, payload)` — the *version is not
/// checked here*: the caller decides whether a mismatch is a miss
/// (server, client) or diagnostic output (ping).
fn read_frame(r: &mut impl Read, magic: &[u8; 4]) -> Result<(u32, u8, Vec<u8>)> {
    let mut head = [0u8; HEADER_LEN];
    r.read_exact(&mut head).context("reading frame header")?;
    if &head[..4] != magic {
        bail!("bad frame magic {:02x?}", &head[..4]);
    }
    let version = u32::from_le_bytes(head[4..8].try_into().unwrap());
    let tag = head[8];
    let len = u32::from_le_bytes(head[9..13].try_into().unwrap()) as usize;
    if len > MAX_FRAME {
        bail!("frame length {len} exceeds limit");
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).context("reading frame payload")?;
    Ok((version, tag, payload))
}

fn stage_tag(stage: CachedStage) -> u8 {
    match stage {
        CachedStage::Load => 0,
        CachedStage::Tune => 1,
        CachedStage::Build => 2,
    }
}

fn stage_from_u8(tag: u8) -> Option<CachedStage> {
    Some(match tag {
        0 => CachedStage::Load,
        1 => CachedStage::Tune,
        2 => CachedStage::Build,
        _ => return None,
    })
}

/// `stage u8 | key u64` — the GET payload and the PUT payload prefix.
fn entry_ref(stage: CachedStage, key: StageKey) -> [u8; 9] {
    let mut b = [0u8; 9];
    b[0] = stage_tag(stage);
    b[1..9].copy_from_slice(&key.0.to_le_bytes());
    b
}

// ================================================================ server --

enum TaskState {
    Open,
    Claimed { conn: u64, last_beat: Instant, since: Instant },
    Done(Json),
}

struct ServedTask {
    id: u64,
    doc: Json,
    deps: Vec<u64>,
    state: TaskState,
}

struct ServedQueue {
    lease_ms: u64,
    tune: Json,
    /// Parent runs with tracing on: claimers enable their tracer and
    /// ship spans back (`OP_TRACE_PUT`).
    trace: bool,
    /// Parent runs with metrics on: claimers enable their registry and
    /// ship drained snapshots back (`OP_METRICS_PUT`).
    metrics: bool,
    /// Fault plan of the dispatching parent; rides every claim so the
    /// whole fleet arms the same deterministic plan ("" = none).
    faults: String,
    /// Per-claim wall-clock deadline (0 = off): a claim held past this
    /// is reopened even while its heartbeat stays alive — the served
    /// analogue of the local parent's deadline watchdog.
    deadline_ms: u64,
    tasks: Vec<ServedTask>,
    /// Worker spans pooled until the parent's next POLL drains them.
    spans: Vec<Json>,
    /// Worker metrics snapshots pooled the same way.
    metric_docs: Vec<Json>,
    /// Last claim or completion — parents use the stall age to decide
    /// when to self-drain.
    last_progress: Instant,
}

struct Shared {
    queues: HashMap<u64, ServedQueue>,
    next_queue: u64,
    blobs: HashMap<u64, Arc<Vec<u8>>>,
    /// Live connections (clones held for shutdown + liveness checks).
    conns: HashMap<u64, TcpStream>,
    /// Connections that ever issued a CLAIM — the served fleet size.
    workers: HashSet<u64>,
    /// Per-worker liveness (`mlonmcu top`): keyed like `workers`,
    /// dropped with the connection.
    fleet: HashMap<u64, FleetWorker>,
}

/// Liveness row of one claiming connection, served by `OP_METRICS`.
struct FleetWorker {
    addr: String,
    last_seen: Instant,
    claims: u64,
    done: u64,
}

impl FleetWorker {
    fn to_json(&self) -> Json {
        let idle_ms = u64::try_from(self.last_seen.elapsed().as_millis())
            .unwrap_or(u64::MAX);
        Json::obj(vec![
            ("addr", Json::Str(self.addr.clone())),
            ("idle_ms", Json::Num(idle_ms as f64)),
            ("claims", Json::Num(self.claims as f64)),
            ("done", Json::Num(self.done as f64)),
        ])
    }
}

/// Touch (creating if needed) the liveness row of a claiming
/// connection. The peer address comes from the live conn map.
fn touch_fleet(s: &mut Shared, conn_id: u64) -> &mut FleetWorker {
    let addr = s
        .conns
        .get(&conn_id)
        .and_then(|c| c.peer_addr().ok())
        .map(|a| a.to_string())
        .unwrap_or_else(|| format!("conn-{conn_id}"));
    let w = s.fleet.entry(conn_id).or_insert(FleetWorker {
        addr,
        last_seen: Instant::now(),
        claims: 0,
        done: 0,
    });
    w.last_seen = Instant::now();
    w
}

/// Serve-tier resource knobs, from the `[serve]` config section.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Byte budget of the in-memory hot-entry cache (`serve.mem_mb`;
    /// 0 disables it).
    pub mem_bytes: u64,
    /// Connection cap — accepts beyond it are dropped immediately so
    /// a runaway fleet cannot exhaust server threads
    /// (`serve.max_conns`).
    pub max_conns: usize,
    /// Idle-connection read timeout in ms (`serve.idle_ms`; 0 = off):
    /// a connection that sends nothing for this long is closed and
    /// its claims reclaimed.
    pub idle_ms: u64,
    /// Snapshot-ring sampling period (`metrics.interval_ms`).
    pub metrics_interval_ms: u64,
    /// Bounded sample count of the snapshot ring (`metrics.ring`).
    pub metrics_ring: usize,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        // idle_ms defaults off: embedded test servers keep claim
        // connections silent for long stretches by design
        ServeConfig {
            mem_bytes: 64 << 20,
            max_conns: 256,
            idle_ms: 0,
            metrics_interval_ms: 1000,
            metrics_ring: 128,
        }
    }
}

impl ServeConfig {
    pub fn from_env(env: &Environment) -> ServeConfig {
        ServeConfig {
            mem_bytes: env.serve_mem_bytes(),
            max_conns: env.serve_max_conns(),
            idle_ms: env.serve_idle_ms(),
            metrics_interval_ms: env.metrics_interval_ms(),
            metrics_ring: env.metrics_ring(),
        }
    }
}

/// Everything a connection thread needs. The queue/blob/conn state
/// lives behind one mutex (`shared`); the hot-entry cache has its
/// own, so a warm `OP_GET` storm never contends with claim
/// bookkeeping; the counters are atomics touched without any lock.
struct ServeState {
    store: Arc<EnvStore>,
    shared: Mutex<Shared>,
    mem: Mutex<HotCache>,
    cfg: ServeConfig,
    /// Total requests handled (any op, any status).
    ops: AtomicU64,
    /// Response payload bytes written (the serving-bandwidth gauge).
    bytes_served: AtomicU64,
    /// Completed queues dropped after their final drain.
    queues_retired: AtomicU64,
    started: Instant,
    /// Bounded ring of timestamped registry deltas, sampled every
    /// `metrics_interval_ms` while the daemon runs.
    ring: Mutex<metrics::SnapshotRing>,
}

/// The `mlonmcu serve` daemon: one `EnvStore` fronted by a bounded
/// in-memory hot cache, plus the in-memory work queue,
/// thread-per-connection.
pub struct Server {
    listener: TcpListener,
    state: Arc<ServeState>,
    stop: Arc<AtomicBool>,
}

/// Handle to a server running on its own thread (tests, embedding).
pub struct ServerHandle {
    pub addr: SocketAddr,
    state: Arc<ServeState>,
    stop: Arc<AtomicBool>,
    thread: std::thread::JoinHandle<()>,
}

impl Server {
    pub fn bind(store: Arc<EnvStore>, addr: &str) -> Result<Server> {
        Server::bind_with(store, addr, ServeConfig::default())
    }

    /// `bind` with explicit serve-tier resource knobs.
    pub fn bind_with(
        store: Arc<EnvStore>,
        addr: &str,
        cfg: ServeConfig,
    ) -> Result<Server> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding {addr}"))?;
        Ok(Server {
            listener,
            state: Arc::new(ServeState {
                store,
                shared: Mutex::new(Shared {
                    queues: HashMap::new(),
                    next_queue: 0,
                    blobs: HashMap::new(),
                    conns: HashMap::new(),
                    workers: HashSet::new(),
                    fleet: HashMap::new(),
                }),
                mem: Mutex::new(HotCache::new(cfg.mem_bytes)),
                ops: AtomicU64::new(0),
                bytes_served: AtomicU64::new(0),
                queues_retired: AtomicU64::new(0),
                started: Instant::now(),
                ring: Mutex::new(metrics::SnapshotRing::new(cfg.metrics_ring)),
                cfg,
            }),
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.listener.local_addr().expect("bound listener has an address")
    }

    /// Accept loop; blocks until shut down (or an accept error).
    pub fn run(self) -> Result<()> {
        self.spawn_sampler();
        let mut next_conn = 0u64;
        for stream in self.listener.incoming() {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = stream else { continue };
            let _ = stream.set_nodelay(true);
            next_conn += 1;
            let conn_id = next_conn;
            {
                let mut s = lock(&self.state);
                if s.conns.len() >= self.state.cfg.max_conns {
                    // over the cap: drop the stream on the floor; the
                    // client sees a reset and retries/degrades
                    continue;
                }
                if let Ok(clone) = stream.try_clone() {
                    s.conns.insert(conn_id, clone);
                }
            }
            let state = Arc::clone(&self.state);
            std::thread::spawn(move || serve_conn(state, conn_id, stream));
        }
        Ok(())
    }

    /// Detached sampler: every `metrics_interval_ms` the registry is
    /// snapshotted into the bounded delta ring `OP_METRICS` serves.
    /// Sleeps in short steps so a shutdown is noticed quickly, and
    /// exits with the stop flag. With metrics disabled the snapshot
    /// is empty and the samples are inert.
    fn spawn_sampler(&self) {
        let state = Arc::clone(&self.state);
        let stop = Arc::clone(&self.stop);
        std::thread::spawn(move || {
            let interval =
                Duration::from_millis(state.cfg.metrics_interval_ms.max(50));
            let step = Duration::from_millis(50).min(interval);
            let mut slept = Duration::ZERO;
            while !stop.load(Ordering::SeqCst) {
                std::thread::sleep(step);
                slept += step;
                if slept < interval {
                    continue;
                }
                slept = Duration::ZERO;
                let now_ms = std::time::SystemTime::now()
                    .duration_since(std::time::UNIX_EPOCH)
                    .map(|d| d.as_millis() as u64)
                    .unwrap_or(0);
                let snap = metrics::snapshot();
                state
                    .ring
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .sample(now_ms, snap);
            }
        });
    }

    /// Bind + run on a background thread; the handle shuts it down.
    pub fn spawn(store: Arc<EnvStore>, addr: &str) -> Result<ServerHandle> {
        Server::spawn_with(store, addr, ServeConfig::default())
    }

    /// `spawn` with explicit serve-tier resource knobs.
    pub fn spawn_with(
        store: Arc<EnvStore>,
        addr: &str,
        cfg: ServeConfig,
    ) -> Result<ServerHandle> {
        let server = Server::bind_with(store, addr, cfg)?;
        let addr = server.local_addr();
        let state = Arc::clone(&server.state);
        let stop = Arc::clone(&server.stop);
        let thread = std::thread::spawn(move || {
            let _ = server.run();
        });
        Ok(ServerHandle { addr, state, stop, thread })
    }
}

impl ServerHandle {
    /// Live served-queue count — tests and the saturation bench use
    /// it to prove completed queues are retired, not leaked.
    pub fn queue_count(&self) -> usize {
        lock(&self.state).queues.len()
    }

    /// Stop accepting, sever every live connection (so clients see the
    /// death immediately — the "server killed mid-fetch" path), and
    /// join the accept thread.
    pub fn shutdown(self) {
        self.stop.store(true, Ordering::SeqCst);
        // unblock accept(); the loop re-checks the flag first
        let _ = TcpStream::connect(self.addr);
        for conn in lock(&self.state).conns.values() {
            let _ = conn.shutdown(std::net::Shutdown::Both);
        }
        let _ = self.thread.join();
    }
}

/// A sibling thread panicking while holding the state lock must not
/// wedge the whole server — the state stays consistent (mutations are
/// single-call) so poisoning is recoverable.
fn lock(state: &ServeState) -> MutexGuard<'_, Shared> {
    state.shared.lock().unwrap_or_else(|e| e.into_inner())
}

fn serve_conn(state: Arc<ServeState>, conn_id: u64, mut stream: TcpStream) {
    if state.cfg.idle_ms > 0 {
        // an idle peer trips the read timeout below and is treated
        // exactly like a dead one: closed, claims reclaimed
        let _ = stream
            .set_read_timeout(Some(Duration::from_millis(state.cfg.idle_ms)));
    }
    loop {
        let (version, op, payload) = match read_frame(&mut stream, REQ_MAGIC) {
            Ok(f) => f,
            Err(_) => break, // EOF / reset / idle timeout / garbage
        };
        let clock = metrics::clock();
        let (status, body) = handle_request(&state, conn_id, version, op, &payload);
        clock.observe_fn(|| format!("wire.server.{}.us", op_name(op)));
        metrics::observe("wire.server.req.bytes", payload.len() as u64);
        metrics::observe("wire.server.rsp.bytes", body.len() as u64);
        state.ops.fetch_add(1, Ordering::Relaxed);
        state.bytes_served.fetch_add(body.len() as u64, Ordering::Relaxed);
        if write_frame(&mut stream, RSP_MAGIC, status, &body).is_err() {
            break;
        }
    }
    release_conn(&state, conn_id);
}

/// Connection death releases everything it held — the wire analogue of
/// the local queue's dead-pid lease reclamation. Done records stay:
/// completion is owned by the queue, not the connection, so a worker
/// that reported its result and *then* died re-opens nothing.
fn release_conn(state: &ServeState, conn_id: u64) {
    let mut s = lock(state);
    for q in s.queues.values_mut() {
        for t in &mut q.tasks {
            if matches!(t.state, TaskState::Claimed { conn, .. } if conn == conn_id)
            {
                t.state = TaskState::Open;
            }
        }
    }
    s.workers.remove(&conn_id);
    s.fleet.remove(&conn_id);
    s.conns.remove(&conn_id);
}

fn handle_request(
    state: &ServeState,
    conn_id: u64,
    version: u32,
    op: u8,
    payload: &[u8],
) -> (u8, Vec<u8>) {
    // a peer built from another artifact format gets misses, never
    // errors or panics — except ping, which reports our version so
    // the mismatch is diagnosable
    if version != persist::FORMAT_VERSION && op != OP_PING {
        return (ST_MISS, Vec::new());
    }
    match op {
        OP_PING => (ST_OK, persist::FORMAT_VERSION.to_le_bytes().to_vec()),
        OP_GET => op_get(state, payload),
        OP_PUT => op_put(state, payload),
        OP_QPUSH => op_qpush(state, payload),
        OP_CLAIM => op_claim(state, conn_id, payload),
        OP_BEAT => op_beat(state, conn_id, payload),
        OP_DONE => op_done(state, conn_id, payload),
        OP_POLL => op_poll(state, conn_id, payload),
        OP_BLOB_PUT => op_blob_put(state, payload),
        OP_BLOB_GET => op_blob_get(state, payload),
        OP_STATS => op_stats(state),
        OP_TRACE_PUT => op_trace_put(state, payload),
        OP_MGET => op_mget(state, payload),
        OP_CLAIM_DEPS => op_claim_deps(state, conn_id, payload),
        OP_METRICS => op_metrics(state),
        OP_METRICS_PUT => op_metrics_put(state, conn_id, payload),
        _ => (ST_ERR, Vec::new()),
    }
}

fn parse_entry_ref(payload: &[u8]) -> Option<(CachedStage, StageKey)> {
    if payload.len() < 9 {
        return None;
    }
    let stage = stage_from_u8(payload[0])?;
    let key = u64::from_le_bytes(payload[1..9].try_into().unwrap());
    Some((stage, StageKey(key)))
}

/// One entry fetch through the hot tier: memory first (hit/miss
/// counted inside the cache), then the store, promoting disk hits
/// into memory. Entries are content-addressed — a cached value can
/// never be *wrong*, so there is no invalidation to get right.
fn fetch_entry(
    state: &ServeState,
    stage: CachedStage,
    key: StageKey,
) -> Option<Arc<Vec<u8>>> {
    if state.cfg.mem_bytes > 0 {
        let mut mem = state.mem.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(bytes) = mem.get(stage, key) {
            return Some(bytes);
        }
    }
    let bytes = Arc::new(state.store.load_raw(key, stage)?);
    if state.cfg.mem_bytes > 0 {
        let mut mem = state.mem.lock().unwrap_or_else(|e| e.into_inner());
        mem.put(stage, key, Arc::clone(&bytes));
    }
    Some(bytes)
}

fn op_get(state: &ServeState, payload: &[u8]) -> (u8, Vec<u8>) {
    let Some((stage, key)) = parse_entry_ref(payload) else {
        return (ST_ERR, Vec::new());
    };
    match fetch_entry(state, stage, key) {
        Some(bytes) => (ST_OK, bytes.as_ref().clone()),
        None => (ST_MISS, Vec::new()),
    }
}

fn op_put(state: &ServeState, payload: &[u8]) -> (u8, Vec<u8>) {
    let Some((stage, key)) = parse_entry_ref(payload) else {
        return (ST_ERR, Vec::new());
    };
    // save_raw re-verifies the encoding: a bad peer cannot poison us
    match state.store.save_raw(key, stage, &payload[9..]) {
        Ok(()) => {
            if state.cfg.mem_bytes > 0 {
                // a pushed entry is about to be hot: a fleet uploads
                // exactly what its siblings are about to fetch
                let mut mem =
                    state.mem.lock().unwrap_or_else(|e| e.into_inner());
                mem.put(stage, key, Arc::new(payload[9..].to_vec()));
            }
            (ST_OK, Vec::new())
        }
        Err(_) => (ST_ERR, Vec::new()),
    }
}

/// Soft cap on an MGET response body: entries that would push past it
/// are reported as misses so the frame always fits `MAX_FRAME`.
const MGET_BODY_BUDGET: usize = MAX_FRAME - 4096;
/// Cap on entries per MGET request (forged counts must not allocate).
const MGET_MAX_ENTRIES: usize = 1024;

fn op_mget(state: &ServeState, payload: &[u8]) -> (u8, Vec<u8>) {
    if payload.len() < 4 {
        return (ST_ERR, Vec::new());
    }
    let count = u32::from_le_bytes(payload[..4].try_into().unwrap()) as usize;
    if count > MGET_MAX_ENTRIES || payload.len() < 4 + count * 9 {
        return (ST_ERR, Vec::new());
    }
    let mut body = Vec::new();
    for i in 0..count {
        let at = 4 + i * 9;
        let Some((stage, key)) = parse_entry_ref(&payload[at..at + 9]) else {
            return (ST_ERR, Vec::new());
        };
        let entry = fetch_entry(state, stage, key);
        match entry {
            Some(bytes) if body.len() + bytes.len() <= MGET_BODY_BUDGET => {
                body.push(ST_OK);
                body.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
                body.extend_from_slice(&bytes);
            }
            // absent — or present but over the response budget: a
            // miss is always safe, the client falls back to GET
            _ => {
                body.push(ST_MISS);
                body.extend_from_slice(&0u32.to_le_bytes());
            }
        }
    }
    (ST_OK, body)
}

fn op_qpush(state: &ServeState, payload: &[u8]) -> (u8, Vec<u8>) {
    let Ok(text) = std::str::from_utf8(payload) else {
        return (ST_ERR, Vec::new());
    };
    let Ok(doc) = Json::parse(text) else {
        return (ST_ERR, Vec::new());
    };
    let lease_ms = doc
        .get("lease_ms")
        .and_then(Json::as_i64)
        .unwrap_or(5000)
        .clamp(50, 600_000) as u64;
    let tune = doc.get("tune").cloned().unwrap_or(Json::Null);
    let trace = matches!(doc.get("trace"), Some(Json::Bool(true)));
    let metrics_on = matches!(doc.get("metrics"), Some(Json::Bool(true)));
    let faults = doc
        .get("faults")
        .and_then(Json::as_str)
        .unwrap_or("")
        .to_string();
    let deadline_ms = doc
        .get("deadline_ms")
        .and_then(Json::as_i64)
        .unwrap_or(0)
        .clamp(0, 3_600_000) as u64;
    let Some(docs) = doc.get("tasks").and_then(Json::as_arr) else {
        return (ST_ERR, Vec::new());
    };
    let mut tasks = Vec::with_capacity(docs.len());
    for d in docs {
        let Some(id) = d.get("id").and_then(Json::as_i64) else {
            return (ST_ERR, Vec::new());
        };
        // deps arrive either as bare ids or as the dispatcher's richer
        // `{id, kind, key}` records (task_doc) — accept both, readiness
        // gating only needs the id
        let deps = d
            .get("deps")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .filter_map(|el| {
                el.as_i64().or_else(|| el.get("id").and_then(Json::as_i64))
            })
            .map(|x| x.max(0) as u64)
            .collect();
        tasks.push(ServedTask {
            id: id.max(0) as u64,
            doc: d.clone(),
            deps,
            state: TaskState::Open,
        });
    }
    let mut s = lock(state);
    s.next_queue += 1;
    let qid = s.next_queue;
    s.queues.insert(
        qid,
        ServedQueue {
            lease_ms,
            tune,
            trace,
            metrics: metrics_on,
            faults,
            deadline_ms,
            tasks,
            spans: Vec::new(),
            metric_docs: Vec::new(),
            last_progress: Instant::now(),
        },
    );
    (ST_OK, qid.to_le_bytes().to_vec())
}

/// Reopen claims whose heartbeat went silent for a full lease (the
/// connected-but-stuck case; dead connections are reclaimed eagerly by
/// `release_conn`), plus — when the queue carries a deadline — claims
/// held past it even with a live heartbeat (hung worker: the stage is
/// wedged but its beat thread still runs).
fn reclaim_stale(q: &mut ServedQueue) {
    let lease = Duration::from_millis(q.lease_ms);
    let deadline = Duration::from_millis(q.deadline_ms);
    for t in &mut q.tasks {
        let expired = matches!(
            t.state,
            TaskState::Claimed { last_beat, since, .. }
                if last_beat.elapsed() > lease
                    || (q.deadline_ms > 0 && since.elapsed() > deadline)
        );
        if expired {
            t.state = TaskState::Open;
        }
    }
}

/// Claim selection shared by `OP_CLAIM` and `OP_CLAIM_DEPS`: mark the
/// first ready task of the first eligible queue claimed by `conn_id`
/// and return the claim doc.
fn try_claim(s: &mut Shared, conn_id: u64, want: u64) -> Option<Json> {
    // even an idle claimer is part of the fleet: the parent must see
    // it in the worker count before deciding to drain the queue itself
    s.workers.insert(conn_id);
    touch_fleet(s, conn_id);
    let mut qids: Vec<u64> = s.queues.keys().copied().collect();
    qids.sort_unstable();
    for qid in qids {
        if want != 0 && qid != want {
            continue;
        }
        let q = s.queues.get_mut(&qid).expect("queue id from key scan");
        reclaim_stale(q);
        // readiness = every dep has a done record (failed deps count:
        // the claimer propagates the failure); lowest id first, the
        // same order the local queue drains in
        let ready = (0..q.tasks.len()).find(|&i| {
            matches!(q.tasks[i].state, TaskState::Open)
                && q.tasks[i].deps.iter().all(|d| {
                    q.tasks
                        .iter()
                        .any(|t| t.id == *d && matches!(t.state, TaskState::Done(_)))
                })
        });
        let Some(i) = ready else { continue };
        q.tasks[i].state = TaskState::Claimed {
            conn: conn_id,
            last_beat: Instant::now(),
            since: Instant::now(),
        };
        q.last_progress = Instant::now();
        let task = q.tasks[i].doc.clone();
        let metrics_on = q.metrics;
        let deps_done: Vec<Json> = q.tasks[i]
            .deps
            .iter()
            .filter_map(|d| {
                q.tasks.iter().find_map(|t| match (&t.state, t.id == *d) {
                    (TaskState::Done(rec), true) => Some(rec.clone()),
                    _ => None,
                })
            })
            .collect();
        let claim = Json::obj(vec![
            ("queue", Json::Num(qid as f64)),
            ("lease_ms", Json::Num(q.lease_ms as f64)),
            ("tune", q.tune.clone()),
            ("trace", Json::Bool(q.trace)),
            ("metrics", Json::Bool(metrics_on)),
            ("faults", Json::Str(q.faults.clone())),
            ("deadline_ms", Json::Num(q.deadline_ms as f64)),
            ("task", task),
            ("deps_done", Json::Arr(deps_done)),
        ]);
        if let Some(w) = s.fleet.get_mut(&conn_id) {
            w.claims += 1;
        }
        return Some(claim);
    }
    None
}

fn op_claim(
    state: &ServeState,
    conn_id: u64,
    payload: &[u8],
) -> (u8, Vec<u8>) {
    if payload.len() < 8 {
        return (ST_ERR, Vec::new());
    }
    let want = u64::from_le_bytes(payload[..8].try_into().unwrap());
    match try_claim(&mut lock(state), conn_id, want) {
        Some(doc) => (ST_OK, doc.to_string().into_bytes()),
        None => (ST_EMPTY, Vec::new()),
    }
}

/// Entry refs a claimed task will fetch before executing: its own
/// `(kind, key)` — the primary lookup — plus each dep's. The task
/// docs carry the stage name and hex key (`task_doc` in dispatch.rs);
/// docs without them (hand-rolled queues) prefetch nothing.
fn claim_entry_refs(doc: &Json) -> Vec<(CachedStage, StageKey)> {
    fn one(d: &Json) -> Option<(CachedStage, StageKey)> {
        let stage = CachedStage::from_name(d.get("kind")?.as_str()?)?;
        let key = u64::from_str_radix(d.get("key")?.as_str()?, 16).ok()?;
        Some((stage, StageKey(key)))
    }
    let Some(task) = doc.get("task") else { return Vec::new() };
    let mut refs: Vec<(CachedStage, StageKey)> = one(task).into_iter().collect();
    for dep in task.get("deps").and_then(Json::as_arr).unwrap_or(&[]) {
        if let Some(r) = one(dep) {
            if !refs.contains(&r) {
                refs.push(r);
            }
        }
    }
    refs
}

fn op_claim_deps(
    state: &ServeState,
    conn_id: u64,
    payload: &[u8],
) -> (u8, Vec<u8>) {
    if payload.len() < 8 {
        return (ST_ERR, Vec::new());
    }
    let want = u64::from_le_bytes(payload[..8].try_into().unwrap());
    // collect the refs under the queue lock, fetch the bytes outside
    // it — artifact I/O must not stall claim bookkeeping
    let (doc, refs) = {
        let mut s = lock(state);
        match try_claim(&mut s, conn_id, want) {
            Some(doc) => {
                let refs = claim_entry_refs(&doc);
                (doc, refs)
            }
            None => return (ST_EMPTY, Vec::new()),
        }
    };
    let claim = doc.to_string().into_bytes();
    let mut body = (claim.len() as u32).to_le_bytes().to_vec();
    body.extend_from_slice(&claim);
    let mut entries = Vec::new();
    let mut count = 0u32;
    let mut budget = MGET_BODY_BUDGET.saturating_sub(body.len() + 4);
    for (stage, key) in refs {
        // only hits ride along — a missing entry is not an error,
        // the claimer computes it like it always has
        let Some(bytes) = fetch_entry(state, stage, key) else { continue };
        if bytes.len() + 13 > budget {
            continue;
        }
        budget -= bytes.len() + 13;
        entries.extend_from_slice(&entry_ref(stage, key));
        entries.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
        entries.extend_from_slice(&bytes);
        count += 1;
    }
    body.extend_from_slice(&count.to_le_bytes());
    body.extend_from_slice(&entries);
    (ST_OK, body)
}

fn parse_two_u64(payload: &[u8]) -> Option<(u64, u64)> {
    if payload.len() < 16 {
        return None;
    }
    Some((
        u64::from_le_bytes(payload[..8].try_into().unwrap()),
        u64::from_le_bytes(payload[8..16].try_into().unwrap()),
    ))
}

fn op_beat(
    state: &ServeState,
    conn_id: u64,
    payload: &[u8],
) -> (u8, Vec<u8>) {
    let Some((qid, tid)) = parse_two_u64(payload) else {
        return (ST_ERR, Vec::new());
    };
    let mut s = lock(state);
    if let Some(q) = s.queues.get_mut(&qid) {
        for t in &mut q.tasks {
            if t.id == tid {
                if let TaskState::Claimed { conn, ref mut last_beat, .. } =
                    t.state
                {
                    // only the claim owner refreshes: a reclaimed task
                    // belongs to someone else now
                    if conn == conn_id {
                        *last_beat = Instant::now();
                        return (ST_OK, Vec::new());
                    }
                }
                return (ST_MISS, Vec::new());
            }
        }
    }
    (ST_MISS, Vec::new())
}

fn op_done(
    state: &ServeState,
    conn_id: u64,
    payload: &[u8],
) -> (u8, Vec<u8>) {
    let Some((qid, tid)) = parse_two_u64(payload) else {
        return (ST_ERR, Vec::new());
    };
    let Ok(text) = std::str::from_utf8(&payload[16..]) else {
        return (ST_ERR, Vec::new());
    };
    let Ok(rec) = Json::parse(text) else {
        return (ST_ERR, Vec::new());
    };
    let mut s = lock(state);
    if let Some(w) = s.fleet.get_mut(&conn_id) {
        w.last_seen = Instant::now();
        w.done += 1;
    }
    let Some(q) = s.queues.get_mut(&qid) else {
        // a straggler reporting into a retired queue: its result was
        // already superseded and drained — dropping it is the queue
        // analogue of first-writer-wins, not an error
        return (ST_MISS, Vec::new());
    };
    for t in &mut q.tasks {
        if t.id == tid {
            // first writer wins, exactly like the local queue's
            // hard-link done records: a reclaimed-then-finished
            // duplicate is dropped silently
            if !matches!(t.state, TaskState::Done(_)) {
                t.state = TaskState::Done(rec);
                q.last_progress = Instant::now();
            }
            return (ST_OK, Vec::new());
        }
    }
    (ST_ERR, Vec::new())
}

fn op_poll(
    state: &ServeState,
    conn_id: u64,
    payload: &[u8],
) -> (u8, Vec<u8>) {
    if payload.len() < 8 {
        return (ST_ERR, Vec::new());
    }
    let qid = u64::from_le_bytes(payload[..8].try_into().unwrap());
    let mut s = lock(state);
    // the poller is the parent: it must not count itself as a worker
    let workers = s.workers.iter().filter(|&&c| c != conn_id).count();
    let Some(q) = s.queues.get_mut(&qid) else {
        return (ST_ERR, Vec::new());
    };
    reclaim_stale(q);
    let mut open = 0usize;
    let mut claimed = 0usize;
    let done: Vec<Json> = q
        .tasks
        .iter()
        .filter_map(|t| match &t.state {
            TaskState::Done(rec) => Some(rec.clone()),
            TaskState::Open => {
                open += 1;
                None
            }
            TaskState::Claimed { .. } => {
                claimed += 1;
                None
            }
        })
        .collect();
    // a u128 millisecond age converts lossily through `as f64`; clamp
    // through u64 so an absurd clock can only saturate, never wrap
    let stalled_ms = u64::try_from(q.last_progress.elapsed().as_millis())
        .unwrap_or(u64::MAX);
    // worker spans and metrics snapshots are handed to the poller
    // exactly once
    let spans = std::mem::take(&mut q.spans);
    let metric_docs = std::mem::take(&mut q.metric_docs);
    let rsp = Json::obj(vec![
        ("total", Json::Num(q.tasks.len() as f64)),
        ("open", Json::Num(open as f64)),
        ("claimed", Json::Num(claimed as f64)),
        ("workers", Json::Num(workers as f64)),
        ("stalled_ms", Json::Num(stalled_ms as f64)),
        ("done", Json::Arr(done)),
        ("spans", Json::Arr(spans)),
        ("metrics", Json::Arr(metric_docs)),
    ]);
    // every task has reported and this poll hands over the full
    // result set (done records are cumulative, spans just drained):
    // the queue's life is over — retire it instead of leaking one
    // ServedQueue per session for the daemon's whole uptime
    if open == 0 && claimed == 0 {
        s.queues.remove(&qid);
        state.queues_retired.fetch_add(1, Ordering::Relaxed);
    }
    (ST_OK, rsp.to_string().into_bytes())
}

/// Pool tracer spans shipped by a queue's workers
/// (`qid u64 | Chrome trace JSON`) until the parent polls them off.
fn op_trace_put(state: &ServeState, payload: &[u8]) -> (u8, Vec<u8>) {
    if payload.len() < 8 {
        return (ST_ERR, Vec::new());
    }
    let qid = u64::from_le_bytes(payload[..8].try_into().unwrap());
    let Ok(text) = std::str::from_utf8(&payload[8..]) else {
        return (ST_ERR, Vec::new());
    };
    let Ok(doc) = Json::parse(text) else {
        return (ST_ERR, Vec::new());
    };
    let Some(events) = doc.get("traceEvents").and_then(Json::as_arr) else {
        return (ST_ERR, Vec::new());
    };
    let mut s = lock(state);
    let Some(q) = s.queues.get_mut(&qid) else {
        // retired queue: the poller is gone, nobody will drain these
        // spans — drop them like a straggler's done record
        return (ST_MISS, Vec::new());
    };
    q.spans.extend(events.iter().cloned());
    (ST_OK, Vec::new())
}

fn op_blob_put(state: &ServeState, payload: &[u8]) -> (u8, Vec<u8>) {
    if payload.len() < 8 {
        return (ST_ERR, Vec::new());
    }
    let fp = u64::from_le_bytes(payload[..8].try_into().unwrap());
    let bytes = Arc::new(payload[8..].to_vec());
    lock(state).blobs.insert(fp, bytes);
    (ST_OK, Vec::new())
}

fn op_blob_get(state: &ServeState, payload: &[u8]) -> (u8, Vec<u8>) {
    if payload.len() < 8 {
        return (ST_ERR, Vec::new());
    }
    let fp = u64::from_le_bytes(payload[..8].try_into().unwrap());
    match lock(state).blobs.get(&fp) {
        Some(bytes) => (ST_OK, bytes.as_ref().clone()),
        None => (ST_MISS, Vec::new()),
    }
}

fn op_stats(state: &ServeState) -> (u8, Vec<u8>) {
    let doc = Json::obj(stats_fields(state));
    (ST_OK, doc.to_string().into_bytes())
}

/// The OP_STATS field set, shared with OP_METRICS (which extends it
/// with the registry, the snapshot ring and per-worker liveness).
fn stats_fields(state: &ServeState) -> Vec<(&'static str, Json)> {
    let (blobs, queues, workers, conns, open, claimed, done) = {
        let s = lock(state);
        let (mut open, mut claimed, mut done) = (0usize, 0usize, 0usize);
        for q in s.queues.values() {
            for t in &q.tasks {
                match t.state {
                    TaskState::Open => open += 1,
                    TaskState::Claimed { .. } => claimed += 1,
                    TaskState::Done(_) => done += 1,
                }
            }
        }
        (
            s.blobs.len(),
            s.queues.len(),
            s.workers.len(),
            s.conns.len(),
            open,
            claimed,
            done,
        )
    };
    let st = state.store.stats();
    let mem = {
        let m = state.mem.lock().unwrap_or_else(|e| e.into_inner());
        m.stats()
    };
    let ops = state.ops.load(Ordering::Relaxed);
    let uptime_ms = u64::try_from(state.started.elapsed().as_millis())
        .unwrap_or(u64::MAX)
        .max(1);
    vec![
        ("format", Json::Num(persist::FORMAT_VERSION as f64)),
        ("entries", Json::Num(st.entries as f64)),
        ("total_bytes", Json::Num(st.total_bytes as f64)),
        ("loads", Json::Num(st.loads as f64)),
        ("tunes", Json::Num(st.tunes as f64)),
        ("builds", Json::Num(st.builds as f64)),
        ("blobs", Json::Num(blobs as f64)),
        ("queues", Json::Num(queues as f64)),
        ("workers", Json::Num(workers as f64)),
        // serve-tier throughput + hygiene gauges
        ("conns", Json::Num(conns as f64)),
        ("ops", Json::Num(ops as f64)),
        ("ops_per_sec", Json::Num(ops as f64 * 1000.0 / uptime_ms as f64)),
        ("uptime_ms", Json::Num(uptime_ms as f64)),
        (
            "bytes_served",
            Json::Num(state.bytes_served.load(Ordering::Relaxed) as f64),
        ),
        ("store_reads", Json::Num(state.store.read_ops() as f64)),
        ("mem_hits", Json::Num(mem.hits as f64)),
        ("mem_misses", Json::Num(mem.misses as f64)),
        ("mem_entries", Json::Num(mem.entries as f64)),
        ("mem_bytes", Json::Num(mem.bytes as f64)),
        ("mem_budget", Json::Num(mem.budget as f64)),
        ("mem_evictions", Json::Num(mem.evictions as f64)),
        (
            "queues_retired",
            Json::Num(state.queues_retired.load(Ordering::Relaxed) as f64),
        ),
        ("tasks_open", Json::Num(open as f64)),
        ("tasks_claimed", Json::Num(claimed as f64)),
        ("tasks_done", Json::Num(done as f64)),
    ]
}

/// `OP_METRICS`: the OP_STATS fields plus the daemon's merged metrics
/// registry (its own wire/store numbers and everything workers
/// shipped via `OP_METRICS_PUT`), the snapshot ring of timestamped
/// deltas, and one liveness row per claiming connection.
fn op_metrics(state: &ServeState) -> (u8, Vec<u8>) {
    let mut fields = stats_fields(state);
    fields.push(("registry", metrics::snapshot().to_json()));
    fields.push((
        "ring",
        state.ring.lock().unwrap_or_else(|e| e.into_inner()).to_json(),
    ));
    let workers_live = {
        let s = lock(state);
        let mut rows: Vec<&FleetWorker> = s.fleet.values().collect();
        rows.sort_by(|a, b| a.addr.cmp(&b.addr));
        Json::Arr(rows.iter().map(|w| w.to_json()).collect())
    };
    fields.push(("workers_live", workers_live));
    (ST_OK, Json::obj(fields).to_string().into_bytes())
}

/// Pool a worker's drained metrics snapshot (`qid u64 | snapshot
/// JSON`) for the parent's next POLL, and merge it into the daemon's
/// own registry so `mlonmcu top` sees fleet-wide distributions even
/// after the queue is gone.
fn op_metrics_put(
    state: &ServeState,
    conn_id: u64,
    payload: &[u8],
) -> (u8, Vec<u8>) {
    if payload.len() < 8 {
        return (ST_ERR, Vec::new());
    }
    let qid = u64::from_le_bytes(payload[..8].try_into().unwrap());
    let Ok(text) = std::str::from_utf8(&payload[8..]) else {
        return (ST_ERR, Vec::new());
    };
    let Ok(doc) = Json::parse(text) else {
        return (ST_ERR, Vec::new());
    };
    let Ok(snap) = metrics::Snapshot::from_json(&doc) else {
        return (ST_ERR, Vec::new());
    };
    metrics::record_all(&snap);
    let mut s = lock(state);
    if let Some(w) = s.fleet.get_mut(&conn_id) {
        w.last_seen = Instant::now();
    }
    let Some(q) = s.queues.get_mut(&qid) else {
        // retired queue: the poller is gone — the registry merge above
        // already preserved the numbers for `top`
        return (ST_MISS, Vec::new());
    };
    q.metric_docs.push(doc);
    (ST_OK, Vec::new())
}

// ================================================================ client --

/// Client-side knobs, from the `[remote]` config section.
#[derive(Debug, Clone)]
pub struct RemoteConfig {
    pub addr: String,
    pub timeout_ms: u64,
    pub retries: u32,
    pub backoff_ms: u64,
    /// Queue-stall age after which a dispatching parent drains one
    /// task itself instead of waiting for workers.
    pub grace_ms: u64,
}

impl RemoteConfig {
    /// `None` when no server is configured (`remote.connect` empty).
    pub fn from_env(env: &Environment) -> Option<RemoteConfig> {
        Some(RemoteConfig {
            addr: env.remote_connect()?,
            timeout_ms: env.remote_timeout_ms(),
            retries: env.remote_retries(),
            backoff_ms: env.remote_backoff_ms(),
            grace_ms: env.remote_grace_ms(),
        })
    }
}

/// Outcome of a CLAIM: a task, an empty queue, or a server that
/// refused us outright (version-gated) — a refused worker must exit
/// rather than poll forever.
pub enum Claim {
    Task(Json),
    Empty,
    Refused,
}

/// Idle pooled streams kept per client — enough that a worker's main
/// loop, its heartbeat thread and a couple of prefetches overlap
/// without reconnecting, small enough that a fleet of clients doesn't
/// hold thousands of sockets open.
const POOL_CAP: usize = 4;

/// One logical link to a serve daemon: lazy connect, per-request
/// timeout, bounded retry with exponential backoff + jitter.
///
/// Concurrent callers do not serialize: stateless ops (get/put/blob/
/// stats/…) check a stream out of a small pool for exactly the
/// duration of one exchange, and every backoff sleep runs with no
/// lock held. Queue ops (claim/beat/poll) instead share one *pinned*
/// stream — the server binds a claim to the connection that made it
/// (the connection's liveness is the lease), so they must all present
/// the same identity.
pub struct Client {
    cfg: RemoteConfig,
    pool: Mutex<Vec<TcpStream>>,
    queue_slot: Mutex<Option<TcpStream>>,
    /// Jitter source; locked only for the draw, never across I/O or
    /// sleeps.
    rng: Mutex<XorShift64>,
}

impl Client {
    pub fn new(cfg: RemoteConfig) -> Client {
        Client {
            cfg,
            pool: Mutex::new(Vec::new()),
            queue_slot: Mutex::new(None),
            rng: Mutex::new(XorShift64::from_entropy()),
        }
    }

    pub fn addr(&self) -> &str {
        &self.cfg.addr
    }

    fn connect(cfg: &RemoteConfig) -> Result<TcpStream> {
        let timeout = Duration::from_millis(cfg.timeout_ms);
        let addrs: Vec<SocketAddr> = cfg
            .addr
            .to_socket_addrs()
            .with_context(|| format!("resolving {}", cfg.addr))?
            .collect();
        let mut last: Option<std::io::Error> = None;
        for addr in addrs {
            match TcpStream::connect_timeout(&addr, timeout) {
                Ok(s) => {
                    let _ = s.set_read_timeout(Some(timeout));
                    let _ = s.set_write_timeout(Some(timeout));
                    let _ = s.set_nodelay(true);
                    return Ok(s);
                }
                Err(e) => last = Some(e),
            }
        }
        match last {
            Some(e) => Err(e).context(format!("connecting {}", cfg.addr)),
            None => bail!("{} resolves to no address", cfg.addr),
        }
    }

    /// One exchange over `stream` (connecting it first if `None`). On
    /// error the caller drops the stream: a half-used connection can't
    /// be trusted for the next frame.
    fn attempt(
        cfg: &RemoteConfig,
        stream: &mut Option<TcpStream>,
        op: u8,
        payload: &[u8],
    ) -> Result<(u8, Vec<u8>)> {
        if stream.is_none() {
            *stream = Some(Self::connect(cfg)?);
        }
        let s = stream.as_mut().expect("stream just connected");
        // injected send faults feed the real retry/degrade
        // machinery: a dropped frame is a transport error, a
        // torn frame actually hits the wire (the server junks
        // the connection) before erroring out here
        use crate::util::faults::{self, FaultKind};
        match faults::fire("transport.send") {
            Some(FaultKind::Drop) => {
                bail!("injected fault at transport.send: frame dropped")
            }
            Some(FaultKind::Truncate) => {
                let mut buf = Vec::new();
                write_frame(&mut buf, REQ_MAGIC, op, payload)?;
                buf.truncate(buf.len() / 2);
                let _ = s.write_all(&buf);
                let _ = s.flush();
                bail!("injected fault at transport.send: frame torn")
            }
            _ => {} // Delay already slept inside fire
        }
        write_frame(s, REQ_MAGIC, op, payload)?;
        match faults::fire("transport.recv") {
            Some(FaultKind::Drop) | Some(FaultKind::Truncate) => {
                // abandon the in-flight response; the error path
                // resets the connection so no desynced frame is
                // ever parsed
                bail!("injected fault at transport.recv: response lost")
            }
            _ => {}
        }
        let (version, status, body) = read_frame(s, RSP_MAGIC)?;
        if version != persist::FORMAT_VERSION {
            // version skew is a miss, never a crash and never a
            // retried "error"
            return Ok((ST_MISS, Vec::new()));
        }
        Ok((status, body))
    }

    /// Exponential backoff (doubling, capped) plus jitter so a fleet
    /// doesn't hammer in lockstep. Runs with **no lock held** — one
    /// request riding out its backoff must not convoy the process's
    /// other wire traffic.
    fn backoff(&self, attempt: u32) {
        let base = self.cfg.backoff_ms.max(1) << (attempt - 1).min(6);
        let jitter = {
            let mut rng = self.rng.lock().unwrap_or_else(|e| e.into_inner());
            rng.below(base)
        };
        std::thread::sleep(Duration::from_millis(base + jitter));
    }

    /// One request → one response over a pooled stream, retrying
    /// transport errors up to `retries` times. Concurrent callers each
    /// check out their own stream, so requests — and their backoff
    /// sleeps — never serialize behind one another.
    pub fn request(&self, op: u8, payload: &[u8]) -> Result<(u8, Vec<u8>)> {
        let _span = crate::util::trace::span("transport", op_name(op))
            .arg("addr", self.cfg.addr.as_str());
        let clock = metrics::clock();
        let mut last_err = None;
        for attempt in 0..=self.cfg.retries {
            if attempt > 0 {
                self.backoff(attempt);
            }
            let mut stream = {
                let mut pool =
                    self.pool.lock().unwrap_or_else(|e| e.into_inner());
                pool.pop()
            };
            match Self::attempt(&self.cfg, &mut stream, op, payload) {
                Ok(r) => {
                    if let Some(s) = stream {
                        let mut pool =
                            self.pool.lock().unwrap_or_else(|e| e.into_inner());
                        if pool.len() < POOL_CAP {
                            pool.push(s);
                        }
                    }
                    clock.observe_fn(|| format!("wire.client.{}.us", op_name(op)));
                    metrics::observe("wire.client.rsp.bytes", r.1.len() as u64);
                    return Ok(r);
                }
                Err(e) => last_err = Some(e), // broken stream dropped
            }
        }
        Err(last_err.expect("at least one attempt ran"))
    }

    /// `request` over the pinned queue stream. The server binds claims
    /// to the connection that made them and a beat from any other
    /// connection is refused, so CLAIM/BEAT/POLL must share one
    /// stream; the slot lock covers only the exchange itself — backoff
    /// sleeps happen between lock holds.
    fn request_pinned(&self, op: u8, payload: &[u8]) -> Result<(u8, Vec<u8>)> {
        let _span = crate::util::trace::span("transport", op_name(op))
            .arg("addr", self.cfg.addr.as_str());
        let clock = metrics::clock();
        let mut last_err = None;
        for attempt in 0..=self.cfg.retries {
            if attempt > 0 {
                self.backoff(attempt);
            }
            let mut slot =
                self.queue_slot.lock().unwrap_or_else(|e| e.into_inner());
            match Self::attempt(&self.cfg, &mut slot, op, payload) {
                Ok(r) => {
                    clock.observe_fn(|| format!("wire.client.{}.us", op_name(op)));
                    metrics::observe("wire.client.rsp.bytes", r.1.len() as u64);
                    return Ok(r);
                }
                Err(e) => {
                    // reconnecting means a new server-side identity:
                    // claims held by the dead stream are already being
                    // released, exactly like a worker that died
                    *slot = None;
                    last_err = Some(e);
                }
            }
        }
        Err(last_err.expect("at least one attempt ran"))
    }

    /// Reachability probe; returns the server's format version.
    pub fn ping(&self) -> Result<u32> {
        let (status, body) = self.request(OP_PING, &[])?;
        if status != ST_OK || body.len() < 4 {
            bail!("ping refused (status {status})");
        }
        Ok(u32::from_le_bytes(body[..4].try_into().unwrap()))
    }

    /// Fetch an entry's raw bytes. `Ok(None)` is a miss; the caller
    /// still has to `persist::decode` (and treat failure as a miss).
    pub fn get(&self, stage: CachedStage, key: StageKey) -> Result<Option<Vec<u8>>> {
        let (status, body) = self.request(OP_GET, &entry_ref(stage, key))?;
        match status {
            ST_OK => Ok(Some(body)),
            ST_MISS | ST_EMPTY => Ok(None),
            _ => bail!("remote get failed (status {status})"),
        }
    }

    /// Fetch many entries in one round trip; `None` per entry means
    /// miss (or an entry the response budget couldn't fit — re-`get`
    /// it individually if it matters). A version-gated server answers
    /// all-`None`, same as per-entry misses.
    pub fn mget(
        &self,
        refs: &[(CachedStage, StageKey)],
    ) -> Result<Vec<Option<Vec<u8>>>> {
        if refs.is_empty() {
            return Ok(Vec::new());
        }
        let mut payload = (refs.len() as u32).to_le_bytes().to_vec();
        for &(stage, key) in refs {
            payload.extend_from_slice(&entry_ref(stage, key));
        }
        let (status, body) = self.request(OP_MGET, &payload)?;
        if status != ST_OK {
            return Ok(vec![None; refs.len()]);
        }
        let mut out = Vec::with_capacity(refs.len());
        let mut at = 0usize;
        for _ in 0..refs.len() {
            let Some(head) = body.get(at..at + 5) else {
                // truncated tail: the entries we did get stand
                out.push(None);
                continue;
            };
            let st = head[0];
            let len =
                u32::from_le_bytes(head[1..5].try_into().unwrap()) as usize;
            at += 5;
            match (st, body.get(at..at + len)) {
                (ST_OK, Some(bytes)) => {
                    out.push(Some(bytes.to_vec()));
                    at += len;
                }
                _ => out.push(None),
            }
        }
        Ok(out)
    }

    /// Push an already-encoded entry; the server re-verifies it.
    pub fn put(&self, stage: CachedStage, key: StageKey, bytes: &[u8]) -> Result<()> {
        let mut payload = entry_ref(stage, key).to_vec();
        payload.extend_from_slice(bytes);
        let (status, _) = self.request(OP_PUT, &payload)?;
        if status != ST_OK {
            bail!("remote put refused (status {status})");
        }
        Ok(())
    }

    pub fn blob_put(&self, fp: u64, bytes: &[u8]) -> Result<()> {
        let mut payload = fp.to_le_bytes().to_vec();
        payload.extend_from_slice(bytes);
        let (status, _) = self.request(OP_BLOB_PUT, &payload)?;
        if status != ST_OK {
            bail!("blob put refused (status {status})");
        }
        Ok(())
    }

    pub fn blob_get(&self, fp: u64) -> Result<Option<Vec<u8>>> {
        let (status, body) = self.request(OP_BLOB_GET, &fp.to_le_bytes())?;
        match status {
            ST_OK => Ok(Some(body)),
            ST_MISS | ST_EMPTY => Ok(None),
            _ => bail!("blob get failed (status {status})"),
        }
    }

    /// Publish a queue document; returns the served queue id.
    pub fn qpush(&self, doc: &Json) -> Result<u64> {
        let (status, body) = self.request(OP_QPUSH, doc.to_string().as_bytes())?;
        if status != ST_OK || body.len() < 8 {
            bail!("queue push refused (status {status})");
        }
        Ok(u64::from_le_bytes(body[..8].try_into().unwrap()))
    }

    /// Claim the next ready task (`queue` 0 = any queue).
    pub fn claim(&self, queue: u64) -> Result<Claim> {
        let (status, body) = self.request_pinned(OP_CLAIM, &queue.to_le_bytes())?;
        match status {
            ST_OK => {
                let text = std::str::from_utf8(&body)?;
                Ok(Claim::Task(Json::parse(text)?))
            }
            ST_EMPTY => Ok(Claim::Empty),
            // MISS here means the server version-gated us
            _ => Ok(Claim::Refused),
        }
    }

    /// Claim the next ready task *and* receive the artifacts it will
    /// fetch (its own stage entry, if cached, plus its deps') in the
    /// same round trip. Entries that didn't ride along are simply
    /// absent — the claimer falls back to `get` per entry.
    pub fn claim_deps(
        &self,
        queue: u64,
    ) -> Result<(Claim, Vec<((CachedStage, StageKey), Vec<u8>)>)> {
        let (status, body) =
            self.request_pinned(OP_CLAIM_DEPS, &queue.to_le_bytes())?;
        match status {
            ST_OK => {}
            ST_EMPTY => return Ok((Claim::Empty, Vec::new())),
            _ => return Ok((Claim::Refused, Vec::new())),
        }
        let too_short = || anyhow::anyhow!("claim-deps response truncated");
        if body.len() < 4 {
            return Err(too_short());
        }
        let dlen = u32::from_le_bytes(body[..4].try_into().unwrap()) as usize;
        let mut at = 4usize;
        let text = std::str::from_utf8(
            body.get(at..at + dlen).ok_or_else(too_short)?,
        )?;
        let doc = Json::parse(text)?;
        at += dlen;
        let count = u32::from_le_bytes(
            body.get(at..at + 4).ok_or_else(too_short)?.try_into().unwrap(),
        ) as usize;
        at += 4;
        let mut entries = Vec::with_capacity(count.min(64));
        for _ in 0..count {
            let head = body.get(at..at + 13).ok_or_else(too_short)?;
            let (stage, key) = parse_entry_ref(&head[..9])
                .ok_or_else(|| anyhow::anyhow!("claim-deps bad entry ref"))?;
            let len =
                u32::from_le_bytes(head[9..13].try_into().unwrap()) as usize;
            at += 13;
            let bytes = body.get(at..at + len).ok_or_else(too_short)?;
            at += len;
            entries.push(((stage, key), bytes.to_vec()));
        }
        Ok((Claim::Task(doc), entries))
    }

    pub fn beat(&self, queue: u64, task: u64) -> Result<()> {
        let mut payload = queue.to_le_bytes().to_vec();
        payload.extend_from_slice(&task.to_le_bytes());
        self.request_pinned(OP_BEAT, &payload).map(|_| ())
    }

    pub fn done(&self, queue: u64, task: u64, record: &Json) -> Result<()> {
        let mut payload = queue.to_le_bytes().to_vec();
        payload.extend_from_slice(&task.to_le_bytes());
        payload.extend_from_slice(record.to_string().as_bytes());
        let (status, _) = self.request(OP_DONE, &payload)?;
        // MISS: the queue was already drained and retired — this
        // straggler's record has nowhere to go, which is fine
        if status != ST_OK && status != ST_MISS {
            bail!("done record refused (status {status})");
        }
        Ok(())
    }

    /// Queue progress: `{total, open, claimed, workers, stalled_ms,
    /// done: [...], spans: [...]}`. Pinned: the poller's own claim
    /// connection must be the one excluded from the worker count.
    pub fn poll(&self, queue: u64) -> Result<Json> {
        let (status, body) = self.request_pinned(OP_POLL, &queue.to_le_bytes())?;
        if status != ST_OK {
            bail!("poll refused (status {status})");
        }
        Ok(Json::parse(std::str::from_utf8(&body)?)?)
    }

    /// Server-side store stats as JSON (`cache stats --connect`).
    pub fn stats(&self) -> Result<Json> {
        let (status, body) = self.request(OP_STATS, &[])?;
        if status != ST_OK {
            bail!("stats refused (status {status})");
        }
        Ok(Json::parse(std::str::from_utf8(&body)?)?)
    }

    /// Ship drained tracer spans for a served queue. Workers call this
    /// right before `done` so the poll that observes the completion
    /// also collects (or has already collected) the spans behind it.
    pub fn trace_put(
        &self,
        queue: u64,
        spans: Vec<crate::util::trace::Span>,
    ) -> Result<()> {
        let mut payload = queue.to_le_bytes().to_vec();
        payload.extend_from_slice(
            crate::util::trace::to_chrome_json(spans).as_bytes(),
        );
        let (status, _) = self.request(OP_TRACE_PUT, &payload)?;
        // MISS: queue already drained + retired; dropping a
        // straggler's spans mirrors dropping its done record
        if status != ST_OK && status != ST_MISS {
            bail!("trace put refused (status {status})");
        }
        Ok(())
    }

    /// Fleet metrics pull (`mlonmcu top`, `metrics export --connect`).
    /// `ST_MISS` means the server version-gated us.
    pub fn metrics(&self) -> Result<Json> {
        let (status, body) = self.request(OP_METRICS, &[])?;
        if status == ST_MISS {
            bail!("metrics refused: server speaks another format version");
        }
        if status != ST_OK {
            bail!("metrics refused (status {status})");
        }
        Ok(Json::parse(std::str::from_utf8(&body)?)?)
    }

    /// Ship a drained metrics snapshot for a served queue. Workers
    /// call this right before `done`, mirroring `trace_put`, so the
    /// poll observing the completion also collects the numbers.
    pub fn metrics_put(
        &self,
        queue: u64,
        snap: &metrics::Snapshot,
    ) -> Result<()> {
        let mut payload = queue.to_le_bytes().to_vec();
        payload.extend_from_slice(snap.to_json().to_string().as_bytes());
        let (status, _) = self.request(OP_METRICS_PUT, &payload)?;
        // MISS: queue already drained + retired (the server still
        // merged the snapshot into its own registry), or version skew
        if status != ST_OK && status != ST_MISS {
            bail!("metrics put refused (status {status})");
        }
        Ok(())
    }
}

/// Human-readable op name for transport spans and diagnostics.
pub fn op_name(op: u8) -> &'static str {
    match op {
        OP_PING => "ping",
        OP_GET => "get",
        OP_PUT => "put",
        OP_QPUSH => "qpush",
        OP_CLAIM => "claim",
        OP_BEAT => "beat",
        OP_DONE => "done",
        OP_POLL => "poll",
        OP_BLOB_PUT => "blob-put",
        OP_BLOB_GET => "blob-get",
        OP_STATS => "stats",
        OP_TRACE_PUT => "trace-put",
        OP_MGET => "mget",
        OP_CLAIM_DEPS => "claim-deps",
        OP_METRICS => "metrics",
        OP_METRICS_PUT => "metrics-put",
        _ => "op?",
    }
}

// =========================================================== store tier --

/// Outcome of a remote-tier lookup, as the cache's counters see it.
pub enum RemoteLookup {
    Hit(Artifact),
    Miss,
    /// Transport failure (counted once — the tier then degrades).
    Error,
    /// Tier degraded to local-only; nothing was attempted.
    Off,
}

/// The remote cache tier: consulted after the local env store misses,
/// with a circuit breaker that degrades to local-only on the first
/// transport failure (counted and reported, never fatal).
pub struct RemoteStore {
    client: Client,
    degraded: AtomicBool,
}

impl RemoteStore {
    pub fn new(cfg: RemoteConfig) -> RemoteStore {
        RemoteStore { client: Client::new(cfg), degraded: AtomicBool::new(false) }
    }

    /// `None` unless `remote.connect` (or `--connect`) names a server.
    /// Construction never dials out — the first lookup does.
    pub fn from_env(env: &Environment) -> Option<Arc<RemoteStore>> {
        RemoteConfig::from_env(env).map(|cfg| Arc::new(RemoteStore::new(cfg)))
    }

    pub fn client(&self) -> &Client {
        &self.client
    }

    pub fn config(&self) -> &RemoteConfig {
        &self.client.cfg
    }

    pub fn addr(&self) -> &str {
        self.client.addr()
    }

    pub fn is_degraded(&self) -> bool {
        self.degraded.load(Ordering::SeqCst)
    }

    /// Trip the breaker; true if this call tripped it (first failure).
    fn mark_degraded(&self) -> bool {
        !self.degraded.swap(true, Ordering::SeqCst)
    }

    /// Fetch + verify one entry. Bytes from the wire go through
    /// `persist::decode` — a truncated frame, corrupt payload or
    /// foreign format version all decode as a plain miss.
    pub fn load(&self, key: StageKey, stage: CachedStage) -> RemoteLookup {
        if self.is_degraded() {
            return RemoteLookup::Off;
        }
        let bytes = match self.client.get(stage, key) {
            Ok(Some(b)) => b,
            Ok(None) => return RemoteLookup::Miss,
            Err(e) => {
                if self.mark_degraded() {
                    crate::log_warn!(
                        "remote store {}: {e:#}; degrading to local-only",
                        self.addr()
                    );
                    return RemoteLookup::Error;
                }
                return RemoteLookup::Off;
            }
        };
        match persist::decode(&bytes, key) {
            Ok(a) if a.stage() == stage => RemoteLookup::Hit(a),
            Ok(_) | Err(_) => {
                match persist::peek_version(&bytes) {
                    Some(v) if v != persist::FORMAT_VERSION => crate::log_warn!(
                        "remote store {}: entry {} has format v{v} (ours: v{}); \
                         treating as miss",
                        self.addr(),
                        key.hex(),
                        persist::FORMAT_VERSION
                    ),
                    _ => crate::log_warn!(
                        "remote store {}: entry {} failed verification; \
                         treating as miss",
                        self.addr(),
                        key.hex()
                    ),
                }
                RemoteLookup::Miss
            }
        }
    }

    /// Best-effort push. A degraded tier skips silently; a fresh
    /// transport failure trips the breaker like a failed load.
    pub fn save(&self, key: StageKey, artifact: &Artifact) {
        if self.is_degraded() {
            return;
        }
        let bytes = persist::encode(key, artifact);
        if let Err(e) = self.client.put(artifact.stage(), key, &bytes) {
            if self.mark_degraded() {
                crate::log_warn!(
                    "remote store {}: push failed ({e:#}); degrading to local-only",
                    self.addr()
                );
            }
        }
    }
}

/// Open the store directory a serve daemon exports — shared by `serve`
/// and tests.
pub fn open_served_store(
    cache_dir: &Path,
    budget_bytes: u64,
) -> Result<Arc<EnvStore>> {
    Ok(Arc::new(EnvStore::open(cache_dir, budget_bytes)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::model::testutil::tiny_conv;
    use crate::session::cache::load_key;
    use std::path::PathBuf;

    fn tmp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("mlonmcu_transport_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn cfg(addr: &SocketAddr) -> RemoteConfig {
        RemoteConfig {
            addr: addr.to_string(),
            timeout_ms: 2000,
            retries: 1,
            backoff_ms: 10,
            grace_ms: 100,
        }
    }

    fn spawn_server(tag: &str) -> (ServerHandle, Arc<EnvStore>, PathBuf) {
        let dir = tmp(tag);
        let store = Arc::new(EnvStore::open(&dir, u64::MAX).unwrap());
        let handle = Server::spawn(Arc::clone(&store), "127.0.0.1:0").unwrap();
        (handle, store, dir)
    }

    fn graph_artifact() -> Artifact {
        Artifact::Graph(std::sync::Arc::new(tiny_conv()))
    }

    #[test]
    fn frame_roundtrip_and_bad_magic() {
        let mut buf = Vec::new();
        write_frame(&mut buf, REQ_MAGIC, OP_GET, b"payload").unwrap();
        let (version, tag, payload) =
            read_frame(&mut &buf[..], REQ_MAGIC).unwrap();
        assert_eq!(version, persist::FORMAT_VERSION);
        assert_eq!(tag, OP_GET);
        assert_eq!(payload, b"payload");
        // wrong magic expectation rejected
        assert!(read_frame(&mut &buf[..], RSP_MAGIC).is_err());
        // truncation at every boundary is an error, not a panic
        for cut in [0, 5, HEADER_LEN, buf.len() - 1] {
            assert!(read_frame(&mut &buf[..cut], REQ_MAGIC).is_err());
        }
        // implausible length prefix rejected before allocation
        let mut huge = buf.clone();
        huge[9..13].copy_from_slice(&(u32::MAX).to_le_bytes());
        assert!(read_frame(&mut &huge[..], REQ_MAGIC).is_err());
    }

    #[test]
    fn ping_get_put_roundtrip() {
        let (server, store, dir) = spawn_server("roundtrip");
        let client = Client::new(cfg(&server.addr));
        assert_eq!(client.ping().unwrap(), persist::FORMAT_VERSION);

        let key = load_key(1);
        assert!(client.get(CachedStage::Load, key).unwrap().is_none());
        let bytes = persist::encode(key, &graph_artifact());
        client.put(CachedStage::Load, key, &bytes).unwrap();
        let back = client.get(CachedStage::Load, key).unwrap().unwrap();
        assert!(persist::decode(&back, key).is_ok());
        assert_eq!(store.stats().loads, 1);

        // corrupt push is refused server-side, store stays clean
        let mut bad = bytes.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0xFF;
        assert!(client.put(CachedStage::Load, load_key(2), &bad).is_err());
        assert_eq!(store.stats().entries, 1);

        server.shutdown();
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn remote_store_hit_miss_and_degrade() {
        let (server, _store, dir) = spawn_server("tier");
        let remote = RemoteStore::new(cfg(&server.addr));
        let key = load_key(3);
        assert!(matches!(
            remote.load(key, CachedStage::Load),
            RemoteLookup::Miss
        ));
        remote.save(key, &graph_artifact());
        assert!(matches!(
            remote.load(key, CachedStage::Load),
            RemoteLookup::Hit(Artifact::Graph(_))
        ));

        // server death: exactly one Error, then Off forever
        server.shutdown();
        assert!(matches!(
            remote.load(key, CachedStage::Load),
            RemoteLookup::Error
        ));
        assert!(remote.is_degraded());
        assert!(matches!(
            remote.load(key, CachedStage::Load),
            RemoteLookup::Off
        ));
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn blob_roundtrip() {
        let (server, _store, dir) = spawn_server("blob");
        let client = Client::new(cfg(&server.addr));
        assert!(client.blob_get(42).unwrap().is_none());
        client.blob_put(42, b"model bytes").unwrap();
        assert_eq!(client.blob_get(42).unwrap().unwrap(), b"model bytes");
        server.shutdown();
        std::fs::remove_dir_all(dir).unwrap();
    }

    fn queue_doc() -> Json {
        // 1 -> 2 dependency chain
        Json::obj(vec![
            ("lease_ms", Json::Num(400.0)),
            (
                "tune",
                Json::obj(vec![
                    ("trials", Json::Num(8.0)),
                    ("seed", Json::Num(7.0)),
                ]),
            ),
            (
                "tasks",
                Json::Arr(vec![
                    Json::obj(vec![
                        ("id", Json::Num(1.0)),
                        ("kind", Json::Str("load".into())),
                        ("deps", Json::Arr(vec![])),
                    ]),
                    Json::obj(vec![
                        ("id", Json::Num(2.0)),
                        ("kind", Json::Str("build".into())),
                        ("deps", Json::Arr(vec![Json::Num(1.0)])),
                    ]),
                ]),
            ),
        ])
    }

    #[test]
    fn queue_claim_respects_deps_and_done_flow() {
        let (server, _store, dir) = spawn_server("queue");
        let client = Client::new(cfg(&server.addr));
        let qid = client.qpush(&queue_doc()).unwrap();
        assert!(qid > 0);

        // only task 1 is ready; a second claim on the same conn while
        // it is held sees an empty queue (task 2 is dep-blocked)
        let Claim::Task(doc) = client.claim(qid).unwrap() else {
            panic!("expected a task");
        };
        assert_eq!(doc.get("task").unwrap().get("id").unwrap().as_i64(), Some(1));
        assert_eq!(doc.get("lease_ms").unwrap().as_i64(), Some(400));
        assert_eq!(
            doc.get("tune").unwrap().get("trials").unwrap().as_i64(),
            Some(8)
        );
        assert!(matches!(client.claim(qid).unwrap(), Claim::Empty));

        client.beat(qid, 1).unwrap();
        let rec = Json::obj(vec![
            ("id", Json::Num(1.0)),
            ("ok", Json::Bool(true)),
        ]);
        client.done(qid, 1, &rec).unwrap();

        // task 2 unblocks, and the claim carries dep 1's done record
        let Claim::Task(doc) = client.claim(qid).unwrap() else {
            panic!("dep-complete task must be claimable");
        };
        assert_eq!(doc.get("task").unwrap().get("id").unwrap().as_i64(), Some(2));
        let deps = doc.get("deps_done").unwrap().as_arr().unwrap();
        assert_eq!(deps.len(), 1);
        assert_eq!(deps[0].get("id").unwrap().as_i64(), Some(1));

        client
            .done(qid, 2, &Json::obj(vec![("id", Json::Num(2.0))]))
            .unwrap();
        let poll = client.poll(qid).unwrap();
        assert_eq!(poll.get("total").unwrap().as_i64(), Some(2));
        assert_eq!(poll.get("done").unwrap().as_arr().unwrap().len(), 2);
        // the polling connection does not count itself as a worker
        assert_eq!(poll.get("workers").unwrap().as_i64(), Some(0));

        server.shutdown();
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn traced_queue_flags_claims_and_pools_spans_until_polled() {
        let (server, _store, dir) = spawn_server("tracedq");
        let client = Client::new(cfg(&server.addr));
        let doc = Json::obj(vec![
            ("lease_ms", Json::Num(400.0)),
            ("trace", Json::Bool(true)),
            (
                "tasks",
                Json::Arr(vec![Json::obj(vec![
                    ("id", Json::Num(1.0)),
                    ("deps", Json::Arr(vec![])),
                ])]),
            ),
        ]);
        let qid = client.qpush(&doc).unwrap();
        let Claim::Task(c) = client.claim(qid).unwrap() else {
            panic!("expected a task");
        };
        // the claim tells the worker to record spans
        assert!(matches!(c.get("trace"), Some(Json::Bool(true))));

        let spans = vec![crate::util::trace::Span {
            name: "load".into(),
            cat: "stage".into(),
            ts_us: 10,
            dur_us: 5,
            pid: 7,
            tid: 1,
            args: vec![("outcome".into(), "ok".into())],
        }];
        client.trace_put(qid, spans).unwrap();
        client
            .done(qid, 1, &Json::obj(vec![("id", Json::Num(1.0))]))
            .unwrap();

        // the poll observing completion also drains the span pool…
        let poll = client.poll(qid).unwrap();
        let events = poll.get("spans").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].get("pid").unwrap().as_i64(), Some(7));
        assert_eq!(events[0].get("name").unwrap().as_str(), Some("load"));
        // …exactly once: that drain ended the completed queue's life,
        // so a straggling poll finds it retired
        assert!(client.poll(qid).is_err());

        // untraced queues advertise trace: false on every claim
        let qid2 = client.qpush(&queue_doc()).unwrap();
        let Claim::Task(c) = client.claim(qid2).unwrap() else {
            panic!("expected a task");
        };
        assert!(matches!(c.get("trace"), Some(Json::Bool(false))));
        server.shutdown();
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn metrics_pull_merges_fleet_and_poll_drains_snapshots_once() {
        let _g = metrics::test_gate();
        metrics::enable();
        let _ = metrics::drain();
        let (server, _store, dir) = spawn_server("metricsq");
        let client = Client::new(cfg(&server.addr));

        // a metrics-flagged queue advertises the flag on its claims
        let doc = Json::obj(vec![
            ("lease_ms", Json::Num(400.0)),
            ("metrics", Json::Bool(true)),
            (
                "tasks",
                Json::Arr(vec![
                    Json::obj(vec![
                        ("id", Json::Num(1.0)),
                        ("deps", Json::Arr(vec![])),
                    ]),
                    Json::obj(vec![
                        ("id", Json::Num(2.0)),
                        ("deps", Json::Arr(vec![])),
                    ]),
                ]),
            ),
        ]);
        let qid = client.qpush(&doc).unwrap();
        let Claim::Task(c) = client.claim(qid).unwrap() else {
            panic!("expected a task");
        };
        assert!(matches!(c.get("metrics"), Some(Json::Bool(true))));

        // a worker ships its drained snapshot; the server both pools
        // it for the parent and merges it into its own registry
        // names nothing else records: concurrent tests in this binary
        // share the process-global registry while it is enabled here
        let mut snap = metrics::Snapshot::default();
        snap.counters.insert("test.fleet.hits".into(), 3);
        snap.hists.insert(
            "test.fleet.us".into(),
            metrics::Histogram::from_values([100, 900]),
        );
        client.metrics_put(qid, &snap).unwrap();

        let pulled = client.metrics().unwrap();
        // OP_STATS fields ride along
        assert_eq!(
            pulled.get("format").and_then(Json::as_i64),
            Some(persist::FORMAT_VERSION as i64)
        );
        assert_eq!(pulled.get("tasks_open").and_then(Json::as_i64), Some(1));
        let reg = pulled.get("registry").expect("registry in metrics doc");
        let merged = metrics::Snapshot::from_json(reg).unwrap();
        assert_eq!(merged.counters["test.fleet.hits"], 3);
        assert_eq!(merged.hists["test.fleet.us"].count, 2);
        // the claiming connection shows up as a live worker row
        let live = pulled.get("workers_live").and_then(Json::as_arr).unwrap();
        assert_eq!(live.len(), 1);
        assert_eq!(live[0].get("claims").and_then(Json::as_i64), Some(1));
        assert!(live[0].get("addr").and_then(Json::as_str).is_some());
        assert!(pulled.get("ring").and_then(|r| r.get("samples")).is_some());

        // the parent's poll drains the pooled snapshot exactly once
        let poll = client.poll(qid).unwrap();
        let drained = poll.get("metrics").and_then(Json::as_arr).unwrap();
        assert_eq!(drained.len(), 1);
        let back = metrics::Snapshot::from_json(&drained[0]).unwrap();
        assert_eq!(back.counters["test.fleet.hits"], 3);
        let poll = client.poll(qid).unwrap();
        assert!(poll
            .get("metrics")
            .and_then(Json::as_arr)
            .unwrap()
            .is_empty());

        metrics::disable();
        let _ = metrics::drain();
        server.shutdown();
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn metrics_ops_version_skew_is_a_miss() {
        let (server, _store, dir) = spawn_server("metricskew");
        let mut stream = TcpStream::connect(server.addr).unwrap();
        let mut head = [0u8; HEADER_LEN];
        head[..4].copy_from_slice(REQ_MAGIC);
        head[4..8].copy_from_slice(&(persist::FORMAT_VERSION + 1).to_le_bytes());
        for op in [OP_METRICS, OP_METRICS_PUT] {
            head[8] = op;
            head[9..13].copy_from_slice(&0u32.to_le_bytes());
            stream.write_all(&head).unwrap();
            let (_, status, body) = read_frame(&mut stream, RSP_MAGIC).unwrap();
            assert_eq!(status, ST_MISS, "op {op} must version-gate to a miss");
            assert!(body.is_empty());
        }
        server.shutdown();
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn object_form_deps_gate_readiness_like_bare_ids() {
        // the dispatcher's task_doc emits deps as {id, kind, key}
        // records, not bare ids — readiness gating must honour them
        let (server, _store, dir) = spawn_server("objdeps");
        let client = Client::new(cfg(&server.addr));
        let doc = Json::obj(vec![
            ("lease_ms", Json::Num(400.0)),
            (
                "tasks",
                Json::Arr(vec![
                    Json::obj(vec![
                        ("id", Json::Num(1.0)),
                        ("deps", Json::Arr(vec![])),
                    ]),
                    Json::obj(vec![
                        ("id", Json::Num(2.0)),
                        (
                            "deps",
                            Json::Arr(vec![Json::obj(vec![
                                ("id", Json::Num(1.0)),
                                ("kind", Json::Str("load".into())),
                                ("key", Json::Str("00ff".into())),
                            ])]),
                        ),
                    ]),
                ]),
            ),
        ]);
        let qid = client.qpush(&doc).unwrap();
        let Claim::Task(c) = client.claim(qid).unwrap() else {
            panic!("expected task 1");
        };
        assert_eq!(c.get("task").unwrap().get("id").unwrap().as_i64(), Some(1));
        // task 2 must be dep-blocked until 1 is done
        assert!(matches!(client.claim(qid).unwrap(), Claim::Empty));
        client
            .done(qid, 1, &Json::obj(vec![("id", Json::Num(1.0))]))
            .unwrap();
        let Claim::Task(c) = client.claim(qid).unwrap() else {
            panic!("task 2 must unblock");
        };
        assert_eq!(c.get("task").unwrap().get("id").unwrap().as_i64(), Some(2));
        let deps = c.get("deps_done").unwrap().as_arr().unwrap();
        assert_eq!(deps[0].get("id").unwrap().as_i64(), Some(1));
        server.shutdown();
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn dead_connection_releases_its_claims() {
        let (server, _store, dir) = spawn_server("deadconn");
        let parent = Client::new(cfg(&server.addr));
        let qid = parent.qpush(&queue_doc()).unwrap();

        // a worker claims task 1 and then its connection dies
        {
            let doomed = Client::new(cfg(&server.addr));
            assert!(matches!(doomed.claim(qid).unwrap(), Claim::Task(_)));
            // poll from the parent: the doomed worker is in the fleet
            let poll = parent.poll(qid).unwrap();
            assert_eq!(poll.get("workers").unwrap().as_i64(), Some(1));
        } // drop severs the TCP connection

        // the reclaim is driven by the server noticing the EOF; give
        // its connection thread a moment
        let reclaimed = (0..100).any(|_| {
            std::thread::sleep(Duration::from_millis(10));
            matches!(parent.claim(qid), Ok(Claim::Task(_)))
        });
        assert!(reclaimed, "dead connection's claim must be reclaimed");

        server.shutdown();
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn version_mismatch_is_a_miss_never_a_crash() {
        let (server, store, dir) = spawn_server("badver");
        let key = load_key(5);
        store.save(key, &graph_artifact()).unwrap();

        // a raw client stamping a foreign format version: every data
        // op answers MISS, ping still answers OK
        let mut stream = TcpStream::connect(server.addr).unwrap();
        let mut head = [0u8; HEADER_LEN];
        head[..4].copy_from_slice(REQ_MAGIC);
        head[4..8].copy_from_slice(&(persist::FORMAT_VERSION + 1).to_le_bytes());
        head[8] = OP_GET;
        head[9..13].copy_from_slice(&9u32.to_le_bytes());
        stream.write_all(&head).unwrap();
        stream.write_all(&entry_ref(CachedStage::Load, key)).unwrap();
        let (_, status, body) = read_frame(&mut stream, RSP_MAGIC).unwrap();
        assert_eq!(status, ST_MISS, "foreign version must read as a miss");
        assert!(body.is_empty());

        head[8] = OP_PING;
        head[9..13].copy_from_slice(&0u32.to_le_bytes());
        stream.write_all(&head).unwrap();
        let (_, status, body) = read_frame(&mut stream, RSP_MAGIC).unwrap();
        assert_eq!(status, ST_OK, "ping must answer so skew is diagnosable");
        assert_eq!(
            u32::from_le_bytes(body[..4].try_into().unwrap()),
            persist::FORMAT_VERSION
        );

        server.shutdown();
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn retry_is_bounded_and_backs_off() {
        // nothing listens here: request must fail after exactly
        // retries+1 attempts, spending at least the base backoff
        let cfg = RemoteConfig {
            addr: "127.0.0.1:1".to_string(), // reserved port, refused
            timeout_ms: 200,
            retries: 2,
            backoff_ms: 20,
            grace_ms: 100,
        };
        let client = Client::new(cfg);
        let watch = crate::util::Stopwatch::start();
        assert!(client.ping().is_err());
        let ms = watch.elapsed_ms();
        // attempts sleep 20..40 then 40..80 ms: bounded both ways
        assert!(ms >= 55.0, "backoff must actually wait ({ms:.0}ms)");
        assert!(ms < 5_000.0, "retry must terminate quickly ({ms:.0}ms)");
    }

    #[test]
    fn concurrent_requests_do_not_convoy_behind_backoff() {
        // a fake server that swallows pings (the pinger times out and
        // backs off) but answers everything else instantly
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            for stream in listener.incoming() {
                let Ok(mut stream) = stream else { break };
                std::thread::spawn(move || loop {
                    let Ok((_, op, _)) = read_frame(&mut stream, REQ_MAGIC)
                    else {
                        break;
                    };
                    if op == OP_PING {
                        continue; // never answered
                    }
                    if write_frame(&mut stream, RSP_MAGIC, ST_MISS, &[])
                        .is_err()
                    {
                        break;
                    }
                });
            }
        });
        let client = Arc::new(Client::new(RemoteConfig {
            addr: addr.to_string(),
            timeout_ms: 300,
            retries: 2,
            backoff_ms: 300,
            grace_ms: 100,
        }));
        // thread A: a ping doomed to time out and ride its backoff
        // chain (≥ 900 ms of timeouts + sleeps)
        let pinger = {
            let c = Arc::clone(&client);
            std::thread::spawn(move || {
                let _ = c.ping();
            })
        };
        std::thread::sleep(Duration::from_millis(50)); // ping in flight
        // threads B and C share the client and must finish while A is
        // still timing out / sleeping — the old single-stream mutex
        // would have convoyed them behind A's whole retry chain
        let watch = crate::util::Stopwatch::start();
        let others: Vec<_> = (0..2)
            .map(|_| {
                let c = Arc::clone(&client);
                std::thread::spawn(move || c.blob_get(1))
            })
            .collect();
        for t in others {
            assert!(t.join().unwrap().unwrap().is_none());
        }
        let ms = watch.elapsed_ms();
        assert!(
            ms < 800.0,
            "pooled requests must not convoy behind a backoff ({ms:.0}ms)"
        );
        let _ = pinger.join();
    }

    #[test]
    fn completed_queue_is_retired_after_final_poll() {
        let (server, _store, dir) = spawn_server("retire");
        let client = Client::new(cfg(&server.addr));
        let qid = client.qpush(&queue_doc()).unwrap();
        assert_eq!(server.queue_count(), 1);

        assert!(matches!(client.claim(qid).unwrap(), Claim::Task(_)));
        client
            .done(qid, 1, &Json::obj(vec![("id", Json::Num(1.0))]))
            .unwrap();
        // task 2 still open: polling must NOT retire the queue
        let poll = client.poll(qid).unwrap();
        assert_eq!(poll.get("open").unwrap().as_i64(), Some(1));
        assert_eq!(server.queue_count(), 1);

        assert!(matches!(client.claim(qid).unwrap(), Claim::Task(_)));
        client
            .done(qid, 2, &Json::obj(vec![("id", Json::Num(2.0))]))
            .unwrap();
        // the poll that hands over the full result set retires the
        // queue — the map shrinks instead of leaking one per session
        let poll = client.poll(qid).unwrap();
        assert_eq!(poll.get("done").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(poll.get("open").unwrap().as_i64(), Some(0));
        assert_eq!(poll.get("claimed").unwrap().as_i64(), Some(0));
        assert_eq!(server.queue_count(), 0, "drained queue must be retired");

        // stragglers are dropped silently, not errors…
        client
            .done(qid, 2, &Json::obj(vec![("id", Json::Num(2.0))]))
            .unwrap();
        // …while a poll of the dead queue is a real error
        assert!(client.poll(qid).is_err());
        let stats = client.stats().unwrap();
        assert_eq!(stats.get("queues_retired").unwrap().as_i64(), Some(1));
        assert_eq!(stats.get("queues").unwrap().as_i64(), Some(0));
        server.shutdown();
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn done_then_dead_connection_does_not_reopen_the_task() {
        let (server, _store, dir) = spawn_server("donedead");
        let parent = Client::new(cfg(&server.addr));
        let qid = parent.qpush(&queue_doc()).unwrap();

        // a worker claims task 1, reports it done, and THEN dies —
        // release_conn runs after the done record landed
        {
            let doomed = Client::new(cfg(&server.addr));
            let Claim::Task(c) = doomed.claim(qid).unwrap() else {
                panic!("expected task 1");
            };
            assert_eq!(
                c.get("task").unwrap().get("id").unwrap().as_i64(),
                Some(1)
            );
            doomed
                .done(
                    qid,
                    1,
                    &Json::obj(vec![
                        ("id", Json::Num(1.0)),
                        ("ok", Json::Bool(true)),
                    ]),
                )
                .unwrap();
        } // drop severs the TCP connection

        // wait for the server to process the disconnect
        let gone = (0..100).any(|_| {
            std::thread::sleep(Duration::from_millis(10));
            parent.poll(qid).unwrap().get("workers").unwrap().as_i64()
                == Some(0)
        });
        assert!(gone, "server must notice the dead connection");

        // completion belongs to the queue, not the connection: the
        // only claimable task is 2, carrying the dead worker's record
        let Claim::Task(c) = parent.claim(qid).unwrap() else {
            panic!("task 2 must be claimable");
        };
        assert_eq!(c.get("task").unwrap().get("id").unwrap().as_i64(), Some(2));
        let deps = c.get("deps_done").unwrap().as_arr().unwrap();
        assert!(matches!(deps[0].get("ok"), Some(Json::Bool(true))));
        // and task 1 was NOT re-opened by the release
        assert!(matches!(parent.claim(qid).unwrap(), Claim::Empty));
        server.shutdown();
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn server_mem_cache_answers_warm_gets_without_store_reads() {
        let (server, store, dir) = spawn_server("hotmem");
        let client = Client::new(cfg(&server.addr));
        let key = load_key(7);
        let bytes = persist::encode(key, &graph_artifact());
        client.put(CachedStage::Load, key, &bytes).unwrap();
        let cold_reads = store.read_ops();
        for _ in 0..3 {
            let got = client.get(CachedStage::Load, key).unwrap().unwrap();
            assert_eq!(got, bytes);
        }
        assert_eq!(
            store.read_ops(),
            cold_reads,
            "warm GETs must be served from server memory, not the store"
        );
        let stats = client.stats().unwrap();
        assert_eq!(stats.get("mem_hits").unwrap().as_i64(), Some(3));
        assert!(stats.get("mem_entries").unwrap().as_i64() >= Some(1));
        assert!(stats.get("ops").unwrap().as_i64().unwrap() >= 4);
        assert!(
            stats.get("bytes_served").unwrap().as_i64().unwrap()
                >= 3 * bytes.len() as i64
        );
        server.shutdown();
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn mget_batches_hits_and_misses_in_one_frame() {
        let (server, _store, dir) = spawn_server("mget");
        let client = Client::new(cfg(&server.addr));
        let (k1, k2, k3) = (load_key(1), load_key(2), load_key(3));
        let b1 = persist::encode(k1, &graph_artifact());
        let b3 = persist::encode(k3, &graph_artifact());
        client.put(CachedStage::Load, k1, &b1).unwrap();
        client.put(CachedStage::Load, k3, &b3).unwrap();
        let got = client
            .mget(&[
                (CachedStage::Load, k1),
                (CachedStage::Load, k2),
                (CachedStage::Load, k3),
            ])
            .unwrap();
        assert_eq!(got.len(), 3);
        assert_eq!(got[0].as_deref(), Some(&b1[..]));
        assert!(got[1].is_none(), "absent entry is a per-entry miss");
        assert_eq!(got[2].as_deref(), Some(&b3[..]));
        assert!(client.mget(&[]).unwrap().is_empty());
        server.shutdown();
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn claim_deps_rides_cached_artifacts_on_the_claim() {
        let (server, _store, dir) = spawn_server("claimdeps");
        let client = Client::new(cfg(&server.addr));
        let dep_key = load_key(21);
        let dep_bytes = persist::encode(dep_key, &graph_artifact());
        client.put(CachedStage::Load, dep_key, &dep_bytes).unwrap();
        // task docs carry the dispatcher's kind/key fields, so the
        // server knows which artifacts each claim will fetch
        let doc = Json::obj(vec![
            ("lease_ms", Json::Num(400.0)),
            (
                "tasks",
                Json::Arr(vec![
                    Json::obj(vec![
                        ("id", Json::Num(1.0)),
                        ("kind", Json::Str("load".into())),
                        ("key", Json::Str(dep_key.hex())),
                        ("deps", Json::Arr(vec![])),
                    ]),
                    Json::obj(vec![
                        ("id", Json::Num(2.0)),
                        ("kind", Json::Str("build".into())),
                        ("key", Json::Str("00000000000000ff".into())),
                        (
                            "deps",
                            Json::Arr(vec![Json::obj(vec![
                                ("id", Json::Num(1.0)),
                                ("kind", Json::Str("load".into())),
                                ("key", Json::Str(dep_key.hex())),
                            ])]),
                        ),
                    ]),
                ]),
            ),
        ]);
        let qid = client.qpush(&doc).unwrap();
        // task 1's own entry is already cached: it rides the claim
        let (claim, entries) = client.claim_deps(qid).unwrap();
        let Claim::Task(c) = claim else { panic!("expected task 1") };
        assert_eq!(c.get("task").unwrap().get("id").unwrap().as_i64(), Some(1));
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].0, (CachedStage::Load, dep_key));
        assert_eq!(entries[0].1, dep_bytes);
        client
            .done(qid, 1, &Json::obj(vec![("id", Json::Num(1.0))]))
            .unwrap();
        // task 2's own build entry is absent; dep 1's entry rides
        let (claim, entries) = client.claim_deps(qid).unwrap();
        let Claim::Task(c) = claim else { panic!("expected task 2") };
        assert_eq!(c.get("task").unwrap().get("id").unwrap().as_i64(), Some(2));
        assert_eq!(c.get("deps_done").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].0, (CachedStage::Load, dep_key));
        // an empty queue answers Empty with no entries
        client
            .done(qid, 2, &Json::obj(vec![("id", Json::Num(2.0))]))
            .unwrap();
        let (claim, entries) = client.claim_deps(qid).unwrap();
        assert!(matches!(claim, Claim::Empty));
        assert!(entries.is_empty());
        server.shutdown();
        std::fs::remove_dir_all(dir).unwrap();
    }
}
